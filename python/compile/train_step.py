"""L2: one functional training step — loss (Eq. 9), grad, AdamW — lowered as
a single HLO module so the Rust trainer can drive pretraining without Python.

    L = L_ce + beta * L_b          (beta = 0.01, paper Appendix B.2)

AdamW with decoupled weight decay 0.1, grad-norm clip 1.0 and a
warmup+cosine schedule mirroring the paper's Strategy 1; the schedule is
computed *inside* the step from the integer step counter carried in the
optimizer state, so the artifact is self-contained.
"""

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .configs import MoEConfig
from .model import ModelParams, init_params, model_fwd


class OptState(NamedTuple):
    step: jax.Array   # i32 scalar
    m: ModelParams    # first moments (same pytree as params)
    v: ModelParams    # second moments


class StepMetrics(NamedTuple):
    loss: jax.Array
    ce: jax.Array
    balance: jax.Array
    grad_norm: jax.Array
    lr: jax.Array
    dropped: jax.Array        # mean dropped assignments per layer
    ffn_per_token: jax.Array  # mean over layers


# Paper Strategy 1 hyper-parameters, scaled to reproduction step counts.
WARMUP_STEPS = 100
MAX_LR = 5e-4
FINAL_LR = 5e-5
TOTAL_STEPS = 2000
WEIGHT_DECAY = 0.1
CLIP_NORM = 1.0
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


def lr_schedule(step):
    """Linear warmup from ~0 then cosine decay MAX_LR -> FINAL_LR."""
    step = step.astype(jnp.float32)
    warm = MAX_LR * jnp.maximum(step, 1.0) / WARMUP_STEPS
    t = jnp.clip((step - WARMUP_STEPS) / (TOTAL_STEPS - WARMUP_STEPS), 0, 1)
    cos = FINAL_LR + 0.5 * (MAX_LR - FINAL_LR) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < WARMUP_STEPS, warm, cos)


def loss_fn(params: ModelParams, tokens: jax.Array, cfg: MoEConfig):
    """Next-token CE + beta * heterogeneous balance loss over [B, S] tokens."""
    logits, aux = model_fwd(params, tokens, cfg)
    # Shift: predict token t+1 from prefix <= t.
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    targets = tokens[:, 1:]
    ce = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    loss = ce + cfg.balance_coef * aux.balance_loss
    return loss, (ce, aux)


def init_opt_state(params: ModelParams) -> OptState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros)


def train_step(params: ModelParams, opt: OptState, tokens: jax.Array,
               cfg: MoEConfig) -> Tuple[ModelParams, OptState, StepMetrics]:
    (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, tokens, cfg
    )
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, CLIP_NORM / (gnorm + 1e-6))
    step = opt.step + 1
    lr = lr_schedule(step)
    b1c = 1 - ADAM_B1 ** step.astype(jnp.float32)
    b2c = 1 - ADAM_B2 ** step.astype(jnp.float32)

    tmap = jax.tree_util.tree_map
    new_m = tmap(lambda g, m: ADAM_B1 * m + (1 - ADAM_B1) * g * scale,
                 grads, opt.m)
    new_v = tmap(lambda g, v: ADAM_B2 * v + (1 - ADAM_B2) * (g * scale) ** 2,
                 grads, opt.v)
    new_params = tmap(
        lambda p, m, v: p - lr * ((m / b1c) / (jnp.sqrt(v / b2c) + ADAM_EPS)
                                  + WEIGHT_DECAY * p),
        params, new_m, new_v,
    )
    metrics = StepMetrics(
        loss=loss, ce=ce, balance=aux.balance_loss, grad_norm=gnorm, lr=lr,
        dropped=aux.dropped.mean(), ffn_per_token=aux.ffn_per_token.mean(),
    )
    return new_params, OptState(step=step, m=new_m, v=new_v), metrics


def make_init_fn(cfg: MoEConfig):
    """(seed i32) -> (params, opt_state) for AOT lowering."""

    def init(seed):
        params = init_params(jax.random.PRNGKey(seed), cfg)
        return params, init_opt_state(params)

    return init


def make_train_step_fn(cfg: MoEConfig):
    def step(params, opt, tokens):
        return train_step(params, opt, tokens, cfg)

    return step


def make_fwd_fn(cfg: MoEConfig):
    def fwd(params, tokens):
        logits, aux = model_fwd(params, tokens, cfg)
        return (logits, aux.expert_counts, aux.dropped, aux.ffn_per_token,
                aux.top1_prob, aux.top2_prob, aux.balance_loss)

    return fwd


def make_eval_fn(cfg: MoEConfig):
    """(params, tokens) -> (ce_loss,) for perplexity evaluation."""

    def ev(params, tokens):
        logits, _ = model_fwd(params, tokens, cfg)
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        targets = tokens[:, 1:]
        ce = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
        return (ce,)

    return ev

"""Model configurations mirroring MoE++ Table 2 at reproduction scale.

The paper trains 0.6B--7B models on 32xA100 with Megatron; this repository
reproduces the *mechanisms* (zero-computation experts, pathway-aware router,
heterogeneous capacity/load-balance) at CPU scale. Each preset here is the
scaled twin of a Table 2 row; the ratio structure (N_FFN, zero/copy/constant
split, top-2 routing, gamma=1.1, beta=0.01) is preserved exactly.
"""

from dataclasses import dataclass, field, asdict
from typing import Tuple
import json


@dataclass(frozen=True)
class MoEConfig:
    """Configuration for one MoE/MoE++ layer stack and its transformer."""

    name: str = "sm-8e"
    # Transformer dims.
    vocab_size: int = 512
    n_layers: int = 4
    d_model: int = 128
    d_ff: int = 352  # intermediate size of each FFN expert (SwiGLU)
    n_heads: int = 4
    seq_len: int = 128
    # MoE structure.
    n_ffn_experts: int = 8
    n_zero: int = 1
    n_copy: int = 1
    n_const: int = 2
    top_k: int = 2
    # Heterogeneous load-balance / capacity hyper-parameters (paper defaults).
    tau: float = 0.75
    capacity_factor: float = 1.1  # gamma
    balance_coef: float = 0.01  # beta
    # Router.
    gating_residual: bool = True
    # Variant switch: "moepp" (heterogeneous) or "vanilla" (FFN-only MoE).
    variant: str = "moepp"

    @property
    def n_zc(self) -> int:
        """Total number of zero-computation experts (0 for vanilla)."""
        if self.variant == "vanilla":
            return 0
        return self.n_zero + self.n_copy + self.n_const

    @property
    def n_experts(self) -> int:
        return self.n_ffn_experts + self.n_zc

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def capacities(self, n_tokens: int) -> Tuple[int, int]:
        """Heterogeneous expert capacity, Eq. 8 of the paper.

        Returns (ffn_capacity, zc_capacity). For the vanilla variant the FFN
        capacity reduces to the homogeneous gamma*T*K/N formula used by
        GShard-style implementations.
        """
        gamma, tau = self.capacity_factor, self.tau
        if self.variant == "vanilla":
            cap = int(gamma * self.top_k * n_tokens / self.n_experts) + 1
            return cap, 0
        denom = tau * self.n_ffn_experts + self.n_zc
        # Top-K routing makes T*K assignments in total; Eq. 8 is written per
        # token, we scale by K so the total capacity covers all assignments.
        ffn_cap = int(gamma * self.top_k * tau * n_tokens / denom) + 1
        zc_cap = int(gamma * self.top_k * n_tokens / denom) + 1
        return ffn_cap, zc_cap

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2)


def parse_spec(spec: str) -> "MoEConfig":
    """Parse an extended preset spec: `preset[:variant][@k=v,k=v...]`.

    Override keys (for ablation artifacts): tau, nz (n_zero), nk (n_copy),
    nc (n_const), gr (gating_residual 0/1), ff (d_ff), nf (n_ffn_experts),
    k (top_k). Examples:
        "test@tau=0.25"       tau ablation (Table 3 sweep)
        "test@nz=0,nk=0"      only constant experts (Table 5 row)
        "test@gr=0"           no gating residuals (Table 6)
        "test:vanilla@nf=1,k=1,ff=128"  dense baseline (Table 4)
    """
    base, _, ov = spec.partition("@")
    cfg = preset(base)
    if not ov:
        return cfg
    import dataclasses
    kw = dataclasses.asdict(cfg)
    keymap = {"tau": ("tau", float), "nz": ("n_zero", int),
              "nk": ("n_copy", int), "nc": ("n_const", int),
              "gr": ("gating_residual", lambda v: bool(int(v))),
              "ff": ("d_ff", int), "nf": ("n_ffn_experts", int),
              "k": ("top_k", int)}
    for pair in ov.split(","):
        key, _, val = pair.partition("=")
        field_name, conv = keymap[key.strip()]
        kw[field_name] = conv(val)
    return MoEConfig(**kw)


def spec_tag(spec: str) -> str:
    """Deterministic artifact tag for a spec: `test@tau=0.25` ->
    `test_tau0.25`; `test:vanilla` -> `test_vanilla`; `test` ->
    `test_moepp`."""
    base, _, ov = spec.partition("@")
    name, _, variant = base.partition(":")
    tag = f"{name}_{variant or 'moepp'}"
    if ov:
        tag += "_" + ov.replace("=", "").replace(",", "_")
    return tag


def preset(name: str) -> MoEConfig:
    """Named presets; `:vanilla` twins are the vanilla-MoE baselines."""
    table = {
        # Scaled twin of "MoE++ 0.6B/(8+4)E" (Table 2 row 1).
        "sm-8e": MoEConfig(name="sm-8e"),
        # Scaled twin of "MoE++ 1B/(16+4)E".
        "sm-16e": MoEConfig(name="sm-16e", n_ffn_experts=16),
        # Scaled twin of "MoE++ 2B/(32+8)E" (1 zero / 1 copy / 6 constant).
        "sm-32e": MoEConfig(name="sm-32e", n_ffn_experts=32, n_const=6),
        # Scaled twin of "MoE++ 7B/(16+4)E".
        "md-16e": MoEConfig(
            name="md-16e", n_layers=8, d_model=256, d_ff=704, n_heads=8,
            n_ffn_experts=16,
        ),
        # End-to-end validation model (examples/train_e2e.rs).
        "e2e": MoEConfig(
            name="e2e", vocab_size=2048, n_layers=6, d_model=256, d_ff=704,
            n_heads=8, n_ffn_experts=8, seq_len=128,
        ),
        # Tiny config for fast tests.
        "test": MoEConfig(
            name="test", vocab_size=64, n_layers=2, d_model=32, d_ff=64,
            n_heads=2, n_ffn_experts=4, seq_len=16,
        ),
    }
    base_name, _, variant = name.partition(":")
    cfg = table[base_name]
    if variant == "vanilla":
        return MoEConfig(**{**asdict(cfg), "variant": "vanilla",
                            "n_zero": 0, "n_copy": 0, "n_const": 0})
    return cfg


ALL_PRESETS = ["sm-8e", "sm-16e", "sm-32e", "md-16e", "e2e", "test"]

"""L2: the MoE++ layer (and the vanilla-MoE baseline) as a static-shape JAX
computation suitable for AOT lowering.

Dense (GShard-style) dispatch with the paper's *heterogeneous* extensions:

  * experts [0, n_ffn) are FFN experts, [n_ffn, N) are zero-computation
    experts ordered [zero..., copy..., constant...];
  * heterogeneous expert capacity (Eq. 8): FFN experts get
    gamma*K*tau*T/(tau*N_F + N_Z) slots, ZC experts gamma*K*T/(tau*N_F+N_Z);
  * over-capacity assignments are dropped — the token's residual connection
    carries it unchanged (paper Sec. 3.3);
  * heterogeneous load-balance loss (Eq. 7) with eta in {1, tau};
  * pathway-aware router with gating residuals (Eq. 6), threaded between
    layers as the raw scores of the previous layer;
  * gates are the full-softmax probabilities of the selected experts, with
    no renormalisation after top-k or drops (Eq. 1).

The FFN experts run through the Pallas grouped kernel; zero/copy/constant
experts never enter the dispatch buffers at all — their contribution is a
weighted combine over the *original* token stream, which is exactly why they
are free: no gather, no FFN FLOPs, no all-to-all in the distributed mapping.
"""

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import MoEConfig
from .kernels.autodiff import (constant_expert_ad as constant_expert,
                               grouped_expert_ffn_ad as grouped_expert_ffn,
                               router_scores_softmax_ad)


class MoELayerParams(NamedTuple):
    """Parameters of one MoE++ layer (ZC slots empty for vanilla)."""

    router_w: jax.Array          # [N, D]
    router_wg: jax.Array         # [N, N] gating-residual transform
    ffn_w1: jax.Array            # [N_FFN, D, F]
    ffn_w3: jax.Array            # [N_FFN, D, F]
    ffn_w2: jax.Array            # [N_FFN, F, D]
    const_wc: jax.Array          # [n_const, 2, D]
    const_v: jax.Array           # [n_const, D]


class MoELayerAux(NamedTuple):
    """Per-layer routing statistics, returned for analysis/figures."""

    balance_loss: jax.Array      # scalar, Eq. 7
    expert_counts: jax.Array     # [N] pre-capacity assignment counts
    dropped: jax.Array           # scalar count of dropped assignments
    ffn_per_token: jax.Array     # scalar mean surviving FFN experts/token
    scores: jax.Array            # [T, N] raw scores (-> next layer residual)
    top1_prob: jax.Array         # scalar mean max router prob
    top2_prob: jax.Array         # scalar mean 2nd router prob


def init_layer_params(key, cfg: MoEConfig) -> MoELayerParams:
    """Initialise one layer. ZC params are zero-sized for the vanilla variant."""
    d, f = cfg.d_model, cfg.d_ff
    n, nf, nc = cfg.n_experts, cfg.n_ffn_experts, cfg.n_const
    ks = jax.random.split(key, 6)
    scale = d ** -0.5
    return MoELayerParams(
        router_w=jax.random.normal(ks[0], (n, d)) * scale,
        # Zero-init: gating residual starts as identity pass-through of the
        # current layer's scores (Eq. 6 reduces to W x at init).
        router_wg=jnp.zeros((n, n)),
        ffn_w1=jax.random.normal(ks[1], (nf, d, f)) * scale,
        ffn_w3=jax.random.normal(ks[2], (nf, d, f)) * scale,
        ffn_w2=jax.random.normal(ks[3], (nf, f, d)) * (f ** -0.5),
        const_wc=jax.random.normal(ks[4], (max(nc, 0), 2, d)) * scale,
        const_v=jax.random.normal(ks[5], (max(nc, 0), d)) * 0.02,
    )


def _positions_in_expert(mask: jax.Array) -> jax.Array:
    """Slot-major position of each assignment within its expert's queue.

    mask [T, K, N] one-hot assignments. Priority follows GShard/Megatron:
    all slot-0 (top-1) assignments in token order first, then slot-1.
    Returns pos [T, K, N] (only meaningful where mask==1).
    """
    t, k, n = mask.shape
    # Reorder to [K, T, N] so a single cumulative sum walks slot-major order.
    m = jnp.transpose(mask, (1, 0, 2)).reshape(k * t, n)
    pos = jnp.cumsum(m, axis=0) - m
    return jnp.transpose(pos.reshape(k, t, n), (1, 0, 2))


def moe_layer_fwd(
    params: MoELayerParams,
    x: jax.Array,                   # [T, D] flattened tokens
    prev_scores: Optional[jax.Array],  # [T, N] or None (layer 0)
    cfg: MoEConfig,
) -> Tuple[jax.Array, MoELayerAux]:
    """Forward one MoE/MoE++ layer. Returns (y [T, D], aux)."""
    t, d = x.shape
    n, nf, k = cfg.n_experts, cfg.n_ffn_experts, cfg.top_k
    nz, nk, nc = cfg.n_zero, cfg.n_copy, cfg.n_const

    # --- Pathway-aware router (Eq. 6) -------------------------------------
    use_res = cfg.gating_residual and prev_scores is not None
    prev = prev_scores if use_res else jnp.zeros((t, n))
    probs, scores = router_scores_softmax_ad(
        x, params.router_w, prev, params.router_wg, use_res
    )

    # --- Top-K selection (Eq. 1) ------------------------------------------
    # argsort instead of lax.top_k: the consumer XLA (0.5.1) text parser
    # predates the standalone `topk` HLO op; a stable sort lowers to plain
    # `sort`, and stable argsort of -probs matches lax.top_k's tie-breaking
    # (lower index first).
    # (stop_gradient: indices are non-differentiable; this also keeps the
    # sort JVP — whose gather uses batching dims too new for XLA 0.5.1 —
    # out of the lowered train graph.)
    top_idx = jnp.argsort(jax.lax.stop_gradient(-probs), axis=-1,
                          stable=True)[:, :k]  # [T, K]
    mask = jax.nn.one_hot(top_idx, n)                    # [T, K, N]

    # --- Heterogeneous load-balance loss (Eq. 7) ---------------------------
    f_frac = mask.sum(axis=1).mean(axis=0)               # f_i
    p_mean = probs.mean(axis=0)                          # P_i
    eta = jnp.where(jnp.arange(n) < nf, 1.0, cfg.tau)
    balance_loss = n * jnp.sum(eta * f_frac * p_mean)

    # --- Heterogeneous capacity (Eq. 8) + drops ----------------------------
    ffn_cap, zc_cap = cfg.capacities(t)
    cap = jnp.where(jnp.arange(n) < nf, ffn_cap, zc_cap)  # [N]
    pos = _positions_in_expert(mask)                      # [T, K, N]
    keep = mask * (pos < cap[None, None, :])              # [T, K, N]
    dropped = mask.sum() - keep.sum()

    # Combine weight per (token, expert): softmax prob if kept (Eq. 1).
    gate_te = (keep * probs[:, None, :]).sum(axis=1)      # [T, N]

    # --- FFN experts: dispatch -> grouped Pallas FFN -> combine ------------
    keep_ffn = keep[..., :nf].sum(axis=1)                 # [T, N_FFN] {0,1}
    pos_ffn = (pos[..., :nf] * keep[..., :nf]).sum(axis=1)  # [T, N_FFN]
    # One-hot capacity slot per surviving assignment: [T, N_FFN, C].
    slot = jax.nn.one_hot(pos_ffn.astype(jnp.int32), ffn_cap) \
        * keep_ffn[..., None]
    x_disp = jnp.einsum("tec,td->ecd", slot, x)           # [N_FFN, C, D]
    y_exp = grouped_expert_ffn(x_disp, params.ffn_w1, params.ffn_w3,
                               params.ffn_w2)             # [N_FFN, C, D]
    w_slot = slot * gate_te[:, :nf, None]                 # gate-weighted
    y = jnp.einsum("tec,ecd->td", w_slot, y_exp)          # [T, D]

    # --- Zero-computation experts: weighted combine, no dispatch -----------
    if cfg.variant != "vanilla":
        off = nf
        # Zero experts (Eq. 3) contribute nothing — their gate weight simply
        # evaporates (this is what lets top-2 degrade to top-1).
        off += nz
        # Copy experts (Eq. 4): g * x.
        if nk > 0:
            g_copy = gate_te[:, off:off + nk].sum(axis=1, keepdims=True)
            y = y + g_copy * x
        off += nk
        # Constant experts (Eq. 5): g * (a1 x + a2 v), via the Pallas kernel.
        for j in range(nc):
            g_cj = gate_te[:, off + j:off + j + 1]
            y_cj = constant_expert(x, params.const_wc[j], params.const_v[j])
            y = y + g_cj * y_cj

    # Stats are observational — never differentiated (and jnp.sort's vjp is
    # broken on this jax/jaxlib pin).
    ffn_per_token = jax.lax.stop_gradient(keep_ffn.sum() / t)
    sorted_probs = jnp.sort(jax.lax.stop_gradient(probs), axis=-1)
    aux = MoELayerAux(
        balance_loss=balance_loss,
        expert_counts=mask.sum(axis=(0, 1)),
        dropped=dropped,
        ffn_per_token=ffn_per_token,
        scores=scores,
        top1_prob=sorted_probs[:, -1].mean(),
        top2_prob=sorted_probs[:, -2].mean(),
    )
    return y, aux


def moe_layer_fwd_ref(params, x, prev_scores, cfg):
    """Direct per-token oracle of moe_layer_fwd (python loops; tests only)."""
    import numpy as np

    from .kernels import ref

    t, d = x.shape
    n, nf, k = cfg.n_experts, cfg.n_ffn_experts, cfg.top_k
    nz, nk, nc = cfg.n_zero, cfg.n_copy, cfg.n_const
    use_res = cfg.gating_residual and prev_scores is not None
    scores = np.asarray(
        ref.router_scores_ref(
            x, params.router_w,
            prev_scores if use_res else None,
            params.router_wg if use_res else None,
        )
    )
    probs = np.asarray(jax.nn.softmax(jnp.asarray(scores), axis=-1))
    ffn_cap, zc_cap = cfg.capacities(t)
    cap = [ffn_cap if i < nf else zc_cap for i in range(n)]
    # Slot-major assignment order, matching _positions_in_expert.
    top_idx = np.argsort(-probs, axis=-1, kind="stable")[:, :k]
    load = [0] * n
    kept = []  # (token, expert, gate)
    for slot_k in range(k):
        for tok in range(t):
            e = int(top_idx[tok, slot_k])
            if load[e] < cap[e]:
                load[e] += 1
                kept.append((tok, e, probs[tok, e]))
    y = np.zeros((t, d), dtype=np.float32)
    x_np = np.asarray(x)
    for tok, e, g in kept:
        if e < nf:
            out = ref.expert_ffn_ref(
                x_np[tok:tok + 1], params.ffn_w1[e], params.ffn_w3[e],
                params.ffn_w2[e],
            )
            y[tok] += g * np.asarray(out[0])
        elif e < nf + nz:
            pass  # zero expert
        elif e < nf + nz + nk:
            y[tok] += g * x_np[tok]
        else:
            j = e - nf - nz - nk
            out = ref.constant_expert_ref(
                x_np[tok:tok + 1], params.const_wc[j], params.const_v[j]
            )
            y[tok] += g * np.asarray(out[0])
    return y, scores

"""L2: decoder-only transformer LM with every FFN replaced by a MoE++ (or
vanilla MoE) layer — the scaled twin of the paper's Table 2 models.

Architecture follows the paper's Megatron/LLaMA-style setup: RMSNorm,
rotary position embeddings, causal multi-head attention, SwiGLU MoE experts,
top-2 routing, untied output head. Gating residuals (Eq. 6) thread each
layer's raw router scores into the next layer's router.

Everything is a pure function over explicitly-passed parameters so the whole
model lowers to a single HLO module with a stable, manifest-documented
parameter order (see aot.py).
"""

from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .configs import MoEConfig
from .moe_layer import (MoELayerAux, MoELayerParams, init_layer_params,
                        moe_layer_fwd)


class BlockParams(NamedTuple):
    """One transformer block: attention + MoE++ layer + 2 norms."""

    attn_norm: jax.Array     # [D]
    wq: jax.Array            # [D, D]
    wk: jax.Array            # [D, D]
    wv: jax.Array            # [D, D]
    wo: jax.Array            # [D, D]
    moe_norm: jax.Array      # [D]
    moe: MoELayerParams


class ModelParams(NamedTuple):
    embed: jax.Array         # [V, D]
    blocks: Tuple[BlockParams, ...]
    final_norm: jax.Array    # [D]
    head: jax.Array          # [D, V]


class ModelAux(NamedTuple):
    """Stacked per-layer routing statistics (for figures 4/5/6)."""

    balance_loss: jax.Array   # scalar, mean over layers
    expert_counts: jax.Array  # [L, N]
    dropped: jax.Array        # [L]
    ffn_per_token: jax.Array  # [L]
    top1_prob: jax.Array      # [L]
    top2_prob: jax.Array      # [L]


def rms_norm(x, g, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, positions):
    """Rotary position embedding. x [B, S, H, Hd]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 10000.0 ** (-jnp.arange(0, half) / half)
    angles = positions[:, :, None, None] * freqs[None, None, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attention(bp: BlockParams, x, cfg: MoEConfig):
    """Causal multi-head attention with RoPE. x [B, S, D]."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q = rope((x @ bp.wq).reshape(b, s, h, hd), pos)
    k = rope((x @ bp.wk).reshape(b, s, h, hd), pos)
    v = (x @ bp.wv).reshape(b, s, h, hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(hd)
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(causal[None, None], logits, -1e30)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
    return out @ bp.wo


def init_params(key, cfg: MoEConfig) -> ModelParams:
    ks = jax.random.split(key, cfg.n_layers + 3)
    d, v = cfg.d_model, cfg.vocab_size
    scale = d ** -0.5
    blocks = []
    for i in range(cfg.n_layers):
        bks = jax.random.split(ks[i], 5)
        blocks.append(BlockParams(
            attn_norm=jnp.ones((d,)),
            wq=jax.random.normal(bks[0], (d, d)) * scale,
            wk=jax.random.normal(bks[1], (d, d)) * scale,
            wv=jax.random.normal(bks[2], (d, d)) * scale,
            wo=jax.random.normal(bks[3], (d, d)) * scale,
            moe_norm=jnp.ones((d,)),
            moe=init_layer_params(bks[4], cfg),
        ))
    return ModelParams(
        embed=jax.random.normal(ks[-3], (v, d)) * 0.02,
        blocks=tuple(blocks),
        final_norm=jnp.ones((d,)),
        head=jax.random.normal(ks[-2], (d, v)) * scale,
    )


def model_fwd(params: ModelParams, tokens: jax.Array,
              cfg: MoEConfig) -> Tuple[jax.Array, ModelAux]:
    """Forward pass. tokens [B, S] int32 -> (logits [B, S, V], aux)."""
    b, s = tokens.shape
    d = cfg.d_model
    x = params.embed[tokens]  # [B, S, D]
    prev_scores = None
    auxes: List[MoELayerAux] = []
    for bp in params.blocks:
        x = x + attention(bp, rms_norm(x, bp.attn_norm), cfg)
        h = rms_norm(x, bp.moe_norm).reshape(b * s, d)
        y, aux = moe_layer_fwd(bp.moe, h, prev_scores, cfg)
        # Gating residual: raw scores feed the next layer's router (Eq. 6).
        prev_scores = aux.scores
        x = x + y.reshape(b, s, d)
        auxes.append(aux)
    x = rms_norm(x, params.final_norm)
    logits = x @ params.head
    aux = ModelAux(
        balance_loss=jnp.stack([a.balance_loss for a in auxes]).mean(),
        expert_counts=jnp.stack([a.expert_counts for a in auxes]),
        dropped=jnp.stack([a.dropped for a in auxes]),
        ffn_per_token=jnp.stack([a.ffn_per_token for a in auxes]),
        top1_prob=jnp.stack([a.top1_prob for a in auxes]),
        top2_prob=jnp.stack([a.top2_prob for a in auxes]),
    )
    return logits, aux


def count_params(params: ModelParams) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))


def count_activated_params(cfg: MoEConfig) -> Tuple[int, float]:
    """(total params, expected activated params per token).

    Activated = dense backbone + K expert-FFNs weighted by the expected
    fraction of top-K slots landing on FFN experts. For MoE++ with balanced
    routing that fraction is tau*N_F/(tau*N_F + N_Z) (Table 1); for vanilla
    MoE it is 1. This is the accounting behind the paper's "<=0.2B/0.6B"
    notation and the Table 1 complexity ratio.
    """
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    per_ffn = 3 * d * f
    router = cfg.n_experts * d + cfg.n_experts ** 2
    const_p = cfg.n_const * 3 * d
    attn = 4 * d * d + 2 * d
    per_layer_total = attn + cfg.n_ffn_experts * per_ffn + router + const_p
    total = v * d + cfg.n_layers * per_layer_total + d + d * v
    if cfg.variant == "vanilla":
        ffn_frac = 1.0
    else:
        ffn_frac = (cfg.tau * cfg.n_ffn_experts /
                    (cfg.tau * cfg.n_ffn_experts + cfg.n_zc))
    activated = (v * d + d * v + d +
                 cfg.n_layers * (attn + router + const_p +
                                 cfg.top_k * ffn_frac * per_ffn))
    return total, activated

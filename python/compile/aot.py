"""AOT lowering: every computation the Rust runtime executes, emitted as HLO
*text* plus a manifest.json describing parameter order, shapes and dtypes.

HLO text — NOT `HloModuleProto.serialize()` — is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Artifacts per model variant (variant = preset x {moepp, vanilla}):
    {tag}_init        (seed i32)                  -> params ++ opt_state
    {tag}_fwd         (params..., tokens)         -> logits ++ aux stats
    {tag}_train_step  (params..., opt..., tokens) -> params' ++ opt' ++ metrics
    {tag}_eval        (params..., tokens)         -> (ce,)
Shared kernels:
    expert_ffn_{preset}_b{B}  (x[B,D], w1, w3, w2) -> y[B,D]   (serving path)
    router_probe_{preset}     (x, w, prev, wg)     -> (probs, scores)

Python runs once at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import MoEConfig, parse_spec, preset, spec_tag
from .kernels.expert_ffn import expert_ffn
from .kernels.gating import router_scores_softmax
from .model import count_activated_params, init_params
from .train_step import (init_opt_state, make_eval_fn, make_fwd_fn,
                         make_init_fn, make_train_step_fn)

# Batch sizes baked into the training/eval artifacts (XLA shapes are static).
TRAIN_BATCH = {"test": 4, "sm-8e": 8, "sm-16e": 8, "sm-32e": 8,
               "md-16e": 4, "e2e": 8}
# Expert-FFN bucket sizes for the L3 serving hot path; the engine pads each
# expert micro-batch up to the nearest bucket.
FFN_BUCKETS = [8, 16, 32, 64, 128]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return sanitize_hlo_text(comp.as_hlo_text())


def sanitize_hlo_text(text: str) -> str:
    """Strip HLO attributes newer than the consumer's XLA (0.5.1) parser.

    `topk(..., k=K, largest=true)`: the old parser knows `topk` with `k`
    but not `largest`; descending order was the only behaviour then, so
    dropping the attribute preserves semantics. (jax.lax.top_k only ever
    emits largest=true.)
    """
    assert "largest=false" not in text, "topk largest=false unsupported"
    return text.replace(", largest=true", "")


def _leaf_specs(tree, prefix, include_empty=False):
    """Flatten a pytree into [(name, shape, dtype)] in traversal order.

    Zero-element leaves (e.g. the vanilla variant's empty constant-expert
    slots) are excluded by default: XLA prunes zero-sized parameters from
    *some* compiled programs but not others, so they must never cross the
    PJRT boundary at all.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        if leaf.size == 0 and not include_empty:
            continue
        name = prefix + jax.tree_util.keystr(path)
        specs.append({
            "name": name,
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        })
    return specs


def _filtered_flatten_utils(tree_shape):
    """(nonzero ShapeDtypeStructs, keep-list, unflatten, filter) for a
    pytree whose zero-element leaves are elided at the artifact boundary."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_shape)
    keep = [leaf.size > 0 for leaf in leaves]
    nonzero = [jax.ShapeDtypeStruct(l.shape, l.dtype)
               for l, k in zip(leaves, keep) if k]

    def unflatten(args):
        assert len(args) == sum(keep)
        it = iter(args)
        full = [next(it) if k else jnp.zeros(l.shape, l.dtype)
                for l, k in zip(leaves, keep)]
        return jax.tree_util.tree_unflatten(treedef, full)

    def filter_out(tree):
        out_leaves = jax.tree_util.tree_leaves(tree)
        return tuple(v for v, k in zip(out_leaves, keep) if k)

    return nonzero, keep, unflatten, filter_out


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"artifacts": {}, "configs": {}}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, example_args, input_specs, output_names):
        """Lower fn at example_args; write HLO text + manifest entry."""
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        flat_out, _ = jax.tree_util.tree_flatten(
            jax.eval_shape(fn, *example_args))
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": input_specs,
            "outputs": [
                {"name": output_names[i] if i < len(output_names)
                 else f"out{i}",
                 "shape": list(o.shape), "dtype": str(o.dtype)}
                for i, o in enumerate(flat_out)
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"  {name}: {len(text)} chars, "
              f"{len(input_specs)} in / {len(flat_out)} out", flush=True)

    def save_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"wrote {path}")


def emit_model_artifacts(em: Emitter, cfg: MoEConfig, tag: str):
    """init / fwd / train_step / eval for one model variant."""
    batch = TRAIN_BATCH[cfg.name]
    tokens_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)

    # Abstract params/opt trees (shapes only — no real init at lower time).
    params_shape = jax.eval_shape(lambda s: init_params(
        jax.random.PRNGKey(s), cfg), jnp.zeros((), jnp.int32))
    opt_shape = jax.eval_shape(init_opt_state, params_shape)

    p_specs = _leaf_specs(params_shape, "params")
    o_specs = _leaf_specs(opt_shape, "opt")
    p_flat, _p_keep, p_unflatten, p_filter = \
        _filtered_flatten_utils(params_shape)
    o_flat, _o_keep, o_unflatten, o_filter = \
        _filtered_flatten_utils(opt_shape)

    tok_spec = {"name": "tokens", "shape": [batch, cfg.seq_len],
                "dtype": "int32"}

    # --- init: seed -> params ++ opt ---------------------------------------
    init_fn = make_init_fn(cfg)

    def init_flat(seed):
        params, opt = init_fn(seed)
        return p_filter(params) + o_filter(opt)

    em.emit(f"{tag}_init", init_flat,
            (jax.ShapeDtypeStruct((), jnp.int32),),
            [{"name": "seed", "shape": [], "dtype": "int32"}],
            [s["name"] for s in p_specs] + [s["name"] for s in o_specs])

    # --- fwd: params ++ tokens -> logits ++ stats ---------------------------
    fwd_fn = make_fwd_fn(cfg)

    def fwd_flat(*args):
        params = p_unflatten(args[:len(p_flat)])
        tokens = args[-1]
        return fwd_fn(params, tokens)

    em.emit(f"{tag}_fwd", fwd_flat, tuple(p_flat) + (tokens_spec,),
            p_specs + [tok_spec],
            ["logits", "expert_counts", "dropped", "ffn_per_token",
             "top1_prob", "top2_prob", "balance_loss"])

    # --- train_step ---------------------------------------------------------
    step_fn = make_train_step_fn(cfg)

    def step_flat(*args):
        params = p_unflatten(args[:len(p_flat)])
        opt = o_unflatten(args[len(p_flat):len(p_flat) + len(o_flat)])
        tokens = args[-1]
        new_p, new_o, metrics = step_fn(params, opt, tokens)
        return p_filter(new_p) + o_filter(new_o) + tuple(metrics)

    em.emit(f"{tag}_train_step", step_flat,
            tuple(p_flat) + tuple(o_flat) + (tokens_spec,),
            p_specs + o_specs + [tok_spec],
            [s["name"] for s in p_specs] + [s["name"] for s in o_specs]
            + ["loss", "ce", "balance", "grad_norm", "lr", "dropped",
               "ffn_per_token"])

    # --- eval ----------------------------------------------------------------
    eval_fn = make_eval_fn(cfg)

    def eval_flat(*args):
        params = p_unflatten(args[:len(p_flat)])
        return eval_fn(params, args[-1])

    em.emit(f"{tag}_eval", eval_flat, tuple(p_flat) + (tokens_spec,),
            p_specs + [tok_spec], ["ce"])

    total, activated = count_activated_params(cfg)
    self_cfg = json.loads(cfg.to_json())
    self_cfg.update({
        "train_batch": batch,
        "n_params_analytic": total,
        "n_activated_analytic": activated,
        "param_order": [s["name"] for s in p_specs],
        "opt_order": [s["name"] for s in o_specs],
        "ffn_capacity": cfg.capacities(batch * cfg.seq_len)[0],
        "zc_capacity": cfg.capacities(batch * cfg.seq_len)[1],
    })
    em.manifest["configs"][tag] = self_cfg


def emit_kernel_artifacts(em: Emitter, cfg: MoEConfig, pname: str):
    """Standalone expert-FFN buckets + router probe for preset dims."""
    d, f, n = cfg.d_model, cfg.d_ff, cfg.n_experts
    for b in FFN_BUCKETS:
        em.emit(
            f"expert_ffn_{pname}_b{b}",
            lambda x, w1, w3, w2: (expert_ffn(x, w1, w3, w2),),
            (jax.ShapeDtypeStruct((b, d), jnp.float32),
             jax.ShapeDtypeStruct((d, f), jnp.float32),
             jax.ShapeDtypeStruct((d, f), jnp.float32),
             jax.ShapeDtypeStruct((f, d), jnp.float32)),
            [{"name": "x", "shape": [b, d], "dtype": "float32"},
             {"name": "w1", "shape": [d, f], "dtype": "float32"},
             {"name": "w3", "shape": [d, f], "dtype": "float32"},
             {"name": "w2", "shape": [f, d], "dtype": "float32"}],
            ["y"],
        )
    t = 64
    em.emit(
        f"router_probe_{pname}",
        lambda x, w, prev, wg: router_scores_softmax(
            x, w, prev, wg, use_residual=True),
        (jax.ShapeDtypeStruct((t, d), jnp.float32),
         jax.ShapeDtypeStruct((n, d), jnp.float32),
         jax.ShapeDtypeStruct((t, n), jnp.float32),
         jax.ShapeDtypeStruct((n, n), jnp.float32)),
        [{"name": "x", "shape": [t, d], "dtype": "float32"},
         {"name": "w", "shape": [n, d], "dtype": "float32"},
         {"name": "prev", "shape": [t, n], "dtype": "float32"},
         {"name": "wg", "shape": [n, n], "dtype": "float32"}],
        ["probs", "scores"],
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="test,e2e",
                    help="comma-separated preset names")
    ap.add_argument("--variants", default="moepp,vanilla")
    ap.add_argument("--kernels-for", default="test,e2e",
                    help="presets to emit standalone kernel buckets for")
    ap.add_argument("--specs", default="",
                    help="extra full specs (see configs.parse_spec), "
                         "semicolon-separated, e.g. 'test@tau=0.25;test@gr=0'")
    args = ap.parse_args()

    em = Emitter(args.out)
    # Merge into an existing manifest so selective rebuilds work.
    man_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(man_path):
        with open(man_path) as f:
            em.manifest = json.load(f)

    for pname in args.presets.split(","):
        if not pname:
            continue
        for variant in args.variants.split(","):
            key = pname if variant == "moepp" else f"{pname}:{variant}"
            cfg = preset(key)
            tag = f"{pname}_{variant}"
            print(f"[aot] {tag}", flush=True)
            emit_model_artifacts(em, cfg, tag)
    for spec in args.specs.split(";"):
        spec = spec.strip()
        if not spec:
            continue
        cfg = parse_spec(spec)
        tag = spec_tag(spec)
        print(f"[aot] {tag} (spec '{spec}')", flush=True)
        emit_model_artifacts(em, cfg, tag)
    for pname in args.kernels_for.split(","):
        if not pname:
            continue
        print(f"[aot] kernels {pname}", flush=True)
        emit_kernel_artifacts(em, preset(pname), pname)
    em.save_manifest()


if __name__ == "__main__":
    main()

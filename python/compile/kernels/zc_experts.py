"""L1 Pallas kernel: the constant expert (Eq. 5), the only zero-computation
expert with any arithmetic at all.

    y = a1 * x + a2 * v,   [a1, a2] = softmax(Wc x)

Deliberately *not* MXU work: Wc is [2, D], so the score computation is a pair
of dot products per token (VPU lane work on TPU), followed by a 2-way softmax
and an axpy. Zero and copy experts have no kernel — they are a masked fill /
a copy, which the L2 combine and the L3 engine implement directly; that
absence is precisely the paper's "zero-computation" claim.

`interpret=True` is mandatory — see expert_ffn.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_TILE = 256


def _const_kernel(x_ref, wc_ref, v_ref, o_ref):
    """y = a1*x + a2*v with [a1,a2] = softmax(x Wc^T), fused per token tile."""
    x = x_ref[...]                       # [B_t, D]
    logits = jnp.dot(x, wc_ref[...].T)   # [B_t, 2] — VPU-scale work
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    alphas = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = alphas[:, 0:1] * x + alphas[:, 1:2] * v_ref[...][None, :]


def _pick_tile(total, preferred):
    t = min(preferred, total)
    while total % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("b_tile",))
def constant_expert(x, wc, v, *, b_tile=None):
    """Constant expert via Pallas. x [B, D], wc [2, D], v [D] -> y [B, D].

    Equivalent to ref.constant_expert_ref.
    """
    b, d = x.shape
    bt = _pick_tile(b, b_tile or B_TILE)
    grid = (b // bt,)
    return pl.pallas_call(
        _const_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((2, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, wc, v)

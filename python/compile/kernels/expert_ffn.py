"""L1 Pallas kernel: the SwiGLU FFN expert — the MoE compute hot-spot.

TPU design (see DESIGN.md §8, Hardware-Adaptation):

The paper's efficiency analysis is GPU-framed (each expert FFN is a pair of
GEMMs on an A100). On TPU the same insight maps to: tile the token batch so
an x-tile, the weight tiles, and the accumulator live in VMEM, and feed the
MXU with 128x128-shaped matmuls. The grid walks token tiles in the first
dimension and F-tiles in the second; the up-projections (w1/w3) stream
F-tiles through VMEM while the partial down-projection accumulates into a
[B_TILE, D] scratch accumulator — a single HBM pass over the weights per
token tile.

`interpret=True` is mandatory here: the CPU PJRT plugin cannot execute the
Mosaic custom-call a real TPU lowering would emit. Numerics are identical;
TPU efficiency is estimated from the BlockSpec footprint (EXPERIMENTS.md
§Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Default tile sizes chosen for TPU VMEM (~16 MiB/core):
#   x tile   [128, D]          f32: 128*D*4
#   w1/w3    [D, 512] each     f32: D*512*4 * 2
#   w2 tile  [512, D]          f32: 512*D*4
#   acc      [128, D]          f32: 128*D*4
# At D=1024 this is ~6.5 MiB — comfortably resident, double-bufferable.
B_TILE = 128
F_TILE = 512


def _ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_ref, *, n_f_tiles):
    """One (token-tile, F-tile) grid step of the SwiGLU expert.

    x_ref   [B_t, D]   — token tile (resident across the F loop)
    w1_ref  [D, F_t]   — gate up-projection tile
    w3_ref  [D, F_t]   — linear up-projection tile
    w2_ref  [F_t, D]   — down-projection tile
    acc_ref [B_t, D]   — VMEM scratch accumulator
    """
    f_idx = pl.program_id(1)

    @pl.when(f_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    # Up-projections for this F tile; MXU-shaped matmuls.
    h_gate = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h_lin = jnp.dot(x, w3_ref[...], preferred_element_type=jnp.float32)
    h = h_gate * jax.nn.sigmoid(h_gate) * h_lin  # SwiGLU
    # Partial down-projection accumulates across F tiles.
    acc_ref[...] += jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)

    @pl.when(f_idx == n_f_tiles - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _pick_tile(total, preferred):
    """Largest divisor of `total` that is <= preferred (tiles must divide)."""
    t = min(preferred, total)
    while total % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("b_tile", "f_tile"))
def expert_ffn(x, w1, w3, w2, *, b_tile=None, f_tile=None):
    """SwiGLU FFN expert via Pallas. x [B, D] -> y [B, D].

    Equivalent to ref.expert_ffn_ref; tiling is an implementation detail.
    """
    b, d = x.shape
    f = w1.shape[1]
    bt = _pick_tile(b, b_tile or B_TILE)
    ft = _pick_tile(f, f_tile or F_TILE)
    n_f_tiles = f // ft

    grid = (b // bt, n_f_tiles)
    return pl.pallas_call(
        functools.partial(_ffn_kernel, n_f_tiles=n_f_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),   # x: token tile
            pl.BlockSpec((d, ft), lambda i, j: (0, j)),   # w1: F tile
            pl.BlockSpec((d, ft), lambda i, j: (0, j)),   # w3: F tile
            pl.BlockSpec((ft, d), lambda i, j: (j, 0)),   # w2: F tile
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        scratch_shapes=[pltpu_scratch(bt, d)],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, w1, w3, w2)


def pltpu_scratch(bt, d):
    """Scratch shape helper compatible across jax versions."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bt, d), jnp.float32)


def _grouped_ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_ref, *,
                        n_f_tiles):
    """Grid step (expert e, token-tile i, F-tile j) of the grouped expert FFN.

    Identical arithmetic to `_ffn_kernel`; the leading grid dimension walks
    experts, so each expert's capacity buffer is processed with that expert's
    weight tiles. This is the shape the MoE++ layer's dense dispatch feeds:
    x [N_FFN, C, D] -> y [N_FFN, C, D].
    """
    f_idx = pl.program_id(2)

    @pl.when(f_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # [B_t, D] — squeeze the expert block dim
    h_gate = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
    h_lin = jnp.dot(x, w3_ref[0], preferred_element_type=jnp.float32)
    h = h_gate * jax.nn.sigmoid(h_gate) * h_lin
    acc_ref[...] += jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f_idx == n_f_tiles - 1)
    def _flush():
        o_ref[0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("b_tile", "f_tile"))
def grouped_expert_ffn(x, w1, w3, w2, *, b_tile=None, f_tile=None):
    """All experts' SwiGLU FFNs in one Pallas call.

    x [N, C, D] (per-expert capacity buffers), w1/w3 [N, D, F], w2 [N, F, D]
    -> y [N, C, D]. Equivalent to vmapping expert_ffn over the expert dim.
    """
    n, c, d = x.shape
    f = w1.shape[2]
    bt = _pick_tile(c, b_tile or B_TILE)
    ft = _pick_tile(f, f_tile or F_TILE)
    n_f_tiles = f // ft

    grid = (n, c // bt, n_f_tiles)
    return pl.pallas_call(
        functools.partial(_grouped_ffn_kernel, n_f_tiles=n_f_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, d, ft), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, d, ft), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, ft, d), lambda e, i, j: (e, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda e, i, j: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, d), jnp.float32),
        scratch_shapes=[pltpu_scratch(bt, d)],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, w1, w3, w2)


def vmem_footprint_bytes(d, b_tile=B_TILE, f_tile=F_TILE, bytes_per=4):
    """Estimated VMEM residency of one grid step (for the §Perf audit)."""
    x = b_tile * d
    w = 2 * d * f_tile + f_tile * d
    acc = b_tile * d
    out = b_tile * d
    return (x + w + acc + out) * bytes_per

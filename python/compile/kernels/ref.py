"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness ground
truth) and for the MoE++ layer semantics shared with the Rust implementation.

Everything here is deliberately written in the most direct way possible —
these functions define *what is correct*; the Pallas kernels and the Rust
native engine define *how it runs fast*.
"""

import jax
import jax.numpy as jnp


def silu(x):
    return x * jax.nn.sigmoid(x)


def expert_ffn_ref(x, w1, w3, w2):
    """SwiGLU FFN expert: y = (silu(x @ w1) * (x @ w3)) @ w2.

    Shapes: x [B, D], w1 [D, F], w3 [D, F], w2 [F, D] -> y [B, D].
    Matches LLaMA-style gated FFN used as the MoE expert (paper Sec. 3).
    """
    return (silu(x @ w1) * (x @ w3)) @ w2


def router_scores_ref(x, w, prev_scores=None, wg=None):
    """Pathway-aware router scores, Eq. 6.

    x [T, D]; w [N, D]; prev_scores [T, N] (or None for layer 0);
    wg [N, N]. Returns raw scores G(x) [T, N] (pre-softmax).
    """
    scores = x @ w.T
    if prev_scores is not None and wg is not None:
        scores = scores + prev_scores @ wg.T
    return scores


def constant_expert_ref(x, wc, v):
    """Constant expert, Eq. 5: y = a1*x + a2*v, [a1,a2] = softmax(Wc x).

    x [B, D]; wc [2, D]; v [D]. Returns y [B, D].
    """
    alphas = jax.nn.softmax(x @ wc.T, axis=-1)  # [B, 2]
    return alphas[:, 0:1] * x + alphas[:, 1:2] * v[None, :]


def zero_expert_ref(x):
    """Zero expert, Eq. 3: discard."""
    return jnp.zeros_like(x)


def copy_expert_ref(x):
    """Copy expert, Eq. 4: identity shortcut."""
    return x


def topk_gates_ref(scores, k):
    """Softmax over N then keep top-k values (Eq. 1 gating).

    Returns (gates [T, N] with zeros off the top-k, topk_idx [T, k]).
    Note: per Eq. 1 the softmax is over *all* N experts and the non-top-k
    entries are zeroed without renormalisation.
    """
    probs = jax.nn.softmax(scores, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)
    mask = jnp.zeros_like(probs)
    mask = jax.vmap(lambda m, i: m.at[i].set(1.0))(mask, top_idx)
    return probs * mask, top_idx


def load_balance_loss_ref(scores, topk_idx, n_ffn, tau):
    """Heterogeneous load-balance loss, Eq. 7.

    scores [T, N] raw router scores; topk_idx [T, K] selected experts;
    experts [0, n_ffn) are FFN experts, [n_ffn, N) are zero-computation.
    eta_i = 1 for FFN experts, tau for ZC experts.
    L_b = N * sum_i eta_i * f_i * P_i with f_i the fraction of tokens
    selecting expert i and P_i the mean router probability. The N scaling
    (as in GShard/Switch aux losses) makes the uniform-router baseline
    size-independent.
    """
    t, n = scores.shape
    probs = jax.nn.softmax(scores, axis=-1)
    p = probs.mean(axis=0)  # P_i
    one_hot = jax.nn.one_hot(topk_idx, n).sum(axis=1)  # [T, N]
    f = one_hot.mean(axis=0)  # f_i
    eta = jnp.where(jnp.arange(n) < n_ffn, 1.0, tau)
    return n * jnp.sum(eta * f * p)

# L1: Pallas kernels for the MoE++ compute hot-spots.
from .expert_ffn import expert_ffn  # noqa: F401
from .gating import router_scores_softmax  # noqa: F401
from .zc_experts import constant_expert  # noqa: F401

"""Differentiable wrappers for the Pallas kernels.

Interpret-mode `pallas_call` has no reverse-mode rule, so each kernel gets a
`jax.custom_vjp`: the forward pass runs the Pallas kernel (which therefore
appears in the lowered HLO of fwd/serving artifacts), and the backward pass
is the exact `jax.vjp` of the pure-jnp reference — mathematically identical
since the kernels are bit-faithful reimplementations of the refs (asserted
by python/tests/test_kernels.py).
"""

import jax
import jax.numpy as jnp

from . import ref
from .expert_ffn import grouped_expert_ffn
from .gating import router_scores_softmax
from .zc_experts import constant_expert


# --- grouped expert FFN ------------------------------------------------------

def _grouped_ffn_ref(x, w1, w3, w2):
    return jax.vmap(ref.expert_ffn_ref)(x, w1, w3, w2)


@jax.custom_vjp
def grouped_expert_ffn_ad(x, w1, w3, w2):
    """Differentiable grouped SwiGLU FFN: x [N, C, D] -> y [N, C, D]."""
    return grouped_expert_ffn(x, w1, w3, w2)


def _gffn_fwd(x, w1, w3, w2):
    return grouped_expert_ffn(x, w1, w3, w2), (x, w1, w3, w2)


def _gffn_bwd(res, g):
    _, vjp = jax.vjp(_grouped_ffn_ref, *res)
    return vjp(g)


grouped_expert_ffn_ad.defvjp(_gffn_fwd, _gffn_bwd)


# --- pathway-aware router ----------------------------------------------------

def _router_ref(x, w, prev, wg, use_residual):
    scores = ref.router_scores_ref(
        x, w, prev if use_residual else None, wg if use_residual else None
    )
    return jax.nn.softmax(scores, axis=-1), scores


def make_router_ad(use_residual: bool):
    """Build a differentiable router for a fixed residual setting."""

    @jax.custom_vjp
    def router_ad(x, w, prev, wg):
        probs, scores = router_scores_softmax(
            x, w, prev, wg, use_residual=use_residual
        )
        return probs, scores

    def fwd(x, w, prev, wg):
        return router_ad(x, w, prev, wg), (x, w, prev, wg)

    def bwd(res, g):
        _, vjp = jax.vjp(
            lambda x, w, prev, wg: _router_ref(x, w, prev, wg, use_residual),
            *res,
        )
        return vjp(g)

    router_ad.defvjp(fwd, bwd)
    return router_ad


_ROUTER_AD = {True: make_router_ad(True), False: make_router_ad(False)}


def router_scores_softmax_ad(x, w, prev, wg, use_residual):
    return _ROUTER_AD[bool(use_residual)](x, w, prev, wg)


# --- constant expert ---------------------------------------------------------

@jax.custom_vjp
def constant_expert_ad(x, wc, v):
    """Differentiable constant expert (Eq. 5)."""
    return constant_expert(x, wc, v)


def _const_fwd(x, wc, v):
    return constant_expert(x, wc, v), (x, wc, v)


def _const_bwd(res, g):
    _, vjp = jax.vjp(ref.constant_expert_ref, *res)
    return vjp(g)


constant_expert_ad.defvjp(_const_fwd, _const_bwd)

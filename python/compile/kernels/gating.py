"""L1 Pallas kernel: the pathway-aware router (Eq. 6) — fused score matmul,
gating-residual add, and softmax.

The router is small (an [N, D] matmul per token) but sits on the critical
path of every MoE++ layer and must never round-trip to HBM between the score
computation and the softmax: the kernel keeps the [T_tile, N] score block in
VMEM across all three steps. Top-k extraction happens outside the kernel
(jax.lax.top_k) because k is tiny and the data is already reduced to [T, N].

`interpret=True` is mandatory — see expert_ffn.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

T_TILE = 128


def _router_kernel(x_ref, w_ref, prev_ref, wg_ref, probs_ref, scores_ref, *,
                   use_residual):
    """One token-tile step: scores = x W^T (+ prev Wg^T); probs = softmax.

    x_ref     [T_t, D]
    w_ref     [N, D]
    prev_ref  [T_t, N]  — previous layer's raw scores (zeros at layer 0)
    wg_ref    [N, N]
    probs_ref [T_t, N]  — softmax output
    scores_ref[T_t, N]  — raw scores output (threaded to the next layer)
    """
    x = x_ref[...]
    scores = jnp.dot(x, w_ref[...].T, preferred_element_type=jnp.float32)
    if use_residual:
        scores = scores + jnp.dot(
            prev_ref[...], wg_ref[...].T, preferred_element_type=jnp.float32
        )
    scores_ref[...] = scores
    # Numerically-stable softmax, entirely VMEM-resident.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def _pick_tile(total, preferred):
    t = min(preferred, total)
    while total % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("use_residual", "t_tile"))
def router_scores_softmax(x, w, prev_scores, wg, *, use_residual=True,
                          t_tile=None):
    """Pathway-aware router: returns (probs [T, N], raw_scores [T, N]).

    Matches ref.router_scores_ref + softmax. `prev_scores` must be zeros for
    the first layer (with use_residual=False the residual matmul is elided
    from the kernel entirely).
    """
    t, d = x.shape
    n = w.shape[0]
    tt = _pick_tile(t, t_tile or T_TILE)
    grid = (t // tt,)
    return pl.pallas_call(
        functools.partial(_router_kernel, use_residual=use_residual),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tt, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((tt, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tt, n), lambda i: (i, 0)),
            pl.BlockSpec((tt, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, n), jnp.float32),
            jax.ShapeDtypeStruct((t, n), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, w, prev_scores, wg)

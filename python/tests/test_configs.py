"""Config presets, spec-override parsing, and Eq. 8/Eq. 10 accounting."""

import pytest

from compile.configs import MoEConfig, parse_spec, preset, spec_tag


def test_presets_mirror_table2():
    c = preset("sm-32e")
    assert (c.n_zero, c.n_copy, c.n_const) == (1, 1, 6)
    assert c.n_experts == 40
    v = preset("sm-32e:vanilla")
    assert v.n_experts == 32 and v.variant == "vanilla"


def test_parse_spec_overrides():
    c = parse_spec("test@tau=0.25")
    assert c.tau == 0.25
    c = parse_spec("test@nz=0,nk=0,nc=1")
    assert (c.n_zero, c.n_copy, c.n_const) == (0, 0, 1)
    c = parse_spec("test@gr=0")
    assert not c.gating_residual
    c = parse_spec("test:vanilla@nf=1,k=1,ff=128")
    assert c.variant == "vanilla" and c.n_ffn_experts == 1
    assert c.top_k == 1 and c.d_ff == 128


def test_spec_tags_are_deterministic_and_distinct():
    tags = [spec_tag(s) for s in
            ["test", "test:vanilla", "test@tau=0.25", "test@nz=1,nk=0,nc=0",
             "test@gr=0"]]
    assert tags[0] == "test_moepp"
    assert tags[1] == "test_vanilla"
    assert tags[2] == "test_moepp_tau0.25"
    assert tags[3] == "test_moepp_nz1_nk0_nc0"
    assert len(set(tags)) == len(tags)


def test_capacity_scales_with_k_and_gamma():
    c = preset("test")
    f1, z1 = c.capacities(100)
    import dataclasses
    c2 = MoEConfig(**{**dataclasses.asdict(c), "capacity_factor": 2.2})
    f2, z2 = c2.capacities(100)
    assert f2 > f1 and z2 > z1


def test_vanilla_capacity_homogeneous():
    c = preset("test:vanilla")
    f, z = c.capacities(100)
    assert z == 0
    assert f == int(1.1 * 2 * 100 / c.n_experts) + 1


def test_unknown_preset_raises():
    with pytest.raises(KeyError):
        preset("nonexistent")

"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the router's residual switch); tolerances are
f32-tight since interpret-mode Pallas is numerically plain XLA.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.autodiff import (constant_expert_ad,
                                      grouped_expert_ffn_ad,
                                      router_scores_softmax_ad)
from compile.kernels.expert_ffn import (expert_ffn, grouped_expert_ffn,
                                        vmem_footprint_bytes)
from compile.kernels.gating import router_scores_softmax
from compile.kernels.zc_experts import constant_expert

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape, scale=0.1):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------- expert FFN

@settings(**SETTINGS)
@given(b=st.sampled_from([1, 8, 33, 64]),
       d=st.sampled_from([8, 32]),
       f=st.sampled_from([16, 96]),
       seed=st.integers(0, 2**16))
def test_expert_ffn_matches_ref(b, d, f, seed):
    x = rand(seed, (b, d), 1.0)
    w1, w3, w2 = rand(seed + 1, (d, f)), rand(seed + 2, (d, f)), \
        rand(seed + 3, (f, d))
    np.testing.assert_allclose(
        expert_ffn(x, w1, w3, w2), ref.expert_ffn_ref(x, w1, w3, w2),
        rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(n=st.sampled_from([1, 3, 8]),
       c=st.sampled_from([4, 16]),
       d=st.sampled_from([8, 32]),
       seed=st.integers(0, 2**16))
def test_grouped_expert_ffn_matches_vmapped_ref(n, c, d, seed):
    f = 2 * d
    x = rand(seed, (n, c, d), 1.0)
    w1, w3, w2 = rand(seed + 1, (n, d, f)), rand(seed + 2, (n, d, f)), \
        rand(seed + 3, (n, f, d))
    want = jax.vmap(ref.expert_ffn_ref)(x, w1, w3, w2)
    np.testing.assert_allclose(grouped_expert_ffn(x, w1, w3, w2), want,
                               rtol=2e-5, atol=2e-5)


def test_expert_ffn_tile_shapes_are_irrelevant():
    """Different tilings must be numerically identical (pure refactor)."""
    x = rand(0, (64, 32), 1.0)
    w1, w3, w2 = rand(1, (32, 96)), rand(2, (32, 96)), rand(3, (96, 32))
    a = expert_ffn(x, w1, w3, w2, b_tile=64, f_tile=96)
    b = expert_ffn(x, w1, w3, w2, b_tile=16, f_tile=32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_vmem_footprint_within_budget():
    """DESIGN.md §8: default tiles must fit a 16 MiB VMEM at D=1024."""
    assert vmem_footprint_bytes(1024) < 16 * 2**20


# -------------------------------------------------------------------- router

@settings(**SETTINGS)
@given(t=st.sampled_from([1, 16, 64]),
       d=st.sampled_from([8, 32]),
       n=st.sampled_from([4, 12, 20]),
       use_res=st.booleans(),
       seed=st.integers(0, 2**16))
def test_router_matches_ref(t, d, n, use_res, seed):
    x = rand(seed, (t, d), 1.0)
    w, wg = rand(seed + 1, (n, d)), rand(seed + 2, (n, n))
    prev = rand(seed + 3, (t, n), 1.0)
    probs, scores = router_scores_softmax(x, w, prev, wg,
                                          use_residual=use_res)
    want = ref.router_scores_ref(x, w, prev if use_res else None,
                                 wg if use_res else None)
    np.testing.assert_allclose(scores, want, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(probs, jax.nn.softmax(want, -1),
                               rtol=2e-5, atol=2e-5)


def test_router_probs_are_normalised():
    probs, _ = router_scores_softmax(rand(0, (32, 16), 1.0),
                                     rand(1, (8, 16)), jnp.zeros((32, 8)),
                                     jnp.zeros((8, 8)), use_residual=False)
    np.testing.assert_allclose(probs.sum(-1), np.ones(32), rtol=1e-5)


def test_router_residual_changes_scores():
    """With Wg nonzero the previous pathway must influence routing (Eq. 6)."""
    x, w = rand(0, (16, 8), 1.0), rand(1, (4, 8))
    wg = jnp.eye(4)
    prev = rand(2, (16, 4), 5.0)
    _, s_res = router_scores_softmax(x, w, prev, wg, use_residual=True)
    _, s_none = router_scores_softmax(x, w, prev, wg, use_residual=False)
    assert not np.allclose(s_res, s_none)
    np.testing.assert_allclose(s_res - s_none, prev, rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------- constant expert

@settings(**SETTINGS)
@given(b=st.sampled_from([1, 16, 65]),
       d=st.sampled_from([8, 32]),
       seed=st.integers(0, 2**16))
def test_constant_expert_matches_ref(b, d, seed):
    x = rand(seed, (b, d), 1.0)
    wc, v = rand(seed + 1, (2, d)), rand(seed + 2, (d,), 1.0)
    np.testing.assert_allclose(constant_expert(x, wc, v),
                               ref.constant_expert_ref(x, wc, v),
                               rtol=2e-5, atol=2e-5)


def test_constant_expert_is_convex_combination():
    """Eq. 5: alphas sum to 1, so y - a1 x - a2 v == 0 for any alphas; with
    Wc = 0, alphas = [.5, .5] exactly."""
    d = 16
    x = rand(0, (8, d), 1.0)
    v = rand(1, (d,), 1.0)
    y = constant_expert(x, jnp.zeros((2, d)), v)
    np.testing.assert_allclose(y, 0.5 * x + 0.5 * v[None, :],
                               rtol=1e-5, atol=1e-6)


# -------------------------------------------------- zero/copy (no kernels)

def test_zero_and_copy_refs():
    x = rand(0, (8, 16), 1.0)
    assert np.all(np.asarray(ref.zero_expert_ref(x)) == 0)
    np.testing.assert_array_equal(ref.copy_expert_ref(x), x)


# ------------------------------------------------------- autodiff wrappers

def test_autodiff_wrappers_match_finite_differences():
    """custom_vjp backward (ref vjp) must agree with numeric gradients."""
    n, c, d = 2, 4, 6
    f = 8
    x = rand(0, (n, c, d), 0.5)
    w1, w3, w2 = rand(1, (n, d, f)), rand(2, (n, d, f)), rand(3, (n, f, d))

    def loss(w1):
        return jnp.sum(grouped_expert_ffn_ad(x, w1, w3, w2) ** 2)

    g = jax.grad(loss)(w1)
    eps = 1e-3
    e = jnp.zeros_like(w1).at[0, 1, 2].set(eps)
    fd = (loss(w1 + e) - loss(w1 - e)) / (2 * eps)
    np.testing.assert_allclose(g[0, 1, 2], fd, rtol=2e-2)


def test_router_ad_gradients_flow_through_residual():
    t, d, n = 8, 6, 4
    x, w = rand(0, (t, d), 1.0), rand(1, (n, d))
    prev, wg = rand(2, (t, n), 1.0), rand(3, (n, n))

    def loss(wg):
        probs, _ = router_scores_softmax_ad(x, w, prev, wg, True)
        return jnp.sum(probs ** 2)

    g = jax.grad(loss)(wg)
    assert np.any(np.asarray(g) != 0)

    def loss_nores(wg):
        probs, _ = router_scores_softmax_ad(x, w, prev, wg, False)
        return jnp.sum(probs ** 2)

    g0 = jax.grad(loss_nores)(wg)
    np.testing.assert_array_equal(np.asarray(g0), np.zeros_like(g0))


def test_constant_expert_ad_grad_v():
    d = 8
    x = rand(0, (4, d), 1.0)
    wc, v = rand(1, (2, d)), rand(2, (d,), 1.0)
    g = jax.grad(lambda v: jnp.sum(constant_expert_ad(x, wc, v)))(v)
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.any(np.asarray(g) != 0)

"""AOT pipeline: lowering produces parseable HLO text and a consistent
manifest. (The PJRT load side is exercised by the Rust integration tests.)"""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile.aot import to_hlo_text, _leaf_specs
from compile.configs import preset
from compile.model import init_params


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "parameter" in text


def test_leaf_specs_order_is_deterministic():
    cfg = preset("test")
    shapes = jax.eval_shape(
        lambda s: init_params(jax.random.PRNGKey(s), cfg),
        jnp.zeros((), jnp.int32))
    a = _leaf_specs(shapes, "params")
    b = _leaf_specs(shapes, "params")
    assert a == b
    assert a[0]["name"].startswith("params")
    # embed first per ModelParams field order.
    assert "embed" in a[0]["name"]


@pytest.mark.slow
def test_full_aot_run_writes_manifest():
    with tempfile.TemporaryDirectory() as td:
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", td,
             "--presets", "test", "--variants", "moepp",
             "--kernels-for", ""],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True, timeout=600)
        assert r.returncode == 0, r.stderr
        man = json.load(open(os.path.join(td, "manifest.json")))
        arts = man["artifacts"]
        for suffix in ["init", "fwd", "train_step", "eval"]:
            name = f"test_moepp_{suffix}"
            assert name in arts
            path = os.path.join(td, arts[name]["file"])
            head = open(path).read(200)
            assert head.startswith("HloModule")
        cfgs = man["configs"]["test_moepp"]
        assert cfgs["ffn_capacity"] > 0 and cfgs["zc_capacity"] > 0
        # Train-step inputs = params + opt + tokens; outputs add metrics.
        ts = arts["test_moepp_train_step"]
        assert ts["inputs"][-1]["name"] == "tokens"
        assert [o["name"] for o in ts["outputs"][-7:]] == [
            "loss", "ce", "balance", "grad_norm", "lr", "dropped",
            "ffn_per_token"]

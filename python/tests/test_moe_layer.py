"""L2 MoE++ layer semantics vs the per-token oracle, plus the paper's
equations (7), (8) and the Table 1 complexity accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import MoEConfig, preset
from compile.kernels import ref
from compile.moe_layer import (init_layer_params, moe_layer_fwd,
                               moe_layer_fwd_ref, _positions_in_expert)

SETTINGS = dict(max_examples=8, deadline=None)


def mk(cfg_kw=None, t=32, seed=0):
    cfg = preset("test")
    if cfg_kw:
        cfg = MoEConfig(**{**dataclasses.asdict(cfg), **cfg_kw})
    params = init_layer_params(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, cfg.d_model))
    prev = jax.random.normal(jax.random.PRNGKey(seed + 2),
                             (t, cfg.n_experts))
    return cfg, params, x, prev


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000),
       tau=st.sampled_from([0.1, 0.25, 0.5, 0.75, 1.0]),
       t=st.sampled_from([16, 48]))
def test_layer_matches_per_token_oracle(seed, tau, t):
    cfg, params, x, prev = mk({"tau": tau}, t=t, seed=seed)
    y, aux = moe_layer_fwd(params, x, prev, cfg)
    y_ref, s_ref = moe_layer_fwd_ref(params, x, prev, cfg)
    np.testing.assert_allclose(np.asarray(aux.scores), s_ref,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000))
def test_vanilla_layer_matches_oracle(seed):
    cfg, params, x, _ = mk(None, seed=seed)
    vcfg = preset("test:vanilla")
    vparams = init_layer_params(jax.random.PRNGKey(seed), vcfg)
    y, aux = moe_layer_fwd(vparams, x, None, vcfg)
    y_ref, _ = moe_layer_fwd_ref(vparams, x, None, vcfg)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-4)


def test_layer0_ignores_prev_scores_without_residual():
    cfg, params, x, prev = mk()
    y_none, _ = moe_layer_fwd(params, x, None, cfg)
    cfg_off = MoEConfig(**{**dataclasses.asdict(cfg),
                           "gating_residual": False})
    y_off, _ = moe_layer_fwd(params, x, prev, cfg_off)
    np.testing.assert_allclose(np.asarray(y_none), np.asarray(y_off),
                               rtol=1e-5, atol=1e-6)


def test_gating_residual_changes_routing():
    cfg, params, x, prev = mk()
    params = params._replace(router_wg=jnp.eye(cfg.n_experts) * 10.0)
    _, aux_res = moe_layer_fwd(params, x, prev, cfg)
    _, aux_none = moe_layer_fwd(params, x, None, cfg)
    assert not np.allclose(np.asarray(aux_res.scores),
                           np.asarray(aux_none.scores))


# ------------------------------------------------------------------ Eq. 7/8

def test_capacity_formula_matches_eq8():
    cfg = preset("sm-8e")
    t = 1000
    ffn_cap, zc_cap = cfg.capacities(t)
    gamma, tau, k = cfg.capacity_factor, cfg.tau, cfg.top_k
    denom = tau * cfg.n_ffn_experts + cfg.n_zc
    assert ffn_cap == int(gamma * k * tau * t / denom) + 1
    assert zc_cap == int(gamma * k * t / denom) + 1
    # Smaller tau -> relatively more ZC capacity (paper Sec. 3.3).
    cfg_small = MoEConfig(**{**dataclasses.asdict(cfg), "tau": 0.1})
    f2, z2 = cfg_small.capacities(t)
    assert z2 / f2 > zc_cap / ffn_cap


def test_capacity_is_enforced_and_drops_counted():
    # A router forced to send everything to expert 0: all but C tokens drop.
    cfg, params, x, _ = mk(t=48)
    x = jnp.abs(x) + 0.1  # positive mean => the +100 row always wins top-1
    biased = params._replace(
        router_w=jnp.zeros_like(params.router_w)
        .at[0].set(100.0 * jnp.ones(cfg.d_model) / cfg.d_model))
    y, aux = moe_layer_fwd(biased, x, None, cfg)
    counts = np.asarray(aux.expert_counts)
    assert counts[0] == 48  # everyone wants expert 0 in slot 0
    assert float(aux.dropped) > 0
    ffn_cap, _ = cfg.capacities(48)
    # Surviving expert-0 load is exactly the capacity.
    y_ref, _ = moe_layer_fwd_ref(biased, x, None, cfg)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-4)


def test_balance_loss_matches_ref_formula():
    cfg, params, x, prev = mk(t=64)
    y, aux = moe_layer_fwd(params, x, prev, cfg)
    probs = jax.nn.softmax(aux.scores, axis=-1)
    _, top_idx = jax.lax.top_k(probs, cfg.top_k)
    want = ref.load_balance_loss_ref(aux.scores, top_idx,
                                     cfg.n_ffn_experts, cfg.tau)
    np.testing.assert_allclose(float(aux.balance_loss), float(want),
                               rtol=1e-4)


def test_balance_loss_tau_weighting():
    """Loss must weight ZC experts by tau (Eq. 7): concentrating load on ZC
    experts is cheaper (in loss) when tau is small."""
    cfg, params, x, prev = mk(t=64)
    zc_idx = cfg.n_ffn_experts  # first zero expert
    biased = params._replace(
        router_w=jnp.zeros_like(params.router_w)
        .at[zc_idx].set(jnp.ones(cfg.d_model)))
    lo = MoEConfig(**{**dataclasses.asdict(cfg), "tau": 0.1})
    hi = MoEConfig(**{**dataclasses.asdict(cfg), "tau": 1.0})
    _, aux_lo = moe_layer_fwd(biased, x, None, lo)
    _, aux_hi = moe_layer_fwd(biased, x, None, hi)
    assert float(aux_lo.balance_loss) < float(aux_hi.balance_loss)


# ------------------------------------------------------------- positions

def test_positions_slot_major_priority():
    """Top-1 assignments must claim capacity before any top-2 assignment."""
    t, k, n = 4, 2, 2
    mask = np.zeros((t, k, n), np.float32)
    mask[:, 0, 0] = 1  # all tokens top-1 -> expert 0
    mask[:, 1, 1] = 1  # all tokens top-2 -> expert 1
    mask[0, 1, 0] = 1  # token 0 ALSO top-2 -> expert 0 (illegal dup, but
    mask[0, 1, 1] = 0  # exercises ordering)
    pos = np.asarray(_positions_in_expert(jnp.asarray(mask)))
    # token 0's slot-1 assignment to expert 0 queues after all 4 slot-0 ones.
    assert pos[0, 1, 0] == 4
    assert list(pos[:, 0, 0]) == [0, 1, 2, 3]


# -------------------------------------------------------------- ZC experts

def test_zero_expert_routes_contribute_nothing():
    """Forcing all top-1 to the zero expert must halve the layer output to
    just the top-2 contribution (top-2 degrades to top-1, Sec. 3.1)."""
    cfg, params, x, _ = mk(t=16)
    x = jnp.abs(x) + 0.1  # positive mean => the +100 row always wins top-1
    zc0 = cfg.n_ffn_experts
    biased = params._replace(
        router_w=jnp.zeros_like(params.router_w)
        .at[zc0].set(jnp.ones(cfg.d_model) * 100 / cfg.d_model))
    y, aux = moe_layer_fwd(biased, x, None, cfg)
    y_ref, _ = moe_layer_fwd_ref(biased, x, None, cfg)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-4)
    counts = np.asarray(aux.expert_counts)
    assert counts[zc0] == 16


def test_ffn_per_token_below_topk_for_moepp():
    """With ZC experts present some top-2 slots land on them, so mean FFN
    experts per token < K — the paper's computation-saving mechanism."""
    cfg, params, x, prev = mk(t=64)
    _, aux = moe_layer_fwd(params, x, prev, cfg)
    assert float(aux.ffn_per_token) < cfg.top_k

    vcfg = preset("test:vanilla")
    vparams = init_layer_params(jax.random.PRNGKey(0), vcfg)
    _, vaux = moe_layer_fwd(vparams, x, None, vcfg)
    assert float(vaux.ffn_per_token) > float(aux.ffn_per_token)

"""L2 train step: loss decreases, schedule shape, optimizer invariants."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.configs import preset
from compile.train_step import (MAX_LR, FINAL_LR, WARMUP_STEPS, TOTAL_STEPS,
                                init_opt_state, loss_fn, lr_schedule,
                                make_eval_fn, train_step)
from compile.model import init_params


def test_lr_schedule_shape():
    s = jnp.arange(0, TOTAL_STEPS + 500)
    lr = np.asarray(jax.vmap(lr_schedule)(s))
    assert lr[1] < lr[WARMUP_STEPS // 2] < lr[WARMUP_STEPS]
    np.testing.assert_allclose(lr[WARMUP_STEPS], MAX_LR, rtol=1e-3)
    np.testing.assert_allclose(lr[TOTAL_STEPS:], FINAL_LR, rtol=1e-3)
    assert np.all(np.diff(lr[WARMUP_STEPS:]) <= 1e-9)  # monotone decay


def test_loss_decreases_over_steps():
    cfg = preset("test")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, cfg.seq_len),
                              0, cfg.vocab_size)
    step = jax.jit(lambda p, o, t: train_step(p, o, t, cfg))
    first = None
    for i in range(12):
        params, opt, m = step(params, opt, toks)
        if first is None:
            first = float(m.loss)
    assert float(m.loss) < first, (first, float(m.loss))
    assert int(opt.step) == 12


def test_grad_norm_finite_and_clipped_update():
    cfg = preset("test")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, cfg.seq_len),
                              0, cfg.vocab_size)
    _, _, m = jax.jit(lambda p, o, t: train_step(p, o, t, cfg))(
        params, opt, toks)
    assert np.isfinite(float(m.grad_norm))
    assert float(m.loss) > 0


def test_balance_loss_enters_objective():
    cfg = preset("test")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq_len),
                              0, cfg.vocab_size)
    loss, (ce, aux) = loss_fn(params, toks, cfg)
    np.testing.assert_allclose(
        float(loss), float(ce) + cfg.balance_coef * float(aux.balance_loss),
        rtol=1e-5)


def test_eval_matches_ce_of_loss_fn():
    cfg = preset("test")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq_len),
                              0, cfg.vocab_size)
    _, (ce, _) = loss_fn(params, toks, cfg)
    (ce2,) = make_eval_fn(cfg)(params, toks)
    np.testing.assert_allclose(float(ce), float(ce2), rtol=1e-5)

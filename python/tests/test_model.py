"""L2 transformer model: shapes, causality, parameter accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from compile.configs import MoEConfig, preset
from compile.model import (count_activated_params, count_params, init_params,
                           model_fwd, rms_norm, rope)


def setup(name="test", seed=0):
    cfg = preset(name)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1),
                              (2, cfg.seq_len), 0, cfg.vocab_size)
    return cfg, params, toks


def test_fwd_shapes():
    cfg, params, toks = setup()
    logits, aux = model_fwd(params, toks, cfg)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab_size)
    assert aux.expert_counts.shape == (cfg.n_layers, cfg.n_experts)
    assert aux.ffn_per_token.shape == (cfg.n_layers,)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_causality():
    """Changing a future token must not affect past logits.

    Expert-capacity drops genuinely couple tokens across positions (a
    changed future token can push an earlier token's slot-1 assignment over
    capacity — GShard-style dispatch is not strictly causal). So causality
    is asserted with capacity effectively unlimited; the drop coupling
    itself is covered by test_moe_layer.py.
    """
    cfg, params, toks = setup()
    cfg = MoEConfig(**{**dataclasses.asdict(cfg), "capacity_factor": 100.0})
    logits1, _ = model_fwd(params, toks, cfg)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    logits2, _ = model_fwd(params, toks2, cfg)
    np.testing.assert_allclose(np.asarray(logits1[:, :-1]),
                               np.asarray(logits2[:, :-1]),
                               rtol=1e-4, atol=1e-5)
    assert not np.allclose(np.asarray(logits1[:, -1]),
                           np.asarray(logits2[:, -1]))


def test_param_count_matches_analytic():
    cfg, params, _ = setup()
    total, activated = count_activated_params(cfg)
    assert count_params(params) == total
    assert activated < total


def test_moepp_activates_fewer_params_than_vanilla():
    """Table 1 / '<=0.2B' accounting: expected FFN fraction scales activated
    params down by tau*N_F/(tau*N_F+N_Z)."""
    cfg = preset("sm-8e")
    vcfg = preset("sm-8e:vanilla")
    _, act = count_activated_params(cfg)
    _, vact = count_activated_params(vcfg)
    assert act < vact


def test_rms_norm_unit_scale():
    x = jnp.full((4, 8), 3.0)
    y = rms_norm(x, jnp.ones(8))
    np.testing.assert_allclose(np.asarray(y), np.ones((4, 8)), rtol=1e-4)


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (1, 6))
    y = rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)


def test_rope_position_zero_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 2, 8))
    y = rope(x, jnp.zeros((1, 1)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_gating_residual_threads_between_layers():
    """With gating_residual=False the model must behave identically to one
    whose Wg matrices are zeroed; with huge Wg it must differ."""
    cfg, params, toks = setup()
    big_blocks = tuple(
        b._replace(moe=b.moe._replace(
            router_wg=jnp.eye(cfg.n_experts) * 50.0))
        for b in params.blocks)
    big = params._replace(blocks=big_blocks)
    cfg_off = MoEConfig(**{**dataclasses.asdict(cfg),
                           "gating_residual": False})
    l_on, _ = model_fwd(big, toks, cfg)
    l_off, _ = model_fwd(big, toks, cfg_off)
    assert not np.allclose(np.asarray(l_on), np.asarray(l_off))

//! Serving example: dynamic batching + MoE++ engine, with the AOT-compiled
//! Pallas expert kernel on the PJRT backend when artifacts are present
//! (falls back to the native backend otherwise).
//!
//!     make artifacts && cargo run --release --example serve_moe

use std::time::{Duration, Instant};

use moepp::bench::workload::request_sizes;
use moepp::config::MoeConfig;
use moepp::coordinator::batcher::{Batcher, BatcherConfig, Request};
use moepp::coordinator::engine::MoeEngine;
use moepp::coordinator::metrics::{LatencyStats, ServingMetrics};
use moepp::runtime::Runtime;
use moepp::tensor::Tensor;
use moepp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = MoeConfig::preset("test");
    // Prefer the PJRT backend (AOT Pallas kernel) when artifacts exist.
    let engine = match Runtime::open("artifacts") {
        Ok(rt) => {
            println!("backend: PJRT (AOT Pallas expert kernel)");
            MoeEngine::pjrt(cfg.clone(), 0, std::sync::Arc::new(rt))?
        }
        Err(_) => {
            println!("backend: native (run `make artifacts` for PJRT)");
            MoeEngine::native(cfg.clone(), 0)
        }
    };

    let mut batcher = Batcher::new(
        BatcherConfig {
            max_tokens: 128,
            max_wait: Duration::from_millis(2),
        },
        cfg.d_model,
    );
    let mut rng = Rng::new(1);
    let mut metrics = ServingMetrics::default();
    let mut latency = LatencyStats::new(4096);
    let mut inflight = std::collections::HashMap::new();

    // A trace of 300 requests: mostly short decode-like, some long
    // prefill-like (see bench::workload).
    for (id, n) in request_sizes(&mut rng, 300, cfg.seq_len)
        .into_iter()
        .enumerate()
    {
        let id = id as u64;
        inflight.insert(id, Instant::now());
        batcher.push(Request {
            id,
            tokens: Tensor::randn(&mut rng, &[n, cfg.d_model], 1.0),
            task: None,
        });
        metrics.requests += 1;
        while batcher.ready(Instant::now()) {
            let batch = batcher.next_batch().unwrap();
            let (y, stats) = engine.forward_stack(&batch.tokens)?;
            metrics.batches += 1;
            metrics.merge_forward(&stats);
            for (rid, _out) in batch.scatter(&y) {
                latency.record(inflight.remove(&rid).unwrap().elapsed());
            }
        }
    }
    while let Some(batch) = batcher.next_batch() {
        let (y, stats) = engine.forward_stack(&batch.tokens)?;
        metrics.batches += 1;
        metrics.merge_forward(&stats);
        for (rid, _out) in batch.scatter(&y) {
            latency.record(inflight.remove(&rid).unwrap().elapsed());
        }
    }

    println!("{}", metrics.report());
    println!(
        "latency p50 {:.2}ms  p95 {:.2}ms  mean {:.2}ms",
        latency.quantile(0.5) * 1e3,
        latency.quantile(0.95) * 1e3,
        latency.mean() * 1e3
    );
    assert!(inflight.is_empty(), "all requests answered");
    Ok(())
}

//! Expert-behaviour analysis (Figures 4/5/6 in miniature): task-level load
//! distribution, token-level FFN activations, and the gating-residual
//! effect — all from the native engine in a few seconds.
//!
//!     cargo run --release --example expert_analysis

use moepp::bench::workload::task_streams;
use moepp::config::MoeConfig;
use moepp::coordinator::engine::MoeEngine;
use moepp::moe::weights::StackWeights;
use moepp::stats::{gating, load, token_level};
use moepp::tensor::Tensor;
use moepp::training::data::Corpus;
use moepp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = MoeConfig::preset("sm-8e");
    let mut engine = MoeEngine::native(cfg.clone(), 0);
    let mut rng = Rng::new(11);

    // --- Fig. 4: expert-load distribution per task ------------------------
    let tasks = task_streams(
        &mut rng,
        &["arc-easy", "arc-challenge", "sciq"],
        256,
        cfg.d_model,
    );
    let loads = load::task_level_load(&mut engine, &tasks)?;
    println!("{}", load::render_layer_report(&cfg, &loads, 0));

    // --- Fig. 5: FFN activations per token by frequency -------------------
    let w = StackWeights::init(0, &cfg);
    let corpus = Corpus::new(cfg.vocab_size, 4, 1234);
    let embed = Tensor::randn(&mut rng, &[cfg.vocab_size, cfg.d_model], 1.0);
    let seqs: Vec<Vec<i32>> =
        (0..32).map(|i| corpus.sample(i % 4, 64, &mut rng)).collect();
    let acts = token_level::token_level_activations(&w, &cfg, &embed, &seqs)?;
    let rows = acts.rows();
    println!("top-frequency tokens (token, freq, mean FFN/layer):");
    for (tok, freq, mean) in rows.iter().take(8) {
        println!("  {tok:>4} {freq:>5} {mean:.3}");
    }

    // --- Fig. 6: gating residuals stabilise routing -----------------------
    let x = Tensor::randn(&mut rng, &[256, cfg.d_model], 1.0);
    let with = gating::trace(&w, &cfg, &x, true)?;
    let without = gating::trace(&w, &cfg, &x, false)?;
    println!(
        "\ngating residuals: mean top-1 routing variance {:.5} (w/) vs \
         {:.5} (w/o)",
        gating::mean_top1_variance(&with),
        gating::mean_top1_variance(&without)
    );
    Ok(())
}

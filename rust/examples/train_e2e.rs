//! End-to-end validation (DESIGN.md): pretrain the `e2e` MoE++ LM (~29M
//! params: 6 layers, d=256, 8 FFN + 4 ZC experts, vocab 2048) for a few
//! hundred steps on the synthetic Markov corpus, entirely through the
//! three-layer stack:
//!
//!   L1 Pallas kernels -> L2 jax train_step -> AOT HLO text ->
//!   L3 rust trainer via PJRT.
//!
//! Logs the loss curve to reports/e2e_loss.csv and records the run in
//! EXPERIMENTS.md. Proves all layers compose: the lowered artifact embeds
//! the Pallas expert kernels, the heterogeneous capacity/balance logic and
//! AdamW, and the Rust side drives data, scheduling and checkpointing.
//!
//!     make artifacts && cargo run --release --example train_e2e -- \
//!         [--steps 200] [--tag e2e_moepp] [--baseline]

use anyhow::Context;
use moepp::runtime::Runtime;
use moepp::training::checkpoint;
use moepp::training::data::Corpus;
use moepp::training::trainer::Trainer;
use moepp::util::cli::Args;
use moepp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.get_usize("steps", 200);
    let tag = args.get_or(
        "tag",
        if args.has("baseline") { "e2e_vanilla" } else { "e2e_moepp" },
    );
    let rt = Runtime::open("artifacts")
        .context("run `make artifacts` first")?;
    let cfg = rt
        .manifest
        .configs
        .get(tag)
        .with_context(|| format!("no config '{tag}' in manifest"))?
        .clone();
    println!(
        "e2e training: {tag} — {} layers, d={}, {}+{} experts, vocab {}",
        cfg.n_layers, cfg.d_model, cfg.n_ffn_experts, cfg.n_zc(),
        cfg.vocab_size
    );

    let mut trainer = Trainer::new(&rt, tag, 0)?;
    let corpus = Corpus::new(cfg.vocab_size, 4, 1234);
    let mut rng = Rng::new(42);
    let t0 = std::time::Instant::now();
    let history = trainer.train(&corpus, steps, &mut rng, 10)?;
    let wall = t0.elapsed().as_secs_f64();

    // Held-out evaluation.
    let mut eval_rng = Rng::new(0xE7A1);
    let (ce, ppl) = trainer.eval(&corpus, 8, &mut eval_rng)?;

    // Loss curve CSV.
    std::fs::create_dir_all("reports")?;
    let mut csv = String::from("step,loss,ce,balance,ffn_per_token,drop\n");
    for (i, m) in history.iter().enumerate() {
        csv.push_str(&format!(
            "{i},{:.6},{:.6},{:.6},{:.4},{:.1}\n",
            m.loss, m.ce, m.balance, m.ffn_per_token, m.dropped
        ));
    }
    let csv_path = format!("reports/e2e_loss_{tag}.csv");
    std::fs::write(&csv_path, csv)?;
    checkpoint::save(
        std::path::Path::new(&format!("reports/e2e_{tag}.ckpt")),
        trainer.params(),
    )?;

    let first = history.first().unwrap();
    let last10: Vec<f64> = history
        .iter()
        .rev()
        .take(10)
        .map(|m| m.loss)
        .collect();
    let final_loss = last10.iter().sum::<f64>() / last10.len() as f64;
    println!(
        "\n{} steps in {:.1}s ({:.2}s/step)\n\
         loss {:.4} -> {:.4} (mean of last 10)\n\
         held-out ce {:.4}  ppl {:.2}\n\
         mean FFN/token {:.2} (top-{} routing)\n\
         loss curve -> {csv_path}",
        steps,
        wall,
        wall / steps as f64,
        first.loss,
        final_loss,
        ce,
        ppl,
        history.iter().map(|m| m.ffn_per_token).sum::<f64>()
            / history.len() as f64,
        cfg.top_k,
    );
    anyhow::ensure!(
        final_loss < first.loss,
        "training must reduce loss ({:.4} -> {final_loss:.4})",
        first.loss
    );
    Ok(())
}

//! Quickstart: build a MoE++ engine, route a token batch, inspect how the
//! zero-computation experts change the work profile vs vanilla MoE.
//!
//!     cargo run --release --example quickstart

use moepp::config::MoeConfig;
use moepp::coordinator::engine::MoeEngine;
use moepp::moe::complexity;
use moepp::tensor::Tensor;
use moepp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Pick the scaled twin of the paper's "MoE++ 0.6B/(8+4)E" (Table 2):
    //    8 FFN experts + 1 zero + 1 copy + 2 constant, top-2, tau = 0.75.
    let cfg = MoeConfig::preset("sm-8e");
    println!(
        "MoE++ {}: {} FFN + {} ZC experts, top-{} routing, tau={}",
        cfg.name, cfg.n_ffn_experts, cfg.n_zc(), cfg.top_k, cfg.tau
    );

    // 2. Build the serving engine (native expert backend) and its vanilla
    //    twin at the same parameter count.
    let mut moepp = MoeEngine::native(cfg.clone(), 0);
    let mut vanilla =
        MoeEngine::native(MoeConfig::preset("sm-8e:vanilla"), 0);

    // 3. Push one batch of 256 tokens through the full MoE layer stack.
    let mut rng = Rng::new(42);
    let x = Tensor::randn(&mut rng, &[256, cfg.d_model], 1.0);
    let (_y, stats) = moepp.forward_stack(&x)?;
    let (_yv, vstats) = vanilla.forward_stack(&x)?;

    // 4. The paper's mechanism, visible in one forward:
    println!("\n                      MoE++     vanilla MoE");
    println!(
        "FFN experts/token    {:6.2}      {:6.2}   (lower = less compute)",
        stats.mean_ffn_per_token(),
        vstats.mean_ffn_per_token()
    );
    println!(
        "expert forward       {:6.2}ms    {:6.2}ms",
        stats.expert_forward_s * 1e3,
        vstats.expert_forward_s * 1e3
    );
    println!(
        "expert throughput    {:6.0}      {:6.0}   tokens/s",
        stats.expert_throughput(),
        vstats.expert_throughput()
    );
    println!(
        "\nTable-1 complexity model predicts MoE++ needs {:.1}% of vanilla \
         FFN compute;\nmeasured time ratio here: {:.1}%",
        complexity::complexity_ratio(&cfg, 256) * 100.0,
        stats.expert_forward_s / vstats.expert_forward_s * 100.0
    );
    println!(
        "\nper-layer drop counts (heterogeneous capacity, Eq. 8): {:?}",
        stats.per_layer.iter().map(|l| l.dropped).collect::<Vec<_>>()
    );
    Ok(())
}

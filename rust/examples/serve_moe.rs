//! Serving example: the `moepp::serve` continuous-batching service API,
//! with the AOT-compiled Pallas expert kernel on the PJRT backend when
//! artifacts are present (falls back to the native backend otherwise).
//!
//!     make artifacts && cargo run --release --example serve_moe

use std::time::Duration;

use moepp::bench::workload::request_sizes;
use moepp::config::MoeConfig;
use moepp::coordinator::batcher::BatcherConfig;
use moepp::coordinator::engine::MoeEngine;
use moepp::runtime::Runtime;
use moepp::serve::{
    AdmissionError, MoeService, Priority, ServeRequest, ServiceConfig,
};
use moepp::tensor::Tensor;
use moepp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = MoeConfig::preset("test");
    // Prefer the PJRT backend (AOT Pallas kernel) when artifacts exist.
    let engine = match Runtime::open("artifacts") {
        Ok(rt) => {
            println!("backend: PJRT (AOT Pallas expert kernel)");
            MoeEngine::pjrt(cfg.clone(), 0, std::sync::Arc::new(rt))?
        }
        Err(_) => {
            println!("backend: native (run `make artifacts` for PJRT)");
            MoeEngine::native(cfg.clone(), 0)
        }
    };

    let service = MoeService::start(
        engine,
        ServiceConfig {
            batcher: BatcherConfig {
                max_tokens: 128,
                max_wait: Duration::from_millis(2),
            },
            // A small admission window so the trace actually exercises
            // backpressure: rejected submits wait for a completion.
            max_queued_tokens: 512,
            max_pending_requests: 64,
            default_deadline: None,
            obs: None,
        },
    );

    // A trace of 300 requests: mostly short decode-like, some long
    // prefill-like (see bench::workload). Every 4th request is tagged
    // interactive so it is batched ahead of contending standard traffic.
    let mut rng = Rng::new(1);
    let mut handles = Vec::new();
    let mut backpressure = 0u64;
    let mut total_ffn = 0u64;
    let mut total_zc = 0u64;
    let mut answered = 0usize;
    for (id, n) in request_sizes(&mut rng, 300, cfg.seq_len)
        .into_iter()
        .enumerate()
    {
        let priority = if id % 4 == 0 {
            Priority::Interactive
        } else {
            Priority::Standard
        };
        let req = ServeRequest::new(Tensor::randn(
            &mut rng,
            &[n, cfg.d_model],
            1.0,
        ))
        .with_priority(priority);
        let handle = loop {
            match service.submit(req.clone()) {
                Ok(h) => break h,
                Err(AdmissionError::QueueFull { .. })
                | Err(AdmissionError::TooManyPending { .. }) => {
                    // Backpressure: absorb a completion, then retry.
                    backpressure += 1;
                    let resp = handles
                        .remove(0)
                        .wait()
                        .expect("request completes");
                    assert_eq!(resp.output.shape[1], cfg.d_model);
                    total_ffn += resp.stats.counts.ffn;
                    total_zc += resp.stats.counts.zc();
                    answered += 1;
                }
                Err(e) => anyhow::bail!("admission error: {e}"),
            }
        };
        handles.push(handle);
    }

    // Drain the rest; every handle resolves with output + its own stats.
    for h in handles {
        let resp = h.wait().expect("request completes");
        total_ffn += resp.stats.counts.ffn;
        total_zc += resp.stats.counts.zc();
        answered += 1;
    }

    let latency = service.latency();
    let metrics = service.shutdown();
    println!("{}", metrics.report());
    println!(
        "latency p50 {:.2}ms  p95 {:.2}ms  mean {:.2}ms",
        latency.quantile(0.5) * 1e3,
        latency.quantile(0.95) * 1e3,
        latency.mean() * 1e3
    );
    println!(
        "per-request accounting: {answered} answered, ffn {total_ffn} \
         zc {total_zc} (backpressure retries {backpressure})"
    );
    // Per-request slices must reconcile with the batch-level totals.
    assert_eq!(total_ffn, metrics.ffn_assignments);
    assert_eq!(total_zc, metrics.zc_assignments);
    Ok(())
}

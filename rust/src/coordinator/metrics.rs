//! Serving metrics: latency/throughput accounting with streaming quantiles
//! (reservoir-free P² is overkill here — we keep a bounded sorted sample).

use std::time::Duration;

/// Bounded latency recorder with exact quantiles over the retained window.
#[derive(Clone, Debug)]
pub struct LatencyStats {
    samples: Vec<f64>, // seconds
    cap: usize,
    pub count: u64,
    pub total_s: f64,
}

impl LatencyStats {
    pub fn new(cap: usize) -> LatencyStats {
        LatencyStats { samples: Vec::new(), cap, count: 0, total_s: 0.0 }
    }

    pub fn record(&mut self, d: Duration) {
        let s = d.as_secs_f64();
        self.count += 1;
        self.total_s += s;
        if self.samples.len() == self.cap {
            // Overwrite pseudo-randomly (deterministic stride) to keep a
            // spread-out window without an RNG dependency.
            let idx = (self.count as usize * 7919) % self.cap;
            self.samples[idx] = s;
        } else {
            self.samples.push(s);
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_s / self.count as f64
        }
    }

    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() - 1) as f64 * q).round() as usize;
        v[idx]
    }
}

/// Aggregate serving counters.
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub dropped_assignments: u64,
    pub ffn_assignments: u64,
    pub zc_assignments: u64,
    pub expert_forward_s: f64,
    pub routing_s: f64,
}

impl ServingMetrics {
    pub fn merge_forward(&mut self,
                         stats: &crate::coordinator::engine::ForwardStats) {
        self.tokens += stats.tokens as u64;
        self.expert_forward_s += stats.expert_forward_s;
        self.routing_s += stats.routing_s;
        for l in &stats.per_layer {
            self.dropped_assignments += l.dropped as u64;
            self.ffn_assignments += l.ffn_assignments as u64;
            self.zc_assignments += l.zc_assignments as u64;
        }
    }

    pub fn expert_throughput(&self) -> f64 {
        self.tokens as f64 / self.expert_forward_s.max(1e-12)
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} batches={} tokens={} expert_tput={:.0} tok/s \
             ffn={} zc={} dropped={} (drop rate {:.3}%)",
            self.requests,
            self.batches,
            self.tokens,
            self.expert_throughput(),
            self.ffn_assignments,
            self.zc_assignments,
            self.dropped_assignments,
            100.0 * self.dropped_assignments as f64
                / (self.ffn_assignments + self.zc_assignments
                    + self.dropped_assignments)
                    .max(1) as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_quantiles() {
        let mut l = LatencyStats::new(1000);
        for i in 1..=100 {
            l.record(Duration::from_millis(i));
        }
        assert_eq!(l.count, 100);
        assert!((l.mean() - 0.0505).abs() < 1e-3);
        assert!((l.quantile(0.5) - 0.050).abs() < 0.003);
        assert!(l.quantile(0.99) >= 0.098);
    }

    #[test]
    fn bounded_window() {
        let mut l = LatencyStats::new(10);
        for i in 0..1000 {
            l.record(Duration::from_micros(i));
        }
        assert_eq!(l.count, 1000);
        assert_eq!(l.samples.len(), 10);
    }

    #[test]
    fn metrics_report_smoke() {
        let m = ServingMetrics { tokens: 100, expert_forward_s: 0.5,
                                 ..Default::default() };
        assert_eq!(m.expert_throughput(), 200.0);
        assert!(m.report().contains("tokens=100"));
    }
}

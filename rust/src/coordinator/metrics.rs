//! Serving metrics: latency/throughput accounting with exact quantiles
//! over a bounded sliding window, plus the service-level counters the
//! `moepp::serve` scheduler maintains (queue depth, admission rejects,
//! time-to-first-batch).

use std::collections::VecDeque;
use std::time::Duration;

/// Bounded latency recorder with exact quantiles over the retained window.
///
/// The window is a FIFO over the most recent `cap` samples; a parallel
/// buffer holds the same multiset *kept sorted on insert* (binary-search
/// insert/remove), so quantile reads never allocate or sort — they index
/// straight into the sorted buffer with nearest-rank interpolation.
#[derive(Clone, Debug)]
pub struct LatencyStats {
    /// Insertion-order window (seconds), bounded by `cap` — eviction order.
    window: VecDeque<f64>,
    /// The same samples, kept sorted at all times.
    sorted: Vec<f64>,
    cap: usize,
    pub count: u64,
    pub total_s: f64,
}

impl LatencyStats {
    pub fn new(cap: usize) -> LatencyStats {
        LatencyStats {
            window: VecDeque::new(),
            sorted: Vec::new(),
            cap: cap.max(1),
            count: 0,
            total_s: 0.0,
        }
    }

    pub fn record(&mut self, d: Duration) {
        let s = d.as_secs_f64();
        self.count += 1;
        self.total_s += s;
        if self.window.len() == self.cap {
            // Slide: evict the oldest sample from both structures. The
            // evicted value is bit-identical to what was inserted, so the
            // binary search lands on an exact match.
            let old = self.window.pop_front().unwrap();
            let at = self.sorted.partition_point(|&x| x < old);
            debug_assert!(self.sorted[at] == old);
            self.sorted.remove(at);
        }
        self.window.push_back(s);
        let at = self.sorted.partition_point(|&x| x < s);
        self.sorted.insert(at, s);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_s / self.count as f64
        }
    }

    /// Number of samples currently retained (≤ cap).
    pub fn window_len(&self) -> usize {
        self.window.len()
    }

    /// Quantile over the retained window, nearest-rank with linear
    /// interpolation between adjacent order statistics. O(1) — the window
    /// is maintained sorted on insert.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let pos = q.clamp(0.0, 1.0) * (self.sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.sorted[lo] + (self.sorted[hi] - self.sorted[lo]) * frac
    }
}

/// Aggregate serving counters. The forward-path fields are merged from
/// [`ForwardStats`]; the queue-path fields (rejects, cancels, queue depth,
/// time-to-first-batch) are maintained by the `moepp::serve` scheduler.
///
/// [`ForwardStats`]: crate::coordinator::engine::ForwardStats
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub dropped_assignments: u64,
    pub ffn_assignments: u64,
    pub zc_assignments: u64,
    pub expert_forward_s: f64,
    pub routing_s: f64,
    /// Submissions bounced by admission control (backpressure).
    pub rejected: u64,
    /// Requests cancelled by their caller before execution.
    pub cancelled: u64,
    /// Requests whose queue deadline passed before they reached a batch.
    pub expired: u64,
    /// Requests failed by a backend error.
    pub failed: u64,
    /// Peak queued tokens observed (admission queue + batcher).
    pub peak_queue_tokens: u64,
    /// Seconds from service start to the first batch hitting the backend
    /// (0 until a batch executes).
    pub time_to_first_batch_s: f64,
    /// Placement replans the backend applied between batches (cluster
    /// backends with an online `placement::Replanner`; 0 elsewhere).
    pub replans: u64,
}

impl ServingMetrics {
    pub fn merge_forward(&mut self,
                         stats: &crate::coordinator::engine::ForwardStats) {
        self.tokens += stats.tokens as u64;
        self.expert_forward_s += stats.expert_forward_s;
        self.routing_s += stats.routing_s;
        for l in &stats.per_layer {
            self.dropped_assignments += l.dropped as u64;
            self.ffn_assignments += l.ffn_assignments as u64;
            self.zc_assignments += l.zc_assignments as u64;
        }
    }

    pub fn expert_throughput(&self) -> f64 {
        self.tokens as f64 / self.expert_forward_s.max(1e-12)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} batches={} tokens={} expert_tput={:.0} tok/s \
             ffn={} zc={} dropped={} (drop rate {:.3}%)",
            self.requests,
            self.batches,
            self.tokens,
            self.expert_throughput(),
            self.ffn_assignments,
            self.zc_assignments,
            self.dropped_assignments,
            100.0 * self.dropped_assignments as f64
                / (self.ffn_assignments + self.zc_assignments
                    + self.dropped_assignments)
                    .max(1) as f64,
        );
        s.push_str(&format!(
            "\nadmission: rejected={} cancelled={} expired={} failed={} \
             peak_queue={} tok  first_batch={:.2}ms",
            self.rejected,
            self.cancelled,
            self.expired,
            self.failed,
            self.peak_queue_tokens,
            self.time_to_first_batch_s * 1e3,
        ));
        if self.replans > 0 {
            s.push_str(&format!("\nplacement: replans={}", self.replans));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_quantiles() {
        let mut l = LatencyStats::new(1000);
        for i in 1..=100 {
            l.record(Duration::from_millis(i));
        }
        assert_eq!(l.count, 100);
        assert!((l.mean() - 0.0505).abs() < 1e-3);
        assert!((l.quantile(0.5) - 0.0505).abs() < 1e-9);
        assert!(l.quantile(0.99) >= 0.098);
        assert_eq!(l.quantile(0.0), 0.001);
        assert_eq!(l.quantile(1.0), 0.100);
    }

    #[test]
    fn quantile_interpolates_between_ranks() {
        let mut l = LatencyStats::new(16);
        l.record(Duration::from_secs(1));
        l.record(Duration::from_secs(3));
        // Midpoint of the two order statistics.
        assert!((l.quantile(0.5) - 2.0).abs() < 1e-12);
        assert!((l.quantile(0.25) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn bounded_window_slides_fifo() {
        let mut l = LatencyStats::new(10);
        for i in 0..1000 {
            l.record(Duration::from_micros(i));
        }
        assert_eq!(l.count, 1000);
        assert_eq!(l.window_len(), 10);
        // Only the most recent 10 samples (990..=999 µs) remain.
        assert!((l.quantile(0.0) - 990e-6).abs() < 1e-12);
        assert!((l.quantile(1.0) - 999e-6).abs() < 1e-12);
        // Sorted invariant holds after heavy sliding.
        assert!(l.sorted.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(l.sorted.len(), l.window.len());
    }

    #[test]
    fn duplicate_samples_evict_cleanly() {
        let mut l = LatencyStats::new(4);
        for _ in 0..3 {
            l.record(Duration::from_millis(5));
        }
        for _ in 0..6 {
            l.record(Duration::from_millis(7));
        }
        assert_eq!(l.window_len(), 4);
        assert_eq!(l.quantile(0.0), 0.007);
        assert_eq!(l.quantile(1.0), 0.007);
    }

    #[test]
    fn metrics_report_smoke() {
        let m = ServingMetrics { tokens: 100, expert_forward_s: 0.5,
                                 rejected: 3, ..Default::default() };
        assert_eq!(m.expert_throughput(), 200.0);
        assert!(m.report().contains("tokens=100"));
        assert!(m.report().contains("rejected=3"));
    }
}

//! Serving metrics: latency/throughput accounting with exact quantiles
//! over a bounded sliding window, plus the service-level counters the
//! `moepp::serve` scheduler maintains (queue depth, admission rejects,
//! time-to-first-batch).

use std::collections::VecDeque;
use std::time::Duration;

/// Bounded latency recorder with exact quantiles over the retained window.
///
/// The window is a FIFO over the most recent `cap` samples; a parallel
/// buffer holds the same multiset *kept sorted on insert* (binary-search
/// insert/remove), so quantile reads never allocate or sort — they index
/// straight into the sorted buffer with nearest-rank interpolation.
#[derive(Clone, Debug)]
pub struct LatencyStats {
    /// Insertion-order window (seconds), bounded by `cap` — eviction order.
    window: VecDeque<f64>,
    /// The same samples, kept sorted at all times.
    sorted: Vec<f64>,
    cap: usize,
    pub count: u64,
    pub total_s: f64,
    /// Requests resubmitted after a `WorkerLost` batch failure
    /// (DESIGN.md §16). Their eventual service time — recorded on the
    /// retry's delivery — spans both attempts, so this count explains
    /// retry-shaped tail latency in the same snapshot.
    pub retried: u64,
    /// Delivered requests that rode a batch with degraded (copy-expert
    /// fallback) tokens.
    pub degraded: u64,
}

impl LatencyStats {
    pub fn new(cap: usize) -> LatencyStats {
        LatencyStats {
            window: VecDeque::new(),
            sorted: Vec::new(),
            cap: cap.max(1),
            count: 0,
            total_s: 0.0,
            retried: 0,
            degraded: 0,
        }
    }

    pub fn record(&mut self, d: Duration) {
        let s = d.as_secs_f64();
        self.count += 1;
        self.total_s += s;
        if self.window.len() == self.cap {
            // Slide: evict the oldest sample from both structures. The
            // evicted value is bit-identical to what was inserted, so the
            // binary search lands on an exact match.
            let old = self.window.pop_front().unwrap();
            let at = self.sorted.partition_point(|&x| x < old);
            debug_assert!(self.sorted[at] == old);
            self.sorted.remove(at);
        }
        self.window.push_back(s);
        let at = self.sorted.partition_point(|&x| x < s);
        self.sorted.insert(at, s);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_s / self.count as f64
        }
    }

    /// Number of samples currently retained (≤ cap).
    pub fn window_len(&self) -> usize {
        self.window.len()
    }

    /// Quantile over the retained window, nearest-rank with linear
    /// interpolation between adjacent order statistics. O(1) — the window
    /// is maintained sorted on insert.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let pos = q.clamp(0.0, 1.0) * (self.sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.sorted[lo] + (self.sorted[hi] - self.sorted[lo]) * frac
    }
}

/// Aggregate serving counters. The forward-path fields are merged from
/// [`ForwardStats`]; the queue-path fields (rejects, cancels, queue depth,
/// time-to-first-batch) are maintained by the `moepp::serve` scheduler.
///
/// [`ForwardStats`]: crate::coordinator::engine::ForwardStats
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub dropped_assignments: u64,
    pub ffn_assignments: u64,
    pub zc_assignments: u64,
    pub expert_forward_s: f64,
    pub routing_s: f64,
    /// Submissions bounced by admission control (backpressure).
    pub rejected: u64,
    /// Requests cancelled by their caller before execution.
    pub cancelled: u64,
    /// Requests whose queue deadline passed before they reached a batch.
    pub expired: u64,
    /// Requests failed by a backend error.
    pub failed: u64,
    /// Peak queued tokens observed (admission queue + batcher).
    pub peak_queue_tokens: u64,
    /// Seconds from service start to the first batch hitting the backend
    /// (0 until a batch executes).
    pub time_to_first_batch_s: f64,
    /// Placement replans the backend applied between batches (cluster
    /// backends with an online `placement::Replanner`; 0 elsewhere).
    pub replans: u64,
    /// Requests resubmitted exactly once after their batch was lost to a
    /// worker fault (DESIGN.md §16).
    pub retried: u64,
    /// Delivered requests that rode a degraded batch (some expert had no
    /// surviving replica; its tokens fell back to copy-expert outputs).
    pub degraded: u64,
}

impl ServingMetrics {
    pub fn merge_forward(&mut self,
                         stats: &crate::coordinator::engine::ForwardStats) {
        self.tokens += stats.tokens as u64;
        self.expert_forward_s += stats.expert_forward_s;
        self.routing_s += stats.routing_s;
        for l in &stats.per_layer {
            self.dropped_assignments += l.dropped as u64;
            self.ffn_assignments += l.ffn_assignments as u64;
            self.zc_assignments += l.zc_assignments as u64;
        }
    }

    pub fn expert_throughput(&self) -> f64 {
        self.tokens as f64 / self.expert_forward_s.max(1e-12)
    }

    /// Rebuild the metrics purely from an observability registry
    /// (DESIGN.md §15). The serving layer mirrors every counter update
    /// into the registry at the same site it updates the lock-guarded
    /// struct, so at quiescence every integer field here is `==` to its
    /// [`ServingMetrics`] twin; the float second fields are derived from
    /// the integer-nanosecond counters (`_ns / 1e9`), exact to the
    /// per-batch truncation of the cast.
    pub fn from_registry(obs: &crate::obs::Obs) -> ServingMetrics {
        let r = obs.registry();
        let h = &obs.h;
        ServingMetrics {
            requests: r.counter_value(h.requests),
            batches: r.counter_value(h.batches),
            tokens: r.counter_value(h.tokens),
            dropped_assignments: r.counter_value(h.dropped_assignments),
            ffn_assignments: r.counter_value(h.ffn_assignments),
            zc_assignments: r.counter_value(h.zc_assignments),
            expert_forward_s: r.counter_value(h.expert_forward_ns)
                as f64
                / 1e9,
            routing_s: r.counter_value(h.routing_ns) as f64 / 1e9,
            rejected: r.counter_value(h.rejected),
            cancelled: r.counter_value(h.cancelled),
            expired: r.counter_value(h.expired),
            failed: r.counter_value(h.failed),
            peak_queue_tokens: r.gauge_value(h.peak_queue_tokens),
            time_to_first_batch_s: r
                .gauge_value(h.time_to_first_batch_ns)
                as f64
                / 1e9,
            replans: r.counter_value(h.replans),
            retried: r.counter_value(h.retried),
            degraded: r.counter_value(h.degraded_requests),
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} batches={} tokens={} expert_tput={:.0} tok/s \
             ffn={} zc={} dropped={} (drop rate {:.3}%)",
            self.requests,
            self.batches,
            self.tokens,
            self.expert_throughput(),
            self.ffn_assignments,
            self.zc_assignments,
            self.dropped_assignments,
            100.0 * self.dropped_assignments as f64
                / (self.ffn_assignments + self.zc_assignments
                    + self.dropped_assignments)
                    .max(1) as f64,
        );
        s.push_str(&format!(
            "\nadmission: rejected={} cancelled={} expired={} failed={} \
             peak_queue={} tok  first_batch={:.2}ms",
            self.rejected,
            self.cancelled,
            self.expired,
            self.failed,
            self.peak_queue_tokens,
            self.time_to_first_batch_s * 1e3,
        ));
        if self.replans > 0 {
            s.push_str(&format!("\nplacement: replans={}", self.replans));
        }
        if self.retried > 0 || self.degraded > 0 {
            s.push_str(&format!(
                "\nfaults: retried={} degraded={}",
                self.retried, self.degraded
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_quantiles() {
        let mut l = LatencyStats::new(1000);
        for i in 1..=100 {
            l.record(Duration::from_millis(i));
        }
        assert_eq!(l.count, 100);
        assert!((l.mean() - 0.0505).abs() < 1e-3);
        assert!((l.quantile(0.5) - 0.0505).abs() < 1e-9);
        assert!(l.quantile(0.99) >= 0.098);
        assert_eq!(l.quantile(0.0), 0.001);
        assert_eq!(l.quantile(1.0), 0.100);
    }

    #[test]
    fn quantile_interpolates_between_ranks() {
        let mut l = LatencyStats::new(16);
        l.record(Duration::from_secs(1));
        l.record(Duration::from_secs(3));
        // Midpoint of the two order statistics.
        assert!((l.quantile(0.5) - 2.0).abs() < 1e-12);
        assert!((l.quantile(0.25) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn bounded_window_slides_fifo() {
        let mut l = LatencyStats::new(10);
        for i in 0..1000 {
            l.record(Duration::from_micros(i));
        }
        assert_eq!(l.count, 1000);
        assert_eq!(l.window_len(), 10);
        // Only the most recent 10 samples (990..=999 µs) remain.
        assert!((l.quantile(0.0) - 990e-6).abs() < 1e-12);
        assert!((l.quantile(1.0) - 999e-6).abs() < 1e-12);
        // Sorted invariant holds after heavy sliding.
        assert!(l.sorted.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(l.sorted.len(), l.window.len());
    }

    #[test]
    fn duplicate_samples_evict_cleanly() {
        let mut l = LatencyStats::new(4);
        for _ in 0..3 {
            l.record(Duration::from_millis(5));
        }
        for _ in 0..6 {
            l.record(Duration::from_millis(7));
        }
        assert_eq!(l.window_len(), 4);
        assert_eq!(l.quantile(0.0), 0.007);
        assert_eq!(l.quantile(1.0), 0.007);
    }

    #[test]
    fn metrics_report_smoke() {
        let m = ServingMetrics { tokens: 100, expert_forward_s: 0.5,
                                 rejected: 3, ..Default::default() };
        assert_eq!(m.expert_throughput(), 200.0);
        assert!(m.report().contains("tokens=100"));
        assert!(m.report().contains("rejected=3"));
    }

    fn fake_stats() -> crate::coordinator::engine::ForwardStats {
        let mut s = crate::coordinator::engine::ForwardStats::default();
        s.tokens = 6;
        s.expert_forward_s = 0.5;
        s.routing_s = 0.125;
        s.per_layer = vec![
            crate::moe::layer::LayerStats {
                expert_counts: Vec::new(),
                dropped: 1,
                ffn_assignments: 7,
                zc_assignments: 4,
                ffn_per_token: 0.0,
                balance_loss: 0.0,
            },
            crate::moe::layer::LayerStats {
                expert_counts: Vec::new(),
                dropped: 0,
                ffn_assignments: 3,
                zc_assignments: 9,
                ffn_per_token: 0.0,
                balance_loss: 0.0,
            },
        ];
        s
    }

    #[test]
    fn merge_forward_is_purely_additive_across_repeated_calls() {
        // Regression guard: merging the same batch stats twice must give
        // exactly double of one merge — no per-call double counting of
        // the per-layer walk, no hidden resets between calls.
        let stats = fake_stats();
        let mut once = ServingMetrics::default();
        once.merge_forward(&stats);
        assert_eq!(once.tokens, 6);
        assert_eq!(once.ffn_assignments, 10);
        assert_eq!(once.zc_assignments, 13);
        assert_eq!(once.dropped_assignments, 1);
        let mut twice = ServingMetrics::default();
        twice.merge_forward(&stats);
        twice.merge_forward(&stats);
        assert_eq!(twice.tokens, 2 * once.tokens);
        assert_eq!(twice.ffn_assignments, 2 * once.ffn_assignments);
        assert_eq!(twice.zc_assignments, 2 * once.zc_assignments);
        assert_eq!(
            twice.dropped_assignments,
            2 * once.dropped_assignments
        );
        assert_eq!(
            twice.expert_forward_s,
            2.0 * once.expert_forward_s
        );
        assert_eq!(twice.routing_s, 2.0 * once.routing_s);
    }

    #[test]
    fn time_to_first_batch_set_once_across_restartless_reuse() {
        // The service keeps serving batch after batch without restarting;
        // time_to_first_batch_s must latch at the first batch and stay
        // put (and never remain at its 0 default once a batch ran).
        use crate::config::MoeConfig;
        use crate::coordinator::engine::MoeEngine;
        use crate::serve::service::{MoeService, ServiceConfig};
        use crate::util::rng::Rng;
        let cfg = MoeConfig::preset("test");
        let service = MoeService::start(
            MoeEngine::native(cfg.clone(), 0),
            ServiceConfig {
                batcher: crate::coordinator::batcher::BatcherConfig {
                    max_tokens: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..ServiceConfig::default()
            },
        );
        let mut rng = Rng::new(11);
        let x = crate::tensor::Tensor::randn(
            &mut rng,
            &[4, cfg.d_model],
            1.0,
        );
        service.submit_tokens(x.clone()).unwrap().wait().unwrap();
        let first = service.metrics();
        assert!(first.batches >= 1);
        assert!(first.time_to_first_batch_s > 0.0);
        for _ in 0..3 {
            service.submit_tokens(x.clone()).unwrap().wait().unwrap();
        }
        let later = service.shutdown();
        assert!(later.batches > first.batches);
        assert_eq!(
            later.time_to_first_batch_s,
            first.time_to_first_batch_s,
            "later batches must not restamp time_to_first_batch_s"
        );
    }

    #[test]
    fn registry_rebuild_reconciles_exactly_with_serving_metrics() {
        // The PR 2 reconciliation discipline extended to the obs layer:
        // replay a small serve run with the bundle installed, then the
        // registry-rebuilt ServingMetrics must equal the lock-guarded
        // one field-for-field on every integer counter/gauge.
        use crate::config::MoeConfig;
        use crate::coordinator::engine::MoeEngine;
        use crate::obs::Obs;
        use crate::serve::service::{MoeService, ServiceConfig};
        use crate::util::rng::Rng;
        let obs = Obs::shared();
        obs.trace.set_enabled(true);
        let cfg = MoeConfig::preset("test");
        let service = MoeService::start(
            MoeEngine::native(cfg.clone(), 0),
            ServiceConfig {
                batcher: crate::coordinator::batcher::BatcherConfig {
                    max_tokens: 8,
                    max_wait: Duration::from_millis(1),
                },
                obs: Some(obs.clone()),
                ..ServiceConfig::default()
            },
        );
        let mut rng = Rng::new(12);
        for _ in 0..5 {
            let x = crate::tensor::Tensor::randn(
                &mut rng,
                &[4, cfg.d_model],
                1.0,
            );
            service.submit_tokens(x).unwrap().wait().unwrap();
        }
        let rebuilt = service.metrics_from_registry().unwrap();
        let m = service.shutdown();
        assert_eq!(rebuilt.requests, m.requests);
        let r = ServingMetrics::from_registry(&obs);
        assert_eq!(r.requests, m.requests);
        assert_eq!(r.batches, m.batches);
        assert_eq!(r.tokens, m.tokens);
        assert_eq!(r.ffn_assignments, m.ffn_assignments);
        assert_eq!(r.zc_assignments, m.zc_assignments);
        assert_eq!(r.dropped_assignments, m.dropped_assignments);
        assert_eq!(r.rejected, m.rejected);
        assert_eq!(r.cancelled, m.cancelled);
        assert_eq!(r.expired, m.expired);
        assert_eq!(r.failed, m.failed);
        assert_eq!(r.peak_queue_tokens, m.peak_queue_tokens);
        assert_eq!(r.replans, m.replans);
        assert_eq!(r.retried, m.retried);
        assert_eq!(r.degraded, m.degraded);
        // Float seconds come from the integer-ns twins: exact up to the
        // sub-nanosecond truncation of one cast per batch.
        let tol = 1e-9 * m.batches as f64 + 1e-12;
        assert!(
            (r.expert_forward_s - m.expert_forward_s).abs() <= tol,
            "expert_forward ns twin drifted: {} vs {}",
            r.expert_forward_s,
            m.expert_forward_s
        );
        assert!((r.routing_s - m.routing_s).abs() <= tol);
        assert!(
            (r.time_to_first_batch_s - m.time_to_first_batch_s).abs()
                <= 2e-9
        );
        assert!(r.time_to_first_batch_s > 0.0);
    }
}

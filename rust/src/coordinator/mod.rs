//! L3 serving coordinator — the paper's systems contribution.
//!
//! The public serving surface lives in [`crate::serve`]: `MoeService`
//! owns a scheduler thread that drives the pieces below as a continuous
//! batching loop (admission → batch → execute → scatter → complete,
//! DESIGN.md §9). The modules here are the mechanism, not the API —
//! driving [`batcher`] or the engine's `forward_stack` by hand for
//! serving is deprecated.
//!
//! The pipeline for a token batch entering the MoE++ stack:
//!
//! 1. [`batcher`] groups incoming requests into token batches;
//! 2. the pathway-aware router runs natively per layer (an [N, D] matvec —
//!    negligible, and it keeps routing on the coordinator so dispatch
//!    decisions precede any tensor movement);
//! 3. [`dispatch`] applies heterogeneous capacity (Eq. 8) and builds
//!    per-FFN-expert micro-batches;
//! 4. **zero-computation experts short-circuit inline** — zero is a no-op,
//!    copy a scaled add, constant a 2×D matvec — they never enter the FFN
//!    queue. This single property produces the paper's throughput gain
//!    (Table 3) and, in the distributed mapping (see [`crate::cluster`]),
//!    the elimination of their all-to-all traffic;
//! 5. FFN micro-batches execute on the chosen [`engine::Backend`]: the
//!    native Rust expert or the AOT-compiled Pallas kernel via PJRT,
//!    padded to the nearest compiled bucket;
//! 6. outputs are gate-weighted and combined (Eq. 1).

pub mod batcher;
pub mod dispatch;
pub mod engine;
pub mod metrics;

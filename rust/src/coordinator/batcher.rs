//! Dynamic request batcher: accumulate incoming requests into token batches
//! bounded by `max_tokens` and `max_wait`, vLLM-router-style.
//!
//! Requests carry token hidden-states (rows of D floats) plus an opaque id;
//! the batcher concatenates them, records the row spans, and hands batches
//! to the engine. Responses are scattered back per request.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::tensor::Tensor;

/// One serving request: a group of tokens entering the MoE stack.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// [n_tokens, d_model] hidden states.
    pub tokens: Tensor,
    /// Task tag for the load-distribution figures (Fig. 4).
    pub task: Option<String>,
}

/// A planned batch: concatenated tokens + per-request row spans.
#[derive(Debug)]
pub struct Batch {
    pub tokens: Tensor,
    pub spans: Vec<(u64, std::ops::Range<usize>)>,
}

impl Batch {
    pub fn n_tokens(&self) -> usize {
        self.tokens.shape[0]
    }

    /// Split a stacked result tensor back into per-request responses.
    pub fn scatter(&self, result: &Tensor) -> Vec<(u64, Tensor)> {
        let (_, d) = result.dims2();
        self.spans
            .iter()
            .map(|(id, span)| {
                let rows = span.len();
                let mut out = Tensor::zeros(&[rows, d]);
                out.data.copy_from_slice(
                    &result.data[span.start * d..span.end * d],
                );
                (*id, out)
            })
            .collect()
    }
}

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_tokens: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_tokens: 256,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// Deadline-or-size dynamic batcher.
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<(Request, Instant)>,
    queued_tokens: usize,
    d_model: usize,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig, d_model: usize) -> Batcher {
        Batcher { cfg, queue: VecDeque::new(), queued_tokens: 0, d_model }
    }

    pub fn push(&mut self, req: Request) {
        assert_eq!(req.tokens.shape[1], self.d_model, "d_model mismatch");
        self.queued_tokens += req.tokens.shape[0];
        self.queue.push_back((req, Instant::now()));
    }

    pub fn queued_tokens(&self) -> usize {
        self.queued_tokens
    }

    /// True if a batch should be emitted now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queued_tokens >= self.cfg.max_tokens
            || now.duration_since(self.queue[0].1) >= self.cfg.max_wait
    }

    /// Build the next batch (up to max_tokens; whole requests only, but a
    /// single oversized request becomes its own batch).
    pub fn next_batch(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let mut rows = 0usize;
        let mut members = Vec::new();
        while let Some((req, _)) = self.queue.front() {
            let n = req.tokens.shape[0];
            if !members.is_empty() && rows + n > self.cfg.max_tokens {
                break;
            }
            rows += n;
            members.push(self.queue.pop_front().unwrap().0);
            if rows >= self.cfg.max_tokens {
                break;
            }
        }
        self.queued_tokens -= rows;
        let mut tokens = Tensor::zeros(&[rows, self.d_model]);
        let mut spans = Vec::new();
        let mut at = 0;
        for req in members {
            let n = req.tokens.shape[0];
            tokens.data[at * self.d_model..(at + n) * self.d_model]
                .copy_from_slice(&req.tokens.data);
            spans.push((req.id, at..at + n));
            at += n;
        }
        Some(Batch { tokens, spans })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{gen, Prop};

    fn req(id: u64, n: usize, d: usize, fill: f32) -> Request {
        Request { id, tokens: Tensor::full(&[n, d], fill), task: None }
    }

    #[test]
    fn batches_whole_requests_up_to_max() {
        let mut b = Batcher::new(
            BatcherConfig { max_tokens: 10, max_wait: Duration::ZERO },
            4,
        );
        b.push(req(1, 4, 4, 1.0));
        b.push(req(2, 4, 4, 2.0));
        b.push(req(3, 4, 4, 3.0));
        let batch = b.next_batch().unwrap();
        // 4+4 fits, adding the third would exceed 10.
        assert_eq!(batch.n_tokens(), 8);
        assert_eq!(batch.spans.len(), 2);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.n_tokens(), 4);
        assert!(b.next_batch().is_none());
        assert_eq!(b.queued_tokens(), 0);
    }

    #[test]
    fn oversized_request_is_its_own_batch() {
        let mut b = Batcher::new(
            BatcherConfig { max_tokens: 8, max_wait: Duration::ZERO },
            2,
        );
        b.push(req(9, 20, 2, 1.0));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.n_tokens(), 20);
    }

    #[test]
    fn ready_honours_deadline_and_size() {
        let cfg = BatcherConfig {
            max_tokens: 100,
            max_wait: Duration::from_millis(50),
        };
        let mut b = Batcher::new(cfg, 2);
        assert!(!b.ready(Instant::now()));
        b.push(req(1, 10, 2, 0.0));
        let now = Instant::now();
        assert!(!b.ready(now)); // under size, under deadline
        assert!(b.ready(now + Duration::from_millis(60))); // deadline hit
        b.push(req(2, 95, 2, 0.0));
        assert!(b.ready(Instant::now())); // size hit
    }

    #[test]
    fn scatter_reverses_concatenation() {
        let mut b = Batcher::new(BatcherConfig::default(), 3);
        b.push(req(1, 2, 3, 1.0));
        b.push(req(2, 3, 3, 2.0));
        let batch = b.next_batch().unwrap();
        let out = batch.scatter(&batch.tokens);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, Tensor::full(&[2, 3], 1.0));
        assert_eq!(out[1].1, Tensor::full(&[3, 3], 2.0));
    }

    #[test]
    fn prop_no_token_lost_or_duplicated() {
        Prop::new("batcher-conservation").cases(40).run(
            |rng| {
                let n_reqs = gen::usize_in(rng, 1, 12);
                let sizes: Vec<usize> =
                    (0..n_reqs).map(|_| gen::usize_in(rng, 1, 30)).collect();
                let max_tokens = gen::usize_in(rng, 4, 64);
                (sizes, max_tokens)
            },
            |(sizes, max_tokens)| {
                let d = 2;
                let mut b = Batcher::new(
                    BatcherConfig {
                        max_tokens: *max_tokens,
                        max_wait: Duration::ZERO,
                    },
                    d,
                );
                for (i, &n) in sizes.iter().enumerate() {
                    b.push(req(i as u64, n, d, i as f32));
                }
                let mut seen = vec![0usize; sizes.len()];
                while let Some(batch) = b.next_batch() {
                    for (id, span) in &batch.spans {
                        seen[*id as usize] += span.len();
                        // Row content matches the request's fill value.
                        let row = batch.tokens.row(span.start);
                        if row[0] != *id as f32 {
                            return Err("row content mismatch".into());
                        }
                    }
                }
                if seen != *sizes {
                    return Err(format!("token counts: {seen:?} vs {sizes:?}"));
                }
                Ok(())
            },
        );
    }
}

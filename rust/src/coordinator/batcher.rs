//! Dynamic request batcher: accumulate incoming requests into token batches
//! bounded by `max_tokens` and `max_wait`, vLLM-router-style.
//!
//! Requests carry token hidden-states (rows of D floats) plus an opaque id;
//! the batcher concatenates them, records the row spans, and hands batches
//! to the engine. Responses are scattered back per request.
//!
//! **Deprecated as a public serving surface.** Driving this type by hand
//! (push → `ready()` → `next_batch()` → forward → `scatter`) is the old
//! lock-step serving loop; it cannot express concurrency, backpressure,
//! cancellation or per-request accounting. All serving now goes through
//! [`crate::serve::MoeService`] (DESIGN.md §9), which owns a `Batcher`
//! internally on its scheduler thread. Direct use is only appropriate
//! inside the serve scheduler and in tests of the batching policy itself.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::tensor::Tensor;

/// One serving request: a group of tokens entering the MoE stack.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// [n_tokens, d_model] hidden states.
    pub tokens: Tensor,
    /// Task tag for the load-distribution figures (Fig. 4).
    pub task: Option<String>,
}

/// A planned batch: concatenated tokens + per-request row spans.
#[derive(Debug)]
pub struct Batch {
    pub tokens: Tensor,
    pub spans: Vec<(u64, std::ops::Range<usize>)>,
}

impl Batch {
    pub fn n_tokens(&self) -> usize {
        self.tokens.shape[0]
    }

    /// Split a stacked result tensor back into per-request responses.
    pub fn scatter(&self, result: &Tensor) -> Vec<(u64, Tensor)> {
        let (_, d) = result.dims2();
        self.spans
            .iter()
            .map(|(id, span)| {
                let rows = span.len();
                let mut out = Tensor::zeros(&[rows, d]);
                out.data.copy_from_slice(
                    &result.data[span.start * d..span.end * d],
                );
                (*id, out)
            })
            .collect()
    }
}

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_tokens: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_tokens: 256,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// Deadline-or-size dynamic batcher.
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<(Request, Instant)>,
    queued_tokens: usize,
    d_model: usize,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig, d_model: usize) -> Batcher {
        Batcher { cfg, queue: VecDeque::new(), queued_tokens: 0, d_model }
    }

    pub fn push(&mut self, req: Request) {
        assert_eq!(req.tokens.shape[1], self.d_model, "d_model mismatch");
        self.queued_tokens += req.tokens.shape[0];
        self.queue.push_back((req, Instant::now()));
    }

    pub fn queued_tokens(&self) -> usize {
        self.queued_tokens
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Queued requests (not tokens).
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// The instant at which `ready` will turn true on the deadline rule
    /// (oldest entry + max_wait); `None` when the queue is empty. Lets a
    /// scheduler sleep exactly until the next flush is due.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.front().map(|(_, at)| *at + self.cfg.max_wait)
    }

    /// True if a batch should be emitted now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queued_tokens >= self.cfg.max_tokens
            || now.duration_since(self.queue[0].1) >= self.cfg.max_wait
    }

    /// Remove a queued request by id (serving-side cancellation: the
    /// request must never execute). Returns it if it was still queued.
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        let idx = self.queue.iter().position(|(r, _)| r.id == id)?;
        let (req, _) = self.queue.remove(idx).expect("index in range");
        self.queued_tokens -= req.tokens.shape[0];
        Some(req)
    }

    /// Build the next batch (up to max_tokens; whole requests only, but a
    /// single oversized request becomes its own batch).
    pub fn next_batch(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let mut rows = 0usize;
        let mut members = Vec::new();
        while let Some((req, _)) = self.queue.front() {
            let n = req.tokens.shape[0];
            if !members.is_empty() && rows + n > self.cfg.max_tokens {
                break;
            }
            rows += n;
            members.push(self.queue.pop_front().unwrap().0);
            if rows >= self.cfg.max_tokens {
                break;
            }
        }
        self.queued_tokens -= rows;
        let mut tokens = Tensor::zeros(&[rows, self.d_model]);
        let mut spans = Vec::new();
        let mut at = 0;
        for req in members {
            let n = req.tokens.shape[0];
            tokens.data[at * self.d_model..(at + n) * self.d_model]
                .copy_from_slice(&req.tokens.data);
            spans.push((req.id, at..at + n));
            at += n;
        }
        Some(Batch { tokens, spans })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{gen, Prop};

    fn req(id: u64, n: usize, d: usize, fill: f32) -> Request {
        Request { id, tokens: Tensor::full(&[n, d], fill), task: None }
    }

    #[test]
    fn batches_whole_requests_up_to_max() {
        let mut b = Batcher::new(
            BatcherConfig { max_tokens: 10, max_wait: Duration::ZERO },
            4,
        );
        b.push(req(1, 4, 4, 1.0));
        b.push(req(2, 4, 4, 2.0));
        b.push(req(3, 4, 4, 3.0));
        let batch = b.next_batch().unwrap();
        // 4+4 fits, adding the third would exceed 10.
        assert_eq!(batch.n_tokens(), 8);
        assert_eq!(batch.spans.len(), 2);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.n_tokens(), 4);
        assert!(b.next_batch().is_none());
        assert_eq!(b.queued_tokens(), 0);
    }

    #[test]
    fn oversized_request_is_its_own_batch() {
        let mut b = Batcher::new(
            BatcherConfig { max_tokens: 8, max_wait: Duration::ZERO },
            2,
        );
        b.push(req(9, 20, 2, 1.0));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.n_tokens(), 20);
    }

    #[test]
    fn ready_honours_deadline_and_size() {
        let cfg = BatcherConfig {
            max_tokens: 100,
            max_wait: Duration::from_millis(50),
        };
        let mut b = Batcher::new(cfg, 2);
        assert!(!b.ready(Instant::now()));
        b.push(req(1, 10, 2, 0.0));
        let now = Instant::now();
        assert!(!b.ready(now)); // under size, under deadline
        assert!(b.ready(now + Duration::from_millis(60))); // deadline hit
        b.push(req(2, 95, 2, 0.0));
        assert!(b.ready(Instant::now())); // size hit
    }

    #[test]
    fn queued_tokens_consistent_across_partial_flushes() {
        // Regression: the queued-token gauge must track exactly the sum of
        // queued request sizes through any interleaving of pushes and
        // partial flushes (the serve scheduler's backpressure reads it).
        let mut b = Batcher::new(
            BatcherConfig { max_tokens: 8, max_wait: Duration::ZERO },
            2,
        );
        let sizes = [3usize, 3, 5, 2, 9, 1, 4];
        let mut queued: Vec<usize> = Vec::new();
        for (i, &n) in sizes.iter().enumerate() {
            b.push(req(i as u64, n, 2, 0.0));
            queued.push(n);
            assert_eq!(b.queued_tokens(), queued.iter().sum::<usize>());
            if i % 2 == 1 {
                let batch = b.next_batch().unwrap();
                for _ in &batch.spans {
                    queued.remove(0);
                }
                assert_eq!(
                    b.queued_tokens(),
                    queued.iter().sum::<usize>(),
                    "after flush at push {i}"
                );
            }
        }
        while let Some(batch) = b.next_batch() {
            for _ in &batch.spans {
                queued.remove(0);
            }
            assert_eq!(b.queued_tokens(), queued.iter().sum::<usize>());
        }
        assert_eq!(b.queued_tokens(), 0);
        assert!(b.is_empty());
    }

    #[test]
    fn oversized_request_does_not_starve_followers() {
        // Regression: an oversized request becomes its own batch and the
        // requests queued behind it flush on the very next call — it must
        // not wedge the queue or absorb its followers.
        let mut b = Batcher::new(
            BatcherConfig { max_tokens: 8, max_wait: Duration::ZERO },
            2,
        );
        b.push(req(0, 20, 2, 0.0)); // oversized
        b.push(req(1, 2, 2, 1.0));
        b.push(req(2, 3, 2, 2.0));
        assert_eq!(b.queued_tokens(), 25);
        let first = b.next_batch().unwrap();
        assert_eq!(first.spans.len(), 1, "oversized rides alone");
        assert_eq!(first.spans[0].0, 0);
        assert_eq!(first.n_tokens(), 20);
        // Followers are immediately reachable, in order, and the batcher
        // still reports ready on the size/deadline rules for them.
        assert_eq!(b.queued_tokens(), 5);
        assert!(b.ready(Instant::now()), "followers must not be starved");
        let second = b.next_batch().unwrap();
        assert_eq!(
            second.spans.iter().map(|(id, _)| *id).collect::<Vec<_>>(),
            vec![1, 2]
        );
        assert_eq!(b.queued_tokens(), 0);
    }

    #[test]
    fn remove_pulls_request_out_of_queue() {
        let mut b = Batcher::new(
            BatcherConfig { max_tokens: 100, max_wait: Duration::ZERO },
            2,
        );
        b.push(req(1, 3, 2, 1.0));
        b.push(req(2, 5, 2, 2.0));
        b.push(req(3, 2, 2, 3.0));
        assert!(b.remove(9).is_none());
        let removed = b.remove(2).unwrap();
        assert_eq!(removed.tokens.shape, vec![5, 2]);
        assert_eq!(b.queued_tokens(), 5);
        let batch = b.next_batch().unwrap();
        assert_eq!(
            batch.spans.iter().map(|(id, _)| *id).collect::<Vec<_>>(),
            vec![1, 3],
            "removed request must not appear in any batch"
        );
        assert_eq!(b.queued_tokens(), 0);
    }

    #[test]
    fn next_deadline_tracks_oldest_entry() {
        let cfg = BatcherConfig {
            max_tokens: 100,
            max_wait: Duration::from_millis(10),
        };
        let mut b = Batcher::new(cfg, 2);
        assert!(b.next_deadline().is_none());
        b.push(req(1, 4, 2, 0.0));
        let dl = b.next_deadline().unwrap();
        assert!(!b.ready(dl - Duration::from_millis(1)));
        assert!(b.ready(dl));
        b.next_batch().unwrap();
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn scatter_reverses_concatenation() {
        let mut b = Batcher::new(BatcherConfig::default(), 3);
        b.push(req(1, 2, 3, 1.0));
        b.push(req(2, 3, 3, 2.0));
        let batch = b.next_batch().unwrap();
        let out = batch.scatter(&batch.tokens);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, Tensor::full(&[2, 3], 1.0));
        assert_eq!(out[1].1, Tensor::full(&[3, 3], 2.0));
    }

    #[test]
    fn prop_no_token_lost_or_duplicated() {
        Prop::new("batcher-conservation").cases(40).run(
            |rng| {
                let n_reqs = gen::usize_in(rng, 1, 12);
                let sizes: Vec<usize> =
                    (0..n_reqs).map(|_| gen::usize_in(rng, 1, 30)).collect();
                let max_tokens = gen::usize_in(rng, 4, 64);
                (sizes, max_tokens)
            },
            |(sizes, max_tokens)| {
                let d = 2;
                let mut b = Batcher::new(
                    BatcherConfig {
                        max_tokens: *max_tokens,
                        max_wait: Duration::ZERO,
                    },
                    d,
                );
                for (i, &n) in sizes.iter().enumerate() {
                    b.push(req(i as u64, n, d, i as f32));
                }
                let mut seen = vec![0usize; sizes.len()];
                while let Some(batch) = b.next_batch() {
                    for (id, span) in &batch.spans {
                        seen[*id as usize] += span.len();
                        // Row content matches the request's fill value.
                        let row = batch.tokens.row(span.start);
                        if row[0] != *id as f32 {
                            return Err("row content mismatch".into());
                        }
                    }
                }
                if seen != *sizes {
                    return Err(format!("token counts: {seen:?} vs {sizes:?}"));
                }
                Ok(())
            },
        );
    }
}

//! The MoE++ serving engine: route → dispatch → expert execution → combine
//! over a stack of MoE layers, with per-stage timing.
//!
//! Two interchangeable expert backends:
//!
//! * [`Backend::Native`] — the pure-Rust SwiGLU expert (moe::experts);
//! * [`Backend::Pjrt`]   — the AOT-compiled Pallas kernel executed via the
//!   PJRT runtime, with expert micro-batches padded to the nearest compiled
//!   bucket (weights are pre-converted to literals once at engine build).
//!
//! "Expert forward time" reported by [`ForwardStats`] is the paper's
//! footnote-1 metric: time spent in FFN experts + zero-computation experts,
//! excluding attention/embedding — the quantity Table 3 compares.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::dispatch::DispatchPlan;
use crate::config::{ExpertKind, MoeConfig};
use crate::moe::layer::LayerStats;
use crate::moe::router::route;
use crate::moe::weights::StackWeights;
use crate::runtime::host::HostValue;
use crate::runtime::{Executable, Runtime};
use crate::tensor::Tensor;

/// Expert execution backend.
pub enum Backend {
    /// Pure-Rust experts (always available).
    Native,
    /// AOT Pallas kernel via PJRT; holds pre-built weight literals per
    /// (layer, expert): [w1, w3, w2].
    Pjrt {
        runtime: Arc<Runtime>,
        preset: String,
        weight_literals: Vec<Vec<[xla::Literal; 3]>>,
        /// Cached executables keyed by bucket size.
        executables: std::collections::BTreeMap<usize, Arc<Executable>>,
    },
}

/// Aggregate timing + routing statistics for one stack forward.
#[derive(Clone, Debug, Default)]
pub struct ForwardStats {
    /// Wall-clock seconds inside the expert stage (FFN + ZC + combine).
    pub expert_forward_s: f64,
    /// Seconds inside FFN expert execution only.
    pub ffn_s: f64,
    /// Seconds inside zero-computation expert execution only.
    pub zc_s: f64,
    /// Seconds in routing (score matmul + top-k).
    pub routing_s: f64,
    pub per_layer: Vec<LayerStats>,
    pub tokens: usize,
}

impl ForwardStats {
    /// Expert-forward throughput (tokens/s), the Table 3 metric.
    pub fn expert_throughput(&self) -> f64 {
        self.tokens as f64 / self.expert_forward_s.max(1e-12)
    }

    pub fn mean_ffn_per_token(&self) -> f64 {
        if self.per_layer.is_empty() {
            return 0.0;
        }
        self.per_layer.iter().map(|s| s.ffn_per_token).sum::<f64>()
            / self.per_layer.len() as f64
    }

    pub fn total_dropped(&self) -> usize {
        self.per_layer.iter().map(|s| s.dropped).sum()
    }
}

/// The serving engine for one model variant.
pub struct MoeEngine {
    pub cfg: MoeConfig,
    /// Per-layer configs (tau may vary — Appendix A.2 layer-wise
    /// heterogeneity via `with_schedule`; uniform by default).
    pub layer_cfgs: Vec<MoeConfig>,
    pub weights: StackWeights,
    pub backend: Backend,
}

impl MoeEngine {
    pub fn native(cfg: MoeConfig, seed: u64) -> MoeEngine {
        let weights = StackWeights::init(seed, &cfg);
        let layer_cfgs = vec![cfg.clone(); cfg.n_layers];
        MoeEngine { cfg, layer_cfgs, weights, backend: Backend::Native }
    }

    /// Apply a per-layer tau schedule (paper Appendix A.2 future work).
    pub fn with_schedule(mut self,
                         schedule: &crate::moe::layerwise::LayerSchedule)
        -> MoeEngine {
        self.layer_cfgs = schedule.configs(&self.cfg);
        self
    }

    /// Build a PJRT-backed engine; compiles every FFN bucket up front so
    /// the request path never compiles.
    pub fn pjrt(cfg: MoeConfig, seed: u64, runtime: Arc<Runtime>)
        -> Result<MoeEngine> {
        let weights = StackWeights::init(seed, &cfg);
        let preset = cfg.name.clone();
        let mut weight_literals = Vec::new();
        for layer in &weights.layers {
            let mut per_expert = Vec::new();
            for e in &layer.ffn {
                per_expert.push([
                    HostValue::F32(e.w1.clone()).to_literal()?,
                    HostValue::F32(e.w3.clone()).to_literal()?,
                    HostValue::F32(e.w2.clone()).to_literal()?,
                ]);
            }
            weight_literals.push(per_expert);
        }
        let mut executables = std::collections::BTreeMap::new();
        for name in runtime.manifest.artifacts.keys() {
            if let Some(b) =
                name.strip_prefix(&format!("expert_ffn_{preset}_b"))
            {
                if let Ok(bucket) = b.parse::<usize>() {
                    executables.insert(bucket, runtime.load(name)?);
                }
            }
        }
        anyhow::ensure!(
            !executables.is_empty(),
            "no expert_ffn_{preset}_b* artifacts; run `make artifacts`"
        );
        let layer_cfgs = vec![cfg.clone(); cfg.n_layers];
        Ok(MoeEngine {
            cfg,
            layer_cfgs,
            weights,
            backend: Backend::Pjrt {
                runtime,
                preset,
                weight_literals,
                executables,
            },
        })
    }

    /// Forward a token batch through every MoE layer (gating residuals
    /// threaded), returning outputs and stats. `x` is [T, D].
    pub fn forward_stack(&self, x: &Tensor) -> Result<(Tensor, ForwardStats)> {
        let (t, d) = x.dims2();
        let mut stats = ForwardStats { tokens: t, ..Default::default() };
        let mut h = x.clone();
        let mut prev_scores: Option<Tensor> = None;
        for (li, layer) in self.weights.layers.iter().enumerate() {
            let lcfg = &self.layer_cfgs[li];
            let t0 = Instant::now();
            let prev = if lcfg.gating_residual {
                prev_scores.as_ref()
            } else {
                None
            };
            let routing = route(&h, &layer.router, prev, lcfg.top_k);
            stats.routing_s += t0.elapsed().as_secs_f64();

            let plan = DispatchPlan::build(&routing, lcfg, t);

            let t1 = Instant::now();
            let mut y = Tensor::zeros(&[t, d]);
            let mut scratch =
                crate::moe::experts::FfnScratch::new(self.cfg.d_ff);
            let mut gather = Tensor::zeros(&[1, d]);
            // --- FFN experts (queued micro-batches) ------------------------
            for batch in &plan.ffn_batches {
                self.run_ffn_batch(li, batch.expert, &h, &batch.tokens,
                                   &batch.gates, &mut scratch, &mut gather,
                                   &mut y)?;
            }
            let ffn_elapsed = t1.elapsed().as_secs_f64();

            // --- ZC experts (inline, never queued) -------------------------
            let t2 = Instant::now();
            for a in &plan.zc_inline {
                let xrow = h.row(a.token);
                let orow = &mut y.data[a.token * d..(a.token + 1) * d];
                match self.cfg.kind(a.expert) {
                    ExpertKind::Zero => {}
                    ExpertKind::Copy => {
                        crate::moe::experts::copy_expert_into(
                            xrow, a.gate, orow)
                    }
                    ExpertKind::Constant => {
                        let j = a.expert - self.cfg.n_ffn_experts
                            - self.cfg.n_zero - self.cfg.n_copy;
                        layer.consts[j]
                            .forward_token_into(xrow, a.gate, orow)
                    }
                    ExpertKind::Ffn => unreachable!("ffn in zc list"),
                }
            }
            let zc_elapsed = t2.elapsed().as_secs_f64();

            stats.ffn_s += ffn_elapsed;
            stats.zc_s += zc_elapsed;
            stats.expert_forward_s += t1.elapsed().as_secs_f64();

            let ffn_assignments = plan.ffn_assignments();
            stats.per_layer.push(LayerStats {
                expert_counts: plan.expert_counts.clone(),
                dropped: plan.dropped.len(),
                ffn_assignments,
                zc_assignments: plan.zc_inline.len(),
                ffn_per_token: ffn_assignments as f64 / t as f64,
                balance_loss: crate::moe::balance::balance_loss(
                    &routing, lcfg),
            });
            prev_scores = Some(routing.scores);
            // Residual stream (as in the transformer block): h <- h + y.
            // Without it, fully-dropped tokens become zero rows and the
            // sparse expert kernels would skip them, corrupting the
            // expert-forward cost accounting.
            for (hv, yv) in h.data.iter_mut().zip(&y.data) {
                *hv += yv;
            }
        }
        Ok((h, stats))
    }

    /// Execute one FFN expert micro-batch and scatter-add gated outputs.
    #[allow(clippy::too_many_arguments)]
    fn run_ffn_batch(
        &self,
        layer: usize,
        expert: usize,
        h: &Tensor,
        tokens: &[usize],
        gates: &[f32],
        scratch: &mut crate::moe::experts::FfnScratch,
        gather: &mut Tensor,
        y: &mut Tensor,
    ) -> Result<()> {
        let d = self.cfg.d_model;
        match &self.backend {
            Backend::Native => {
                // Gather the micro-batch, run the batched allocation-free
                // expert, scatter-add gated rows (§Perf: one weight stream
                // per batch, zero per-token allocations).
                let e = &self.weights.layers[layer].ffn[expert];
                let n = tokens.len();
                if gather.numel() < n * d {
                    *gather = Tensor::zeros(&[n, d]);
                } else {
                    gather.shape = vec![n, d];
                }
                for (i, &tok) in tokens.iter().enumerate() {
                    gather.data[i * d..(i + 1) * d]
                        .copy_from_slice(h.row(tok));
                }
                e.forward_batch_into(gather, Some(gates), scratch,
                                     &mut y.data, Some(tokens));
                Ok(())
            }
            Backend::Pjrt { weight_literals, executables, .. } => {
                // Pad the micro-batch to the nearest compiled bucket; split
                // if it exceeds the largest bucket.
                let max_bucket = *executables.keys().last().unwrap();
                let mut start = 0;
                while start < tokens.len() {
                    let n = (tokens.len() - start).min(max_bucket);
                    let bucket = *executables
                        .keys()
                        .find(|&&b| b >= n)
                        .unwrap();
                    let exe = &executables[&bucket];
                    let mut xb = Tensor::zeros(&[bucket, d]);
                    for (i, &tok) in
                        tokens[start..start + n].iter().enumerate()
                    {
                        xb.row_mut(i).copy_from_slice(h.row(tok));
                    }
                    let x_lit = HostValue::F32(xb).to_literal()?;
                    let w = &weight_literals[layer][expert];
                    let result = exe
                        .run_literals(&[&x_lit, &w[0], &w[1], &w[2]])?;
                    let out = result.into_iter().next().unwrap().into_f32()?;
                    for (i, (&tok, &g)) in tokens[start..start + n]
                        .iter()
                        .zip(&gates[start..start + n])
                        .enumerate()
                    {
                        let orow = &mut y.data[tok * d..(tok + 1) * d];
                        crate::tensor::ops::axpy(g, out.row(i), orow);
                    }
                    start += n;
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::layer::layer_forward;
    use crate::util::rng::Rng;

    #[test]
    fn native_engine_matches_reference_layer_stack() {
        let cfg = MoeConfig::preset("test");
        let engine = MoeEngine::native(cfg.clone(), 11);
        let mut rng = Rng::new(99);
        let x = Tensor::randn(&mut rng, &[24, cfg.d_model], 1.0);
        let (y, stats) = engine.forward_stack(&x).unwrap();
        // Reference: sequential layer_forward with residual threading.
        let mut h = x.clone();
        let mut prev: Option<Tensor> = None;
        for layer in &engine.weights.layers {
            let (out, routing, _) =
                layer_forward(layer, &h, prev.as_ref(), &cfg);
            prev = Some(routing.scores);
            for (hv, yv) in h.data.iter_mut().zip(&out.data) {
                *hv += yv;
            }
        }
        assert!(y.approx_eq(&h, 1e-4, 1e-4));
        assert_eq!(stats.per_layer.len(), cfg.n_layers);
        assert_eq!(stats.tokens, 24);
        assert!(stats.expert_forward_s > 0.0);
    }

    #[test]
    fn moepp_engine_does_less_ffn_work_than_vanilla() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&mut rng, &[128, 32], 1.0);
        let e1 = MoeEngine::native(MoeConfig::preset("test"), 1);
        let e2 = MoeEngine::native(MoeConfig::preset("test:vanilla"), 1);
        let (_, s1) = e1.forward_stack(&x).unwrap();
        let (_, s2) = e2.forward_stack(&x).unwrap();
        assert!(s1.mean_ffn_per_token() < s2.mean_ffn_per_token());
    }

    #[test]
    fn layerwise_schedule_changes_per_layer_work() {
        // Appendix A.2 feature: edge-heavy tau keeps more FFN work in the
        // first/last layers than the middle ones.
        let cfg = MoeConfig::preset("test"); // 2 layers -> per-layer taus
        let sched = crate::moe::layerwise::LayerSchedule::PerLayer(
            vec![1.0, 0.1]);
        let engine = MoeEngine::native(cfg.clone(), 2).with_schedule(&sched);
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&mut rng, &[128, cfg.d_model], 1.0);
        let (_, stats) = engine.forward_stack(&x).unwrap();
        // Layer 0 (tau=1.0) has more FFN capacity than layer 1 (tau=0.1):
        // its surviving FFN work must be strictly larger.
        assert!(stats.per_layer[0].ffn_per_token
                > stats.per_layer[1].ffn_per_token,
                "{:?}", stats.per_layer.iter()
                    .map(|l| l.ffn_per_token).collect::<Vec<_>>());
    }

    #[test]
    fn stats_accounting_consistent() {
        let cfg = MoeConfig::preset("test");
        let engine = MoeEngine::native(cfg.clone(), 3);
        let mut rng = Rng::new(7);
        let x = Tensor::randn(&mut rng, &[64, cfg.d_model], 1.0);
        let (_, stats) = engine.forward_stack(&x).unwrap();
        for l in &stats.per_layer {
            // kept + dropped == T * K
            assert_eq!(
                l.ffn_assignments + l.zc_assignments + l.dropped,
                64 * cfg.top_k
            );
        }
        assert!(stats.expert_throughput() > 0.0);
    }
}

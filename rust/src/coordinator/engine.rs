//! The MoE++ serving engine: a thin shell over the shared execution layer
//! ([`crate::moe::exec`], DESIGN.md §7) that picks the expert backend and
//! owns the weights.
//!
//! Interchangeable expert backends:
//!
//! * [`Backend::Native`] — the pure-Rust SwiGLU expert via
//!   [`exec::NativeBatched`]: arena-backed gathers and scratch
//!   (DESIGN.md §11), and (with `workers > 1`) the layer's FFN work cut
//!   into (expert, row-range) shards fanned across the engine's
//!   persistent [`ExecPool`] (DESIGN.md §12; `ExecutorKind::Scoped`
//!   keeps the old spawn-per-call helpers as the measured baseline) so a
//!   hot expert no longer serialises the layer and steady-state batches
//!   spawn no threads;
//! * [`Backend::Pjrt`]   — the AOT-compiled Pallas kernel executed via the
//!   PJRT runtime, with expert micro-batches padded to the nearest compiled
//!   bucket (weights are pre-converted to literals once at engine build).
//!
//! "Expert forward time" reported by [`ForwardStats`] is the paper's
//! footnote-1 metric: time spent in FFN experts + zero-computation experts,
//! excluding attention/embedding — the quantity Table 3 compares.

use std::sync::Arc;

use anyhow::Result;

use super::dispatch::DispatchPlan;
use crate::config::{MoeConfig, Precision};
use crate::moe::arena::{ExecArena, FfnArena};
use crate::moe::exec::{
    self, ExpertBackend, FfnLayerReport, NativeBatched, NativeQuant,
};
use crate::moe::weights::{QuantStackWeights, StackWeights};
use crate::obs::Obs;
use crate::runtime::host::HostValue;
use crate::runtime::{Executable, Runtime};
use crate::tensor::Tensor;
use crate::util::pool::{ExecPool, Executor};

pub use crate::moe::exec::{ForwardStats, Partition};
pub use crate::util::pool::ExecutorKind;

/// Expert execution backend selector.
pub enum Backend {
    /// Pure-Rust experts (always available). `workers` controls how many
    /// threads the per-layer FFN work fans out over and `partition` how
    /// that work is cut (token shards by default; `Partition::Batch` is
    /// the historical batch-per-worker baseline); results are
    /// bitwise-identical for every worker count and partition.
    Native { workers: usize, partition: Partition },
    /// AOT Pallas kernel via PJRT; holds pre-built weight literals per
    /// (layer, expert): [w1, w3, w2].
    Pjrt {
        runtime: Arc<Runtime>,
        preset: String,
        weight_literals: Vec<Vec<[xla::Literal; 3]>>,
        /// Cached executables keyed by bucket size.
        executables: std::collections::BTreeMap<usize, Arc<Executable>>,
    },
}

/// The serving engine for one model variant.
pub struct MoeEngine {
    pub cfg: MoeConfig,
    /// Per-layer configs (tau — or even expert counts — may vary;
    /// Appendix A.2 layer-wise heterogeneity via `with_schedule` or
    /// [`MoeEngine::heterogeneous`]; uniform by default).
    pub layer_cfgs: Vec<MoeConfig>,
    pub weights: StackWeights,
    pub backend: Backend,
    /// Reusable execution buffers (DESIGN.md §11) — one arena per engine,
    /// which is one per scheduler when the engine backs a `MoeService`.
    arena: ExecArena,
    /// Which executor fans out the per-layer FFN work (DESIGN.md §12):
    /// the persistent pool by default, scoped spawns as the baseline.
    executor: ExecutorKind,
    /// The engine's long-lived worker pool, owned next to the arena (one
    /// per forward driver = one per scheduler thread under `MoeService`).
    /// Built lazily on the thread that runs forwards; `None` until then
    /// or when the scoped executor is selected.
    pool: Option<ExecPool>,
    /// Observability bundle (DESIGN.md §15). When installed, forwards
    /// stamp per-layer routing/dispatch/expert/combine timing and shard
    /// records into it; recording never changes the math.
    obs: Option<Arc<Obs>>,
    /// Stack-wide per-expert serving precision (DESIGN.md §17). Empty
    /// (the default) means every expert serves f32. Indexed by FFN
    /// expert slot; missing tail entries default to f32.
    precision: Vec<Precision>,
    /// Pre-quantized int8 copies of the `Precision::Int8` experts,
    /// rebuilt whenever the precision map changes — `Some` iff any
    /// expert is int8, which switches the native backend to
    /// [`NativeQuant`].
    qweights: Option<QuantStackWeights>,
}

impl MoeEngine {
    pub fn native(cfg: MoeConfig, seed: u64) -> MoeEngine {
        MoeEngine::native_with_workers(cfg, seed, 1)
    }

    /// Native engine fanning each layer's FFN work over `workers` threads
    /// (token-shard partitioning by default; see
    /// [`MoeEngine::with_partition`]).
    pub fn native_with_workers(
        cfg: MoeConfig,
        seed: u64,
        workers: usize,
    ) -> MoeEngine {
        let weights = StackWeights::init(seed, &cfg);
        let layer_cfgs = vec![cfg.clone(); cfg.n_layers];
        MoeEngine {
            cfg,
            layer_cfgs,
            weights,
            backend: Backend::Native {
                workers: workers.max(1),
                partition: Partition::default(),
            },
            arena: ExecArena::new(),
            executor: ExecutorKind::default(),
            pool: None,
            obs: None,
            precision: Vec::new(),
            qweights: None,
        }
    }

    /// Select the native backend's work partitioning (no-op for PJRT).
    pub fn with_partition(mut self, p: Partition) -> MoeEngine {
        if let Backend::Native { partition, .. } = &mut self.backend {
            *partition = p;
        }
        self
    }

    /// Select how parallel FFN work is executed (DESIGN.md §12):
    /// [`ExecutorKind::Pool`] (default) fans out over the engine's
    /// long-lived [`ExecPool`]; [`ExecutorKind::Scoped`] keeps the
    /// spawn-per-call scoped helpers as the measured baseline. Outputs
    /// are bitwise-identical either way.
    pub fn with_executor(mut self, kind: ExecutorKind) -> MoeEngine {
        self.executor = kind;
        if kind == ExecutorKind::Scoped {
            self.pool = None;
        }
        self
    }

    /// Install an observability bundle: subsequent forwards stamp their
    /// per-layer/per-shard records into it (DESIGN.md §15).
    pub fn set_obs(&mut self, obs: Arc<Obs>) {
        self.obs = Some(obs);
    }

    /// Install a stack-wide per-expert precision map (DESIGN.md §17):
    /// expert slot `e` of *every* layer serves at `precision[e]`
    /// (missing tail entries default to f32). Int8 experts are
    /// quantized once here — never on the forward path — and subsequent
    /// forwards run [`NativeQuant`], dispatching each expert to its
    /// precision's kernel. An all-f32 map drops the quantized copies
    /// and restores the plain batched backend. Native backend only
    /// (PJRT kernels are compiled f32; ignored there).
    pub fn with_precision(
        mut self,
        precision: Vec<Precision>,
    ) -> MoeEngine {
        self.set_precision(precision);
        self
    }

    /// See [`MoeEngine::with_precision`].
    pub fn set_precision(&mut self, precision: Vec<Precision>) {
        let any_int8 =
            precision.contains(&Precision::Int8)
                && matches!(self.backend, Backend::Native { .. });
        self.qweights = any_int8
            .then(|| QuantStackWeights::build(&self.weights, &precision));
        self.precision = precision;
    }

    /// The installed precision map (empty = uniform f32).
    pub fn precision(&self) -> &[Precision] {
        &self.precision
    }

    /// Arena growth count (see [`ExecArena::growths`]): constant across
    /// steady-state batches once warmed up — regression-tested.
    pub fn arena_growths(&self) -> u64 {
        self.arena.growths()
    }

    /// Worker threads the engine's pool has ever spawned — paid once at
    /// pool construction, constant across steady-state batches (the
    /// thread-spawn analogue of [`MoeEngine::arena_growths`];
    /// regression-tested). Zero until the first pool forward or under
    /// the scoped executor.
    pub fn pool_spawns(&self) -> u64 {
        self.pool.as_ref().map_or(0, |p| p.spawns())
    }

    /// Build an engine whose layers carry fully heterogeneous configs
    /// (expert counts included). Layer weights are initialised per layer
    /// config; every routing/dispatch/classification decision for layer
    /// `i` uses `layer_cfgs[i]`.
    ///
    /// Gating residuals thread the previous layer's [T, N] scores through
    /// a layer's [N, N] `Wg`, so a layer with `gating_residual` enabled
    /// must have the same expert count as its predecessor — asserted here
    /// rather than panicking on a matmul dimension check mid-forward.
    pub fn heterogeneous(
        layer_cfgs: Vec<MoeConfig>,
        seed: u64,
    ) -> MoeEngine {
        assert!(!layer_cfgs.is_empty());
        for (i, w) in layer_cfgs.windows(2).enumerate() {
            assert!(
                !w[1].gating_residual
                    || w[1].n_experts() == w[0].n_experts(),
                "layer {}: gating residuals require equal expert counts \
                 in consecutive layers ({} vs {}); disable \
                 gating_residual on that layer or equalise expert counts",
                i + 1,
                w[1].n_experts(),
                w[0].n_experts()
            );
        }
        let weights = StackWeights::init_per_layer(seed, &layer_cfgs);
        let mut cfg = layer_cfgs[0].clone();
        cfg.n_layers = layer_cfgs.len();
        MoeEngine {
            cfg,
            layer_cfgs,
            weights,
            backend: Backend::Native {
                workers: 1,
                partition: Partition::default(),
            },
            arena: ExecArena::new(),
            executor: ExecutorKind::default(),
            pool: None,
            obs: None,
            precision: Vec::new(),
            qweights: None,
        }
    }

    /// Apply a per-layer tau schedule (paper Appendix A.2 future work).
    pub fn with_schedule(mut self,
                         schedule: &crate::moe::layerwise::LayerSchedule)
        -> MoeEngine {
        self.layer_cfgs = schedule.configs(&self.cfg);
        self
    }

    /// Build a PJRT-backed engine; compiles every FFN bucket up front so
    /// the request path never compiles.
    pub fn pjrt(cfg: MoeConfig, seed: u64, runtime: Arc<Runtime>)
        -> Result<MoeEngine> {
        let weights = StackWeights::init(seed, &cfg);
        let preset = cfg.name.clone();
        let mut weight_literals = Vec::new();
        for layer in &weights.layers {
            let mut per_expert = Vec::new();
            for e in &layer.ffn {
                per_expert.push([
                    HostValue::F32(e.w1.clone()).to_literal()?,
                    HostValue::F32(e.w3.clone()).to_literal()?,
                    HostValue::F32(e.w2.clone()).to_literal()?,
                ]);
            }
            weight_literals.push(per_expert);
        }
        let mut executables = std::collections::BTreeMap::new();
        for name in runtime.manifest.artifacts.keys() {
            if let Some(b) =
                name.strip_prefix(&format!("expert_ffn_{preset}_b"))
            {
                if let Ok(bucket) = b.parse::<usize>() {
                    executables.insert(bucket, runtime.load(name)?);
                }
            }
        }
        anyhow::ensure!(
            !executables.is_empty(),
            "no expert_ffn_{preset}_b* artifacts; run `make artifacts`"
        );
        let layer_cfgs = vec![cfg.clone(); cfg.n_layers];
        Ok(MoeEngine {
            cfg,
            layer_cfgs,
            weights,
            backend: Backend::Pjrt {
                runtime,
                preset,
                weight_literals,
                executables,
            },
            arena: ExecArena::new(),
            executor: ExecutorKind::default(),
            pool: None,
            obs: None,
            precision: Vec::new(),
            qweights: None,
        })
    }

    /// Forward a token batch through every MoE layer (gating residuals
    /// threaded), returning outputs and stats. `x` is [T, D]. Takes
    /// `&mut self` because the engine's [`ExecArena`] backs every
    /// reusable buffer of the forward (DESIGN.md §11).
    pub fn forward_stack(
        &mut self,
        x: &Tensor,
    ) -> Result<(Tensor, ForwardStats)> {
        let workers = match &self.backend {
            Backend::Native { workers, .. } => *workers,
            Backend::Pjrt { .. } => 1,
        };
        // The pool is built lazily so its parked workers are children of
        // whichever thread drives forwards (the scheduler thread under
        // MoeService) — spawned once, never per batch. Only the native
        // backend fans out on the host; PJRT runs on-device and would
        // never touch a pool.
        if self.executor == ExecutorKind::Pool
            && self.pool.is_none()
            && matches!(self.backend, Backend::Native { .. })
        {
            self.pool = Some(ExecPool::new(workers));
        }
        let exec = match (self.executor, &self.pool) {
            (ExecutorKind::Pool, Some(p)) => Executor::Pool(p),
            _ => Executor::Scoped { workers },
        };
        let mut native;
        let mut quantized;
        let mut pjrt;
        let be: &mut dyn ExpertBackend =
            match (&self.backend, &self.qweights) {
                (Backend::Native { partition, .. }, Some(q)) => {
                    quantized = NativeQuant {
                        layers: &self.weights.layers,
                        qlayers: &q.layers,
                        partition: *partition,
                    };
                    &mut quantized
                }
                (Backend::Native { partition, .. }, None) => {
                    native = NativeBatched {
                        layers: &self.weights.layers,
                        partition: *partition,
                    };
                    &mut native
                }
                (
                    Backend::Pjrt {
                        weight_literals, executables, ..
                    },
                    _,
                ) => {
                    pjrt = PjrtBackend { weight_literals, executables };
                    &mut pjrt
                }
            };
        let (y, stats, _) = exec::forward_stack(
            be,
            &self.weights,
            &self.layer_cfgs,
            x,
            &mut self.arena,
            &exec,
            self.obs.as_deref(),
        )?;
        Ok((y, stats))
    }
}

/// PJRT expert backend: pads each micro-batch to the nearest compiled
/// bucket (splitting batches above the largest bucket) and scatter-adds
/// the gated kernel outputs.
struct PjrtBackend<'a> {
    weight_literals: &'a [Vec<[xla::Literal; 3]>],
    executables: &'a std::collections::BTreeMap<usize, Arc<Executable>>,
}

impl ExpertBackend for PjrtBackend<'_> {
    // The PJRT path stages through freshly-built literals (the XLA FFI
    // owns the buffers), so it has no use for the arena's host pools,
    // and the kernel runs on the device — no host fan-out either.
    fn execute_ffn(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        y: &mut Tensor,
        _arena: &mut FfnArena,
        _exec: &Executor,
    ) -> Result<FfnLayerReport> {
        let (_, d) = h.dims2();
        let max_bucket = *self
            .executables
            .keys()
            .last()
            .expect("pjrt engine compiled at least one bucket");
        for batch in &plan.ffn_batches {
            let tokens = &batch.tokens;
            let gates = &batch.gates;
            let mut start = 0;
            while start < tokens.len() {
                let n = (tokens.len() - start).min(max_bucket);
                let bucket = *self
                    .executables
                    .keys()
                    .find(|&&b| b >= n)
                    .unwrap();
                let exe = &self.executables[&bucket];
                let mut xb = Tensor::zeros(&[bucket, d]);
                for (i, &tok) in
                    tokens[start..start + n].iter().enumerate()
                {
                    xb.row_mut(i).copy_from_slice(h.row(tok));
                }
                let x_lit = HostValue::F32(xb).to_literal()?;
                let w = &self.weight_literals[layer][batch.expert];
                let result =
                    exe.run_literals(&[&x_lit, &w[0], &w[1], &w[2]])?;
                let out = result.into_iter().next().unwrap().into_f32()?;
                for (i, (&tok, &g)) in tokens[start..start + n]
                    .iter()
                    .zip(&gates[start..start + n])
                    .enumerate()
                {
                    let orow = &mut y.data[tok * d..(tok + 1) * d];
                    crate::tensor::ops::axpy(g, out.row(i), orow);
                }
                start += n;
            }
        }
        Ok(FfnLayerReport::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::layer::layer_forward;
    use crate::util::rng::Rng;

    #[test]
    fn native_engine_matches_reference_layer_stack() {
        let cfg = MoeConfig::preset("test");
        let mut engine = MoeEngine::native(cfg.clone(), 11);
        let mut rng = Rng::new(99);
        let x = Tensor::randn(&mut rng, &[24, cfg.d_model], 1.0);
        let (y, stats) = engine.forward_stack(&x).unwrap();
        // Reference: sequential layer_forward with residual threading.
        let mut h = x.clone();
        let mut prev: Option<Tensor> = None;
        for layer in &engine.weights.layers {
            let (out, routing, _) =
                layer_forward(layer, &h, prev.as_ref(), &cfg);
            prev = Some(routing.scores);
            for (hv, yv) in h.data.iter_mut().zip(&out.data) {
                *hv += yv;
            }
        }
        assert!(y.approx_eq(&h, 1e-4, 1e-4));
        assert_eq!(stats.per_layer.len(), cfg.n_layers);
        assert_eq!(stats.tokens, 24);
        assert!(stats.expert_forward_s > 0.0);
    }

    #[test]
    fn moepp_engine_does_less_ffn_work_than_vanilla() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&mut rng, &[128, 32], 1.0);
        let mut e1 = MoeEngine::native(MoeConfig::preset("test"), 1);
        let mut e2 =
            MoeEngine::native(MoeConfig::preset("test:vanilla"), 1);
        let (_, s1) = e1.forward_stack(&x).unwrap();
        let (_, s2) = e2.forward_stack(&x).unwrap();
        assert!(s1.mean_ffn_per_token() < s2.mean_ffn_per_token());
    }

    #[test]
    fn layerwise_schedule_changes_per_layer_work() {
        // Appendix A.2 feature: edge-heavy tau keeps more FFN work in the
        // first/last layers than the middle ones.
        let cfg = MoeConfig::preset("test"); // 2 layers -> per-layer taus
        let sched = crate::moe::layerwise::LayerSchedule::PerLayer(
            vec![1.0, 0.1]);
        let mut engine =
            MoeEngine::native(cfg.clone(), 2).with_schedule(&sched);
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&mut rng, &[128, cfg.d_model], 1.0);
        let (_, stats) = engine.forward_stack(&x).unwrap();
        // Layer 0 (tau=1.0) has more FFN capacity than layer 1 (tau=0.1):
        // its surviving FFN work must be strictly larger.
        assert!(stats.per_layer[0].ffn_per_token
                > stats.per_layer[1].ffn_per_token,
                "{:?}", stats.per_layer.iter()
                    .map(|l| l.ffn_per_token).collect::<Vec<_>>());
    }

    #[test]
    fn stats_accounting_consistent() {
        let cfg = MoeConfig::preset("test");
        let mut engine = MoeEngine::native(cfg.clone(), 3);
        let mut rng = Rng::new(7);
        let x = Tensor::randn(&mut rng, &[64, cfg.d_model], 1.0);
        let (_, stats) = engine.forward_stack(&x).unwrap();
        for l in &stats.per_layer {
            // kept + dropped == T * K
            assert_eq!(
                l.ffn_assignments + l.zc_assignments + l.dropped,
                64 * cfg.top_k
            );
        }
        assert!(stats.expert_throughput() > 0.0);
    }

    #[test]
    fn parallel_workers_match_serial_engine() {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(13);
        let x = Tensor::randn(&mut rng, &[96, cfg.d_model], 1.0);
        let mut serial = MoeEngine::native_with_workers(cfg.clone(), 4, 1);
        let (y1, s1) = serial.forward_stack(&x).unwrap();
        for executor in ExecutorKind::all() {
            for partition in Partition::all() {
                for workers in [2, 4] {
                    let mut par = MoeEngine::native_with_workers(
                        cfg.clone(), 4, workers,
                    )
                    .with_partition(partition)
                    .with_executor(executor);
                    let (yw, sw) = par.forward_stack(&x).unwrap();
                    assert_eq!(
                        y1.data, yw.data,
                        "workers={workers} {} {} diverged",
                        partition.label(), executor.label()
                    );
                    for (a, b) in s1.per_layer.iter().zip(&sw.per_layer) {
                        assert_eq!(a.ffn_assignments, b.ffn_assignments);
                        assert_eq!(a.zc_assignments, b.zc_assignments);
                        assert_eq!(a.dropped, b.dropped);
                    }
                    if executor == ExecutorKind::Pool {
                        assert_eq!(
                            par.pool_spawns(),
                            workers as u64 - 1,
                            "pool spawns once at construction"
                        );
                    } else {
                        assert_eq!(par.pool_spawns(), 0);
                    }
                }
            }
        }
    }

    #[test]
    fn engine_precision_map_selects_backend_and_stays_deterministic() {
        let cfg = MoeConfig::preset("test"); // 4 FFN experts
        let mut rng = Rng::new(31);
        let x = Tensor::randn(&mut rng, &[48, cfg.d_model], 1.0);
        // An all-f32 map is a bit-exact no-op vs the default engine.
        let mut plain = MoeEngine::native(cfg.clone(), 6);
        let (y0, _) = plain.forward_stack(&x).unwrap();
        let mut f32map = MoeEngine::native(cfg.clone(), 6)
            .with_precision(vec![Precision::F32; 4]);
        let (y1, _) = f32map.forward_stack(&x).unwrap();
        assert_eq!(y0.data, y1.data);
        // A mixed map is bitwise-reproducible across worker counts and
        // across repeat forwards on one engine.
        let mixed = vec![
            Precision::F32,
            Precision::Int8,
            Precision::F32,
            Precision::Int8,
        ];
        let mut serial = MoeEngine::native(cfg.clone(), 6)
            .with_precision(mixed.clone());
        let (ym, sm) = serial.forward_stack(&x).unwrap();
        assert_ne!(ym.data, y0.data, "int8 experts must change outputs");
        let (ym2, _) = serial.forward_stack(&x).unwrap();
        assert_eq!(ym.data, ym2.data);
        for workers in [2, 4] {
            let mut par =
                MoeEngine::native_with_workers(cfg.clone(), 6, workers)
                    .with_precision(mixed.clone());
            let (yw, sw) = par.forward_stack(&x).unwrap();
            assert_eq!(ym.data, yw.data, "workers={workers} diverged");
            for (a, b) in sm.per_layer.iter().zip(&sw.per_layer) {
                assert_eq!(a.ffn_assignments, b.ffn_assignments);
            }
        }
        assert_eq!(serial.precision(), mixed.as_slice());
    }

    #[test]
    fn heterogeneous_layers_classify_with_their_own_config() {
        // Regression for the per-layer classification bug: the old engine
        // classified ZC-inline assignments with the *base* config's
        // kind()/const-index arithmetic while routing/dispatch used the
        // per-layer config. With layers whose expert counts differ, the
        // two disagree (e.g. index 5 is Copy under 4-FFN layer 0 but an
        // FFN expert under 6-FFN layer 1); every lookup must go through
        // the layer's own config.
        let mut c0 = MoeConfig::preset("test"); // 4 FFN + 1+1+2 ZC
        c0.gating_residual = false; // router dims differ across layers
        let mut c1 = c0.clone();
        c1.n_ffn_experts = 6;
        c1.n_const = 1; // 6 FFN + 1+1+1 ZC = 9 experts
        let cfgs = vec![c0.clone(), c1.clone()];
        let mut engine = MoeEngine::heterogeneous(cfgs.clone(), 21);
        assert_eq!(engine.weights.layers[0].ffn.len(), 4);
        assert_eq!(engine.weights.layers[1].ffn.len(), 6);
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&mut rng, &[40, c0.d_model], 1.0);
        let (y, stats) = engine.forward_stack(&x).unwrap();
        // Reference: per-layer oracle with the matching layer config.
        let mut h = x.clone();
        for (li, layer) in engine.weights.layers.iter().enumerate() {
            let (out, _, _) = layer_forward(layer, &h, None, &cfgs[li]);
            for (hv, yv) in h.data.iter_mut().zip(&out.data) {
                *hv += yv;
            }
        }
        assert!(y.approx_eq(&h, 1e-4, 1e-4));
        assert_eq!(stats.per_layer.len(), 2);
        for (l, lcfg) in stats.per_layer.iter().zip(&cfgs) {
            assert_eq!(
                l.ffn_assignments + l.zc_assignments + l.dropped,
                40 * lcfg.top_k
            );
        }
    }
}

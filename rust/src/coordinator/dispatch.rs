//! Capacity-aware dispatch planning: turn a routing decision into
//! per-FFN-expert micro-batches plus inline ZC work lists.
//!
//! Shares exact semantics with `moe::layer::dispatch` (slot-major priority,
//! Eq. 8 capacities, Eq. 1 gates — DESIGN.md §6) — property-tested against
//! it — but produces the structure the shared executor
//! (`moe::exec`, DESIGN.md §7) runs on any [`ExpertBackend`]: gathered
//! expert batches instead of per-assignment loops.
//!
//! [`ExpertBackend`]: crate::moe::exec::ExpertBackend

use crate::config::{ExpertKind, MoeConfig};
use crate::moe::layer::{dispatch, Assignment};
use crate::moe::router::Routing;

/// Work for one FFN expert: which tokens (rows of x) it processes.
#[derive(Clone, Debug, Default)]
pub struct ExpertBatch {
    pub expert: usize,
    pub tokens: Vec<usize>,
    pub gates: Vec<f32>,
}

/// A fully-planned layer step.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    /// Non-empty FFN expert micro-batches.
    pub ffn_batches: Vec<ExpertBatch>,
    /// Inline ZC assignments (zero included for accounting).
    pub zc_inline: Vec<Assignment>,
    /// Dropped assignments (over capacity).
    pub dropped: Vec<Assignment>,
    /// Pre-capacity assignment counts per expert.
    pub expert_counts: Vec<usize>,
}

impl DispatchPlan {
    /// Build a plan from a routing decision over `n_tokens` tokens.
    pub fn build(routing: &Routing, cfg: &MoeConfig, n_tokens: usize)
        -> DispatchPlan {
        let d = dispatch(routing, cfg, n_tokens);
        let mut ffn: Vec<ExpertBatch> = (0..cfg.n_ffn_experts)
            .map(|e| ExpertBatch { expert: e, ..Default::default() })
            .collect();
        let mut zc_inline = Vec::new();
        for a in &d.kept {
            match cfg.kind(a.expert) {
                ExpertKind::Ffn => {
                    ffn[a.expert].tokens.push(a.token);
                    ffn[a.expert].gates.push(a.gate);
                }
                _ => zc_inline.push(*a),
            }
        }
        ffn.retain(|b| !b.tokens.is_empty());
        DispatchPlan {
            ffn_batches: ffn,
            zc_inline,
            dropped: d.dropped,
            expert_counts: crate::moe::balance::assignment_counts(
                routing,
                cfg.n_experts(),
            ),
        }
    }

    pub fn ffn_assignments(&self) -> usize {
        self.ffn_batches.iter().map(|b| b.tokens.len()).sum()
    }

    pub fn kept_assignments(&self) -> usize {
        self.ffn_assignments() + self.zc_inline.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::router::route;
    use crate::moe::weights::MoeLayerWeights;
    use crate::tensor::Tensor;
    use crate::util::proptest::{gen, Prop};
    use crate::util::rng::Rng;

    fn plan_for(seed: u64, t: usize) -> (MoeConfig, Routing, DispatchPlan) {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(seed);
        let w = MoeLayerWeights::init(&mut rng, &cfg);
        let x = Tensor::randn(&mut rng, &[t, cfg.d_model], 1.0);
        let routing = route(&x, &w.router, None, cfg.top_k);
        let plan = DispatchPlan::build(&routing, &cfg, t);
        (cfg, routing, plan)
    }

    #[test]
    fn plan_is_equivalent_to_reference_dispatch() {
        Prop::new("plan-equals-dispatch").cases(30).run(
            |rng| (gen::usize_in(rng, 1, 80), rng.next_u64()),
            |&(t, seed)| {
                let (cfg, routing, plan) = plan_for(seed, t);
                let d = crate::moe::layer::dispatch(&routing, &cfg, t);
                // Same total kept/dropped.
                if plan.kept_assignments() != d.kept.len() {
                    return Err(format!(
                        "kept {} vs {}", plan.kept_assignments(),
                        d.kept.len()));
                }
                if plan.dropped.len() != d.dropped.len() {
                    return Err("dropped mismatch".into());
                }
                // Every FFN batch token appears in d.kept with same gate.
                for b in &plan.ffn_batches {
                    for (tok, g) in b.tokens.iter().zip(&b.gates) {
                        let found = d.kept.iter().any(|a| {
                            a.expert == b.expert && a.token == *tok
                                && (a.gate - g).abs() < 1e-7
                        });
                        if !found {
                            return Err(format!(
                                "batch entry ({}, {tok}) not in reference",
                                b.expert));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zc_never_enters_ffn_queue() {
        let (cfg, _routing, plan) = plan_for(3, 64);
        for b in &plan.ffn_batches {
            assert!(b.expert < cfg.n_ffn_experts);
        }
        for a in &plan.zc_inline {
            assert!(a.expert >= cfg.n_ffn_experts);
        }
    }

    #[test]
    fn batch_sizes_respect_capacity() {
        let (cfg, _routing, plan) = plan_for(4, 96);
        let caps = cfg.capacity_vec(96);
        for b in &plan.ffn_batches {
            assert!(b.tokens.len() <= caps[b.expert]);
        }
    }

    #[test]
    fn empty_batches_are_pruned() {
        let (_, _, plan) = plan_for(5, 2); // 2 tokens can fill ≤4 experts
        assert!(plan.ffn_batches.len() <= 4);
        assert!(plan.ffn_batches.iter().all(|b| !b.tokens.is_empty()));
    }
}

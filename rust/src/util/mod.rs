//! From-scratch utility substrates (this environment is offline; no serde,
//! clap, rand, rayon or criterion are available).

pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod threadpool;

/// Format a byte count as a human-readable string.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in seconds with an adaptive unit.
pub fn human_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn duration_units() {
        assert_eq!(human_duration(0.5e-9 * 2.0), "1.0 ns");
        assert!(human_duration(2.5e-6).ends_with("µs"));
        assert!(human_duration(0.25).ends_with("ms"));
        assert!(human_duration(2.0).ends_with(" s"));
    }
}

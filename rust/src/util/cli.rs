//! Tiny CLI argument parser (no clap in this offline environment).
//!
//! Supports `moepp <subcommand> --flag value --switch positional` with typed
//! accessors and automatic usage/error reporting.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argv entries (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--k=v` or `--k v` or bare switch.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| {
                panic!("--{key} expects an integer, got '{v}'")
            }))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| {
                panic!("--{key} expects a number, got '{v}'")
            }))
            .unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        // NB: a bare `--switch` consumes a following non-flag token as its
        // value, so positionals go before switches.
        let a = parse("bench table3 --preset sm-8e --tau 0.75 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("preset"), Some("sm-8e"));
        assert_eq!(a.get_f64("tau", 0.0), 0.75);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["table3"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("train --steps=100 --lr=5e-4");
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 5e-4);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("serve --quiet");
        assert!(a.has("quiet"));
        assert_eq!(a.get("quiet"), None);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
    }

    #[test]
    fn no_subcommand_when_flag_first() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has("help"));
    }
}

//! [`ExecPool`] — the long-lived executor behind the expert-forward hot
//! path (DESIGN.md §12).
//!
//! The scoped helpers in [`crate::util::threadpool`] spawn OS threads on
//! every call; after PR 4 removed the steady-state allocations, that
//! per-layer spawn cost became the dominant fixed overhead at small batch
//! sizes (ROADMAP "persistent worker pool"). An `ExecPool` spawns its
//! workers once and parks them on a condvar; each [`ExecPool::run`] call
//! publishes one lifetime-erased parallel job which the parked workers
//! (and the calling thread) drain through an atomic index queue, then
//! fences until every claimed index has finished executing. Steady-state
//! forwards therefore perform **zero thread spawns** — the pool analogue
//! of the arena's zero-allocation guarantee, regression-tested the same
//! way (`ExecPool::spawns`, [`thread_spawns`]).
//!
//! Ownership mirrors the arena (DESIGN.md §11): one pool per forward
//! driver — `MoeEngine` and `ClusterSim` each own one next to their
//! `ExecArena`, which makes it one pool per scheduler thread when either
//! backs a `MoeService`. Backends receive the pool as an [`Executor`]
//! through `ExpertBackend::execute_ffn`; [`Executor::Scoped`] keeps the
//! old spawn-per-call helpers alive as the measured baseline
//! (`moepp bench forward --executor pool|scoped|both`). Outputs are
//! bitwise-identical across executors and worker counts — executors only
//! decide *where/when* compute runs, never the combine order (§11).
//!
//! Besides parallel jobs the pool accepts detached one-shot tasks
//! ([`ExecPool::submit`] → [`TaskHandle`]): this is what carries the
//! placement replanner's local search off the serving scheduler thread
//! (DESIGN.md §12, "off-thread replanning"). Contracts:
//!
//! * **panic containment** — a panicking parallel index or task never
//!   kills a worker: panics are caught per unit, counted, and re-raised
//!   on the *caller* (`run` panics after its fence; a task's panic
//!   surfaces as `Err` on its handle). The pool stays usable.
//! * **epoch/fence** — [`ExecPool::epoch`] counts completed parallel
//!   jobs; [`ExecPool::fence`] blocks until no job is installed and the
//!   task queue is drained and idle. `run` itself always fences before
//!   returning (that is what makes the lifetime erasure of the job
//!   closure sound).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

/// Process-wide count of threads ever spawned by pool workers *and* the
/// scoped helpers in [`crate::util::threadpool`] — the counter the
/// steady-state "zero thread spawns" serve regression pins constant
/// (analogous to `ExecArena::growths`).
static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);

pub fn thread_spawns() -> u64 {
    // ordering: monotone diagnostic counter; no data published with it.
    THREAD_SPAWNS.load(Ordering::Relaxed)
}

pub(crate) fn note_spawn() {
    // ordering: monotone diagnostic counter; no data published with it.
    THREAD_SPAWNS.fetch_add(1, Ordering::Relaxed);
}

// ----------------------------------------------------------------- pool

/// One published parallel job: a lifetime-erased `Fn(usize)` plus the
/// atomic claim/completion counters the workers drain it through.
struct Job {
    /// Raw (lifetime-erased) pointer to the caller's closure. Only
    /// dereferenced for successfully claimed indices (`i < n`), all of
    /// which finish before `run` returns — `run`'s fence waits for
    /// `done == n`, so the pointee outlives every dereference.
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
    /// Indices claimed per `fetch_add` — 1 for small jobs, larger when
    /// `n` dwarfs the pool width so claim traffic amortises
    /// ([`claim_chunk`]).
    chunk: usize,
    /// Next index to claim (may overshoot `n`; overshoots never touch `f`).
    next: AtomicUsize,
    /// Indices fully executed. `done == n` is the job-complete signal.
    done: AtomicUsize,
    panics: AtomicUsize,
}

/// Claim granularity for an `n`-index job on a width-`width` pool:
/// single-index claims until the job is much larger than `width * 4`
/// (so small jobs still balance perfectly), then `n / (width * 4)` —
/// every thread sees ~4 claims even if one chunk runs long — capped at
/// 32 indices so tail imbalance from one slow chunk stays bounded.
fn claim_chunk(n: usize, width: usize) -> usize {
    (n / (width.max(1) * 4)).clamp(1, 32)
}

// SAFETY: `f` points at a `Sync` closure, so shared references to it may
// cross threads; the raw pointer itself is only dereferenced under the
// `i < n` claim rule above, within the lifetime `run` guarantees.
unsafe impl Send for Job {}
// SAFETY: same argument — `&Job` exposes only atomics and the shared
// reference to a `Sync` closure, so concurrent shared access is sound.
unsafe impl Sync for Job {}

std::thread_local! {
    /// The pool whose parallel job this thread is currently draining
    /// (null otherwise) — the nested-`run` guard: a `run` issued from
    /// inside a job closure of the *same* pool must execute inline, or
    /// it would wait for the job slot its own caller is keeping busy
    /// (self-deadlock). Keyed by `Shared` address so independent pools
    /// still compose freely.
    static DRAINING: std::cell::Cell<*const ()> =
        const { std::cell::Cell::new(std::ptr::null()) };
}

// lint: no-alloc — job drain is the per-index hot loop (DESIGN.md §12).
impl Job {
    /// Claim-and-execute until the index queue runs dry. Shared by the
    /// workers and the submitting thread (which participates instead of
    /// blocking). Returns once no unclaimed index remains.
    fn drain(&self, shared: &Shared) {
        let key = shared as *const Shared as *const ();
        let prev = DRAINING.with(|d| d.replace(key));
        self.drain_inner(shared);
        DRAINING.with(|d| d.set(prev));
    }

    // Per-index panics are caught below, so `drain` always restores the
    // thread-local marker.
    fn drain_inner(&self, shared: &Shared) {
        loop {
            // ordering: pure claim ticket — no data rides on the index;
            // completion is published through `done` (AcqRel) below.
            let start = self.next.fetch_add(self.chunk, Ordering::Relaxed);
            if start >= self.n {
                return;
            }
            let end = (start + self.chunk).min(self.n);
            // SAFETY: every executed index is `< n` — see the field docs.
            let f = unsafe { &*self.f };
            for i in start..end {
                if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
                    // ordering: tally only read after the fence's
                    // acquire of `done == n`, which orders it.
                    self.panics.fetch_add(1, Ordering::Relaxed);
                }
            }
            let ran = end - start;
            if self.done.fetch_add(ran, Ordering::AcqRel) + ran == self.n
            {
                // Lock-then-notify pairs with the fence's check-then-wait
                // under the same lock: no lost wakeup.
                let _guard = shared.state.lock().unwrap();
                shared.done_cv.notify_all();
            }
        }
    }
}
// lint: end

type Task = Box<dyn FnOnce() + Send>;

struct State {
    job: Option<Arc<Job>>,
    tasks: VecDeque<Task>,
    /// Tasks popped from the queue and currently executing.
    tasks_active: usize,
    /// Worker threads currently spawned.
    threads: usize,
    /// Completed parallel jobs.
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here waiting for a job or task.
    work_cv: Condvar,
    /// `run` exclusion, job fences and `fence()` wait here.
    done_cv: Condvar,
    /// Worker threads ever spawned by this pool.
    spawns: AtomicU64,
}

/// A long-lived worker pool: `width - 1` parked worker threads plus the
/// submitting thread, which always participates in parallel jobs. A
/// width-1 pool runs jobs inline and spawns no threads at all (its single
/// lazy worker appears only if [`ExecPool::submit`] is used).
pub struct ExecPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    width: usize,
}

impl ExecPool {
    /// Pool of total parallel width `width` (submitter included): spawns
    /// `width - 1` worker threads immediately, so the spawn cost is paid
    /// once at construction, never on the per-layer hot path.
    pub fn new(width: usize) -> ExecPool {
        let width = width.max(1);
        let pool = ExecPool {
            shared: Arc::new(Shared {
                state: Mutex::new(State {
                    job: None,
                    tasks: VecDeque::new(),
                    tasks_active: 0,
                    threads: 0,
                    epoch: 0,
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
                spawns: AtomicU64::new(0),
            }),
            handles: Mutex::new(Vec::new()),
            width,
        };
        {
            let mut st = pool.shared.state.lock().unwrap();
            for _ in 1..width {
                pool.spawn_worker(&mut st);
            }
        }
        pool
    }

    /// Total parallel width of `run` (worker threads + the caller).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Worker threads ever spawned by this pool — constant after
    /// construction (plus at most one lazy `submit` worker), which is the
    /// steady-state zero-spawn regression signal.
    pub fn spawns(&self) -> u64 {
        // ordering: diagnostic counter; spawns happen-before any use of
        // the pool that could observe them.
        self.shared.spawns.load(Ordering::Relaxed)
    }

    /// Completed parallel jobs since construction.
    pub fn epoch(&self) -> u64 {
        self.shared.state.lock().unwrap().epoch
    }

    fn spawn_worker(&self, st: &mut State) {
        let shared = self.shared.clone();
        let handle = std::thread::Builder::new()
            .name(format!("moepp-pool-w{}", st.threads))
            .spawn(move || worker_loop(&shared))
            .expect("spawn pool worker");
        self.handles.lock().unwrap().push(handle);
        st.threads += 1;
        // ordering: diagnostic counter, bumped under the state lock.
        self.shared.spawns.fetch_add(1, Ordering::Relaxed);
        note_spawn();
    }

    /// Run `f(i)` for every `i in 0..n` across the pool, returning once
    /// all indices have executed (the fence). The caller participates, so
    /// a width-1 pool degenerates to a plain serial loop with no
    /// synchronisation at all. If any index panicked, the panic is
    /// re-raised here — after the fence, so no worker is left touching
    /// caller-owned data — and the pool remains usable.
    ///
    /// Nested `run` on the **same** pool (a job closure calling `run`
    /// again) executes inline serially instead of installing a second
    /// job: the nested call would otherwise wait for a job slot its own
    /// caller keeps busy — a guaranteed self-deadlock. Nesting across
    /// *different* pools, and `run` from inside a `submit` task, are
    /// fine (those always make progress).
    // lint: no-alloc — steady-state dispatch: one Arc per job, no other
    // heap traffic (the zero-allocation twin of `ExecArena`).
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        let nested = DRAINING.with(|d| d.get())
            == Arc::as_ptr(&self.shared) as *const ();
        if self.width <= 1 || n == 1 || nested {
            for i in 0..n {
                f(i);
            }
            return;
        }
        // SAFETY: lifetime-erased borrow of `f`. Erasure is sound because
        // the claim rule (only `i < n` dereferences) plus the fence below
        // (`done == n` before this function returns) guarantee no
        // dereference outlives `f`.
        let f_erased: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(&f)
        };
        let job = Arc::new(Job {
            f: f_erased as *const (dyn Fn(usize) + Sync),
            n,
            chunk: claim_chunk(n, self.width),
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            // One job at a time: a concurrent `run` waits for the slot.
            while st.job.is_some() {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            // alloc-ok: Arc refcount bump, not a heap allocation.
            st.job = Some(job.clone());
            self.shared.work_cv.notify_all();
        }
        job.drain(&self.shared);
        {
            let mut st = self.shared.state.lock().unwrap();
            while job.done.load(Ordering::Acquire) < n {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            st.epoch += 1;
            // Wake run-exclusion and fence() waiters.
            self.shared.done_cv.notify_all();
        }
        // ordering: read after the fence acquired `done == n`, which
        // orders every worker's tally bump before this load.
        let panics = job.panics.load(Ordering::Relaxed);
        if panics > 0 {
            panic!("ExecPool::run: {panics} of {n} parallel task(s) \
                    panicked (workers contained and still parked)");
        }
    }
    // lint: end

    /// Enqueue a detached one-shot task; the returned [`TaskHandle`]
    /// yields the result (or the panic message). Tasks execute on pool
    /// workers — never on the calling thread — so this is what carries
    /// planning work off the serving scheduler. A width-1 pool lazily
    /// spawns its single worker on first use.
    pub fn submit<T, F>(&self, f: F) -> TaskHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let slot = Arc::new(TaskSlot {
            result: Mutex::new(None),
            cv: Condvar::new(),
        });
        let task_slot = slot.clone();
        let task: Task = Box::new(move || {
            let r = catch_unwind(AssertUnwindSafe(f))
                .map_err(|p| panic_message(&p));
            *task_slot.result.lock().unwrap() = Some(r);
            task_slot.cv.notify_all();
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.threads == 0 {
                self.spawn_worker(&mut st);
            }
            st.tasks.push_back(task);
            self.shared.work_cv.notify_all();
        }
        TaskHandle { slot }
    }

    /// Block until no parallel job is installed and the task queue is
    /// empty and idle.
    pub fn fence(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.job.is_some()
            || !st.tasks.is_empty()
            || st.tasks_active > 0
        {
            st = self.shared.done_cv.wait(st).unwrap();
        }
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "pool task panicked".to_string()
    }
}

// lint: no-alloc — parked workers allocate nothing between jobs.
fn worker_loop(shared: &Shared) {
    enum Work {
        Job(Arc<Job>),
        Task(Task),
    }
    loop {
        let work = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = &st.job {
                    // ordering: cheap already-drained probe; a stale
                    // read only costs one harmless claim attempt.
                    if job.next.load(Ordering::Relaxed) < job.n {
                        // alloc-ok: Arc refcount bump, no allocation.
                        break Work::Job(job.clone());
                    }
                }
                if let Some(t) = st.tasks.pop_front() {
                    st.tasks_active += 1;
                    break Work::Task(t);
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        match work {
            // Per-index panics are caught inside drain.
            Work::Job(job) => job.drain(shared),
            Work::Task(t) => {
                // The submit wrapper catches its own panic; this outer
                // guard just keeps a worker alive no matter what.
                let _ = catch_unwind(AssertUnwindSafe(t));
                let mut st = shared.state.lock().unwrap();
                st.tasks_active -= 1;
                shared.done_cv.notify_all();
            }
        }
    }
}
// lint: end

// -------------------------------------------------------------- handles

struct TaskSlot<T> {
    result: Mutex<Option<Result<T, String>>>,
    cv: Condvar,
}

/// Receiver for a [`ExecPool::submit`] task: poll with
/// [`TaskHandle::try_take`] or block with [`TaskHandle::wait`]. `Err`
/// carries the task's panic message (the worker survives).
pub struct TaskHandle<T> {
    slot: Arc<TaskSlot<T>>,
}

impl<T> TaskHandle<T> {
    /// Take the result if the task has finished; `None` while running.
    pub fn try_take(&self) -> Option<Result<T, String>> {
        self.slot.result.lock().unwrap().take()
    }

    /// Block until the task finishes and take its result.
    pub fn wait(self) -> Result<T, String> {
        let mut g = self.slot.result.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.slot.cv.wait(g).unwrap();
        }
    }
}

// ------------------------------------------------------------ executors

/// How a forward driver fans a layer's FFN work across threads — the
/// handle threaded through `forward_stack` / `execute_layer` /
/// `ExpertBackend::execute_ffn` (DESIGN.md §12). Outputs are
/// bitwise-identical across variants: executors schedule compute, the
/// canonical serial combine (§11) fixes the float summation order.
pub enum Executor<'a> {
    /// Spawn scoped threads per call (`util::threadpool`) — the
    /// pre-pool behaviour, kept as the measured baseline.
    Scoped { workers: usize },
    /// Fan out over a long-lived [`ExecPool`] (parked workers, zero
    /// steady-state spawns).
    Pool(&'a ExecPool),
}

impl Executor<'static> {
    /// A serial executor for oracle/reference paths.
    pub fn serial() -> Executor<'static> {
        Executor::Scoped { workers: 1 }
    }
}

impl Executor<'_> {
    /// Parallel width backends should size their work partitions for.
    pub fn workers(&self) -> usize {
        match self {
            Executor::Scoped { workers } => (*workers).max(1),
            Executor::Pool(p) => p.width(),
        }
    }

    /// Run `f(i)` for `i in 0..n`, returning after all complete.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        match self {
            Executor::Scoped { workers } => {
                crate::util::threadpool::parallel_for(n, *workers, f)
            }
            Executor::Pool(p) => p.run(n, f),
        }
    }

    /// Ordered map over disjoint `&mut` elements — the executors'
    /// shared primitive, and the **only** place the disjoint-`&mut`
    /// erasure lives: both variants guarantee each index in
    /// [`Executor::run`] is claimed by exactly one thread (the pool's
    /// atomic job counter / `parallel_for`'s atomic claim counter) and
    /// both fence before returning, so no two threads ever hold the
    /// same slot's `&mut` and no access outlives `data`.
    pub fn for_each_mut<T, F>(&self, data: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let base = data.as_mut_ptr() as usize;
        self.run(data.len(), move |i| {
            // SAFETY: one claim per in-bounds index + the run fence —
            // see the method docs.
            let item = unsafe { &mut *(base as *mut T).add(i) };
            f(i, item);
        });
    }
}

/// Which executor a driver should build — the config-level counterpart of
/// [`Executor`] (CLI `--executor pool|scoped`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Long-lived pool (default: no per-layer spawn cost).
    #[default]
    Pool,
    /// Scoped spawn-per-call fallback (measured baseline).
    Scoped,
}

impl ExecutorKind {
    pub fn parse(s: &str) -> Result<ExecutorKind> {
        match s {
            "pool" => Ok(ExecutorKind::Pool),
            "scoped" => Ok(ExecutorKind::Scoped),
            other => anyhow::bail!(
                "unknown executor '{other}' (expected pool|scoped)"
            ),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ExecutorKind::Pool => "pool",
            ExecutorKind::Scoped => "scoped",
        }
    }

    pub fn all() -> [ExecutorKind; 2] {
        [ExecutorKind::Pool, ExecutorKind::Scoped]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_hits_every_index_once_for_any_width() {
        for width in [1usize, 2, 4, 8] {
            let pool = ExecPool::new(width);
            let hits: Vec<AtomicU64> =
                (0..501).map(|_| AtomicU64::new(0)).collect();
            pool.run(501, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "width={width}"
            );
            assert_eq!(pool.spawns(), width.max(1) as u64 - 1);
        }
    }

    #[test]
    fn claim_chunk_scales_with_job_size_and_is_bounded() {
        // Small jobs claim one index at a time (perfect balance)…
        assert_eq!(claim_chunk(16, 4), 1);
        assert_eq!(claim_chunk(64, 4), 4);
        // …mid-size jobs amortise claims at ~4 per thread…
        assert_eq!(claim_chunk(501, 4), 31);
        // …and huge jobs cap at 32 so tail imbalance stays bounded.
        assert_eq!(claim_chunk(100_000, 4), 32);
        // Degenerate widths never divide by zero or return zero.
        assert_eq!(claim_chunk(0, 0), 1);
        assert_eq!(claim_chunk(3, 1), 1);
    }

    #[test]
    fn chunked_claims_still_hit_every_index_exactly_once() {
        // Large enough that claims are chunked (10_000 / 16 caps at 32):
        // the oracle from the single-index days must keep holding.
        let pool = ExecPool::new(4);
        let hits: Vec<AtomicU64> =
            (0..10_000).map(|_| AtomicU64::new(0)).collect();
        pool.run(10_000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // A ragged size (not a multiple of the chunk) too.
        let hits: Vec<AtomicU64> =
            (0..10_007).map(|_| AtomicU64::new(0)).collect();
        pool.run(10_007, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn repeated_jobs_spawn_nothing_and_bump_epoch() {
        let pool = ExecPool::new(4);
        let after_build = pool.spawns();
        assert_eq!(after_build, 3);
        for round in 0..32 {
            let sum = AtomicU64::new(0);
            pool.run(64, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 64 * 63 / 2);
            assert_eq!(pool.spawns(), after_build, "round {round}");
        }
        assert_eq!(pool.epoch(), 32);
    }

    #[test]
    fn for_each_mut_writes_each_slot_exactly_once_on_both_executors() {
        let pool = ExecPool::new(3);
        for exec in [Executor::Scoped { workers: 3 }, Executor::Pool(&pool)]
        {
            let mut v = vec![0u64; 97];
            exec.for_each_mut(&mut v, |i, slot| *slot = (i * i) as u64);
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, (i * i) as u64);
            }
        }
    }

    #[test]
    fn nested_run_on_the_same_pool_degrades_to_inline_serial() {
        // A job closure calling run() on its own pool must not install a
        // second job (that would self-deadlock waiting for the slot its
        // caller keeps busy): it executes inline, epoch counts only the
        // outer job, and results are complete.
        let pool = ExecPool::new(4);
        let cells: Vec<AtomicU64> =
            (0..6 * 8).map(|_| AtomicU64::new(0)).collect();
        let cells = &cells;
        pool.run(6, |outer| {
            pool.run(8, |inner| {
                cells[outer * 8 + inner].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(
            cells.iter().all(|c| c.load(Ordering::Relaxed) == 1),
            "nested fan-out must cover every (outer, inner) pair once"
        );
        assert_eq!(pool.epoch(), 1, "only the outer job installs");
        // Independent pools still compose: nesting across pools is fine.
        let other = ExecPool::new(2);
        let sum = AtomicU64::new(0);
        pool.run(4, |_| {
            other.run(4, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4 * 6);
    }

    #[test]
    fn parallel_panic_is_contained_and_reraised() {
        let pool = ExecPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, |i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "caller must observe the panic");
        // Workers survived: the pool still runs jobs and spawned nothing.
        let spawns = pool.spawns();
        let sum = AtomicU64::new(0);
        pool.run(8, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 28);
        assert_eq!(pool.spawns(), spawns);
    }

    #[test]
    fn submit_runs_off_the_calling_thread() {
        let pool = ExecPool::new(1); // lazily spawns its task worker
        let caller = std::thread::current().id();
        let h = pool.submit(move || std::thread::current().id());
        let worker = h.wait().unwrap();
        assert_ne!(caller, worker, "task ran on the submitting thread");
        assert_eq!(pool.spawns(), 1, "one lazy worker");
        // Second submit reuses it.
        let h = pool.submit(|| 40 + 2);
        assert_eq!(h.wait().unwrap(), 42);
        assert_eq!(pool.spawns(), 1);
    }

    #[test]
    fn submit_panic_surfaces_on_the_handle_only() {
        let pool = ExecPool::new(1);
        let h = pool.submit(|| -> u32 { panic!("task exploded") });
        let err = h.wait().unwrap_err();
        assert!(err.contains("task exploded"), "{err}");
        // The worker survived and serves the next task.
        assert_eq!(pool.submit(|| 7u32).wait().unwrap(), 7);
    }

    #[test]
    fn fence_waits_for_queued_tasks_and_try_take_polls() {
        let pool = ExecPool::new(2);
        let h = pool.submit(|| {
            std::thread::sleep(std::time::Duration::from_millis(20));
            123u64
        });
        pool.fence();
        // After the fence the result must be immediately available.
        assert_eq!(h.try_take().expect("fenced task done").unwrap(), 123);
    }

    #[test]
    fn jobs_and_tasks_coexist() {
        let pool = ExecPool::new(4);
        let h = pool.submit(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
            1u8
        });
        let sum = AtomicU64::new(0);
        // A parallel job completes even while a worker runs the task
        // (the caller participates, so progress never depends on any
        // single worker being free).
        pool.run(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
        assert_eq!(h.wait().unwrap(), 1);
    }

    #[test]
    fn zero_and_one_sized_jobs_run_inline() {
        let pool = ExecPool::new(4);
        pool.run(0, |_| panic!("must not run"));
        let caller = std::thread::current().id();
        let ran_on = Mutex::new(None);
        pool.run(1, |_| {
            *ran_on.lock().unwrap() = Some(std::thread::current().id());
        });
        assert_eq!(ran_on.lock().unwrap().unwrap(), caller);
    }
}

//! Minimal JSON codec — parser + writer — built from scratch (no serde in
//! this offline environment). Parses `artifacts/manifest.json`, config
//! presets and checkpoint metadata; writes experiment reports.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not needed for
                            // our manifests); map to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// -- writer -------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e2}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(),
                   Some(-250.0));
        // Round-trip through the writer.
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_nested_arrays_and_escapes() {
        let v = Json::parse(r#"[[1,2],[3,[4]],"A\t"]"#).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr[2].as_str(), Some("A\t"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "artifacts": {"m_fwd": {"file": "m_fwd.hlo.txt",
            "inputs": [{"name": "x", "shape": [2, 3], "dtype": "float32"}],
            "outputs": []}},
          "configs": {"m": {"tau": 0.75, "n_ffn_experts": 8}}
        }"#;
        let v = Json::parse(src).unwrap();
        let inp = v.get("artifacts").unwrap().get("m_fwd").unwrap()
            .get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inp[0].get("shape").unwrap().as_arr().unwrap()[1]
            .as_usize(), Some(3));
    }
}

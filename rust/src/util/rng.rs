//! Deterministic pseudo-random number generation (SplitMix64 + xoshiro256++)
//! — the substrate behind parameter init, workload generation and the
//! property-testing harness. No external `rand` crate in this environment.

/// SplitMix64: used to seed the main generator and for cheap streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality; the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for per-worker/per-expert RNGs).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless method.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped — fine
    /// for init/workload purposes).
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut u = self.next_f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
            let k = r.below(13);
            assert!(k < 13);
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 1.0])] += 1;
        }
        assert!(counts[1] > counts[0] && counts[1] > counts[2]);
        let frac = counts[1] as f64 / 30_000.0;
        assert!((frac - 0.5).abs() < 0.03, "{frac}");
    }

    #[test]
    fn split_streams_differ() {
        let mut r = Rng::new(5);
        let mut a = r.split(1);
        let mut b = r.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

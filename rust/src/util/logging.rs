//! Minimal leveled stderr logger with wall-clock-relative timestamps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=error 1=info 2=debug

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_verbose(on: bool) {
    // ordering: standalone level flag, no data published alongside it.
    LEVEL.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

pub fn set_quiet(on: bool) {
    if on {
        // ordering: standalone level flag, no dependent data.
        LEVEL.store(0, Ordering::Relaxed);
    }
}

fn stamp() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: u8, tag: &str, msg: std::fmt::Arguments) {
    // ordering: a stale level only drops/keeps a log line — harmless.
    if level <= LEVEL.load(Ordering::Relaxed) {
        eprintln!("[{:9.3}s {tag}] {msg}", stamp());
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(1, "info", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(2, "debug", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => {
        $crate::util::logging::log(0, "warn", format_args!($($arg)*))
    };
}

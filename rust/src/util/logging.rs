//! Minimal leveled stderr logger with wall-clock-relative timestamps.
//!
//! Levels: error=0, warn=1, info=2, debug=3. The default level is info;
//! `--verbose` raises it to debug and `--quiet` drops it to error —
//! which (unlike the old two-level scheme, where warnings logged at
//! level 0) really does suppress warnings. Every `warn_log!` is also
//! mirrored into the process-wide [`crate::obs::warnings_total`]
//! counter, so suppressed warnings stay countable and exportable
//! (`moepp_warnings_total`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

pub const LEVEL_ERROR: u8 = 0;
pub const LEVEL_WARN: u8 = 1;
pub const LEVEL_INFO: u8 = 2;
pub const LEVEL_DEBUG: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_INFO);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_verbose(on: bool) {
    // ordering: standalone level flag, no data published alongside it.
    LEVEL.store(
        if on { LEVEL_DEBUG } else { LEVEL_INFO },
        Ordering::Relaxed,
    );
}

pub fn set_quiet(on: bool) {
    if on {
        // ordering: standalone level flag, no dependent data.
        LEVEL.store(LEVEL_ERROR, Ordering::Relaxed);
    }
}

/// The current threshold (test hook).
pub fn level() -> u8 {
    // ordering: standalone level flag.
    LEVEL.load(Ordering::Relaxed)
}

fn stamp() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: u8, tag: &str, msg: std::fmt::Arguments) {
    // ordering: a stale level only drops/keeps a log line — harmless.
    if level <= LEVEL.load(Ordering::Relaxed) {
        eprintln!("[{:9.3}s {tag}] {msg}", stamp());
    }
}

/// `warn_log!`'s target: counts the warning whether or not it prints.
pub fn warn(msg: std::fmt::Arguments) {
    crate::obs::note_warning();
    log(LEVEL_WARN, "warn", msg);
}

#[macro_export]
macro_rules! error_log {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::LEVEL_ERROR,
            "error",
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::LEVEL_INFO,
            "info",
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::LEVEL_DEBUG,
            "debug",
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => {
        $crate::util::logging::warn(format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quiet_suppresses_warns_but_warnings_stay_countable() {
        // Level bookkeeping: quiet drops below warn, verbose raises to
        // debug, default sits at info. (Global state — restore after.)
        let before = level();
        set_quiet(true);
        assert!(level() < LEVEL_WARN, "--quiet must suppress warns");
        set_verbose(true);
        assert_eq!(level(), LEVEL_DEBUG);
        set_verbose(false);
        assert_eq!(level(), LEVEL_INFO);
        // warn_log! mirrors into the obs counter even while quiet.
        set_quiet(true);
        let w0 = crate::obs::warnings_total();
        crate::warn_log!("suppressed but counted");
        assert_eq!(crate::obs::warnings_total(), w0 + 1);
        LEVEL.store(before, Ordering::Relaxed);
    }
}

//! Scoped data-parallel helpers built on `std::thread::scope` (no rayon in
//! this offline environment).
//!
//! On this reproduction testbed there is a single CPU core, so the pool
//! defaults to the available parallelism but all algorithms remain correct
//! (and are tested) for any worker count.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_start, chunk)` over mutable, disjoint chunks of `data` on
/// `workers` threads. Chunks are contiguous and cover `data` exactly.
pub fn parallel_chunks_mut<T: Send, F>(
    data: &mut [T],
    workers: usize,
    chunk: usize,
    f: F,
) where
    F: Fn(usize, &mut [T]) + Sync,
{
    let workers = workers.max(1);
    if workers == 1 || data.len() <= chunk {
        let mut start = 0;
        let total = data.len();
        for c in data.chunks_mut(chunk.max(1)) {
            f(start, c);
            start += c.len();
            if start >= total {
                break;
            }
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let n = data.len();
    let base = data.as_mut_ptr() as usize;
    std::thread::scope(|s| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let len = chunk.min(n - start);
                // SAFETY: [start, start+len) ranges are disjoint because
                // `next` hands each range to exactly one worker, and the
                // scope guarantees threads end before `data` is reused.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(
                        (base as *mut T).add(start),
                        len,
                    )
                };
                f(start, slice);
            });
        }
    });
}

/// Parallel iteration over indices [0, n) with a worker-count cap; the body
/// must be side-effect-disjoint per index (enforced by the caller).
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, workers: usize, f: F) {
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map [0, n) -> Vec<R> in parallel, preserving order.
pub fn parallel_map<R: Send + Default + Clone, F>(
    n: usize,
    workers: usize,
    f: F,
) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let mut out = vec![R::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut R>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, workers, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_everything() {
        for workers in [1, 2, 4] {
            let mut v = vec![0u64; 1003];
            parallel_chunks_mut(&mut v, workers, 64, |start, c| {
                for (i, x) in c.iter_mut().enumerate() {
                    *x = (start + i) as u64;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i as u64);
            }
        }
    }

    #[test]
    fn parallel_for_hits_every_index_once() {
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        parallel_for(500, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 3, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn zero_n_is_fine() {
        parallel_for(0, 4, |_| panic!("must not run"));
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}

//! Scoped data-parallel helpers built on `std::thread::scope` (no rayon in
//! this offline environment).
//!
//! These spawn OS threads on **every call**. The serving hot path now
//! fans out over the persistent [`crate::util::pool::ExecPool`] instead
//! (DESIGN.md §12); the scoped helpers remain as the `Executor::Scoped`
//! fallback so `moepp bench forward --executor both` can measure
//! pool-vs-scoped, and every spawn is counted into
//! [`crate::util::pool::thread_spawns`] so the steady-state zero-spawn
//! regression can see them.
//!
//! On this reproduction testbed there is a single CPU core, so callers
//! default to the available parallelism but all algorithms remain correct
//! (and are tested) for any worker count.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::pool::note_spawn;

/// Number of worker threads to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parallel iteration over indices [0, n) with a worker-count cap; the body
/// must be side-effect-disjoint per index (enforced by the caller).
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, workers: usize, f: F) {
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            note_spawn();
            s.spawn(move || loop {
                // ordering: pure claim ticket; scope join publishes the
                // workers' writes back to the caller.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map [0, n) -> Vec<R> in parallel, preserving order. Slots are written
/// through `Executor::for_each_mut` (the single disjoint-`&mut`
/// primitive, which dispatches back to [`parallel_for`] for the scoped
/// variant) — the per-slot `Mutex` this used to take was pure overhead,
/// since no two workers ever share an index.
pub fn parallel_map<R: Send + Default + Clone, F>(
    n: usize,
    workers: usize,
    f: F,
) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let mut out = vec![R::default(); n];
    crate::util::pool::Executor::Scoped { workers }
        .for_each_mut(&mut out, |i, slot| *slot = f(i));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_hits_every_index_once() {
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        parallel_for(500, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        // The order/coverage oracle for the lock-free slot writes.
        let out = parallel_map(100, 3, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn zero_n_is_fine() {
        parallel_for(0, 4, |_| panic!("must not run"));
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}

//! Micro property-testing harness (no proptest crate offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it retries with a simple size-halving shrink pass and panics
//! with the failing seed so the case is reproducible.

use super::rng::Rng;

/// Configuration for a property run.
pub struct Prop {
    pub name: &'static str,
    pub cases: usize,
    pub base_seed: u64,
}

impl Prop {
    pub fn new(name: &'static str) -> Self {
        Self { name, cases: 64, base_seed: 0x5EED }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Run the property over `cases` seeds. `gen` builds an input from an
    /// RNG; `prop` returns Err(reason) on violation.
    pub fn run<T, G, P>(self, gen: G, prop: P)
    where
        T: std::fmt::Debug,
        G: Fn(&mut Rng) -> T,
        P: Fn(&T) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64);
            let mut rng = Rng::new(seed);
            let input = gen(&mut rng);
            if let Err(reason) = prop(&input) {
                panic!(
                    "property '{}' failed (seed {seed}, case {case}): \
                     {reason}\ninput: {input:?}",
                    self.name
                );
            }
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn f32_in(rng: &mut Rng, lo: f32, hi: f32) -> f32 {
        lo + rng.next_f32() * (hi - lo)
    }

    pub fn vec_normal(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.next_normal() * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        Prop::new("sum-commutes").cases(32).run(
            |rng| (rng.next_f32(), rng.next_f32()),
            |(a, b)| {
                if (a + b - (b + a)).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err("addition not commutative?!".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        Prop::new("always-fails").cases(4).run(
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }
}

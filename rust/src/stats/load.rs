//! Fig. 4 / A–E: expert-load distribution at the task level.
//!
//! For each task tag, run the engine over that task's token stream and
//! accumulate per-layer, per-expert assignment fractions, grouped by expert
//! kind (FFN / zero / copy / constant).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::MoeConfig;
use crate::coordinator::engine::MoeEngine;
use crate::tensor::Tensor;

/// Per-(task, layer) expert load snapshot.
#[derive(Clone, Debug, Default)]
pub struct TaskLoad {
    /// [n_layers][n_experts] assignment fractions (sum to top_k per token).
    pub per_layer: Vec<Vec<f64>>,
    pub tokens: usize,
}

impl TaskLoad {
    /// Fraction of assignments per expert *kind* at `layer`.
    pub fn kind_fractions(&self, cfg: &MoeConfig, layer: usize)
        -> BTreeMap<&'static str, f64> {
        let mut m: BTreeMap<&'static str, f64> = BTreeMap::new();
        let total: f64 = self.per_layer[layer].iter().sum();
        for (e, &c) in self.per_layer[layer].iter().enumerate() {
            *m.entry(cfg.kind(e).label()).or_default() +=
                c / total.max(1e-12);
        }
        m
    }

    /// Mean surviving-equivalent FFN activations per token at `layer`
    /// (pre-capacity counts normalised by tokens).
    pub fn ffn_per_token(&self, cfg: &MoeConfig, layer: usize) -> f64 {
        let ffn: f64 = self.per_layer[layer][..cfg.n_ffn_experts]
            .iter()
            .sum();
        ffn / self.tokens as f64
    }
}

/// Run the engine over per-task token streams and collect load stats.
pub fn task_level_load(
    engine: &mut MoeEngine,
    tasks: &[(String, Tensor)],
) -> Result<BTreeMap<String, TaskLoad>> {
    let mut out = BTreeMap::new();
    for (name, tokens) in tasks {
        let (_, stats) = engine.forward_stack(tokens)?;
        let mut load = TaskLoad {
            per_layer: Vec::with_capacity(stats.per_layer.len()),
            tokens: tokens.shape[0],
        };
        for l in &stats.per_layer {
            load.per_layer.push(
                l.expert_counts.iter().map(|&c| c as f64).collect(),
            );
        }
        out.insert(name.clone(), load);
    }
    Ok(out)
}

/// Render the Fig. 4-style report for one layer across tasks.
pub fn render_layer_report(
    cfg: &MoeConfig,
    loads: &BTreeMap<String, TaskLoad>,
    layer: usize,
) -> String {
    let mut s = format!("== expert load distribution, layer {layer} ==\n");
    for (task, load) in loads {
        let kinds = load.kind_fractions(cfg, layer);
        s.push_str(&format!(
            "{task:12} ffn {:.3}  zero {:.3}  copy {:.3}  const {:.3}  \
             (ffn/tok {:.2})\n",
            kinds.get("ffn").unwrap_or(&0.0),
            kinds.get("zero").unwrap_or(&0.0),
            kinds.get("copy").unwrap_or(&0.0),
            kinds.get("const").unwrap_or(&0.0),
            load.ffn_per_token(cfg, layer),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn load_fractions_sum_to_one() {
        let cfg = MoeConfig::preset("test");
        let mut engine = MoeEngine::native(cfg.clone(), 0);
        let mut rng = Rng::new(0);
        let tasks = vec![
            ("taskA".to_string(),
             Tensor::randn(&mut rng, &[64, cfg.d_model], 1.0)),
            ("taskB".to_string(),
             Tensor::randn(&mut rng, &[64, cfg.d_model], 2.0)),
        ];
        let loads = task_level_load(&mut engine, &tasks).unwrap();
        for load in loads.values() {
            for layer in 0..cfg.n_layers {
                let total: f64 =
                    load.kind_fractions(&cfg, layer).values().sum();
                assert!((total - 1.0).abs() < 1e-9, "{total}");
            }
        }
        let report = render_layer_report(&cfg, &loads, 0);
        assert!(report.contains("taskA") && report.contains("taskB"));
    }

    #[test]
    fn distinct_tasks_have_distinct_assignments() {
        // Fig. 4 finding (iii): expert assignment varies across tasks.
        let cfg = MoeConfig::preset("test");
        let mut engine = MoeEngine::native(cfg.clone(), 1);
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&mut rng, &[128, cfg.d_model], 0.5);
        let b = Tensor::randn(&mut rng, &[128, cfg.d_model], 3.0);
        let loads = task_level_load(
            &mut engine,
            &[("a".into(), a), ("b".into(), b)],
        )
        .unwrap();
        let la = &loads["a"].per_layer[0];
        let lb = &loads["b"].per_layer[0];
        assert_ne!(la, lb);
    }
}

//! Fig. 5: number of FFN experts activated per token, at the token level.
//!
//! The paper's finding: semantically heavy tokens (verbs) average ~1.7+ FFN
//! experts, fragments average <1.5. We reproduce the *mechanism* over the
//! synthetic corpus: per token-id mean surviving FFN activations, reported
//! against token frequency (high-frequency ⇒ "simple" function tokens).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::MoeConfig;
use crate::coordinator::dispatch::DispatchPlan;
use crate::moe::router::route;
use crate::moe::weights::StackWeights;
use crate::tensor::Tensor;

/// Accumulated per-token-id FFN activation statistics.
#[derive(Clone, Debug, Default)]
pub struct TokenActivations {
    /// token id -> (sum of surviving FFN assignments across layers, count
    /// of (occurrence, layer) observations).
    pub acc: BTreeMap<i32, (f64, u64)>,
    pub occurrences: BTreeMap<i32, u64>,
}

impl TokenActivations {
    pub fn mean_ffn(&self, token: i32) -> Option<f64> {
        self.acc.get(&token).map(|&(s, c)| s / c as f64)
    }

    /// (token, frequency, mean FFN/layer) rows sorted by frequency desc.
    pub fn rows(&self) -> Vec<(i32, u64, f64)> {
        let mut v: Vec<_> = self
            .acc
            .iter()
            .map(|(&tok, &(s, c))| {
                (tok, self.occurrences[&tok], s / c as f64)
            })
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }
}

/// Run token-id sequences through the MoE stack (embedding them with the
/// engine-owned embedding proxy) and accumulate FFN activations per id.
///
/// `embed` maps token ids to hidden rows — here a deterministic random
/// embedding table, which preserves the property that the same id always
/// takes the same route at layer 0.
pub fn token_level_activations(
    weights: &StackWeights,
    cfg: &MoeConfig,
    embed: &Tensor, // [V, D]
    sequences: &[Vec<i32>],
) -> Result<TokenActivations> {
    let d = cfg.d_model;
    let mut out = TokenActivations::default();
    for seq in sequences {
        let t = seq.len();
        let mut h = Tensor::zeros(&[t, d]);
        for (i, &tok) in seq.iter().enumerate() {
            h.row_mut(i)
                .copy_from_slice(embed.row(tok as usize));
            *out.occurrences.entry(tok).or_default() += 1;
        }
        let mut prev: Option<Tensor> = None;
        for layer in &weights.layers {
            let routing =
                route(&h, &layer.router, prev.as_ref(), cfg.top_k);
            let plan = DispatchPlan::build(&routing, cfg, t);
            let mut per_tok = vec![0u32; t];
            for b in &plan.ffn_batches {
                for &tok_idx in &b.tokens {
                    per_tok[tok_idx] += 1;
                }
            }
            for (i, &tok) in seq.iter().enumerate() {
                let e = out.acc.entry(tok).or_default();
                e.0 += per_tok[i] as f64;
                e.1 += 1;
            }
            // Forward natively for the next layer's input.
            let (y, routing2, _) = crate::moe::layer::layer_forward(
                layer, &h, prev.as_ref(), cfg,
            );
            prev = Some(routing2.scores);
            for (hv, yv) in h.data.iter_mut().zip(&y.data) {
                *hv += yv;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn accumulates_over_layers_and_occurrences() {
        let cfg = MoeConfig::preset("test");
        let w = StackWeights::init(0, &cfg);
        let mut rng = Rng::new(0);
        let embed =
            Tensor::randn(&mut rng, &[cfg.vocab_size, cfg.d_model], 1.0);
        let seqs = vec![vec![1, 2, 3, 1], vec![1, 5, 5, 5]];
        let acts =
            token_level_activations(&w, &cfg, &embed, &seqs).unwrap();
        // Token 1 appears 3 times x 2 layers = 6 observations.
        assert_eq!(acts.acc[&1].1, 3 * cfg.n_layers as u64);
        assert_eq!(acts.occurrences[&1], 3);
        // Mean FFN per layer is within [0, top_k].
        for (_, _, mean) in acts.rows() {
            assert!(mean >= 0.0 && mean <= cfg.top_k as f64);
        }
    }

    #[test]
    fn same_token_same_first_layer_route() {
        // Deterministic embedding ⇒ identical layer-0 routing for repeats.
        let cfg = MoeConfig::preset("test");
        let w = StackWeights::init(3, &cfg);
        let mut rng = Rng::new(1);
        let embed =
            Tensor::randn(&mut rng, &[cfg.vocab_size, cfg.d_model], 1.0);
        let a = token_level_activations(&w, &cfg, &embed,
                                        &[vec![7; 16]]).unwrap();
        // All 16 occurrences of token 7 at layer 0 take the same route, so
        // mean is an integer divided by layers... at least it's constant
        // per occurrence at layer 0; just sanity-check bounds here.
        assert!(a.mean_ffn(7).unwrap() <= cfg.top_k as f64);
    }
}

//! Fig. 6: impact of gating residuals on routing scores.
//!
//! Per layer, with and without residuals, we record the mean and variance
//! of the top-1 and top-2 routing *probabilities* across tokens. The
//! paper's finding: residuals reduce score variance (stable routing)
//! without shifting mean or range.

use anyhow::Result;

use crate::config::MoeConfig;
use crate::moe::router::route;
use crate::moe::weights::StackWeights;
use crate::tensor::Tensor;

#[derive(Clone, Debug, Default)]
pub struct GatingTrace {
    /// Per layer: (mean top1, var top1, mean top2, var top2).
    pub layers: Vec<(f64, f64, f64, f64)>,
    /// Per layer: variance of the raw scores.
    pub score_var: Vec<f64>,
}

/// Trace routing statistics through the stack.
pub fn trace(
    weights: &StackWeights,
    cfg: &MoeConfig,
    x: &Tensor,
    with_residual: bool,
) -> Result<GatingTrace> {
    let mut cfg = cfg.clone();
    cfg.gating_residual = with_residual;
    let t = x.shape[0];
    let mut out = GatingTrace::default();
    let mut h = x.clone();
    let mut prev: Option<Tensor> = None;
    for layer in &weights.layers {
        let routing = route(
            &h,
            &layer.router,
            if with_residual { prev.as_ref() } else { None },
            cfg.top_k,
        );
        let (mut m1, mut m2) = (0.0f64, 0.0f64);
        let mut tops: Vec<(f64, f64)> = Vec::with_capacity(t);
        for tk in &routing.topk {
            let a = tk[0].1 as f64;
            let b = tk.get(1).map(|v| v.1 as f64).unwrap_or(0.0);
            m1 += a;
            m2 += b;
            tops.push((a, b));
        }
        m1 /= t as f64;
        m2 /= t as f64;
        let v1 = tops.iter().map(|(a, _)| (a - m1).powi(2)).sum::<f64>()
            / t as f64;
        let v2 = tops.iter().map(|(_, b)| (b - m2).powi(2)).sum::<f64>()
            / t as f64;
        out.layers.push((m1, v1, m2, v2));
        let sm: f64 = routing.scores.data.iter().map(|&v| v as f64).sum::<f64>()
            / routing.scores.numel() as f64;
        out.score_var.push(
            routing.scores.data.iter()
                .map(|&v| (v as f64 - sm).powi(2)).sum::<f64>()
                / routing.scores.numel() as f64,
        );
        let (y, routing2, _) = crate::moe::layer::layer_forward(
            layer, &h, if with_residual { prev.as_ref() } else { None },
            &cfg,
        );
        prev = Some(routing2.scores);
        h = y;
    }
    Ok(out)
}

/// Mean variance across layers (the Fig. 6 headline comparison).
pub fn mean_top1_variance(trace: &GatingTrace) -> f64 {
    if trace.layers.is_empty() {
        return 0.0;
    }
    trace.layers.iter().map(|l| l.1).sum::<f64>()
        / trace.layers.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn trace_shapes_and_bounds() {
        let cfg = MoeConfig::preset("test");
        let w = StackWeights::init(0, &cfg);
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&mut rng, &[64, cfg.d_model], 1.0);
        let t = trace(&w, &cfg, &x, true).unwrap();
        assert_eq!(t.layers.len(), cfg.n_layers);
        for &(m1, v1, m2, v2) in &t.layers {
            assert!(m1 >= m2, "top1 mean >= top2 mean");
            assert!((0.0..=1.0).contains(&m1));
            assert!(v1 >= 0.0 && v2 >= 0.0);
        }
    }

    #[test]
    fn residual_toggle_changes_downstream_layers() {
        let cfg = MoeConfig::preset("test");
        let mut w = StackWeights::init(1, &cfg);
        // Non-zero Wg so the toggle matters.
        let n = cfg.n_experts();
        for layer in &mut w.layers {
            for i in 0..n {
                layer.router.wg.data[i * n + i] = 0.7;
            }
        }
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&mut rng, &[64, cfg.d_model], 1.0);
        let with = trace(&w, &cfg, &x, true).unwrap();
        let without = trace(&w, &cfg, &x, false).unwrap();
        // Layer 0 identical; deeper layers differ.
        assert!((with.layers[0].0 - without.layers[0].0).abs() < 1e-9);
        assert!(with.score_var[1] != without.score_var[1]);
    }
}

//! Analysis pipelines behind the paper's qualitative figures:
//!
//! * Fig. 4 / A–E — expert-load distribution at the task level;
//! * Fig. 5      — FFN experts activated per token at the token level;
//! * Fig. 6      — effect of gating residuals on routing-score statistics.
//!
//! Each pipeline runs the native engine over tagged evaluation streams and
//! renders CSV plus ASCII bar charts (this testbed has no plotting stack).

pub mod gating;
pub mod load;
pub mod token_level;

/// Render a labelled ASCII horizontal bar chart (max width 50 cols).
pub fn bar_chart(rows: &[(String, f64)]) -> String {
    let max = rows.iter().map(|r| r.1).fold(0.0, f64::max).max(1e-12);
    let label_w = rows.iter().map(|r| r.0.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (label, v) in rows {
        let n = ((v / max) * 50.0).round() as usize;
        out.push_str(&format!(
            "{label:label_w$} | {}{} {v:.3}\n",
            "#".repeat(n),
            " ".repeat(50 - n)
        ));
    }
    out
}

/// Write rows as CSV.
pub fn to_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = header.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn bar_chart_renders() {
        let s = super::bar_chart(&[
            ("ffn".to_string(), 2.0),
            ("zero".to_string(), 1.0),
        ]);
        assert!(s.contains("ffn"));
        let ffn_hashes =
            s.lines().next().unwrap().matches('#').count();
        let zero_hashes = s.lines().nth(1).unwrap().matches('#').count();
        assert_eq!(ffn_hashes, 2 * zero_hashes);
    }

    #[test]
    fn csv_shape() {
        let s = super::to_csv(&["a", "b"],
                              &[vec!["1".into(), "2".into()]]);
        assert_eq!(s, "a,b\n1,2\n");
    }
}

//! Heterogeneous load-balance accounting (paper Eq. 7) and load-imbalance
//! metrics used by the monitoring/figures pipeline.

use crate::config::MoeConfig;
use crate::moe::router::Routing;

/// Eq. 7: L_b = N * sum_i eta_i * f_i * P_i  with eta_i ∈ {1, tau}.
///
/// f_i = fraction of tokens selecting expert i (pre-capacity), P_i = mean
/// router probability of expert i. The N scaling matches the L2 (jax)
/// implementation so values are directly comparable.
pub fn balance_loss(routing: &Routing, cfg: &MoeConfig) -> f64 {
    let n = cfg.n_experts();
    let t = routing.topk.len();
    if t == 0 {
        return 0.0;
    }
    let mut f = vec![0.0f64; n];
    for tk in &routing.topk {
        for &(e, _) in tk {
            f[e] += 1.0;
        }
    }
    let mut p = vec![0.0f64; n];
    for row in 0..t {
        for (i, &pr) in routing.probs.row(row).iter().enumerate() {
            p[i] += pr as f64;
        }
    }
    let tf = t as f64;
    (0..n)
        .map(|i| cfg.eta(i) * (f[i] / tf) * (p[i] / tf))
        .sum::<f64>()
        * n as f64
}

/// Per-expert pre-capacity assignment counts.
pub fn assignment_counts(routing: &Routing, n_experts: usize) -> Vec<usize> {
    let mut counts = vec![0usize; n_experts];
    for tk in &routing.topk {
        for &(e, _) in tk {
            counts[e] += 1;
        }
    }
    counts
}

/// Coefficient of variation of FFN-expert load — the imbalance figure the
/// cluster simulator reports per device group.
pub fn load_cv(counts: &[usize]) -> f64 {
    if counts.is_empty() {
        return 0.0;
    }
    let n = counts.len() as f64;
    let mean = counts.iter().sum::<usize>() as f64 / n;
    if mean == 0.0 {
        return 0.0;
    }
    let var = counts
        .iter()
        .map(|&c| (c as f64 - mean).powi(2))
        .sum::<f64>()
        / n;
    var.sqrt() / mean
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::router::{route, RouterWeights};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn mk_routing(seed: u64, t: usize, cfg: &MoeConfig) -> Routing {
        let mut rng = Rng::new(seed);
        let w = RouterWeights::init(&mut rng, cfg.n_experts(), cfg.d_model);
        let x = Tensor::randn(&mut rng, &[t, cfg.d_model], 1.0);
        route(&x, &w, None, cfg.top_k)
    }

    #[test]
    fn uniform_router_gives_baseline_loss() {
        // With perfectly uniform probs and assignments, Eq. 7 gives
        // N * sum_i eta_i * (K/N) * (1/N) = K * mean(eta).
        let cfg = MoeConfig::preset("test");
        let n = cfg.n_experts();
        let t = 64;
        let probs = Tensor::full(&[t, n], 1.0 / n as f32);
        let mut topk = Vec::new();
        for i in 0..t {
            // Spread assignments round-robin so f is uniform.
            let a = (2 * i) % n;
            let b = (2 * i + 1) % n;
            topk.push(vec![(a, 1.0 / n as f32), (b, 1.0 / n as f32)]);
        }
        let routing = Routing {
            scores: Tensor::zeros(&[t, n]),
            probs,
            topk,
        };
        let got = balance_loss(&routing, &cfg);
        let mean_eta: f64 =
            (0..n).map(|i| cfg.eta(i)).sum::<f64>() / n as f64;
        let want = cfg.top_k as f64 * mean_eta;
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn collapse_increases_loss() {
        let cfg = MoeConfig::preset("test");
        let balanced = mk_routing(0, 128, &cfg);
        let l_bal = balance_loss(&balanced, &cfg);
        // Force collapse: everything to expert 0.
        let mut collapsed = balanced.clone();
        for tk in collapsed.topk.iter_mut() {
            *tk = vec![(0, 0.9), (1, 0.05)];
        }
        let t = collapsed.topk.len();
        let n = cfg.n_experts();
        collapsed.probs = Tensor::zeros(&[t, n]);
        for i in 0..t {
            collapsed.probs.row_mut(i)[0] = 0.9;
            collapsed.probs.row_mut(i)[1] = 0.05;
        }
        let l_col = balance_loss(&collapsed, &cfg);
        assert!(l_col > l_bal, "{l_col} vs {l_bal}");
    }

    #[test]
    fn tau_discounts_zc_concentration() {
        // Same concentrated-on-ZC routing, lower tau -> lower loss.
        let mut cfg = MoeConfig::preset("test");
        let zc0 = cfg.n_ffn_experts; // first zero expert
        let t = 32;
        let n = cfg.n_experts();
        let mut probs = Tensor::zeros(&[t, n]);
        let mut topk = Vec::new();
        for i in 0..t {
            probs.row_mut(i)[zc0] = 0.9;
            probs.row_mut(i)[0] = 0.1;
            topk.push(vec![(zc0, 0.9f32), (0, 0.1f32)]);
        }
        let routing = Routing {
            scores: Tensor::zeros(&[t, n]),
            probs,
            topk,
        };
        cfg.tau = 1.0;
        let hi = balance_loss(&routing, &cfg);
        cfg.tau = 0.1;
        let lo = balance_loss(&routing, &cfg);
        assert!(lo < hi, "{lo} vs {hi}");
    }

    #[test]
    fn counts_and_cv() {
        let cfg = MoeConfig::preset("test");
        let r = mk_routing(1, 200, &cfg);
        let counts = assignment_counts(&r, cfg.n_experts());
        assert_eq!(counts.iter().sum::<usize>(), 200 * cfg.top_k);
        assert_eq!(load_cv(&[5, 5, 5, 5]), 0.0);
        assert!(load_cv(&[10, 0, 0, 0]) > 1.0);
    }
}

//! Expert implementations (paper Sec. 3.1).
//!
//! The FFN expert is the only one with real compute: a SwiGLU MLP
//! (~6·D·F FLOPs/token). The three zero-computation experts are:
//!
//! * zero     — `E(x) = 0`          (Eq. 3): *discard*, costs nothing;
//! * copy     — `E(x) = x`          (Eq. 4): *skip*, a memcpy;
//! * constant — `E(x) = a1·x + a2·v`(Eq. 5): *replace*, a 2×D matvec + axpy.
//!
//! The serving engine exploits exactly this asymmetry: FFN experts queue
//! into bucketed micro-batches (possibly on another device), ZC experts are
//! applied inline where the token already lives.

use crate::tensor::ops::{
    axpy, dot, dot_i8, quantize_row_i8, silu, softmax_slice,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Weights of one SwiGLU FFN expert.
#[derive(Clone, Debug)]
pub struct FfnExpert {
    pub w1: Tensor, // [D, F] gate proj
    pub w3: Tensor, // [D, F] linear proj
    pub w2: Tensor, // [F, D] down proj
}

impl FfnExpert {
    pub fn init(rng: &mut Rng, d: usize, f: usize) -> FfnExpert {
        let sd = (d as f32).powf(-0.5);
        let sf = (f as f32).powf(-0.5);
        FfnExpert {
            w1: Tensor::randn(rng, &[d, f], sd),
            w3: Tensor::randn(rng, &[d, f], sd),
            w2: Tensor::randn(rng, &[f, d], sf),
        }
    }

    /// y = (silu(x@w1) * (x@w3)) @ w2 for a batch of rows.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let (b, d) = x.dims2();
        let mut out = Tensor::zeros(&[b, d]);
        let mut scratch = FfnScratch::new(self.w1.shape[1]);
        self.forward_batch_into(x, None, &mut scratch, &mut out.data, None);
        out
    }

    /// Batched forward with reusable scratch: the engine hot path.
    ///
    /// **Accumulates** `gates[i] * FFN(x[i])` into `out` (axpy — never
    /// overwrites): at contiguous rows in order when `scatter == None`,
    /// or at row `scatter[i]` otherwise. Callers reusing an output
    /// buffer must zero it first (`ShardBuf::prepare` does). `gates ==
    /// None` means gate 1.0 everywhere.
    pub fn forward_batch_into(
        &self,
        x: &Tensor,
        gates: Option<&[f32]>,
        scratch: &mut FfnScratch,
        out: &mut [f32],
        scatter: Option<&[usize]>,
    ) {
        let (b, d) = x.dims2();
        let f = self.w1.shape[1];
        let _ = scratch.ensure(f.max(d));
        // `f_tile == 0` means untiled (one full-width pass), the exact
        // historical loop; tiling never changes results — each output
        // column's accumulation order over k is untouched.
        let ft = if scratch.f_tile == 0 { f } else { scratch.f_tile.min(f) };
        const BLK: usize = FFN_TOKEN_BLOCK;
        let mut i = 0;
        while i < b {
            let blk = (b - i).min(BLK);
            let (hg, hl, acc) = scratch.triple();
            hg[..blk * f].fill(0.0);
            hl[..blk * f].fill(0.0);
            // Up-projections (§Perf iteration 3): the kernel is
            // weight-stream bound (w1/w3/w2 re-read per token), so BLK
            // tokens share one pass over the weight rows — and the pass
            // is tiled to `ft` columns at a time so the 2·BLK hg/hl
            // working rows stay L1-resident at large d_ff (the tile comes
            // from the arena's cache hint, DESIGN.md §11).
            let mut c0 = 0;
            while c0 < f {
                let c1 = (c0 + ft).min(f);
                for k in 0..d {
                    let w1row = &self.w1.data[k * f + c0..k * f + c1];
                    let w3row = &self.w3.data[k * f + c0..k * f + c1];
                    for t in 0..blk {
                        let xv = x.data[(i + t) * d + k];
                        if xv == 0.0 {
                            continue;
                        }
                        axpy(xv, w1row, &mut hg[t * f + c0..t * f + c1]);
                        axpy(xv, w3row, &mut hl[t * f + c0..t * f + c1]);
                    }
                }
                c0 = c1;
            }
            for (a, &v) in hg[..blk * f].iter_mut().zip(&hl[..blk * f]) {
                *a = silu(*a) * v;
            }
            // Down-projection into a contiguous block accumulator, then
            // gate-scale and scatter.
            acc[..blk * d].fill(0.0);
            for k in 0..f {
                let w2row = &self.w2.data[k * d..(k + 1) * d];
                for t in 0..blk {
                    let hv = hg[t * f + k];
                    if hv != 0.0 {
                        axpy(hv, w2row, &mut acc[t * d..(t + 1) * d]);
                    }
                }
            }
            for t in 0..blk {
                let g = gates.map_or(1.0, |gs| gs[i + t]);
                let at = scatter.map_or(i + t, |s| s[i + t]);
                axpy(g, &acc[t * d..(t + 1) * d],
                     &mut out[at * d..(at + 1) * d]);
            }
            i += blk;
        }
    }

    /// Single-token forward into a caller-provided buffer, scaled by `g`.
    pub fn forward_token_into(&self, x: &[f32], g: f32, out: &mut [f32]) {
        let f = self.w1.shape[1];
        let mut hg = vec![0.0f32; f];
        let mut hl = vec![0.0f32; f];
        self.token_kernel(x, g, &mut hg, &mut hl, out);
    }

    /// [`FfnExpert::forward_token_into`] via caller scratch — the oracle
    /// backend's allocation-free path. Bitwise-identical: same loops over
    /// freshly-zeroed intermediates. Returns whether the scratch grew
    /// (arena accounting).
    pub fn forward_token_scratch(
        &self,
        x: &[f32],
        g: f32,
        scratch: &mut FfnScratch,
        out: &mut [f32],
    ) -> bool {
        let d = x.len();
        let f = self.w1.shape[1];
        let grew = scratch.ensure(f.max(d));
        let (hg, hl, _) = scratch.triple();
        hg[..f].fill(0.0);
        hl[..f].fill(0.0);
        self.token_kernel(x, g, &mut hg[..f], &mut hl[..f], out);
        grew
    }

    /// Shared single-token SwiGLU body over zeroed `hg`/`hl` slices of
    /// width `d_ff`.
    fn token_kernel(
        &self,
        x: &[f32],
        g: f32,
        hg: &mut [f32],
        hl: &mut [f32],
        out: &mut [f32],
    ) {
        let d = x.len();
        let f = self.w1.shape[1];
        for (k, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            axpy(xv, &self.w1.data[k * f..(k + 1) * f], hg);
            axpy(xv, &self.w3.data[k * f..(k + 1) * f], hl);
        }
        for (a, &b) in hg.iter_mut().zip(hl.iter()) {
            *a = silu(*a) * b;
        }
        for (k, &hv) in hg.iter().enumerate() {
            if hv != 0.0 {
                axpy(g * hv, &self.w2.data[k * d..(k + 1) * d], out);
            }
        }
    }

    pub fn n_params(&self) -> usize {
        self.w1.numel() + self.w3.numel() + self.w2.numel()
    }
}

/// Tokens processed per weight-stream pass in the batched kernel (and the
/// lane count the scratch buffers are sized for).
pub const FFN_TOKEN_BLOCK: usize = 4;

/// Reusable intermediate buffers for `FfnExpert::forward_batch_into` —
/// keeps the hot loop allocation-free across micro-batches (§Perf).
pub struct FfnScratch {
    hg: Vec<f32>,
    hl: Vec<f32>,
    acc: Vec<f32>,
    /// Up-projection column tile (0 = untiled). Set from the execution
    /// arena's cache hint (`FfnArena::f_tile`, DESIGN.md §11); any value
    /// produces bitwise-identical results — it is purely a locality knob.
    pub f_tile: usize,
}

impl FfnScratch {
    pub fn new(f: usize) -> FfnScratch {
        FfnScratch {
            hg: vec![0.0; FFN_TOKEN_BLOCK * f],
            hl: vec![0.0; FFN_TOKEN_BLOCK * f],
            acc: vec![0.0; FFN_TOKEN_BLOCK * f],
            f_tile: 0,
        }
    }

    /// Grow the buffers to hold `FFN_TOKEN_BLOCK` lanes of width `n`;
    /// returns whether an allocation grew (arena growth accounting).
    pub(crate) fn ensure(&mut self, n: usize) -> bool {
        if self.hg.len() < FFN_TOKEN_BLOCK * n {
            self.hg.resize(FFN_TOKEN_BLOCK * n, 0.0);
            self.hl.resize(FFN_TOKEN_BLOCK * n, 0.0);
            self.acc.resize(FFN_TOKEN_BLOCK * n, 0.0);
            true
        } else {
            false
        }
    }

    fn triple(&mut self) -> (&mut [f32], &mut [f32], &mut [f32]) {
        (&mut self.hg, &mut self.hl, &mut self.acc)
    }
}

/// Per-expert symmetric int8 quantization of one SwiGLU expert
/// (DESIGN.md §17): each weight matrix is stored transposed so every
/// *output channel* is a contiguous int8 row with its own scale
/// (`scale_c = max|w_col_c| / 127`), which is what lets the kernel run
/// the whole reduction in exact i32 arithmetic and dequantize with one
/// multiply per output scalar. Activations are quantized per token row
/// with the same symmetric rule at kernel time. Quantization is a pure
/// per-(expert, channel) / per-token function, so int8 outputs inherit
/// the f32 path's bitwise determinism across workers × partitions ×
/// replica counts.
#[derive(Clone, Debug)]
pub struct QuantFfnExpert {
    pub d_model: usize,
    pub d_ff: usize,
    /// w1ᵀ codes, [F, D] row-major: row `c` is gate-proj output channel
    /// `c`.
    w1q: Vec<i8>,
    /// w3ᵀ codes, [F, D].
    w3q: Vec<i8>,
    /// w2ᵀ codes, [D, F]: row `c` is down-proj output channel `c`.
    w2q: Vec<i8>,
    /// Per-output-channel scales (len F / F / D).
    s1: Vec<f32>,
    s3: Vec<f32>,
    s2: Vec<f32>,
}

impl QuantFfnExpert {
    /// Quantize a full-precision expert. Build-time only (allocates);
    /// the forward path below is allocation-free.
    pub fn from_f32(e: &FfnExpert) -> QuantFfnExpert {
        let (d, f) = e.w1.dims2();
        let mut q = QuantFfnExpert {
            d_model: d,
            d_ff: f,
            w1q: vec![0; f * d],
            w3q: vec![0; f * d],
            w2q: vec![0; d * f],
            s1: vec![0.0; f],
            s3: vec![0.0; f],
            s2: vec![0.0; d],
        };
        let mut col = vec![0.0f32; d.max(f)];
        for c in 0..f {
            for k in 0..d {
                col[k] = e.w1.data[k * f + c];
            }
            q.s1[c] =
                quantize_row_i8(&col[..d], &mut q.w1q[c * d..(c + 1) * d]);
            for k in 0..d {
                col[k] = e.w3.data[k * f + c];
            }
            q.s3[c] =
                quantize_row_i8(&col[..d], &mut q.w3q[c * d..(c + 1) * d]);
        }
        for c in 0..d {
            for k in 0..f {
                col[k] = e.w2.data[k * d + c];
            }
            q.s2[c] =
                quantize_row_i8(&col[..f], &mut q.w2q[c * f..(c + 1) * f]);
        }
        q
    }

    /// Serialized footprint of this expert: int8 codes + f32 scales —
    /// what placement budgeting and migration pricing charge for an
    /// int8 replica (~¼ of the f32 expert).
    pub fn bytes(&self) -> usize {
        self.w1q.len()
            + self.w3q.len()
            + self.w2q.len()
            + (self.s1.len() + self.s3.len() + self.s2.len()) * 4
    }

    // lint: no-alloc — the int8 expert kernel is steady-state serving
    // code: per-token work must stay off the allocator exactly like the
    // f32 kernel above (DESIGN.md §11, §17).
    /// Batched int8 forward: the quantized twin of
    /// [`FfnExpert::forward_batch_into`] — same accumulate-into-`out`
    /// contract, same gate/scatter semantics, same
    /// [`FFN_TOKEN_BLOCK`]-token weight streaming. Each token's result
    /// is a pure function of its row and the codes (the block shares
    /// only the weight stream, never mixes tokens), so outputs are
    /// independent of blocking, shard boundaries and replica slicing.
    pub fn forward_batch_into(
        &self,
        x: &Tensor,
        gates: Option<&[f32]>,
        scratch: &mut QuantScratch,
        out: &mut [f32],
        scatter: Option<&[usize]>,
    ) {
        let (b, d) = x.dims2();
        debug_assert_eq!(d, self.d_model);
        let f = self.d_ff;
        let _ = scratch.ensure(d, f);
        const BLK: usize = FFN_TOKEN_BLOCK;
        let mut i = 0;
        while i < b {
            let blk = (b - i).min(BLK);
            // 1. Per-token symmetric input quantization.
            for t in 0..blk {
                let row = &x.data[(i + t) * d..(i + t + 1) * d];
                scratch.sx[t] =
                    quantize_row_i8(row, &mut scratch.xq[t * d..(t + 1) * d]);
            }
            // 2. Up-projections: one pass over the int8 weight rows,
            // shared by the block's token lanes; exact i32 reduction,
            // one dequantizing multiply per (token, channel) scalar.
            for c in 0..f {
                let w1row = &self.w1q[c * d..(c + 1) * d];
                let w3row = &self.w3q[c * d..(c + 1) * d];
                for t in 0..blk {
                    let xrow = &scratch.xq[t * d..(t + 1) * d];
                    let g = dot_i8(w1row, xrow) as f32
                        * (self.s1[c] * scratch.sx[t]);
                    let l = dot_i8(w3row, xrow) as f32
                        * (self.s3[c] * scratch.sx[t]);
                    scratch.h[t * f + c] = silu(g) * l;
                }
            }
            // 3. Per-token re-quantization of the hidden activations.
            for t in 0..blk {
                scratch.sh[t] = quantize_row_i8(
                    &scratch.h[t * f..(t + 1) * f],
                    &mut scratch.hq[t * f..(t + 1) * f],
                );
            }
            // 4. Down-projection (i32 reduction, dequantized once per
            // output scalar), then gate-scale and scatter like the f32
            // kernel.
            for c in 0..d {
                let w2row = &self.w2q[c * f..(c + 1) * f];
                for t in 0..blk {
                    let hrow = &scratch.hq[t * f..(t + 1) * f];
                    scratch.acc[t * d + c] = dot_i8(w2row, hrow) as f32
                        * (self.s2[c] * scratch.sh[t]);
                }
            }
            for t in 0..blk {
                let g = gates.map_or(1.0, |gs| gs[i + t]);
                let at = scatter.map_or(i + t, |s| s[i + t]);
                axpy(
                    g,
                    &scratch.acc[t * d..(t + 1) * d],
                    &mut out[at * d..(at + 1) * d],
                );
            }
            i += blk;
        }
    }
    // lint: end
}

/// Reusable buffers for [`QuantFfnExpert::forward_batch_into`]: int8
/// code rows for inputs and hidden activations plus the f32 hidden /
/// output-block intermediates, sized for [`FFN_TOKEN_BLOCK`] lanes.
/// Lives next to [`FfnScratch`] in the arena so a mixed-precision layer
/// has both kernels' scratch at hand without allocating (DESIGN.md §11).
#[derive(Default)]
pub struct QuantScratch {
    xq: Vec<i8>,
    hq: Vec<i8>,
    h: Vec<f32>,
    acc: Vec<f32>,
    sx: [f32; FFN_TOKEN_BLOCK],
    sh: [f32; FFN_TOKEN_BLOCK],
}

impl QuantScratch {
    pub fn new() -> QuantScratch {
        QuantScratch::default()
    }

    /// Grow to hold `FFN_TOKEN_BLOCK` lanes of width `d` (model) and `f`
    /// (hidden); returns whether any backing allocation grew (arena
    /// growth accounting).
    pub(crate) fn ensure(&mut self, d: usize, f: usize) -> bool {
        let mut grew = false;
        if self.xq.len() < FFN_TOKEN_BLOCK * d {
            self.xq.resize(FFN_TOKEN_BLOCK * d, 0);
            self.acc.resize(FFN_TOKEN_BLOCK * d, 0.0);
            grew = true;
        }
        if self.hq.len() < FFN_TOKEN_BLOCK * f {
            self.hq.resize(FFN_TOKEN_BLOCK * f, 0);
            self.h.resize(FFN_TOKEN_BLOCK * f, 0.0);
            grew = true;
        }
        grew
    }
}

/// One placed expert's weights at its stack-wide serving precision —
/// what a cluster worker holds per owned expert. Precision is a
/// per-expert property of the placement plan, uniform across every
/// replica of the expert (DESIGN.md §17), so dispatch can split a
/// replicated expert's micro-batch freely without the outputs depending
/// on which replica ran which slice.
#[derive(Clone, Debug)]
pub enum ExpertParams {
    F32(FfnExpert),
    Int8(QuantFfnExpert),
}

impl ExpertParams {
    // lint: no-alloc — per-unit kernel dispatch on the cluster worker's
    // steady-state path (DESIGN.md §17).
    /// Run the batched kernel for this expert's precision. Both arms
    /// share the accumulate/gate/scatter contract of
    /// [`FfnExpert::forward_batch_into`].
    pub fn forward_batch_into(
        &self,
        x: &Tensor,
        gates: Option<&[f32]>,
        scratch: &mut FfnScratch,
        qscratch: &mut QuantScratch,
        out: &mut [f32],
        scatter: Option<&[usize]>,
    ) {
        match self {
            ExpertParams::F32(e) => {
                e.forward_batch_into(x, gates, scratch, out, scatter)
            }
            ExpertParams::Int8(q) => {
                q.forward_batch_into(x, gates, qscratch, out, scatter)
            }
        }
    }
    // lint: end
}

/// Weights of one constant expert (Eq. 5).
#[derive(Clone, Debug)]
pub struct ConstExpert {
    pub wc: Tensor, // [2, D]
    pub v: Tensor,  // [D]
}

impl ConstExpert {
    pub fn init(rng: &mut Rng, d: usize) -> ConstExpert {
        ConstExpert {
            wc: Tensor::randn(rng, &[2, d], (d as f32).powf(-0.5)),
            v: Tensor::randn(rng, &[d], 0.02),
        }
    }

    /// out += g * (a1 x + a2 v), [a1,a2] = softmax(Wc x).
    pub fn forward_token_into(&self, x: &[f32], g: f32, out: &mut [f32]) {
        let d = x.len();
        let mut logits = [
            dot(x, &self.wc.data[0..d]),
            dot(x, &self.wc.data[d..2 * d]),
        ];
        softmax_slice(&mut logits);
        axpy(g * logits[0], x, out);
        axpy(g * logits[1], &self.v.data, out);
    }

    pub fn alphas(&self, x: &[f32]) -> [f32; 2] {
        let d = x.len();
        let mut logits = [
            dot(x, &self.wc.data[0..d]),
            dot(x, &self.wc.data[d..2 * d]),
        ];
        softmax_slice(&mut logits);
        logits
    }
}

/// Zero expert (Eq. 3): contributes nothing.
pub fn zero_expert_into(_x: &[f32], _g: f32, _out: &mut [f32]) {
    // intentionally empty — "discard"
}

/// Copy expert (Eq. 4): out += g * x.
pub fn copy_expert_into(x: &[f32], g: f32, out: &mut [f32]) {
    axpy(g, x, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffn_batch_matches_per_token() {
        let mut rng = Rng::new(0);
        let (d, f) = (16, 32);
        let e = FfnExpert::init(&mut rng, d, f);
        let x = Tensor::randn(&mut rng, &[5, d], 1.0);
        let batch = e.forward(&x);
        for i in 0..5 {
            let mut out = vec![0.0; d];
            e.forward_token_into(x.row(i), 1.0, &mut out);
            for (a, b) in out.iter().zip(batch.row(i)) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn f_tile_never_changes_results_bitwise() {
        // The tile only reorders *which columns* a weight pass touches;
        // every output column's accumulation order is unchanged, so any
        // tile (including awkward non-divisors) is bitwise-identical to
        // the untiled kernel.
        let mut rng = Rng::new(4);
        let (d, f) = (12, 40);
        let e = FfnExpert::init(&mut rng, d, f);
        let x = Tensor::randn(&mut rng, &[7, d], 1.0);
        let gates: Vec<f32> = (0..7).map(|i| 0.1 + 0.1 * i as f32).collect();
        let run = |tile: usize| {
            let mut scratch = FfnScratch::new(f.max(d));
            scratch.f_tile = tile;
            let mut out = vec![0.0f32; 7 * d];
            e.forward_batch_into(&x, Some(&gates), &mut scratch,
                                 &mut out, None);
            out
        };
        let untiled = run(0);
        for tile in [1, 7, 16, 39, 40, 1000] {
            assert_eq!(run(tile), untiled, "tile={tile} diverged");
        }
    }

    #[test]
    fn token_scratch_matches_allocating_token_forward() {
        let mut rng = Rng::new(5);
        let (d, f) = (10, 24);
        let e = FfnExpert::init(&mut rng, d, f);
        let x: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).cos()).collect();
        let mut scratch = FfnScratch::new(4);
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        e.forward_token_into(&x, 0.8, &mut a);
        let grew = e.forward_token_scratch(&x, 0.8, &mut scratch, &mut b);
        assert!(grew, "undersized scratch must report growth");
        assert_eq!(a, b);
        // Steady state: no further growth, still identical.
        b.fill(0.0);
        assert!(!e.forward_token_scratch(&x, 0.8, &mut scratch, &mut b));
        assert_eq!(a, b);
    }

    #[test]
    fn ffn_gate_scales_linearly() {
        let mut rng = Rng::new(1);
        let e = FfnExpert::init(&mut rng, 8, 16);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        e.forward_token_into(&x, 1.0, &mut a);
        e.forward_token_into(&x, 0.25, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x * 0.25 - y).abs() < 1e-5);
        }
    }

    #[test]
    fn const_expert_is_convex_combination() {
        let mut rng = Rng::new(2);
        let d = 12;
        let e = ConstExpert::init(&mut rng, d);
        let x: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
        let [a1, a2] = e.alphas(&x);
        assert!((a1 + a2 - 1.0).abs() < 1e-5);
        assert!(a1 > 0.0 && a2 > 0.0);
        let mut out = vec![0.0; d];
        e.forward_token_into(&x, 1.0, &mut out);
        for j in 0..d {
            let want = a1 * x[j] + a2 * e.v.data[j];
            assert!((out[j] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn const_expert_zero_wc_gives_even_mix() {
        let mut rng = Rng::new(3);
        let d = 6;
        let mut e = ConstExpert::init(&mut rng, d);
        e.wc = Tensor::zeros(&[2, d]);
        let x = vec![1.0; d];
        let [a1, a2] = e.alphas(&x);
        assert!((a1 - 0.5).abs() < 1e-6 && (a2 - 0.5).abs() < 1e-6);
    }

    #[test]
    fn quant_expert_tracks_f32_within_tolerance() {
        // Kernel-level (routing-free) tolerance pin: per-channel int8
        // weights + per-token int8 activations keep the relative L2
        // error of each output row small. The bound is generous — it is
        // a sanity gate, not a precision claim (DESIGN.md §17).
        use crate::util::proptest::{gen, Prop};
        Prop::new("quant-vs-f32-tolerance").cases(20).run(
            |rng| {
                let d = gen::usize_in(rng, 4, 48);
                let f = gen::usize_in(rng, 4, 64);
                let b = gen::usize_in(rng, 1, 9);
                (d, f, b, rng.next_u64())
            },
            |&(d, f, b, seed)| {
                let mut rng = Rng::new(seed);
                let e = FfnExpert::init(&mut rng, d, f);
                let q = QuantFfnExpert::from_f32(&e);
                let x = Tensor::randn(&mut rng, &[b, d], 1.0);
                let want = e.forward(&x);
                let mut got = vec![0.0f32; b * d];
                let mut qs = QuantScratch::new();
                q.forward_batch_into(&x, None, &mut qs, &mut got, None);
                for t in 0..b {
                    let w = want.row(t);
                    let g = &got[t * d..(t + 1) * d];
                    let refn =
                        w.iter().map(|v| v * v).sum::<f32>().sqrt();
                    let errn = w
                        .iter()
                        .zip(g)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f32>()
                        .sqrt();
                    if errn > 0.15 * refn + 1e-4 {
                        return Err(format!(
                            "row {t}: err {errn} vs ref norm {refn} \
                             (d={d} f={f})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn quant_kernel_is_blocking_and_scatter_invariant() {
        // Per-token independence: running the same rows as one batch,
        // token-by-token, or scattered must be bitwise-identical — the
        // property that makes shard/replica boundaries invisible to the
        // int8 path (DESIGN.md §17).
        let mut rng = Rng::new(9);
        let (d, f, b) = (20, 28, 7);
        let e = FfnExpert::init(&mut rng, d, f);
        let q = QuantFfnExpert::from_f32(&e);
        let x = Tensor::randn(&mut rng, &[b, d], 1.0);
        let gates: Vec<f32> =
            (0..b).map(|i| 0.2 + 0.1 * i as f32).collect();
        let mut whole = vec![0.0f32; b * d];
        let mut qs = QuantScratch::new();
        q.forward_batch_into(
            &x, Some(&gates), &mut qs, &mut whole, None,
        );
        // Token at a time, fresh scratch, scattered to its own row.
        let mut single = vec![0.0f32; b * d];
        for t in 0..b {
            let xt =
                Tensor::from_vec(&[1, d], x.row(t).to_vec());
            let mut qs2 = QuantScratch::new();
            let scatter = [t];
            q.forward_batch_into(
                &xt,
                Some(&gates[t..t + 1]),
                &mut qs2,
                &mut single,
                Some(&scatter),
            );
        }
        assert_eq!(whole, single);
    }

    #[test]
    fn quant_expert_bytes_are_a_quarter_of_f32() {
        let mut rng = Rng::new(10);
        let e = FfnExpert::init(&mut rng, 32, 64);
        let q = QuantFfnExpert::from_f32(&e);
        let f32_bytes = e.n_params() * 4;
        // Codes are 1 byte/weight plus the per-channel f32 scales.
        assert_eq!(q.bytes(), e.n_params() + (64 + 64 + 32) * 4);
        assert!(q.bytes() * 3 < f32_bytes, "{} vs {f32_bytes}", q.bytes());
    }

    #[test]
    fn quant_scratch_growth_settles() {
        let mut qs = QuantScratch::new();
        assert!(qs.ensure(8, 16));
        assert!(!qs.ensure(8, 16));
        assert!(!qs.ensure(4, 8), "smaller shapes reuse the buffers");
        assert!(qs.ensure(8, 32), "wider hidden grows again");
    }

    #[test]
    fn zero_and_copy_semantics() {
        let x = vec![1.0, -2.0, 3.0];
        let mut out = vec![10.0, 10.0, 10.0];
        zero_expert_into(&x, 0.7, &mut out);
        assert_eq!(out, vec![10.0, 10.0, 10.0]);
        copy_expert_into(&x, 0.5, &mut out);
        assert_eq!(out, vec![10.5, 9.0, 11.5]);
    }
}

//! Expert implementations (paper Sec. 3.1).
//!
//! The FFN expert is the only one with real compute: a SwiGLU MLP
//! (~6·D·F FLOPs/token). The three zero-computation experts are:
//!
//! * zero     — `E(x) = 0`          (Eq. 3): *discard*, costs nothing;
//! * copy     — `E(x) = x`          (Eq. 4): *skip*, a memcpy;
//! * constant — `E(x) = a1·x + a2·v`(Eq. 5): *replace*, a 2×D matvec + axpy.
//!
//! The serving engine exploits exactly this asymmetry: FFN experts queue
//! into bucketed micro-batches (possibly on another device), ZC experts are
//! applied inline where the token already lives.

use crate::tensor::ops::{axpy, dot, silu, softmax_slice};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Weights of one SwiGLU FFN expert.
#[derive(Clone, Debug)]
pub struct FfnExpert {
    pub w1: Tensor, // [D, F] gate proj
    pub w3: Tensor, // [D, F] linear proj
    pub w2: Tensor, // [F, D] down proj
}

impl FfnExpert {
    pub fn init(rng: &mut Rng, d: usize, f: usize) -> FfnExpert {
        let sd = (d as f32).powf(-0.5);
        let sf = (f as f32).powf(-0.5);
        FfnExpert {
            w1: Tensor::randn(rng, &[d, f], sd),
            w3: Tensor::randn(rng, &[d, f], sd),
            w2: Tensor::randn(rng, &[f, d], sf),
        }
    }

    /// y = (silu(x@w1) * (x@w3)) @ w2 for a batch of rows.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let (b, d) = x.dims2();
        let mut out = Tensor::zeros(&[b, d]);
        let mut scratch = FfnScratch::new(self.w1.shape[1]);
        self.forward_batch_into(x, None, &mut scratch, &mut out.data, None);
        out
    }

    /// Batched forward with reusable scratch: the engine hot path.
    ///
    /// Writes `gates[i] * FFN(x[i])` into `out` — either contiguous rows
    /// (scatter == None) or scatter-added at `scatter[i] * d`. `gates ==
    /// None` means gate 1.0 everywhere, `scatter == None` overwrites rows
    /// in order.
    pub fn forward_batch_into(
        &self,
        x: &Tensor,
        gates: Option<&[f32]>,
        scratch: &mut FfnScratch,
        out: &mut [f32],
        scatter: Option<&[usize]>,
    ) {
        let (b, d) = x.dims2();
        let f = self.w1.shape[1];
        scratch.ensure(f.max(d));
        // Token blocking (§Perf iteration 2): the kernel is weight-stream
        // bound (w1/w3/w2 are re-read per token). Processing BLK tokens per
        // weight pass amortises that traffic BLK-fold; the per-row inner
        // loops re-read each weight row from L1.
        const BLK: usize = 4;
        let mut i = 0;
        while i < b {
            let blk = (b - i).min(BLK);
            let (hg, hl, acc) = scratch.triple(f, d);
            hg[..blk * f].fill(0.0);
            hl[..blk * f].fill(0.0);
            // Up-projections: one pass over w1/w3 rows for all blk tokens.
            for k in 0..d {
                let w1row = &self.w1.data[k * f..(k + 1) * f];
                let w3row = &self.w3.data[k * f..(k + 1) * f];
                for t in 0..blk {
                    let xv = x.data[(i + t) * d + k];
                    if xv == 0.0 {
                        continue;
                    }
                    axpy(xv, w1row, &mut hg[t * f..(t + 1) * f]);
                    axpy(xv, w3row, &mut hl[t * f..(t + 1) * f]);
                }
            }
            for (a, &v) in hg[..blk * f].iter_mut().zip(&hl[..blk * f]) {
                *a = silu(*a) * v;
            }
            // Down-projection into a contiguous block accumulator, then
            // gate-scale and scatter.
            acc[..blk * d].fill(0.0);
            for k in 0..f {
                let w2row = &self.w2.data[k * d..(k + 1) * d];
                for t in 0..blk {
                    let hv = hg[t * f + k];
                    if hv != 0.0 {
                        axpy(hv, w2row, &mut acc[t * d..(t + 1) * d]);
                    }
                }
            }
            for t in 0..blk {
                let g = gates.map_or(1.0, |gs| gs[i + t]);
                let at = scatter.map_or(i + t, |s| s[i + t]);
                axpy(g, &acc[t * d..(t + 1) * d],
                     &mut out[at * d..(at + 1) * d]);
            }
            i += blk;
        }
    }

    /// Single-token forward into a caller-provided buffer, scaled by `g`.
    pub fn forward_token_into(&self, x: &[f32], g: f32, out: &mut [f32]) {
        let d = x.len();
        let f = self.w1.shape[1];
        let mut hg = vec![0.0f32; f];
        let mut hl = vec![0.0f32; f];
        for (k, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            axpy(xv, &self.w1.data[k * f..(k + 1) * f], &mut hg);
            axpy(xv, &self.w3.data[k * f..(k + 1) * f], &mut hl);
        }
        for (a, &b) in hg.iter_mut().zip(&hl) {
            *a = silu(*a) * b;
        }
        for (k, &hv) in hg.iter().enumerate() {
            if hv != 0.0 {
                axpy(g * hv, &self.w2.data[k * d..(k + 1) * d], out);
            }
        }
    }

    pub fn n_params(&self) -> usize {
        self.w1.numel() + self.w3.numel() + self.w2.numel()
    }
}

/// Reusable intermediate buffers for `FfnExpert::forward_batch_into` —
/// keeps the hot loop allocation-free across micro-batches (§Perf).
pub struct FfnScratch {
    hg: Vec<f32>,
    hl: Vec<f32>,
    acc: Vec<f32>,
}

const SCRATCH_BLK: usize = 4;

impl FfnScratch {
    pub fn new(f: usize) -> FfnScratch {
        FfnScratch {
            hg: vec![0.0; SCRATCH_BLK * f],
            hl: vec![0.0; SCRATCH_BLK * f],
            acc: vec![0.0; SCRATCH_BLK * f],
        }
    }

    fn ensure(&mut self, n: usize) {
        if self.hg.len() < SCRATCH_BLK * n {
            self.hg.resize(SCRATCH_BLK * n, 0.0);
            self.hl.resize(SCRATCH_BLK * n, 0.0);
            self.acc.resize(SCRATCH_BLK * n, 0.0);
        }
    }

    fn triple(&mut self, _f: usize, _d: usize)
        -> (&mut [f32], &mut [f32], &mut [f32]) {
        (&mut self.hg, &mut self.hl, &mut self.acc)
    }
}

/// Weights of one constant expert (Eq. 5).
#[derive(Clone, Debug)]
pub struct ConstExpert {
    pub wc: Tensor, // [2, D]
    pub v: Tensor,  // [D]
}

impl ConstExpert {
    pub fn init(rng: &mut Rng, d: usize) -> ConstExpert {
        ConstExpert {
            wc: Tensor::randn(rng, &[2, d], (d as f32).powf(-0.5)),
            v: Tensor::randn(rng, &[d], 0.02),
        }
    }

    /// out += g * (a1 x + a2 v), [a1,a2] = softmax(Wc x).
    pub fn forward_token_into(&self, x: &[f32], g: f32, out: &mut [f32]) {
        let d = x.len();
        let mut logits = [
            dot(x, &self.wc.data[0..d]),
            dot(x, &self.wc.data[d..2 * d]),
        ];
        softmax_slice(&mut logits);
        axpy(g * logits[0], x, out);
        axpy(g * logits[1], &self.v.data, out);
    }

    pub fn alphas(&self, x: &[f32]) -> [f32; 2] {
        let d = x.len();
        let mut logits = [
            dot(x, &self.wc.data[0..d]),
            dot(x, &self.wc.data[d..2 * d]),
        ];
        softmax_slice(&mut logits);
        logits
    }
}

/// Zero expert (Eq. 3): contributes nothing.
pub fn zero_expert_into(_x: &[f32], _g: f32, _out: &mut [f32]) {
    // intentionally empty — "discard"
}

/// Copy expert (Eq. 4): out += g * x.
pub fn copy_expert_into(x: &[f32], g: f32, out: &mut [f32]) {
    axpy(g, x, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffn_batch_matches_per_token() {
        let mut rng = Rng::new(0);
        let (d, f) = (16, 32);
        let e = FfnExpert::init(&mut rng, d, f);
        let x = Tensor::randn(&mut rng, &[5, d], 1.0);
        let batch = e.forward(&x);
        for i in 0..5 {
            let mut out = vec![0.0; d];
            e.forward_token_into(x.row(i), 1.0, &mut out);
            for (a, b) in out.iter().zip(batch.row(i)) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn ffn_gate_scales_linearly() {
        let mut rng = Rng::new(1);
        let e = FfnExpert::init(&mut rng, 8, 16);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        e.forward_token_into(&x, 1.0, &mut a);
        e.forward_token_into(&x, 0.25, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x * 0.25 - y).abs() < 1e-5);
        }
    }

    #[test]
    fn const_expert_is_convex_combination() {
        let mut rng = Rng::new(2);
        let d = 12;
        let e = ConstExpert::init(&mut rng, d);
        let x: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
        let [a1, a2] = e.alphas(&x);
        assert!((a1 + a2 - 1.0).abs() < 1e-5);
        assert!(a1 > 0.0 && a2 > 0.0);
        let mut out = vec![0.0; d];
        e.forward_token_into(&x, 1.0, &mut out);
        for j in 0..d {
            let want = a1 * x[j] + a2 * e.v.data[j];
            assert!((out[j] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn const_expert_zero_wc_gives_even_mix() {
        let mut rng = Rng::new(3);
        let d = 6;
        let mut e = ConstExpert::init(&mut rng, d);
        e.wc = Tensor::zeros(&[2, d]);
        let x = vec![1.0; d];
        let [a1, a2] = e.alphas(&x);
        assert!((a1 - 0.5).abs() < 1e-6 && (a2 - 0.5).abs() < 1e-6);
    }

    #[test]
    fn zero_and_copy_semantics() {
        let x = vec![1.0, -2.0, 3.0];
        let mut out = vec![10.0, 10.0, 10.0];
        zero_expert_into(&x, 0.7, &mut out);
        assert_eq!(out, vec![10.0, 10.0, 10.0]);
        copy_expert_into(&x, 0.5, &mut out);
        assert_eq!(out, vec![10.5, 9.0, 11.5]);
    }
}

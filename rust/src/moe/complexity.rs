//! Table 1: the analytic complexity model of MoE vs MoE++.
//!
//! For T tokens, top-K routing, N_F FFN experts and N_Z zero-computation
//! experts with allocation parameter tau, the expected FFN work of MoE++ is
//!
//! ```text
//! O( tau*N_F / (tau*N_F + N_Z) * T )
//! ```
//!
//! of the vanilla-MoE cost. `moepp bench table1` validates this model
//! against measured expert-stage FLOPs from the serving engine.

use crate::config::MoeConfig;

/// Expected FFN-expert FLOPs for a batch of `t` tokens (one MoE layer).
pub fn expected_ffn_flops(cfg: &MoeConfig, t: usize) -> f64 {
    let per_assignment = cfg.ffn_flops_per_token();
    let assignments = cfg.top_k as f64 * t as f64 * cfg.ffn_token_fraction();
    assignments * per_assignment
}

/// Expected ZC-expert FLOPs (constant experts only: a 2×D matvec + 2 axpy
/// per assignment; zero/copy are free).
pub fn expected_zc_flops(cfg: &MoeConfig, t: usize) -> f64 {
    if cfg.vanilla {
        return 0.0;
    }
    let nz = cfg.n_zc() as f64;
    let zc_assignments =
        cfg.top_k as f64 * t as f64 * (1.0 - cfg.ffn_token_fraction());
    // Fraction of ZC assignments landing on constant experts (uniform
    // within the ZC group under balanced routing).
    let const_frac = cfg.n_const as f64 / nz;
    let const_flops = (2.0 * 2.0 * cfg.d_model as f64) // matvec
        + (4.0 * cfg.d_model as f64); // two axpys
    zc_assignments * const_frac * const_flops
}

/// Table 1 ratio: MoE++ expert compute / vanilla-MoE expert compute at the
/// same parameter count (ZC FLOPs included; they are negligible).
pub fn complexity_ratio(cfg: &MoeConfig, t: usize) -> f64 {
    let vanilla = MoeConfig { vanilla: true, ..cfg.clone() };
    (expected_ffn_flops(cfg, t) + expected_zc_flops(cfg, t))
        / expected_ffn_flops(&vanilla, t)
}

/// The paper's ideal throughput-increase figure implied by the complexity
/// model: 1/ratio - 1 (e.g. Table 3's "+x%" column under perfect scaling).
pub fn ideal_throughput_increase(cfg: &MoeConfig, t: usize) -> f64 {
    1.0 / complexity_ratio(cfg, t) - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_matches_closed_form() {
        let cfg = MoeConfig::preset("sm-8e"); // tau=.75, 8F + 4Z
        let want = 0.75 * 8.0 / (0.75 * 8.0 + 4.0);
        let got = complexity_ratio(&cfg, 10_000);
        // ZC flops add a hair above the pure Table 1 ratio.
        assert!((got - want).abs() < 0.01, "{got} vs {want}");
    }

    #[test]
    fn vanilla_ratio_is_one() {
        let cfg = MoeConfig::preset("sm-8e:vanilla");
        assert!((complexity_ratio(&cfg, 1000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn smaller_tau_means_cheaper() {
        let mut a = MoeConfig::preset("sm-8e");
        let mut b = a.clone();
        a.tau = 0.1;
        b.tau = 1.0;
        assert!(complexity_ratio(&a, 1000) < complexity_ratio(&b, 1000));
    }

    #[test]
    fn table1_sweep_is_monotone_in_tau() {
        let taus = [0.1, 0.25, 0.5, 0.75, 1.0];
        let mut last = 0.0;
        for tau in taus {
            let cfg = MoeConfig { tau, ..MoeConfig::preset("sm-16e") };
            let r = complexity_ratio(&cfg, 4096);
            assert!(r > last, "ratio must increase with tau");
            last = r;
        }
    }

    #[test]
    fn zc_flops_are_negligible() {
        let cfg = MoeConfig::preset("sm-8e");
        let t = 4096;
        assert!(expected_zc_flops(&cfg, t)
            < 0.01 * expected_ffn_flops(&cfg, t));
    }
}

//! Per-layer heterogeneous MoE++ — the paper's Appendix A.2 future-work
//! direction, implemented as a first-class feature.
//!
//! The paper observes (Appendix D) that expert-assignment patterns vary
//! most in the shallow and final layers, suggesting models adapt to tasks
//! primarily there. This module lets each layer carry its own tau (token
//! allocation between FFN and ZC experts): a [`LayerSchedule`] maps layer
//! index -> tau, so e.g. the shallow/final layers can keep more FFN
//! capacity (higher tau) while middle layers shed compute (lower tau).

use crate::config::MoeConfig;

/// Per-layer tau schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerSchedule {
    /// Single tau everywhere (the paper's main setting).
    Uniform(f64),
    /// Explicit per-layer taus (len == n_layers).
    PerLayer(Vec<f64>),
    /// The Appendix-D-motivated shape: `edge` tau on the first and last
    /// `k` layers, `middle` tau elsewhere.
    EdgeHeavy { edge: f64, middle: f64, k: usize },
}

impl LayerSchedule {
    pub fn tau(&self, layer: usize, n_layers: usize) -> f64 {
        match self {
            LayerSchedule::Uniform(t) => *t,
            LayerSchedule::PerLayer(v) => v[layer],
            LayerSchedule::EdgeHeavy { edge, middle, k } => {
                if layer < *k || layer + k >= n_layers {
                    *edge
                } else {
                    *middle
                }
            }
        }
    }

    /// Materialise the per-layer configs for an engine stack.
    pub fn configs(&self, base: &MoeConfig) -> Vec<MoeConfig> {
        (0..base.n_layers)
            .map(|l| MoeConfig {
                tau: self.tau(l, base.n_layers),
                ..base.clone()
            })
            .collect()
    }

    /// Expected FFN-compute ratio vs vanilla (mean of per-layer Table-1
    /// ratios) — the complexity accounting for a scheduled stack.
    pub fn complexity_ratio(&self, base: &MoeConfig, tokens: usize) -> f64 {
        let cfgs = self.configs(base);
        cfgs.iter()
            .map(|c| crate::moe::complexity::complexity_ratio(c, tokens))
            .sum::<f64>()
            / cfgs.len() as f64
    }

    /// Parse from a CLI string: "0.75" | "0.9,0.5,0.5,0.9" |
    /// "edge:0.9,0.25,1".
    pub fn parse(s: &str) -> anyhow::Result<LayerSchedule> {
        if let Some(rest) = s.strip_prefix("edge:") {
            let parts: Vec<&str> = rest.split(',').collect();
            anyhow::ensure!(parts.len() == 3, "edge:EDGE,MIDDLE,K");
            return Ok(LayerSchedule::EdgeHeavy {
                edge: parts[0].parse()?,
                middle: parts[1].parse()?,
                k: parts[2].parse()?,
            });
        }
        if s.contains(',') {
            let v: Result<Vec<f64>, _> =
                s.split(',').map(str::parse).collect();
            return Ok(LayerSchedule::PerLayer(v?));
        }
        Ok(LayerSchedule::Uniform(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_matches_base() {
        let s = LayerSchedule::Uniform(0.5);
        for l in 0..8 {
            assert_eq!(s.tau(l, 8), 0.5);
        }
    }

    #[test]
    fn edge_heavy_shape() {
        let s = LayerSchedule::EdgeHeavy { edge: 0.9, middle: 0.25, k: 2 };
        let taus: Vec<f64> = (0..8).map(|l| s.tau(l, 8)).collect();
        assert_eq!(taus, vec![0.9, 0.9, 0.25, 0.25, 0.25, 0.25, 0.9, 0.9]);
    }

    #[test]
    fn per_layer_configs_carry_taus() {
        let base = MoeConfig::preset("test"); // 2 layers
        let s = LayerSchedule::PerLayer(vec![0.1, 1.0]);
        let cfgs = s.configs(&base);
        assert_eq!(cfgs[0].tau, 0.1);
        assert_eq!(cfgs[1].tau, 1.0);
        // Capacity follows tau per layer (Eq. 8 per layer).
        assert!(cfgs[0].capacities(100).0 < cfgs[1].capacities(100).0);
    }

    #[test]
    fn scheduled_complexity_between_extremes() {
        let base = MoeConfig::preset("sm-8e");
        let lo = LayerSchedule::Uniform(0.1)
            .complexity_ratio(&base, 1024);
        let hi = LayerSchedule::Uniform(1.0)
            .complexity_ratio(&base, 1024);
        let mid = LayerSchedule::EdgeHeavy { edge: 1.0, middle: 0.1, k: 1 }
            .complexity_ratio(&base, 1024);
        assert!(lo < mid && mid < hi, "{lo} {mid} {hi}");
    }

    #[test]
    fn parse_forms() {
        assert_eq!(LayerSchedule::parse("0.75").unwrap(),
                   LayerSchedule::Uniform(0.75));
        assert_eq!(LayerSchedule::parse("0.9,0.5").unwrap(),
                   LayerSchedule::PerLayer(vec![0.9, 0.5]));
        assert_eq!(
            LayerSchedule::parse("edge:0.9,0.25,1").unwrap(),
            LayerSchedule::EdgeHeavy { edge: 0.9, middle: 0.25, k: 1 }
        );
        assert!(LayerSchedule::parse("edge:1").is_err());
        assert!(LayerSchedule::parse("abc").is_err());
    }
}

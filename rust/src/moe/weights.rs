//! Weight containers + initialisation for a full MoE++ layer stack (the
//! native engine's parameters; artifact-driven paths get weights from the
//! PJRT init artifact instead).

use crate::config::MoeConfig;
use crate::moe::experts::{ConstExpert, FfnExpert};
use crate::moe::router::RouterWeights;
use crate::util::rng::Rng;

/// All weights of one MoE++ layer.
#[derive(Clone, Debug)]
pub struct MoeLayerWeights {
    pub router: RouterWeights,
    pub ffn: Vec<FfnExpert>,
    pub consts: Vec<ConstExpert>,
}

impl MoeLayerWeights {
    pub fn init(rng: &mut Rng, cfg: &MoeConfig) -> MoeLayerWeights {
        MoeLayerWeights {
            router: RouterWeights::init(rng, cfg.n_experts(), cfg.d_model),
            ffn: (0..cfg.n_ffn_experts)
                .map(|_| FfnExpert::init(rng, cfg.d_model, cfg.d_ff))
                .collect(),
            consts: (0..if cfg.vanilla { 0 } else { cfg.n_const })
                .map(|_| ConstExpert::init(rng, cfg.d_model))
                .collect(),
        }
    }

    pub fn n_params(&self) -> usize {
        let ffn: usize = self.ffn.iter().map(|e| e.n_params()).sum();
        let consts: usize = self
            .consts
            .iter()
            .map(|c| c.wc.numel() + c.v.numel())
            .sum();
        ffn + consts + self.router.w.numel() + self.router.wg.numel()
    }

    /// Bytes of parameters that must live on *every* device (ZC experts +
    /// router) vs bytes shardable across devices (FFN experts) — the
    /// deployment-friendliness accounting of the paper.
    pub fn replicated_vs_sharded_bytes(&self) -> (usize, usize) {
        let replicated = (self.router.w.numel()
            + self.router.wg.numel()
            + self
                .consts
                .iter()
                .map(|c| c.wc.numel() + c.v.numel())
                .sum::<usize>())
            * 4;
        let sharded =
            self.ffn.iter().map(|e| e.n_params()).sum::<usize>() * 4;
        (replicated, sharded)
    }
}

/// Weights for a stack of MoE++ layers (what the serving engine loads).
#[derive(Clone, Debug)]
pub struct StackWeights {
    pub layers: Vec<MoeLayerWeights>,
}

impl StackWeights {
    pub fn init(seed: u64, cfg: &MoeConfig) -> StackWeights {
        let cfgs = vec![cfg.clone(); cfg.n_layers];
        StackWeights::init_per_layer(seed, &cfgs)
    }

    /// Initialise a stack whose layers may carry different configs (e.g.
    /// per-layer expert counts for heterogeneous schedules). With uniform
    /// configs this is identical to [`StackWeights::init`] — each layer
    /// draws from the same split RNG stream.
    pub fn init_per_layer(seed: u64, cfgs: &[MoeConfig]) -> StackWeights {
        let mut rng = Rng::new(seed);
        StackWeights {
            layers: cfgs
                .iter()
                .enumerate()
                .map(|(i, lcfg)| {
                    let mut lr = rng.split(i as u64 + 1);
                    MoeLayerWeights::init(&mut lr, lcfg)
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes() {
        let cfg = MoeConfig::preset("test");
        let w = StackWeights::init(0, &cfg);
        assert_eq!(w.layers.len(), cfg.n_layers);
        let l = &w.layers[0];
        assert_eq!(l.ffn.len(), cfg.n_ffn_experts);
        assert_eq!(l.consts.len(), cfg.n_const);
        assert_eq!(l.router.w.shape, vec![cfg.n_experts(), cfg.d_model]);
    }

    #[test]
    fn zc_params_are_negligible() {
        // The paper's premise: ZC experts add ~no parameters.
        let cfg = MoeConfig::preset("sm-8e");
        let w = MoeLayerWeights::init(&mut Rng::new(0), &cfg);
        let (replicated, sharded) = w.replicated_vs_sharded_bytes();
        assert!(
            (replicated as f64) < 0.02 * sharded as f64,
            "replicated {replicated} vs sharded {sharded}"
        );
    }

    #[test]
    fn vanilla_has_no_const_experts() {
        let cfg = MoeConfig::preset("test:vanilla");
        let w = MoeLayerWeights::init(&mut Rng::new(0), &cfg);
        assert!(w.consts.is_empty());
    }

    #[test]
    fn deterministic_init() {
        let cfg = MoeConfig::preset("test");
        let a = StackWeights::init(7, &cfg);
        let b = StackWeights::init(7, &cfg);
        assert_eq!(a.layers[0].router.w, b.layers[0].router.w);
        assert_eq!(a.layers[1].ffn[0].w1, b.layers[1].ffn[0].w1);
    }
}

//! Weight containers + initialisation for a full MoE++ layer stack (the
//! native engine's parameters; artifact-driven paths get weights from the
//! PJRT init artifact instead).

use crate::config::{MoeConfig, Precision};
use crate::moe::experts::{ConstExpert, FfnExpert, QuantFfnExpert};
use crate::moe::router::RouterWeights;
use crate::util::rng::Rng;

/// All weights of one MoE++ layer.
#[derive(Clone, Debug)]
pub struct MoeLayerWeights {
    pub router: RouterWeights,
    pub ffn: Vec<FfnExpert>,
    pub consts: Vec<ConstExpert>,
}

impl MoeLayerWeights {
    pub fn init(rng: &mut Rng, cfg: &MoeConfig) -> MoeLayerWeights {
        MoeLayerWeights {
            router: RouterWeights::init(rng, cfg.n_experts(), cfg.d_model),
            ffn: (0..cfg.n_ffn_experts)
                .map(|_| FfnExpert::init(rng, cfg.d_model, cfg.d_ff))
                .collect(),
            consts: (0..if cfg.vanilla { 0 } else { cfg.n_const })
                .map(|_| ConstExpert::init(rng, cfg.d_model))
                .collect(),
        }
    }

    pub fn n_params(&self) -> usize {
        let ffn: usize = self.ffn.iter().map(|e| e.n_params()).sum();
        let consts: usize = self
            .consts
            .iter()
            .map(|c| c.wc.numel() + c.v.numel())
            .sum();
        ffn + consts + self.router.w.numel() + self.router.wg.numel()
    }

    /// Bytes of parameters that must live on *every* device (ZC experts +
    /// router) vs bytes shardable across devices (FFN experts) — the
    /// deployment-friendliness accounting of the paper.
    pub fn replicated_vs_sharded_bytes(&self) -> (usize, usize) {
        let replicated = (self.router.w.numel()
            + self.router.wg.numel()
            + self
                .consts
                .iter()
                .map(|c| c.wc.numel() + c.v.numel())
                .sum::<usize>())
            * 4;
        let sharded =
            self.ffn.iter().map(|e| e.n_params()).sum::<usize>() * 4;
        (replicated, sharded)
    }
}

/// Weights for a stack of MoE++ layers (what the serving engine loads).
#[derive(Clone, Debug)]
pub struct StackWeights {
    pub layers: Vec<MoeLayerWeights>,
}

impl StackWeights {
    pub fn init(seed: u64, cfg: &MoeConfig) -> StackWeights {
        let cfgs = vec![cfg.clone(); cfg.n_layers];
        StackWeights::init_per_layer(seed, &cfgs)
    }

    /// Initialise a stack whose layers may carry different configs (e.g.
    /// per-layer expert counts for heterogeneous schedules). With uniform
    /// configs this is identical to [`StackWeights::init`] — each layer
    /// draws from the same split RNG stream.
    pub fn init_per_layer(seed: u64, cfgs: &[MoeConfig]) -> StackWeights {
        let mut rng = Rng::new(seed);
        StackWeights {
            layers: cfgs
                .iter()
                .enumerate()
                .map(|(i, lcfg)| {
                    let mut lr = rng.split(i as u64 + 1);
                    MoeLayerWeights::init(&mut lr, lcfg)
                })
                .collect(),
        }
    }
}

/// Pre-quantized copies of the int8-precision experts of a stack —
/// built once from [`StackWeights`] when a precision map is installed,
/// so the forward path never quantizes weights per batch.
/// `layers[l][e]` is `Some` iff expert `e` serves at `Precision::Int8`
/// (stack-wide, so the same experts are Some in every layer).
#[derive(Clone, Debug)]
pub struct QuantStackWeights {
    pub layers: Vec<Vec<Option<QuantFfnExpert>>>,
}

impl QuantStackWeights {
    /// Quantize every expert whose stack-wide precision is `Int8`.
    /// `precision` is indexed by FFN expert slot; missing entries
    /// default to `F32` (no quantized copy).
    pub fn build(
        stack: &StackWeights,
        precision: &[Precision],
    ) -> QuantStackWeights {
        QuantStackWeights {
            layers: stack
                .layers
                .iter()
                .map(|l| {
                    l.ffn
                        .iter()
                        .enumerate()
                        .map(|(e, w)| {
                            match precision
                                .get(e)
                                .copied()
                                .unwrap_or_default()
                            {
                                Precision::Int8 => {
                                    Some(QuantFfnExpert::from_f32(w))
                                }
                                Precision::F32 => None,
                            }
                        })
                        .collect()
                })
                .collect(),
        }
    }

    /// Total parameter bytes of the quantized copies (all layers).
    pub fn bytes(&self) -> u64 {
        self.layers
            .iter()
            .flatten()
            .flatten()
            .map(|q| q.bytes() as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes() {
        let cfg = MoeConfig::preset("test");
        let w = StackWeights::init(0, &cfg);
        assert_eq!(w.layers.len(), cfg.n_layers);
        let l = &w.layers[0];
        assert_eq!(l.ffn.len(), cfg.n_ffn_experts);
        assert_eq!(l.consts.len(), cfg.n_const);
        assert_eq!(l.router.w.shape, vec![cfg.n_experts(), cfg.d_model]);
    }

    #[test]
    fn zc_params_are_negligible() {
        // The paper's premise: ZC experts add ~no parameters.
        let cfg = MoeConfig::preset("sm-8e");
        let w = MoeLayerWeights::init(&mut Rng::new(0), &cfg);
        let (replicated, sharded) = w.replicated_vs_sharded_bytes();
        assert!(
            (replicated as f64) < 0.02 * sharded as f64,
            "replicated {replicated} vs sharded {sharded}"
        );
    }

    #[test]
    fn vanilla_has_no_const_experts() {
        let cfg = MoeConfig::preset("test:vanilla");
        let w = MoeLayerWeights::init(&mut Rng::new(0), &cfg);
        assert!(w.consts.is_empty());
    }

    #[test]
    fn quant_stack_quantizes_only_int8_slots() {
        let cfg = MoeConfig::preset("test"); // 4 FFN experts, 2 layers
        let w = StackWeights::init(0, &cfg);
        let prec = vec![
            Precision::F32,
            Precision::Int8,
            Precision::F32,
            Precision::Int8,
        ];
        let q = QuantStackWeights::build(&w, &prec);
        assert_eq!(q.layers.len(), cfg.n_layers);
        for l in &q.layers {
            assert_eq!(l.len(), cfg.n_ffn_experts);
            assert!(l[0].is_none() && l[2].is_none());
            assert!(l[1].is_some() && l[3].is_some());
        }
        // Bytes match the config-side accounting: 2 experts × n_layers.
        let per = cfg.ffn_expert_bytes_at(Precision::Int8);
        assert_eq!(q.bytes(), per * 2 * cfg.n_layers as u64);
        // A short precision map defaults the tail to f32.
        let q2 = QuantStackWeights::build(&w, &[Precision::Int8]);
        assert!(q2.layers[0][0].is_some());
        assert!(q2.layers[0][1..].iter().all(Option::is_none));
    }

    #[test]
    fn deterministic_init() {
        let cfg = MoeConfig::preset("test");
        let a = StackWeights::init(7, &cfg);
        let b = StackWeights::init(7, &cfg);
        assert_eq!(a.layers[0].router.w, b.layers[0].router.w);
        assert_eq!(a.layers[1].ffn[0].w1, b.layers[1].ffn[0].w1);
    }
}

//! The shared MoE++ execution layer (DESIGN.md §7): one implementation of
//! "turn a [`DispatchPlan`] into outputs" used by every forward path.
//!
//! The paper's deployment asymmetry — heavy FFN experts are queued,
//! batched, sharded and communicated while zero-computation experts are
//! applied inline wherever the token lives — used to be re-implemented by
//! the reference layer (`moe::layer`), the serving engine
//! (`coordinator::engine`) and the cluster simulator (`cluster::sim`).
//! This module is now the only place that semantics lives:
//!
//! * [`ExpertBackend`] — the pluggable FFN execution strategy (per-token
//!   oracle, batched native with parallel micro-batches, PJRT buckets, or
//!   the cluster's sharded workers). Backends only ever see FFN work.
//! * [`apply_zc_inline`] — the single zero/copy/constant application.
//! * [`execute_layer`] — FFN stage + ZC stage + [`LayerStats`] accounting
//!   for one planned layer.
//! * [`forward_stack`] — the stack loop: routing with gating-residual
//!   threading, per-layer configs, residual-stream update and
//!   [`ForwardStats`] aggregation.

use std::time::Instant;

use anyhow::Result;

use crate::config::{ExpertKind, MoeConfig};
use crate::coordinator::dispatch::DispatchPlan;
use crate::moe::experts::{ConstExpert, FfnScratch};
use crate::moe::layer::{Assignment, LayerStats};
use crate::moe::router::{route, Routing};
use crate::moe::weights::{MoeLayerWeights, StackWeights};
use crate::tensor::ops::axpy;
use crate::tensor::Tensor;
use crate::util::threadpool::parallel_map;

/// Aggregate timing + routing statistics for one stack forward.
#[derive(Clone, Debug, Default)]
pub struct ForwardStats {
    /// Wall-clock seconds inside the expert stage (FFN + ZC + combine).
    pub expert_forward_s: f64,
    /// Seconds inside FFN expert execution only.
    pub ffn_s: f64,
    /// Seconds inside zero-computation expert execution only.
    pub zc_s: f64,
    /// Seconds in routing (score matmul + top-k).
    pub routing_s: f64,
    pub per_layer: Vec<LayerStats>,
    pub tokens: usize,
    /// Per-token assignment counts summed over layers — the raw material
    /// the serving layer slices into per-request accounting
    /// ([`crate::serve`], DESIGN.md §9). Row `i` of the input batch owns
    /// index `i` here.
    pub token_counts: TokenCounts,
}

/// Per-token assignment counters, one entry per input row, summed across
/// layers. Splitting by expert kind (rather than just FFN-vs-ZC) exposes
/// the paper's "which cheap pathway did this token take" accounting.
#[derive(Clone, Debug, Default)]
pub struct TokenCounts {
    pub ffn: Vec<u32>,
    pub zero: Vec<u32>,
    pub copy: Vec<u32>,
    pub constant: Vec<u32>,
    pub dropped: Vec<u32>,
}

impl TokenCounts {
    pub fn new(n_tokens: usize) -> TokenCounts {
        TokenCounts {
            ffn: vec![0; n_tokens],
            zero: vec![0; n_tokens],
            copy: vec![0; n_tokens],
            constant: vec![0; n_tokens],
            dropped: vec![0; n_tokens],
        }
    }

    fn record_layer(&mut self, plan: &DispatchPlan, cfg: &MoeConfig) {
        for batch in &plan.ffn_batches {
            for &tok in &batch.tokens {
                self.ffn[tok] += 1;
            }
        }
        for a in &plan.zc_inline {
            match cfg.kind(a.expert) {
                ExpertKind::Zero => self.zero[a.token] += 1,
                ExpertKind::Copy => self.copy[a.token] += 1,
                ExpertKind::Constant => self.constant[a.token] += 1,
                ExpertKind::Ffn => unreachable!("ffn in zc list"),
            }
        }
        for a in &plan.dropped {
            self.dropped[a.token] += 1;
        }
    }
}

/// Assignment totals for a set of tokens (one request's rows, or a whole
/// batch). Produced by [`ForwardStats::span_counts`] /
/// [`ForwardStats::total_counts`]; spans of one batch sum exactly to the
/// batch total (tested below), which is what lets per-request serving
/// stats reconcile against batch-level metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AssignmentCounts {
    pub ffn: u64,
    pub zero: u64,
    pub copy: u64,
    pub constant: u64,
    pub dropped: u64,
}

impl AssignmentCounts {
    /// Zero-computation assignments (zero + copy + constant).
    pub fn zc(&self) -> u64 {
        self.zero + self.copy + self.constant
    }

    /// Assignments that survived capacity filtering.
    pub fn kept(&self) -> u64 {
        self.ffn + self.zc()
    }

    /// All routed assignments (kept + dropped) — T * K per layer.
    pub fn total(&self) -> u64 {
        self.kept() + self.dropped
    }

    pub fn add(&mut self, other: &AssignmentCounts) {
        self.ffn += other.ffn;
        self.zero += other.zero;
        self.copy += other.copy;
        self.constant += other.constant;
        self.dropped += other.dropped;
    }
}

impl ForwardStats {
    /// Expert-forward throughput (tokens/s), the Table 3 metric.
    pub fn expert_throughput(&self) -> f64 {
        self.tokens as f64 / self.expert_forward_s.max(1e-12)
    }

    /// Sum the per-token counters over a row span (a request's slice of
    /// the batch). Panics if the span exceeds the forwarded token count.
    pub fn span_counts(
        &self,
        span: std::ops::Range<usize>,
    ) -> AssignmentCounts {
        let sum = |v: &[u32]| -> u64 {
            v[span.clone()].iter().map(|&c| c as u64).sum()
        };
        AssignmentCounts {
            ffn: sum(&self.token_counts.ffn),
            zero: sum(&self.token_counts.zero),
            copy: sum(&self.token_counts.copy),
            constant: sum(&self.token_counts.constant),
            dropped: sum(&self.token_counts.dropped),
        }
    }

    /// Batch-level assignment totals (all tokens).
    pub fn total_counts(&self) -> AssignmentCounts {
        self.span_counts(0..self.tokens)
    }

    pub fn mean_ffn_per_token(&self) -> f64 {
        if self.per_layer.is_empty() {
            return 0.0;
        }
        self.per_layer.iter().map(|s| s.ffn_per_token).sum::<f64>()
            / self.per_layer.len() as f64
    }

    pub fn total_dropped(&self) -> usize {
        self.per_layer.iter().map(|s| s.dropped).sum()
    }
}

/// What a backend reports about one layer's FFN stage. Native backends
/// leave the distributed fields at their defaults; the cluster backend
/// fills in per-device compute, load and all-to-all accounting.
#[derive(Clone, Debug, Default)]
pub struct FfnLayerReport {
    /// Measured compute seconds per device (sharded backends).
    pub device_compute_s: Vec<f64>,
    /// FFN assignments landing on each device.
    pub device_load: Vec<usize>,
    /// Analytic all-to-all time (dispatch + combine).
    pub comm_s: f64,
    /// Off-device bytes moved.
    pub comm_bytes: u64,
}

/// Full record of one executed layer.
#[derive(Clone, Debug)]
pub struct LayerExec {
    pub stats: LayerStats,
    /// Wall seconds in the FFN stage (driver-measured).
    pub ffn_s: f64,
    /// Wall seconds in the inline ZC stage (driver-measured).
    pub zc_s: f64,
    pub report: FfnLayerReport,
}

/// A pluggable FFN-expert execution strategy.
///
/// Contract (DESIGN.md §7): for every micro-batch in `plan.ffn_batches`,
/// scatter-add `gate * FFN_expert(h[token])` into the matching row of `y`.
/// The backend must not touch rows outside the batch token sets, must not
/// apply zero-computation experts (the driver owns those), and must treat
/// `plan` as authoritative — no re-deriving of routing or capacity.
pub trait ExpertBackend {
    fn execute_ffn(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        y: &mut Tensor,
    ) -> Result<FfnLayerReport>;
}

/// The single implementation of zero-computation expert application
/// (paper Sec. 3.1): zero discards, copy adds `g*x`, constant adds the
/// learned convex mix. ZC experts always run inline on the token's home
/// buffer — they are never queued or communicated.
pub fn apply_zc_inline(
    assignments: &[Assignment],
    cfg: &MoeConfig,
    consts: &[ConstExpert],
    h: &Tensor,
    y: &mut Tensor,
) {
    let (_, d) = h.dims2();
    for a in assignments {
        let xrow = h.row(a.token);
        let orow = &mut y.data[a.token * d..(a.token + 1) * d];
        match cfg.kind(a.expert) {
            ExpertKind::Zero => {}
            ExpertKind::Copy => {
                crate::moe::experts::copy_expert_into(xrow, a.gate, orow)
            }
            ExpertKind::Constant => {
                consts[cfg.const_index(a.expert)]
                    .forward_token_into(xrow, a.gate, orow)
            }
            ExpertKind::Ffn => unreachable!("ffn assignment in zc list"),
        }
    }
}

/// Shared per-layer statistics accounting (mirrors L2's MoELayerAux).
pub fn layer_stats(
    plan: &DispatchPlan,
    routing: &Routing,
    cfg: &MoeConfig,
    n_tokens: usize,
) -> LayerStats {
    let ffn_assignments = plan.ffn_assignments();
    LayerStats {
        expert_counts: plan.expert_counts.clone(),
        dropped: plan.dropped.len(),
        ffn_assignments,
        zc_assignments: plan.zc_inline.len(),
        ffn_per_token: ffn_assignments as f64 / n_tokens as f64,
        balance_loss: crate::moe::balance::balance_loss(routing, cfg),
    }
}

/// Execute one planned layer: FFN micro-batches on the backend, ZC experts
/// inline, both timed, plus stats. `y` receives the layer output (the
/// caller owns the residual-stream update).
#[allow(clippy::too_many_arguments)]
pub fn execute_layer(
    backend: &mut dyn ExpertBackend,
    layer: usize,
    plan: &DispatchPlan,
    routing: &Routing,
    cfg: &MoeConfig,
    consts: &[ConstExpert],
    h: &Tensor,
    y: &mut Tensor,
) -> Result<LayerExec> {
    let t0 = Instant::now();
    let report = backend.execute_ffn(layer, plan, h, y)?;
    let ffn_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    apply_zc_inline(&plan.zc_inline, cfg, consts, h, y);
    let zc_s = t1.elapsed().as_secs_f64();

    Ok(LayerExec {
        stats: layer_stats(plan, routing, cfg, h.dims2().0),
        ffn_s,
        zc_s,
        report,
    })
}

/// The stack-level loop shared by the serving engine, the reference stack
/// and the cluster simulator: per layer — route (threading the previous
/// layer's raw scores when gating residuals are on), build the dispatch
/// plan from the *per-layer* config, execute via the backend, apply ZC
/// inline, then update the residual stream `h <- h + y`.
///
/// Without the residual update, fully-dropped tokens would become zero
/// rows and the sparse expert kernels would skip them, corrupting the
/// expert-forward cost accounting.
pub fn forward_stack(
    backend: &mut dyn ExpertBackend,
    weights: &StackWeights,
    layer_cfgs: &[MoeConfig],
    x: &Tensor,
) -> Result<(Tensor, ForwardStats, Vec<LayerExec>)> {
    let (t, d) = x.dims2();
    assert_eq!(
        layer_cfgs.len(),
        weights.layers.len(),
        "one config per layer"
    );
    let mut stats = ForwardStats {
        tokens: t,
        token_counts: TokenCounts::new(t),
        ..Default::default()
    };
    let mut execs = Vec::with_capacity(weights.layers.len());
    let mut h = x.clone();
    let mut prev_scores: Option<Tensor> = None;
    for (li, layer) in weights.layers.iter().enumerate() {
        let lcfg = &layer_cfgs[li];
        let t0 = Instant::now();
        let prev = if lcfg.gating_residual {
            prev_scores.as_ref()
        } else {
            None
        };
        let routing = route(&h, &layer.router, prev, lcfg.top_k);
        stats.routing_s += t0.elapsed().as_secs_f64();

        let plan = DispatchPlan::build(&routing, lcfg, t);
        stats.token_counts.record_layer(&plan, lcfg);
        let mut y = Tensor::zeros(&[t, d]);
        let ex = execute_layer(
            backend, li, &plan, &routing, lcfg, &layer.consts, &h, &mut y,
        )?;
        stats.ffn_s += ex.ffn_s;
        stats.zc_s += ex.zc_s;
        stats.expert_forward_s += ex.ffn_s + ex.zc_s;
        stats.per_layer.push(ex.stats.clone());
        execs.push(ex);

        prev_scores = Some(routing.scores);
        for (hv, yv) in h.data.iter_mut().zip(&y.data) {
            *hv += yv;
        }
    }
    Ok((h, stats, execs))
}

// ------------------------------------------------------------- backends

/// The oracle backend: per-token `forward_token_into`, exactly the
/// reference semantics `moe::layer::layer_forward` is defined by.
pub struct NativeSingle<'a> {
    pub layers: &'a [MoeLayerWeights],
}

impl ExpertBackend for NativeSingle<'_> {
    fn execute_ffn(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        y: &mut Tensor,
    ) -> Result<FfnLayerReport> {
        let (_, d) = h.dims2();
        let w = &self.layers[layer];
        for batch in &plan.ffn_batches {
            let e = &w.ffn[batch.expert];
            for (&tok, &gate) in batch.tokens.iter().zip(&batch.gates) {
                let orow = &mut y.data[tok * d..(tok + 1) * d];
                e.forward_token_into(h.row(tok), gate, orow);
            }
        }
        Ok(FfnLayerReport::default())
    }
}

/// The serving-path native backend: gather each micro-batch, run the
/// allocation-free batched expert, scatter-add gated rows. With
/// `workers > 1`, independent FFN micro-batches are fanned out across
/// `util::threadpool` workers — each batch's dense output is computed in
/// parallel and scatter-added serially in batch order, so results are
/// bitwise-identical for every worker count.
pub struct NativeBatched<'a> {
    pub layers: &'a [MoeLayerWeights],
    pub workers: usize,
}

impl ExpertBackend for NativeBatched<'_> {
    fn execute_ffn(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        y: &mut Tensor,
    ) -> Result<FfnLayerReport> {
        let (_, d) = h.dims2();
        let w = &self.layers[layer];
        let batches = &plan.ffn_batches;
        if self.workers <= 1 || batches.len() <= 1 {
            // Serial: one weight stream per batch, zero per-token
            // allocations, scatter-add directly into y (§Perf).
            let d_ff = w.ffn.first().map_or(0, |e| e.w1.shape[1]);
            let mut scratch = FfnScratch::new(d_ff.max(d));
            let mut gather = Tensor::zeros(&[1, d]);
            for batch in batches {
                let e = &w.ffn[batch.expert];
                let n = batch.tokens.len();
                if gather.numel() < n * d {
                    gather = Tensor::zeros(&[n, d]);
                } else {
                    gather.shape = vec![n, d];
                }
                for (i, &tok) in batch.tokens.iter().enumerate() {
                    gather.data[i * d..(i + 1) * d]
                        .copy_from_slice(h.row(tok));
                }
                e.forward_batch_into(
                    &gather,
                    Some(batch.gates.as_slice()),
                    &mut scratch,
                    &mut y.data,
                    Some(batch.tokens.as_slice()),
                );
            }
        } else {
            // Parallel micro-batches: the expensive dense compute fans out
            // over the pool; the cheap scatter-add stays serial (two FFN
            // experts may both feed one token's output row).
            let outs: Vec<Vec<f32>> =
                parallel_map(batches.len(), self.workers, |i| {
                    let batch = &batches[i];
                    let e = &w.ffn[batch.expert];
                    let n = batch.tokens.len();
                    let mut gather = Tensor::zeros(&[n, d]);
                    for (j, &tok) in batch.tokens.iter().enumerate() {
                        gather.data[j * d..(j + 1) * d]
                            .copy_from_slice(h.row(tok));
                    }
                    let mut scratch = FfnScratch::new(e.w1.shape[1].max(d));
                    let mut out = vec![0.0f32; n * d];
                    e.forward_batch_into(
                        &gather,
                        Some(batch.gates.as_slice()),
                        &mut scratch,
                        &mut out,
                        None,
                    );
                    out
                });
            for (batch, out) in batches.iter().zip(&outs) {
                for (i, &tok) in batch.tokens.iter().enumerate() {
                    let orow = &mut y.data[tok * d..(tok + 1) * d];
                    axpy(1.0, &out[i * d..(i + 1) * d], orow);
                }
            }
        }
        Ok(FfnLayerReport::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(
        preset: &str,
        seed: u64,
        t: usize,
    ) -> (MoeConfig, StackWeights, Tensor) {
        let cfg = MoeConfig::preset(preset);
        let weights = StackWeights::init(seed, &cfg);
        let mut rng = Rng::new(seed ^ 0xABCD);
        let x = Tensor::randn(&mut rng, &[t, cfg.d_model], 1.0);
        (cfg, weights, x)
    }

    fn run_backend(
        backend: &mut dyn ExpertBackend,
        cfg: &MoeConfig,
        weights: &StackWeights,
        x: &Tensor,
    ) -> (Tensor, ForwardStats) {
        let cfgs = vec![cfg.clone(); cfg.n_layers];
        let (y, stats, _) =
            forward_stack(backend, weights, &cfgs, x).unwrap();
        (y, stats)
    }

    #[test]
    fn batched_matches_single_within_tolerance() {
        let (cfg, weights, x) = setup("test", 3, 48);
        let (y_single, s_single) = run_backend(
            &mut NativeSingle { layers: &weights.layers },
            &cfg, &weights, &x,
        );
        let (y_batched, s_batched) = run_backend(
            &mut NativeBatched { layers: &weights.layers, workers: 1 },
            &cfg, &weights, &x,
        );
        assert!(y_batched.approx_eq(&y_single, 1e-5, 1e-5));
        for (a, b) in s_single.per_layer.iter().zip(&s_batched.per_layer) {
            assert_eq!(a.ffn_assignments, b.ffn_assignments);
            assert_eq!(a.zc_assignments, b.zc_assignments);
            assert_eq!(a.dropped, b.dropped);
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        // Parallel compute + serial scatter must be bitwise-deterministic.
        let (cfg, weights, x) = setup("test", 9, 64);
        let (y1, _) = run_backend(
            &mut NativeBatched { layers: &weights.layers, workers: 1 },
            &cfg, &weights, &x,
        );
        for workers in [2, 4, 8] {
            let (yw, _) = run_backend(
                &mut NativeBatched { layers: &weights.layers, workers },
                &cfg, &weights, &x,
            );
            assert_eq!(
                y1.data, yw.data,
                "workers={workers} diverged from serial"
            );
        }
    }

    #[test]
    fn zc_inline_only_touches_assigned_rows() {
        let (cfg, weights, x) = setup("test", 1, 16);
        let routing =
            route(&x, &weights.layers[0].router, None, cfg.top_k);
        let plan = DispatchPlan::build(&routing, &cfg, 16);
        let mut y = Tensor::zeros(&[16, cfg.d_model]);
        apply_zc_inline(
            &plan.zc_inline, &cfg, &weights.layers[0].consts, &x, &mut y,
        );
        let zc_tokens: std::collections::BTreeSet<usize> = plan
            .zc_inline
            .iter()
            .filter(|a| cfg.kind(a.expert) != ExpertKind::Zero)
            .map(|a| a.token)
            .collect();
        for tok in 0..16 {
            let nonzero = y.row(tok).iter().any(|&v| v != 0.0);
            if !zc_tokens.contains(&tok) {
                assert!(!nonzero, "row {tok} written without assignment");
            }
        }
    }

    #[test]
    fn token_counts_reconcile_with_layer_totals() {
        // The per-token counters must sum exactly to the per-layer
        // aggregates — the invariant that lets the serving layer slice a
        // batch's stats into per-request stats without losing anything.
        let (cfg, weights, x) = setup("test", 8, 56);
        let (_, stats) = run_backend(
            &mut NativeBatched { layers: &weights.layers, workers: 1 },
            &cfg, &weights, &x,
        );
        let totals = stats.total_counts();
        let ffn: usize =
            stats.per_layer.iter().map(|l| l.ffn_assignments).sum();
        let zc: usize =
            stats.per_layer.iter().map(|l| l.zc_assignments).sum();
        let dropped: usize = stats.per_layer.iter().map(|l| l.dropped).sum();
        assert_eq!(totals.ffn, ffn as u64);
        assert_eq!(totals.zc(), zc as u64);
        assert_eq!(totals.dropped, dropped as u64);
        assert_eq!(
            totals.total(),
            (56 * cfg.top_k * cfg.n_layers) as u64
        );
        // Disjoint spans sum to the batch total.
        let mut merged = stats.span_counts(0..20);
        merged.add(&stats.span_counts(20..56));
        assert_eq!(merged, totals);
    }

    #[test]
    fn stats_accounting_conserves_assignments() {
        let (cfg, weights, x) = setup("test", 5, 40);
        let (_, stats) = run_backend(
            &mut NativeBatched { layers: &weights.layers, workers: 2 },
            &cfg, &weights, &x,
        );
        assert_eq!(stats.per_layer.len(), cfg.n_layers);
        for l in &stats.per_layer {
            assert_eq!(
                l.ffn_assignments + l.zc_assignments + l.dropped,
                40 * cfg.top_k
            );
        }
        assert!(stats.expert_forward_s > 0.0);
        assert!(stats.expert_throughput() > 0.0);
    }
}

//! The shared MoE++ execution layer (DESIGN.md §7): one implementation of
//! "turn a [`DispatchPlan`] into outputs" used by every forward path.
//!
//! The paper's deployment asymmetry — heavy FFN experts are queued,
//! batched, sharded and communicated while zero-computation experts are
//! applied inline wherever the token lives — used to be re-implemented by
//! the reference layer (`moe::layer`), the serving engine
//! (`coordinator::engine`) and the cluster simulator (`cluster::sim`).
//! This module is now the only place that semantics lives:
//!
//! * [`ExpertBackend`] — the pluggable FFN execution strategy (per-token
//!   oracle, batched native with token-parallel shards, PJRT buckets, or
//!   the cluster's sharded workers). Backends only ever see FFN work, and
//!   draw their gather/scratch/output buffers from the [`FfnArena`] they
//!   are handed (DESIGN.md §11) instead of allocating.
//! * [`apply_zc_inline`] — the single zero/copy/constant application.
//! * [`execute_layer`] — FFN stage + ZC stage + [`LayerStats`] accounting
//!   for one planned layer.
//! * [`forward_stack`] — the stack loop: routing with gating-residual
//!   threading, per-layer configs, residual-stream update and
//!   [`ForwardStats`] aggregation, with every reusable buffer (per-layer
//!   `y`, routing scores, FFN scratch) drawn from the caller's
//!   [`ExecArena`] and all parallel fan-out going through the caller's
//!   [`Executor`] (the driver-owned persistent pool by default, the
//!   scoped spawn-per-call helpers as the measured baseline —
//!   DESIGN.md §12).

use std::time::Instant;

use anyhow::Result;

use crate::config::{ExpertKind, MoeConfig};
use crate::coordinator::dispatch::DispatchPlan;
use crate::moe::arena::{
    gather_rows, pick_f_tile, ExecArena, FfnArena, ShardSpec,
};
use crate::moe::experts::{ConstExpert, QuantFfnExpert, FFN_TOKEN_BLOCK};
use crate::moe::layer::{Assignment, LayerStats};
use crate::moe::router::Routing;
use crate::moe::weights::{MoeLayerWeights, StackWeights};
use crate::obs::{EventKind, Obs, TOK_K_BINS};
use crate::tensor::ops::axpy;
use crate::tensor::Tensor;
use crate::util::pool::Executor;

/// Aggregate timing + routing statistics for one stack forward.
#[derive(Clone, Debug, Default)]
pub struct ForwardStats {
    /// Wall-clock seconds inside the expert stage (FFN + ZC + combine).
    pub expert_forward_s: f64,
    /// Seconds inside FFN expert execution only.
    pub ffn_s: f64,
    /// Seconds inside zero-computation expert execution only.
    pub zc_s: f64,
    /// Seconds in routing (score matmul + top-k).
    pub routing_s: f64,
    pub per_layer: Vec<LayerStats>,
    pub tokens: usize,
    /// Per-token assignment counts summed over layers — the raw material
    /// the serving layer slices into per-request accounting
    /// ([`crate::serve`], DESIGN.md §9). Row `i` of the input batch owns
    /// index `i` here.
    pub token_counts: TokenCounts,
    /// Tokens whose FFN expert had no surviving replica and fell back
    /// to copy-expert semantics (DESIGN.md §16), summed over layers.
    /// Zero on every fault-free path; only the cluster backend's
    /// worker-loss degradation produces them.
    pub degraded_tokens: u64,
}

/// Per-token assignment counters, one entry per input row, summed across
/// layers. Splitting by expert kind (rather than just FFN-vs-ZC) exposes
/// the paper's "which cheap pathway did this token take" accounting.
#[derive(Clone, Debug, Default)]
pub struct TokenCounts {
    pub ffn: Vec<u32>,
    pub zero: Vec<u32>,
    pub copy: Vec<u32>,
    pub constant: Vec<u32>,
    pub dropped: Vec<u32>,
}

impl TokenCounts {
    pub fn new(n_tokens: usize) -> TokenCounts {
        TokenCounts {
            ffn: vec![0; n_tokens],
            zero: vec![0; n_tokens],
            copy: vec![0; n_tokens],
            constant: vec![0; n_tokens],
            dropped: vec![0; n_tokens],
        }
    }

    fn record_layer(&mut self, plan: &DispatchPlan, cfg: &MoeConfig) {
        for batch in &plan.ffn_batches {
            for &tok in &batch.tokens {
                self.ffn[tok] += 1;
            }
        }
        for a in &plan.zc_inline {
            match cfg.kind(a.expert) {
                ExpertKind::Zero => self.zero[a.token] += 1,
                ExpertKind::Copy => self.copy[a.token] += 1,
                ExpertKind::Constant => self.constant[a.token] += 1,
                ExpertKind::Ffn => unreachable!("ffn in zc list"),
            }
        }
        for a in &plan.dropped {
            self.dropped[a.token] += 1;
        }
    }
}

/// Assignment totals for a set of tokens (one request's rows, or a whole
/// batch). Produced by [`ForwardStats::span_counts`] /
/// [`ForwardStats::total_counts`]; spans of one batch sum exactly to the
/// batch total (tested below), which is what lets per-request serving
/// stats reconcile against batch-level metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AssignmentCounts {
    pub ffn: u64,
    pub zero: u64,
    pub copy: u64,
    pub constant: u64,
    pub dropped: u64,
}

impl AssignmentCounts {
    /// Zero-computation assignments (zero + copy + constant).
    pub fn zc(&self) -> u64 {
        self.zero + self.copy + self.constant
    }

    /// Assignments that survived capacity filtering.
    pub fn kept(&self) -> u64 {
        self.ffn + self.zc()
    }

    /// All routed assignments (kept + dropped) — T * K per layer.
    pub fn total(&self) -> u64 {
        self.kept() + self.dropped
    }

    pub fn add(&mut self, other: &AssignmentCounts) {
        self.ffn += other.ffn;
        self.zero += other.zero;
        self.copy += other.copy;
        self.constant += other.constant;
        self.dropped += other.dropped;
    }
}

impl ForwardStats {
    /// Expert-forward throughput (tokens/s), the Table 3 metric.
    pub fn expert_throughput(&self) -> f64 {
        self.tokens as f64 / self.expert_forward_s.max(1e-12)
    }

    /// Sum the per-token counters over a row span (a request's slice of
    /// the batch). Panics if the span exceeds the forwarded token count.
    pub fn span_counts(
        &self,
        span: std::ops::Range<usize>,
    ) -> AssignmentCounts {
        let sum = |v: &[u32]| -> u64 {
            v[span.clone()].iter().map(|&c| c as u64).sum()
        };
        AssignmentCounts {
            ffn: sum(&self.token_counts.ffn),
            zero: sum(&self.token_counts.zero),
            copy: sum(&self.token_counts.copy),
            constant: sum(&self.token_counts.constant),
            dropped: sum(&self.token_counts.dropped),
        }
    }

    /// Batch-level assignment totals (all tokens).
    pub fn total_counts(&self) -> AssignmentCounts {
        self.span_counts(0..self.tokens)
    }

    pub fn mean_ffn_per_token(&self) -> f64 {
        if self.per_layer.is_empty() {
            return 0.0;
        }
        self.per_layer.iter().map(|s| s.ffn_per_token).sum::<f64>()
            / self.per_layer.len() as f64
    }

    pub fn total_dropped(&self) -> usize {
        self.per_layer.iter().map(|s| s.dropped).sum()
    }
}

/// What a backend reports about one layer's FFN stage. Native backends
/// leave the distributed fields at their defaults; the cluster backend
/// fills in per-device compute, load and all-to-all accounting.
#[derive(Clone, Debug, Default)]
pub struct FfnLayerReport {
    /// Measured compute seconds per device (sharded backends).
    pub device_compute_s: Vec<f64>,
    /// FFN assignments landing on each device.
    pub device_load: Vec<usize>,
    /// Analytic all-to-all time (dispatch + combine).
    pub comm_s: f64,
    /// Off-device bytes moved.
    pub comm_bytes: u64,
    /// Tokens degraded to copy-expert semantics because no replica of
    /// their FFN expert survived (DESIGN.md §16) — zero for native
    /// backends and on every fault-free cluster forward.
    pub degraded_tokens: u64,
}

/// Full record of one executed layer.
#[derive(Clone, Debug)]
pub struct LayerExec {
    pub stats: LayerStats,
    /// Wall seconds in the FFN stage (driver-measured).
    pub ffn_s: f64,
    /// Wall seconds in the inline ZC stage (driver-measured).
    pub zc_s: f64,
    pub report: FfnLayerReport,
}

/// A pluggable FFN-expert execution strategy.
///
/// Contract (DESIGN.md §7): for every micro-batch in `plan.ffn_batches`,
/// scatter-add `gate * FFN_expert(h[token])` into the matching row of `y`.
/// The backend must not touch rows outside the batch token sets, must not
/// apply zero-computation experts (the driver owns those), and must treat
/// `plan` as authoritative — no re-deriving of routing or capacity.
/// Reusable buffers come from `arena` (DESIGN.md §11): backends request
/// gather/scratch/shard storage from it so steady-state execution does
/// not allocate. Parallel fan-out goes through `exec` (DESIGN.md §12):
/// backends size their work partition off `exec.workers()` and run it
/// via `exec.run`/`exec.for_each_mut` instead of spawning threads — the
/// driver decides whether that is the persistent pool or the scoped
/// baseline, and outputs must be bitwise-identical either way.
pub trait ExpertBackend {
    #[allow(clippy::too_many_arguments)]
    fn execute_ffn(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        y: &mut Tensor,
        arena: &mut FfnArena,
        exec: &Executor,
    ) -> Result<FfnLayerReport>;
}

// lint: no-alloc — the steady-state forward path: from here to the test
// module, per-token work must not touch the allocator (DESIGN.md §11).
/// The single implementation of zero-computation expert application
/// (paper Sec. 3.1): zero discards, copy adds `g*x`, constant adds the
/// learned convex mix. ZC experts always run inline on the token's home
/// buffer — they are never queued or communicated.
pub fn apply_zc_inline(
    assignments: &[Assignment],
    cfg: &MoeConfig,
    consts: &[ConstExpert],
    h: &Tensor,
    y: &mut Tensor,
) {
    let (_, d) = h.dims2();
    for a in assignments {
        let xrow = h.row(a.token);
        let orow = &mut y.data[a.token * d..(a.token + 1) * d];
        match cfg.kind(a.expert) {
            ExpertKind::Zero => {}
            ExpertKind::Copy => {
                crate::moe::experts::copy_expert_into(xrow, a.gate, orow)
            }
            ExpertKind::Constant => {
                consts[cfg.const_index(a.expert)]
                    .forward_token_into(xrow, a.gate, orow)
            }
            ExpertKind::Ffn => unreachable!("ffn assignment in zc list"),
        }
    }
}

/// Shared per-layer statistics accounting (mirrors L2's MoELayerAux).
pub fn layer_stats(
    plan: &DispatchPlan,
    routing: &Routing,
    cfg: &MoeConfig,
    n_tokens: usize,
) -> LayerStats {
    let ffn_assignments = plan.ffn_assignments();
    LayerStats {
        // alloc-ok: per-layer stats snapshot returned to the caller —
        // part of the output, not the per-token loop.
        expert_counts: plan.expert_counts.clone(),
        dropped: plan.dropped.len(),
        ffn_assignments,
        zc_assignments: plan.zc_inline.len(),
        ffn_per_token: ffn_assignments as f64 / n_tokens as f64,
        balance_loss: crate::moe::balance::balance_loss(routing, cfg),
    }
}

/// Execute one planned layer: FFN micro-batches on the backend, ZC experts
/// inline, both timed, plus stats. `y` receives the layer output (the
/// caller owns the residual-stream update); `arena` supplies the
/// backend's reusable buffers. When `obs` is present the stage timings,
/// per-shard worker timings (native token-shard path) and per-device
/// busy times (sharded backends) are stamped into its trace and
/// histograms — recording only, never affecting the math (§15).
#[allow(clippy::too_many_arguments)]
pub fn execute_layer(
    backend: &mut dyn ExpertBackend,
    layer: usize,
    plan: &DispatchPlan,
    routing: &Routing,
    cfg: &MoeConfig,
    consts: &[ConstExpert],
    h: &Tensor,
    y: &mut Tensor,
    arena: &mut FfnArena,
    exec: &Executor,
    obs: Option<&Obs>,
    batch: u64,
) -> Result<LayerExec> {
    // Staleness guard: only the backend call below may raise it, so a
    // serial (or non-native) layer never re-stamps the previous layer's
    // shard buffers.
    arena.last_shards = 0;
    let t0 = Instant::now();
    let report = backend.execute_ffn(layer, plan, h, y, arena, exec)?;
    let ffn_el = t0.elapsed();
    let ffn_s = ffn_el.as_secs_f64();

    let t1 = Instant::now();
    apply_zc_inline(&plan.zc_inline, cfg, consts, h, y);
    let zc_el = t1.elapsed();
    let zc_s = zc_el.as_secs_f64();

    if let Some(o) = obs {
        let li = layer as u16;
        let ffn_ns = ffn_el.as_nanos() as u64;
        let zc_ns = zc_el.as_nanos() as u64;
        o.registry().record(o.h.ffn_stage_ns, ffn_ns);
        o.registry().record(o.h.zc_stage_ns, zc_ns);
        o.trace.push(EventKind::ExpertForward {
            batch,
            layer: li,
            ffn_ns,
            zc_ns,
        });
        // Per-shard worker timings, written by the workers into their
        // exclusive `ShardBuf.ns` slots; `last_shards` bounds the stamp
        // to buffers this very backend call actually ran.
        for (si, (spec, buf)) in arena
            .shards
            .iter()
            .zip(arena.shard_bufs.iter())
            .take(arena.last_shards)
            .enumerate()
        {
            o.registry().record(o.h.shard_ns, buf.ns);
            o.trace.push(EventKind::ShardForward {
                batch,
                layer: li,
                device: 0,
                shard: si as u16,
                rows: spec.len as u32,
                ns: buf.ns,
            });
        }
        // Per-device busy time from the backend's report (cluster path;
        // native backends leave the report empty).
        for (dev, (&busy_s, &rows)) in report
            .device_compute_s
            .iter()
            .zip(report.device_load.iter())
            .enumerate()
        {
            let ns = (busy_s * 1e9) as u64;
            o.registry().record(o.h.device_busy_ns, ns);
            o.trace.push(EventKind::DeviceBusy {
                batch,
                layer: li,
                device: dev as u16,
                rows: rows as u32,
                ns,
            });
        }
    }

    Ok(LayerExec {
        stats: layer_stats(plan, routing, cfg, h.dims2().0),
        ffn_s,
        zc_s,
        report,
    })
}

/// The stack-level loop shared by the serving engine, the reference stack
/// and the cluster simulator: per layer — route (threading the previous
/// layer's raw scores when gating residuals are on), build the dispatch
/// plan from the *per-layer* config, execute via the backend, apply ZC
/// inline, then update the residual stream `h <- h + y`.
///
/// Without the residual update, fully-dropped tokens would become zero
/// rows and the sparse expert kernels would skip them, corrupting the
/// expert-forward cost accounting.
///
/// All reusable buffers (routing scores/probs/top-k, the per-layer `y`,
/// the backends' gather/scratch/shard storage) come from `arena` and are
/// reused across layers, batches and requests — steady-state forwards
/// allocate only the returned output/stats (DESIGN.md §11).
pub fn forward_stack(
    backend: &mut dyn ExpertBackend,
    weights: &StackWeights,
    layer_cfgs: &[MoeConfig],
    x: &Tensor,
    arena: &mut ExecArena,
    exec: &Executor,
    obs: Option<&Obs>,
) -> Result<(Tensor, ForwardStats, Vec<LayerExec>)> {
    let (t, d) = x.dims2();
    assert_eq!(
        layer_cfgs.len(),
        weights.layers.len(),
        "one config per layer"
    );
    // Claim this forward's batch id up front so mid-forward stamps from
    // backends (e.g. the cluster's replica splits) share it.
    let batch = obs.map_or(0, Obs::next_batch);
    let mut stats = ForwardStats {
        tokens: t,
        token_counts: TokenCounts::new(t),
        ..Default::default()
    };
    let mut execs = Vec::with_capacity(weights.layers.len());
    // alloc-ok: the residual stream is the returned output tensor —
    // one clone per forward, sized once.
    let mut h = x.clone();
    for (li, layer) in weights.layers.iter().enumerate() {
        let lcfg = &layer_cfgs[li];
        let t0 = Instant::now();
        // The arena's residual carry holds the previous layer's raw
        // scores; layer 0 must never read it (it still holds the last
        // batch's tail).
        arena.route.route_layer(
            &h,
            &layer.router,
            lcfg.gating_residual && li > 0,
            lcfg.top_k,
        );
        let route_el = t0.elapsed();
        stats.routing_s += route_el.as_secs_f64();
        if let Some(o) = obs {
            let ns = route_el.as_nanos() as u64;
            o.registry().record(o.h.route_ns, ns);
            o.trace.push(EventKind::Route {
                batch,
                layer: li as u16,
                ns,
            });
        }

        let t1 = Instant::now();
        let plan = DispatchPlan::build(&arena.route.routing, lcfg, t);
        stats.token_counts.record_layer(&plan, lcfg);
        if let Some(o) = obs {
            stamp_dispatch(o, batch, li as u16, &plan, arena, t, t1);
        }
        arena.prepare_y(t, d);
        let (routing, y, ffn) = arena.split();
        let ex = execute_layer(
            backend, li, &plan, routing, lcfg, &layer.consts, &h, y, ffn,
            exec, obs, batch,
        )?;
        stats.ffn_s += ex.ffn_s;
        stats.zc_s += ex.zc_s;
        stats.expert_forward_s += ex.ffn_s + ex.zc_s;
        stats.degraded_tokens += ex.report.degraded_tokens;
        // alloc-ok: stats are caller-visible output, not hot-loop state.
        stats.per_layer.push(ex.stats.clone());
        execs.push(ex);

        let t2 = Instant::now();
        for (hv, yv) in h.data.iter_mut().zip(&y.data) {
            *hv += yv;
        }
        if let Some(o) = obs {
            let ns = t2.elapsed().as_nanos() as u64;
            o.registry().record(o.h.combine_ns, ns);
            o.trace.push(EventKind::Combine {
                batch,
                layer: li as u16,
                ns,
            });
        }
        arena.route.end_layer();
    }
    Ok((h, stats, execs))
}

/// Stamp one layer's dispatch-plan record: assignment split histograms,
/// the tokens-per-FFN-expert-count distribution (built in the arena's
/// reusable `tok_k` scratch — no per-layer allocation) and the
/// [`EventKind::Dispatch`] trace event. Only called when obs is
/// installed, so the obs-off path never touches the scratch.
fn stamp_dispatch(
    o: &Obs,
    batch: u64,
    layer: u16,
    plan: &DispatchPlan,
    arena: &mut ExecArena,
    t: usize,
    t1: Instant,
) {
    let ffn = plan.ffn_assignments() as u64;
    let zc = plan.zc_inline.len() as u64;
    let dropped = plan.dropped.len() as u64;
    o.registry().record(o.h.layer_ffn_assignments, ffn);
    o.registry().record(o.h.layer_zc_assignments, zc);
    let tk = arena.prepare_tok_k(t);
    for b in &plan.ffn_batches {
        for &tok in &b.tokens {
            tk[tok] += 1;
        }
    }
    let mut tok_by_k = [0u32; TOK_K_BINS];
    for &k in tk.iter() {
        tok_by_k[(k as usize).min(TOK_K_BINS - 1)] += 1;
    }
    for (k, &n) in tok_by_k.iter().enumerate() {
        if n > 0 {
            o.registry().record_n(
                o.h.tokens_per_expert_count,
                k as u64,
                n as u64,
            );
        }
    }
    let ns = t1.elapsed().as_nanos() as u64;
    o.registry().record(o.h.dispatch_ns, ns);
    o.trace.push(EventKind::Dispatch {
        batch,
        layer,
        ffn: ffn as u32,
        zc: zc as u32,
        dropped: dropped as u32,
        ns,
        tok_by_k,
    });
}

// ------------------------------------------------------------- backends

/// How [`NativeBatched`] splits a layer's FFN work across workers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Partition {
    /// One work unit per FFN micro-batch — the historical batch-per-worker
    /// fan-out, kept as the measured baseline (`--partition batch`).
    /// Under skewed routing a single hot expert's batch stays serial on
    /// one worker while the rest idle.
    Batch,
    /// (expert, row-range) shards sized from the layer's work estimate,
    /// so a hot expert's micro-batch splits across all workers. Outputs
    /// are scatter-added serially in canonical (batch, shard) order, so
    /// results are bitwise-identical to [`Partition::Batch`] and to the
    /// serial path for every worker count.
    #[default]
    Shard,
}

impl Partition {
    pub fn parse(s: &str) -> Result<Partition> {
        match s {
            "batch" => Ok(Partition::Batch),
            "shard" => Ok(Partition::Shard),
            other => anyhow::bail!(
                "unknown partition '{other}' (expected batch|shard)"
            ),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Partition::Batch => "batch",
            Partition::Shard => "shard",
        }
    }

    pub fn all() -> [Partition; 2] {
        [Partition::Batch, Partition::Shard]
    }
}

/// The oracle backend: per-token forwards (via the arena's scratch),
/// exactly the reference semantics `moe::layer::layer_forward` is defined
/// by.
pub struct NativeSingle<'a> {
    pub layers: &'a [MoeLayerWeights],
}

impl ExpertBackend for NativeSingle<'_> {
    fn execute_ffn(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        y: &mut Tensor,
        arena: &mut FfnArena,
        _exec: &Executor,
    ) -> Result<FfnLayerReport> {
        let (_, d) = h.dims2();
        let w = &self.layers[layer];
        let d_ff = w.ffn.first().map_or(0, |e| e.w1.shape[1]);
        arena.prepare_serial(d_ff, d);
        for batch in &plan.ffn_batches {
            let e = &w.ffn[batch.expert];
            for (&tok, &gate) in batch.tokens.iter().zip(&batch.gates) {
                let orow = &mut y.data[tok * d..(tok + 1) * d];
                let _ = e.forward_token_scratch(
                    h.row(tok), gate, &mut arena.scratch, orow,
                );
            }
        }
        Ok(FfnLayerReport::default())
    }
}

/// Oversubscription factor for shard sizing: aim for this many shards per
/// worker so the atomic work queue smooths uneven expert batches.
const SHARD_OVERSUB: usize = 4;

/// Append `plan`'s work as (batch, row-range) shards onto `shards`, in
/// canonical (batch, start) order. `Partition::Batch` emits one shard per
/// micro-batch; `Partition::Shard` splits each batch into even contiguous
/// ranges sized by **cost**, not row count: `cost_per_row(bi)` is the
/// relative per-row FLOP weight of batch `bi` (its expert's `d_ff` — the
/// `ffn_flops_per_token` ∝ `d_model · d_ff` identity with the shared
/// `d_model` factored out), so per-expert cost differences split into
/// shards of even *work*, not even row counts. For [`NativeBatched`]
/// every stock expert shares its layer's `d_ff`, so the weight is
/// layer-constant and splitting stays row-proportional; [`NativeQuant`]
/// weighs each batch by the expert's streamed **bytes per row**
/// (`d_ff ×` bytes/weight — 4 for f32, 1 for int8), so a mixed-precision
/// layer produces genuinely uneven row ranges of even work
/// (`mixed_precision_costs_split_shards_unevenly` below exercises the
/// real backend cost; DESIGN.md §11/§17). Each batch still
/// gets at least one shard and never more than
/// `ceil(rows / FFN_TOKEN_BLOCK)` (sub-block shards would waste whole
/// weight-stream passes). Shard boundaries never affect results (§11),
/// only load balance.
fn plan_shards(
    plan: &DispatchPlan,
    partition: Partition,
    workers: usize,
    cost_per_row: impl Fn(usize) -> u64,
    shards: &mut Vec<ShardSpec>,
) {
    shards.clear();
    match partition {
        Partition::Batch => {
            for (bi, batch) in plan.ffn_batches.iter().enumerate() {
                shards.push(ShardSpec {
                    batch: bi,
                    start: 0,
                    len: batch.tokens.len(),
                });
            }
        }
        Partition::Shard => {
            let total: u64 = plan
                .ffn_batches
                .iter()
                .enumerate()
                .map(|(bi, b)| {
                    b.tokens.len() as u64 * cost_per_row(bi).max(1)
                })
                .sum();
            let target = total
                .div_ceil((workers.max(1) * SHARD_OVERSUB) as u64)
                .max(1);
            for (bi, batch) in plan.ffn_batches.iter().enumerate() {
                let len = batch.tokens.len();
                if len == 0 {
                    continue;
                }
                let cost = len as u64 * cost_per_row(bi).max(1);
                let by_cost = cost.div_ceil(target) as usize;
                let max_shards = len.div_ceil(FFN_TOKEN_BLOCK).max(1);
                let n_shards = by_cost.clamp(1, max_shards);
                let base = len / n_shards;
                let rem = len % n_shards;
                let mut start = 0;
                for s in 0..n_shards {
                    let sz = base + usize::from(s < rem);
                    if sz == 0 {
                        continue;
                    }
                    shards.push(ShardSpec { batch: bi, start, len: sz });
                    start += sz;
                }
            }
        }
    }
}

/// The serving-path native backend: gather each unit of FFN work, run the
/// allocation-free batched expert kernel, scatter-add gated rows. When
/// the driver's [`Executor`] is wider than one, the layer's work is cut
/// into shards per `partition` and fanned out over it (the persistent
/// pool by default, scoped spawns as the measured baseline); every
/// shard's dense output lands in an arena-owned buffer and is
/// scatter-added serially in canonical (batch, shard) order — two FFN
/// experts may feed one token's output row, and per-token results are
/// independent of shard boundaries, so outputs are **bitwise-identical**
/// for every worker count, both partition strategies and both executors
/// (racing the scatter would be UB).
pub struct NativeBatched<'a> {
    pub layers: &'a [MoeLayerWeights],
    pub partition: Partition,
}

impl ExpertBackend for NativeBatched<'_> {
    fn execute_ffn(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        y: &mut Tensor,
        arena: &mut FfnArena,
        exec: &Executor,
    ) -> Result<FfnLayerReport> {
        let (_, d) = h.dims2();
        let w = &self.layers[layer];
        let batches = &plan.ffn_batches;
        if batches.is_empty() {
            return Ok(FfnLayerReport::default());
        }
        let workers = exec.workers();
        let mut n_shards = 0;
        if workers > 1 {
            let shards_cap = arena.shards.capacity();
            plan_shards(
                plan,
                self.partition,
                workers,
                |bi| w.ffn[batches[bi].expert].w1.shape[1] as u64,
                &mut arena.shards,
            );
            if arena.shards.capacity() > shards_cap {
                arena.growths += 1;
            }
            n_shards = arena.shards.len();
        }
        if n_shards <= 1 {
            // Serial: one weight stream per batch, scatter-add directly
            // into y, every buffer arena-owned (§Perf, DESIGN.md §11).
            // Also taken when the parallel plan degenerates to a single
            // shard — one unit of work gains no parallelism and would
            // pay a needless output-block zero plus a combine pass.
            let d_ff = w.ffn.first().map_or(0, |e| e.w1.shape[1]);
            arena.prepare_serial(d_ff, d);
            for batch in batches {
                let e = &w.ffn[batch.expert];
                gather_rows(
                    &mut arena.gather,
                    h,
                    &batch.tokens,
                    d,
                    &mut arena.growths,
                );
                e.forward_batch_into(
                    &arena.gather,
                    Some(batch.gates.as_slice()),
                    &mut arena.scratch,
                    &mut y.data,
                    Some(batch.tokens.as_slice()),
                );
            }
            return Ok(FfnLayerReport::default());
        }

        // Token-parallel path: cut the layer's FFN work into shards, fan
        // the dense compute out over the executor (each worker writing
        // its own arena-owned shard buffer), then scatter-add serially.
        arena.ensure_shard_bufs(n_shards);
        // Record which shard buffers this call actually runs so the
        // driver can stamp exactly these (and never a previous layer's
        // stale set) — see `FfnArena::last_shards`.
        arena.last_shards = n_shards;
        let l1_budget = arena.l1_budget_bytes;
        let shards = &arena.shards;
        exec.for_each_mut(
            &mut arena.shard_bufs[..n_shards],
            |idx, buf| {
                let w0 = Instant::now();
                let spec = &shards[idx];
                let batch = &batches[spec.batch];
                let e = &w.ffn[batch.expert];
                let f = e.w1.shape[1];
                buf.prepare(
                    spec.len,
                    d,
                    f.max(d),
                    pick_f_tile(f, l1_budget),
                );
                let rows =
                    &batch.tokens[spec.start..spec.start + spec.len];
                for (i, &tok) in rows.iter().enumerate() {
                    buf.gather.data[i * d..(i + 1) * d]
                        .copy_from_slice(h.row(tok));
                }
                let (gather, out, scratch) = buf.parts();
                e.forward_batch_into(
                    gather,
                    Some(
                        &batch.gates[spec.start..spec.start + spec.len],
                    ),
                    scratch,
                    &mut out[..spec.len * d],
                    None,
                );
                // Worker-side wall time for this shard, written into the
                // worker's exclusive buffer; the driver stamps it after
                // the join (no locks, no atomics on the worker path).
                buf.ns = w0.elapsed().as_nanos() as u64;
            },
        );
        // Canonical serial combine: shards are generated in (batch,
        // start) order, and within one batch a token appears in exactly
        // one shard, so each output row accumulates its expert
        // contributions in batch order — the same order the serial path
        // and the batch partition produce.
        for (spec, buf) in
            arena.shards.iter().zip(&arena.shard_bufs[..n_shards])
        {
            let batch = &batches[spec.batch];
            let rows = &batch.tokens[spec.start..spec.start + spec.len];
            for (i, &tok) in rows.iter().enumerate() {
                let orow = &mut y.data[tok * d..(tok + 1) * d];
                axpy(1.0, &buf.out[i * d..(i + 1) * d], orow);
            }
        }
        Ok(FfnLayerReport::default())
    }
}

/// The mixed-precision native backend: structurally identical to
/// [`NativeBatched`] (same serial arm, same token-parallel shards, same
/// canonical (batch, shard)-order combine), but each expert runs the
/// kernel of its **stack-wide precision** — `qlayers[layer][e]` is
/// `Some` for int8 experts (pre-quantized once at precision-install
/// time, see [`crate::moe::weights::QuantStackWeights`]) and `None` for
/// f32 experts, which take exactly the [`NativeBatched`] kernel path.
///
/// Determinism (DESIGN.md §17): the int8 kernel is per-token pure — its
/// per-token quantize → i32-accumulate → dequantize pipeline never mixes
/// tokens, and i32 addition is exactly associative — so, as with the f32
/// path, shard boundaries, worker counts, executors and replica splits
/// cannot change a single output bit. Shard sizing weighs each batch by
/// streamed bytes per row (`d_ff ×` bytes/weight), the first
/// runtime-producible plan where `plan_shards` costs are not
/// layer-constant.
pub struct NativeQuant<'a> {
    pub layers: &'a [MoeLayerWeights],
    /// Per layer, per FFN expert: the int8 copy, `Some` iff the expert
    /// serves quantized (uniform across layers — precision is
    /// stack-wide per expert).
    pub qlayers: &'a [Vec<Option<QuantFfnExpert>>],
    pub partition: Partition,
}

impl NativeQuant<'_> {
    /// Relative per-row cost of `expert` in `layer`: bytes of weight
    /// stream per token (shared `d_model` factored out).
    fn row_cost(
        qlayer: &[Option<QuantFfnExpert>],
        w: &MoeLayerWeights,
        expert: usize,
    ) -> u64 {
        let f = w.ffn[expert].w1.shape[1] as u64;
        match qlayer[expert] {
            Some(_) => f,    // 1 byte/weight
            None => f * 4,   // 4 bytes/weight
        }
    }
}

impl ExpertBackend for NativeQuant<'_> {
    fn execute_ffn(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        y: &mut Tensor,
        arena: &mut FfnArena,
        exec: &Executor,
    ) -> Result<FfnLayerReport> {
        let (_, d) = h.dims2();
        let w = &self.layers[layer];
        let ql = &self.qlayers[layer];
        let batches = &plan.ffn_batches;
        if batches.is_empty() {
            return Ok(FfnLayerReport::default());
        }
        let workers = exec.workers();
        let mut n_shards = 0;
        if workers > 1 {
            let shards_cap = arena.shards.capacity();
            plan_shards(
                plan,
                self.partition,
                workers,
                |bi| Self::row_cost(ql, w, batches[bi].expert),
                &mut arena.shards,
            );
            if arena.shards.capacity() > shards_cap {
                arena.growths += 1;
            }
            n_shards = arena.shards.len();
        }
        if n_shards <= 1 {
            // Serial arm (see NativeBatched): one weight stream per
            // batch, scatter-add straight into y, both precisions'
            // scratch arena-owned.
            let d_ff = w.ffn.first().map_or(0, |e| e.w1.shape[1]);
            arena.prepare_serial_mixed(d_ff, d);
            for batch in batches {
                gather_rows(
                    &mut arena.gather,
                    h,
                    &batch.tokens,
                    d,
                    &mut arena.growths,
                );
                match &ql[batch.expert] {
                    Some(q) => q.forward_batch_into(
                        &arena.gather,
                        Some(batch.gates.as_slice()),
                        &mut arena.qscratch,
                        &mut y.data,
                        Some(batch.tokens.as_slice()),
                    ),
                    None => w.ffn[batch.expert].forward_batch_into(
                        &arena.gather,
                        Some(batch.gates.as_slice()),
                        &mut arena.scratch,
                        &mut y.data,
                        Some(batch.tokens.as_slice()),
                    ),
                }
            }
            return Ok(FfnLayerReport::default());
        }

        // Token-parallel arm: byte-weighted shards over the executor,
        // then the canonical serial combine (see NativeBatched — the
        // combine order is precision-blind).
        arena.ensure_shard_bufs(n_shards);
        arena.last_shards = n_shards;
        let l1_budget = arena.l1_budget_bytes;
        let shards = &arena.shards;
        exec.for_each_mut(
            &mut arena.shard_bufs[..n_shards],
            |idx, buf| {
                let w0 = Instant::now();
                let spec = &shards[idx];
                let batch = &batches[spec.batch];
                let e = &w.ffn[batch.expert];
                let f = e.w1.shape[1];
                buf.prepare(
                    spec.len,
                    d,
                    f.max(d),
                    pick_f_tile(f, l1_budget),
                );
                buf.prepare_quant(d, f);
                let rows =
                    &batch.tokens[spec.start..spec.start + spec.len];
                for (i, &tok) in rows.iter().enumerate() {
                    buf.gather.data[i * d..(i + 1) * d]
                        .copy_from_slice(h.row(tok));
                }
                let gates =
                    &batch.gates[spec.start..spec.start + spec.len];
                let (gather, out, scratch, qscratch) =
                    buf.parts_mixed();
                match &ql[batch.expert] {
                    Some(q) => q.forward_batch_into(
                        gather,
                        Some(gates),
                        qscratch,
                        &mut out[..spec.len * d],
                        None,
                    ),
                    None => e.forward_batch_into(
                        gather,
                        Some(gates),
                        scratch,
                        &mut out[..spec.len * d],
                        None,
                    ),
                }
                buf.ns = w0.elapsed().as_nanos() as u64;
            },
        );
        for (spec, buf) in
            arena.shards.iter().zip(&arena.shard_bufs[..n_shards])
        {
            let batch = &batches[spec.batch];
            let rows = &batch.tokens[spec.start..spec.start + spec.len];
            for (i, &tok) in rows.iter().enumerate() {
                let orow = &mut y.data[tok * d..(tok + 1) * d];
                axpy(1.0, &buf.out[i * d..(i + 1) * d], orow);
            }
        }
        Ok(FfnLayerReport::default())
    }
}
// lint: end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::moe::router::route;
    use crate::moe::weights::QuantStackWeights;
    use crate::util::rng::Rng;

    fn setup(
        preset: &str,
        seed: u64,
        t: usize,
    ) -> (MoeConfig, StackWeights, Tensor) {
        let cfg = MoeConfig::preset(preset);
        let weights = StackWeights::init(seed, &cfg);
        let mut rng = Rng::new(seed ^ 0xABCD);
        let x = Tensor::randn(&mut rng, &[t, cfg.d_model], 1.0);
        (cfg, weights, x)
    }

    fn run_backend(
        backend: &mut dyn ExpertBackend,
        cfg: &MoeConfig,
        weights: &StackWeights,
        x: &Tensor,
        exec: &Executor,
    ) -> (Tensor, ForwardStats) {
        let cfgs = vec![cfg.clone(); cfg.n_layers];
        let mut arena = ExecArena::new();
        let (y, stats, _) = forward_stack(
            backend, weights, &cfgs, x, &mut arena, exec, None,
        )
        .unwrap();
        (y, stats)
    }

    fn batched<'a>(
        weights: &'a StackWeights,
        partition: Partition,
    ) -> NativeBatched<'a> {
        NativeBatched { layers: &weights.layers, partition }
    }

    #[test]
    fn batched_matches_single_within_tolerance() {
        let (cfg, weights, x) = setup("test", 3, 48);
        let (y_single, s_single) = run_backend(
            &mut NativeSingle { layers: &weights.layers },
            &cfg, &weights, &x, &Executor::serial(),
        );
        let (y_batched, s_batched) = run_backend(
            &mut batched(&weights, Partition::Shard),
            &cfg, &weights, &x, &Executor::serial(),
        );
        assert!(y_batched.approx_eq(&y_single, 1e-5, 1e-5));
        for (a, b) in s_single.per_layer.iter().zip(&s_batched.per_layer) {
            assert_eq!(a.ffn_assignments, b.ffn_assignments);
            assert_eq!(a.zc_assignments, b.zc_assignments);
            assert_eq!(a.dropped, b.dropped);
        }
    }

    #[test]
    fn worker_count_partition_and_executor_do_not_change_results() {
        // Parallel compute + serial canonical scatter must be
        // bitwise-deterministic for every worker count, both work
        // partitions (batch fan-out vs token shards) AND both executors
        // (persistent pool vs scoped spawns).
        let (cfg, weights, x) = setup("test", 9, 64);
        let (y1, _) = run_backend(
            &mut batched(&weights, Partition::Shard),
            &cfg, &weights, &x, &Executor::serial(),
        );
        for partition in Partition::all() {
            for workers in [1, 2, 4, 8] {
                let pool = crate::util::pool::ExecPool::new(workers);
                for exec in [
                    Executor::Scoped { workers },
                    Executor::Pool(&pool),
                ] {
                    let (yw, _) = run_backend(
                        &mut batched(&weights, partition),
                        &cfg, &weights, &x, &exec,
                    );
                    assert_eq!(
                        y1.data, yw.data,
                        "workers={workers} partition={} diverged",
                        partition.label()
                    );
                }
            }
        }
    }

    #[test]
    fn shard_partition_covers_all_rows_exactly_once() {
        // plan_shards must partition each batch's rows into contiguous,
        // disjoint, covering ranges in canonical order.
        let (cfg, weights, x) = setup("test", 21, 96);
        let routing =
            route(&x, &weights.layers[0].router, None, cfg.top_k);
        let plan = DispatchPlan::build(&routing, &cfg, 96);
        for workers in [1usize, 2, 4, 8, 64] {
            let mut shards = Vec::new();
            plan_shards(&plan, Partition::Shard, workers, |_| 1, &mut shards);
            let mut cursor: Vec<usize> =
                vec![0; plan.ffn_batches.len()];
            let mut prev_batch = 0usize;
            for s in &shards {
                assert!(s.batch >= prev_batch, "canonical order broken");
                prev_batch = s.batch;
                assert_eq!(
                    s.start, cursor[s.batch],
                    "gap or overlap in batch {}", s.batch
                );
                assert!(s.len > 0);
                cursor[s.batch] += s.len;
            }
            for (bi, b) in plan.ffn_batches.iter().enumerate() {
                assert_eq!(
                    cursor[bi],
                    b.tokens.len(),
                    "batch {bi} not fully covered (workers={workers})"
                );
            }
        }
    }

    #[test]
    fn shard_sizing_follows_flops_not_rows() {
        // Two batches with equal total FLOPs but very different row
        // counts: a narrow expert (cost 1/row, 112 rows) and a wide one
        // (cost 14/row — e.g. 14x the d_ff — 8 rows). Row-based sizing
        // would leave the wide batch whole (8 rows is far below a
        // 120/16-row target) while cost-based sizing splits both batches
        // into shards of even work.
        use crate::coordinator::dispatch::ExpertBatch;
        let mk = |expert: usize, n: usize| ExpertBatch {
            expert,
            tokens: (0..n).collect(),
            gates: vec![1.0; n],
        };
        let plan = DispatchPlan {
            ffn_batches: vec![mk(0, 112), mk(1, 8)],
            zc_inline: Vec::new(),
            dropped: Vec::new(),
            expert_counts: vec![112, 8],
        };
        let cost = |bi: usize| if bi == 0 { 1 } else { 14 };
        let mut shards = Vec::new();
        plan_shards(&plan, Partition::Shard, 4, cost, &mut shards);
        // total cost 224, workers*oversub = 16 -> 14 cost per shard:
        // batch 0 gets ceil(112/14) = 8 shards; batch 1 wants 8 but is
        // clamped to ceil(8/FFN_TOKEN_BLOCK) = 2 whole-block shards.
        let n0 = shards.iter().filter(|s| s.batch == 0).count();
        let n1 = shards.iter().filter(|s| s.batch == 1).count();
        assert_eq!(n0, 8, "{shards:?}");
        assert_eq!(n1, 2, "{shards:?}");
        // Even-work check: every batch-0 shard carries 14 rows (=14
        // cost), every batch-1 shard 4 rows (=56 cost, block-clamped).
        for s in &shards {
            let want = if s.batch == 0 { 14 } else { 4 };
            assert_eq!(s.len, want, "{s:?}");
        }
        // Uniform costs reproduce row-proportional splitting: equal-row
        // batches get equal shard counts.
        let plan_u = DispatchPlan {
            ffn_batches: vec![mk(0, 64), mk(1, 64)],
            zc_inline: Vec::new(),
            dropped: Vec::new(),
            expert_counts: vec![64, 64],
        };
        plan_shards(&plan_u, Partition::Shard, 4, |_| 1, &mut shards);
        let n0 = shards.iter().filter(|s| s.batch == 0).count();
        let n1 = shards.iter().filter(|s| s.batch == 1).count();
        assert_eq!(n0, n1);
    }

    fn quant<'a>(
        weights: &'a StackWeights,
        q: &'a QuantStackWeights,
        partition: Partition,
    ) -> NativeQuant<'a> {
        NativeQuant {
            layers: &weights.layers,
            qlayers: &q.layers,
            partition,
        }
    }

    #[test]
    fn mixed_precision_costs_split_shards_unevenly() {
        // Satellite of the quantization PR: with the *real* NativeQuant
        // cost (streamed bytes/row), two equal-row batches whose experts
        // differ only in precision split into genuinely uneven shard
        // counts — the f32 batch carries 4x the bytes, so ~4x the
        // shards. This retires the "cost weighting is a row-sizing
        // no-op" caveat (DESIGN.md §11).
        use crate::coordinator::dispatch::ExpertBatch;
        let cfg = MoeConfig::preset("test"); // d_ff = 64
        let weights = StackWeights::init(2, &cfg);
        let prec =
            [Precision::F32, Precision::Int8, Precision::F32, Precision::F32];
        let q = QuantStackWeights::build(&weights, &prec);
        let mk = |expert: usize, n: usize| ExpertBatch {
            expert,
            tokens: (0..n).collect(),
            gates: vec![1.0; n],
        };
        let plan = DispatchPlan {
            ffn_batches: vec![mk(0, 64), mk(1, 64)],
            zc_inline: Vec::new(),
            dropped: Vec::new(),
            expert_counts: vec![64, 64],
        };
        let w = &weights.layers[0];
        let ql = &q.layers[0];
        assert_eq!(NativeQuant::row_cost(ql, w, 0), 64 * 4);
        assert_eq!(NativeQuant::row_cost(ql, w, 1), 64);
        let mut shards = Vec::new();
        plan_shards(
            &plan,
            Partition::Shard,
            4,
            |bi| NativeQuant::row_cost(ql, w, plan.ffn_batches[bi].expert),
            &mut shards,
        );
        // total cost 64*256 + 64*64 = 20480; 4 workers * oversub 4 ->
        // target 1280/shard: f32 batch ceil(16384/1280)=13 shards, int8
        // batch ceil(4096/1280)=4.
        let n0 = shards.iter().filter(|s| s.batch == 0).count();
        let n1 = shards.iter().filter(|s| s.batch == 1).count();
        assert_eq!((n0, n1), (13, 4), "{shards:?}");
    }

    #[test]
    fn quant_backend_with_all_f32_is_bitwise_equal_to_batched() {
        // An all-f32 precision map must make NativeQuant a bit-exact
        // alias of NativeBatched: same kernel, same blocking, same
        // combine — and same shard plan (uniform costs are
        // row-proportional regardless of scale).
        let (cfg, weights, x) = setup("test", 11, 48);
        let q = QuantStackWeights::build(
            &weights,
            &[Precision::F32; 4],
        );
        for workers in [1usize, 4] {
            let exec = Executor::Scoped { workers };
            let (yb, _) = run_backend(
                &mut batched(&weights, Partition::Shard),
                &cfg, &weights, &x, &exec,
            );
            let (yq, _) = run_backend(
                &mut quant(&weights, &q, Partition::Shard),
                &cfg, &weights, &x, &exec,
            );
            assert_eq!(yb.data, yq.data, "workers={workers}");
        }
    }

    #[test]
    fn quant_backend_is_bitwise_deterministic_across_fanout() {
        // The §17 contract at backend level: int8 outputs never depend
        // on worker count, partition strategy or executor.
        let (cfg, weights, x) = setup("test", 13, 64);
        let q =
            QuantStackWeights::build(&weights, &[Precision::Int8; 4]);
        let (y1, _) = run_backend(
            &mut quant(&weights, &q, Partition::Shard),
            &cfg, &weights, &x, &Executor::serial(),
        );
        for partition in Partition::all() {
            for workers in [1, 2, 4, 8] {
                let pool = crate::util::pool::ExecPool::new(workers);
                for exec in [
                    Executor::Scoped { workers },
                    Executor::Pool(&pool),
                ] {
                    let (yw, _) = run_backend(
                        &mut quant(&weights, &q, partition),
                        &cfg, &weights, &x, &exec,
                    );
                    assert_eq!(
                        y1.data, yw.data,
                        "workers={workers} partition={} diverged",
                        partition.label()
                    );
                }
            }
        }
    }

    #[test]
    fn quant_backend_tracks_oracle_on_a_routing_stable_stack() {
        // Kernel-level tolerance at backend level: on a single layer the
        // router sees the identical input for both precisions (routing
        // reads h, quantization only perturbs FFN outputs), so the
        // comparison is routing-stable and the error is purely the int8
        // kernel's. Multi-layer stacks can legitimately flip top-k picks
        // on borderline tokens; those gates live in bench::quality.
        let mut cfg = MoeConfig::preset("test");
        cfg.n_layers = 1;
        let weights = StackWeights::init(17, &cfg);
        let mut rng = Rng::new(0x51AB);
        let x = Tensor::randn(&mut rng, &[64, cfg.d_model], 1.0);
        let (y_f32, _) = run_backend(
            &mut NativeSingle { layers: &weights.layers },
            &cfg, &weights, &x, &Executor::serial(),
        );
        let q =
            QuantStackWeights::build(&weights, &[Precision::Int8; 4]);
        let (y_q, _) = run_backend(
            &mut quant(&weights, &q, Partition::Shard),
            &cfg, &weights, &x, &Executor::serial(),
        );
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in y_q.data.iter().zip(&y_f32.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < 0.1, "quantized stack diverged: rel {rel}");
        assert!(den > 0.0);
    }

    #[test]
    fn zc_inline_only_touches_assigned_rows() {
        let (cfg, weights, x) = setup("test", 1, 16);
        let routing =
            route(&x, &weights.layers[0].router, None, cfg.top_k);
        let plan = DispatchPlan::build(&routing, &cfg, 16);
        let mut y = Tensor::zeros(&[16, cfg.d_model]);
        apply_zc_inline(
            &plan.zc_inline, &cfg, &weights.layers[0].consts, &x, &mut y,
        );
        let zc_tokens: std::collections::BTreeSet<usize> = plan
            .zc_inline
            .iter()
            .filter(|a| cfg.kind(a.expert) != ExpertKind::Zero)
            .map(|a| a.token)
            .collect();
        for tok in 0..16 {
            let nonzero = y.row(tok).iter().any(|&v| v != 0.0);
            if !zc_tokens.contains(&tok) {
                assert!(!nonzero, "row {tok} written without assignment");
            }
        }
    }

    #[test]
    fn token_counts_reconcile_with_layer_totals() {
        // The per-token counters must sum exactly to the per-layer
        // aggregates — the invariant that lets the serving layer slice a
        // batch's stats into per-request stats without losing anything.
        let (cfg, weights, x) = setup("test", 8, 56);
        let (_, stats) = run_backend(
            &mut batched(&weights, Partition::Shard),
            &cfg, &weights, &x, &Executor::serial(),
        );
        let totals = stats.total_counts();
        let ffn: usize =
            stats.per_layer.iter().map(|l| l.ffn_assignments).sum();
        let zc: usize =
            stats.per_layer.iter().map(|l| l.zc_assignments).sum();
        let dropped: usize = stats.per_layer.iter().map(|l| l.dropped).sum();
        assert_eq!(totals.ffn, ffn as u64);
        assert_eq!(totals.zc(), zc as u64);
        assert_eq!(totals.dropped, dropped as u64);
        assert_eq!(
            totals.total(),
            (56 * cfg.top_k * cfg.n_layers) as u64
        );
        // Disjoint spans sum to the batch total.
        let mut merged = stats.span_counts(0..20);
        merged.add(&stats.span_counts(20..56));
        assert_eq!(merged, totals);
    }

    #[test]
    fn stats_accounting_conserves_assignments() {
        let (cfg, weights, x) = setup("test", 5, 40);
        let (_, stats) = run_backend(
            &mut batched(&weights, Partition::Shard),
            &cfg, &weights, &x, &Executor::Scoped { workers: 2 },
        );
        assert_eq!(stats.per_layer.len(), cfg.n_layers);
        for l in &stats.per_layer {
            assert_eq!(
                l.ffn_assignments + l.zc_assignments + l.dropped,
                40 * cfg.top_k
            );
        }
        assert!(stats.expert_forward_s > 0.0);
        assert!(stats.expert_throughput() > 0.0);
    }
}

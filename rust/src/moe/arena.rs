//! [`ExecArena`] — the reusable buffer pool behind the expert-forward hot
//! path (DESIGN.md §11).
//!
//! The serving loop used to allocate per layer and per micro-batch: a
//! fresh `y`, fresh routing scores/probs/top-k, a gather tensor and FFN
//! scratch per micro-batch, and a fresh dense output block per parallel
//! worker. The arena owns all of those buffers instead; they grow
//! monotonically to the largest shape seen and are reused across layers,
//! batches and requests, so steady-state serving performs **zero heap
//! allocations** for the listed buffers (dispatch-plan assembly and the
//! returned `ForwardStats` still allocate — they are per-batch *outputs*,
//! not compute scratch).
//!
//! Ownership/lifetime contract:
//!
//! * one arena per forward driver — `MoeEngine` and `ClusterSim` each own
//!   one, which also makes it one-per-scheduler under `MoeService` (the
//!   backend moves onto the scheduler thread);
//! * [`crate::moe::exec::forward_stack`] borrows the arena for the whole
//!   stack forward; backends receive only the [`FfnArena`] sub-pool via
//!   `ExpertBackend::execute_ffn` and must get their gather/scratch/shard
//!   buffers from it rather than allocating;
//! * buffers never shrink; [`ExecArena::growths`] counts every backing
//!   allocation that had to expand, which is what the steady-state
//!   regression test pins to zero after the first batch.

use crate::moe::experts::{FfnScratch, QuantScratch, FFN_TOKEN_BLOCK};
use crate::moe::router::{route_into, Routing, RouterWeights};
use crate::tensor::Tensor;

/// Assumed L1 data-cache budget the kernel tile hint targets (half of a
/// typical 32 KiB L1d; only locality, never results, depends on it).
const DEFAULT_L1_BUDGET_BYTES: usize = 16 * 1024;

/// Up-projection column tile for `d_ff = f` under `l1_budget` bytes: the
/// resident set per column is `FFN_TOKEN_BLOCK` hg + hl lanes plus the
/// two streamed weight rows, 4 bytes each.
pub fn pick_f_tile(f: usize, l1_budget: usize) -> usize {
    let per_col = (2 * FFN_TOKEN_BLOCK + 2) * std::mem::size_of::<f32>();
    let tile = (l1_budget / per_col).max(64) & !15;
    tile.min(f).max(1)
}

/// The full execution arena threaded through `forward_stack`.
pub struct ExecArena {
    /// Routing buffers (scores / probs / top-k, plus the gating-residual
    /// carry).
    pub(crate) route: RouteArena,
    /// The per-layer expert-output buffer `y` (`h += y` afterwards).
    pub(crate) y: Tensor,
    /// FFN-stage buffers handed to the backend.
    pub(crate) ffn: FfnArena,
    /// Obs scratch: per-token FFN-assignment counts for the current
    /// layer (the tokens-per-expert-count distribution, DESIGN.md §15).
    pub(crate) tok_k: Vec<u32>,
    y_growths: u64,
}

impl Default for ExecArena {
    fn default() -> Self {
        ExecArena::new()
    }
}

impl ExecArena {
    pub fn new() -> ExecArena {
        ExecArena {
            route: RouteArena::new(),
            y: Tensor::zeros(&[0, 0]),
            ffn: FfnArena::new(),
            tok_k: Vec::new(),
            y_growths: 0,
        }
    }

    /// Total backing-allocation growths since construction (routing + y +
    /// FFN pools + every shard buffer). Constant across batches once the
    /// arena has warmed up on the workload's largest shapes.
    pub fn growths(&self) -> u64 {
        self.y_growths + self.route.growths + self.ffn.growths()
    }

    // lint: no-alloc — steady-state reuse: reshape-in-place only.
    /// Shape `y` to `[t, d]` and zero it for the next layer.
    pub(crate) fn prepare_y(&mut self, t: usize, d: usize) {
        if self.y.reshape_in_place(&[t, d]) {
            self.y_growths += 1;
        }
        self.y.data.fill(0.0);
    }

    /// Disjoint borrows for one layer execution: the routing decision
    /// (shared), the `y` output buffer and the FFN sub-pool (both
    /// exclusive).
    pub(crate) fn split(
        &mut self,
    ) -> (&Routing, &mut Tensor, &mut FfnArena) {
        (&self.route.routing, &mut self.y, &mut self.ffn)
    }

    /// Zeroed per-token obs scratch for `t` tokens (reused across
    /// layers/batches; growth counted like every other buffer).
    pub(crate) fn prepare_tok_k(&mut self, t: usize) -> &mut [u32] {
        if t > self.tok_k.capacity() {
            self.y_growths += 1;
        }
        if self.tok_k.len() < t {
            self.tok_k.resize(t, 0);
        }
        let s = &mut self.tok_k[..t];
        s.fill(0);
        s
    }
    // lint: end
}

// ------------------------------------------------------------- routing

/// Reused routing state: the layer's [`Routing`] plus the previous
/// layer's raw scores (the Eq. 6 gating-residual carry).
pub(crate) struct RouteArena {
    pub(crate) routing: Routing,
    prev_scores: Tensor,
    /// Parked per-token top-k vectors from batches larger than the
    /// current one — revived on the next large batch so oscillating
    /// batch sizes stay allocation-free.
    topk_spare: Vec<Vec<(usize, f32)>>,
    growths: u64,
}

impl RouteArena {
    fn new() -> RouteArena {
        RouteArena {
            routing: Routing::empty(),
            prev_scores: Tensor::zeros(&[0, 0]),
            topk_spare: Vec::new(),
            growths: 0,
        }
    }

    // lint: no-alloc — per-layer routing reuses the arena's buffers.
    /// Route one layer into the reused buffers. `use_prev` must be false
    /// for the first layer of a stack — the carry holds the *previous
    /// batch's* last scores until then.
    pub(crate) fn route_layer(
        &mut self,
        x: &Tensor,
        weights: &RouterWeights,
        use_prev: bool,
        k: usize,
    ) {
        let prev = if use_prev { Some(&self.prev_scores) } else { None };
        route_into(
            x,
            weights,
            prev,
            k,
            &mut self.routing,
            &mut self.topk_spare,
            &mut self.growths,
        );
    }

    /// Retire the layer: its raw scores become the next layer's residual
    /// input (buffer swap, no copy).
    pub(crate) fn end_layer(&mut self) {
        std::mem::swap(&mut self.prev_scores, &mut self.routing.scores);
    }
    // lint: end
}

// ----------------------------------------------------------- FFN stage

/// What a backend may allocate from: serial gather + scratch, the
/// per-shard buffers of the token-parallel path, and the wire pool of
/// the cluster path.
pub struct FfnArena {
    /// Serial-path micro-batch gather buffer.
    pub(crate) gather: Tensor,
    /// Serial-path (and oracle) FFN scratch.
    pub(crate) scratch: FfnScratch,
    /// Serial-path int8 kernel scratch — sized alongside `scratch` so a
    /// mixed-precision layer runs both kernels allocation-free.
    pub(crate) qscratch: QuantScratch,
    /// Shard descriptors of the current layer (rebuilt per layer, storage
    /// reused).
    pub(crate) shards: Vec<ShardSpec>,
    /// How many of `shards`/`shard_bufs` the *most recent* `execute_ffn`
    /// actually ran in parallel (0 on the serial path), so the driver
    /// never stamps stale shard timings from an earlier layer.
    pub(crate) last_shards: usize,
    /// One buffer set per in-flight shard; workers write disjoint entries.
    pub(crate) shard_bufs: Vec<ShardBuf>,
    /// Pool for tensors that must *leave* the arena — the cluster path's
    /// `WorkUnit` gather/output tensors cross a channel to a device
    /// worker and come back with its `WorkResult`.
    pub(crate) wire: TensorPool,
    pub(crate) l1_budget_bytes: usize,
    pub(crate) growths: u64,
}

impl Default for FfnArena {
    fn default() -> Self {
        FfnArena::new()
    }
}

impl FfnArena {
    pub fn new() -> FfnArena {
        FfnArena {
            gather: Tensor::zeros(&[0, 0]),
            scratch: FfnScratch::new(0),
            qscratch: QuantScratch::new(),
            shards: Vec::new(),
            last_shards: 0,
            shard_bufs: Vec::new(),
            wire: TensorPool::new(),
            l1_budget_bytes: DEFAULT_L1_BUDGET_BYTES,
            growths: 0,
        }
    }

    fn growths(&self) -> u64 {
        self.growths
            + self.wire.growths
            + self.shard_bufs.iter().map(|b| b.growths).sum::<u64>()
    }

    /// Cache hint: the up-projection column tile for `d_ff = f`.
    pub fn f_tile(&self, f: usize) -> usize {
        pick_f_tile(f, self.l1_budget_bytes)
    }

    /// Size the serial-path scratch for experts of width `f` over hidden
    /// size `d`, installing the tile hint.
    pub(crate) fn prepare_serial(&mut self, f: usize, d: usize) {
        if self.scratch.ensure(f.max(d)) {
            self.growths += 1;
        }
        self.scratch.f_tile = self.f_tile(f);
    }

    // lint: no-alloc — steady-state mixed-precision sizing: grows only
    // until both kernels' scratch reach the workload's largest shapes.
    /// Like [`FfnArena::prepare_serial`] but also sizes the int8 scratch
    /// — the `NativeQuant` serial path may meet both precisions in one
    /// layer.
    pub(crate) fn prepare_serial_mixed(&mut self, f: usize, d: usize) {
        self.prepare_serial(f, d);
        if self.qscratch.ensure(d, f) {
            self.growths += 1;
        }
    }
    // lint: end

    /// Grow the shard-buffer pool to at least `n` entries.
    pub(crate) fn ensure_shard_bufs(&mut self, n: usize) {
        if n > self.shard_bufs.capacity() {
            self.growths += 1;
        }
        while self.shard_bufs.len() < n {
            self.shard_bufs.push(ShardBuf::new());
        }
    }
}

/// A free-list of reusable tensors for buffers that must cross a thread
/// boundary by value. The cluster backend `take`s a WorkUnit's gather
/// and output tensors here, sends them to a device worker, and `put`s
/// them back when the WorkResult echoes them — so once every free-list
/// slot has grown to the workload's largest shape, steady-state cluster
/// forwards perform zero wire-buffer allocations.
pub(crate) struct TensorPool {
    free: Vec<Tensor>,
    pub(crate) growths: u64,
}

impl TensorPool {
    fn new() -> TensorPool {
        TensorPool { free: Vec::new(), growths: 0 }
    }

    // lint: no-alloc — take/put recycle wire buffers; growth is counted
    // by `reshape_in_place` and pinned to zero at steady state.
    /// Pop a pooled tensor (or start an empty one) and shape it to
    /// `[rows, cols]`. Contents are unspecified — callers that hand the
    /// buffer to an accumulating kernel must zero it first.
    pub(crate) fn take(&mut self, rows: usize, cols: usize) -> Tensor {
        let mut t =
            self.free.pop().unwrap_or_else(|| Tensor::zeros(&[0, 0]));
        if t.reshape_in_place(&[rows, cols]) {
            self.growths += 1;
        }
        t
    }

    /// Return a tensor to the free list for reuse.
    pub(crate) fn put(&mut self, t: Tensor) {
        self.free.push(t);
    }
}

/// Gather `tokens`' rows of `h` into the reused `gather` tensor.
pub(crate) fn gather_rows(
    gather: &mut Tensor,
    h: &Tensor,
    tokens: &[usize],
    d: usize,
    growths: &mut u64,
) {
    if gather.reshape_in_place(&[tokens.len(), d]) {
        *growths += 1;
    }
    for (i, &tok) in tokens.iter().enumerate() {
        gather.data[i * d..(i + 1) * d].copy_from_slice(h.row(tok));
    }
}
// lint: end

/// One (expert micro-batch, row range) unit of FFN work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Index into `plan.ffn_batches`.
    pub batch: usize,
    /// First row of the batch this shard covers.
    pub start: usize,
    /// Rows covered.
    pub len: usize,
}

/// Private working set of one shard: gather input, dense output block and
/// kernel scratch. Owned by the arena so parallel workers reuse them
/// across layers and batches without allocating.
pub struct ShardBuf {
    pub(crate) gather: Tensor,
    pub(crate) out: Vec<f32>,
    pub(crate) scratch: FfnScratch,
    /// Int8 kernel scratch of this shard (mixed-precision layers).
    pub(crate) qscratch: QuantScratch,
    /// Wall nanoseconds of this shard's last kernel run, written by the
    /// worker that owns the buffer (exclusive `&mut` via
    /// `for_each_mut`), read by the driver when stamping obs — no
    /// locks, no extra channel.
    pub(crate) ns: u64,
    growths: u64,
}

impl ShardBuf {
    fn new() -> ShardBuf {
        ShardBuf {
            gather: Tensor::zeros(&[0, 0]),
            out: Vec::new(),
            scratch: FfnScratch::new(0),
            qscratch: QuantScratch::new(),
            ns: 0,
            growths: 0,
        }
    }

    // lint: no-alloc — per-shard reuse: reshape/resize against warmed
    // capacity only, every growth counted.
    /// Disjoint borrows for the kernel call: gather input (shared),
    /// output block and scratch (exclusive).
    pub(crate) fn parts(
        &mut self,
    ) -> (&Tensor, &mut Vec<f32>, &mut FfnScratch) {
        (&self.gather, &mut self.out, &mut self.scratch)
    }

    /// Disjoint borrows for a mixed-precision kernel call: gather input
    /// (shared), output block plus both precisions' scratch (exclusive).
    pub(crate) fn parts_mixed(
        &mut self,
    ) -> (&Tensor, &mut Vec<f32>, &mut FfnScratch, &mut QuantScratch)
    {
        (
            &self.gather,
            &mut self.out,
            &mut self.scratch,
            &mut self.qscratch,
        )
    }

    /// Additionally size the int8 scratch (call after `prepare` on the
    /// `NativeQuant` parallel path; growth counted like every buffer).
    pub(crate) fn prepare_quant(&mut self, d: usize, f: usize) {
        if self.qscratch.ensure(d, f) {
            self.growths += 1;
        }
    }

    /// Shape for `rows` tokens of width `d`, scratch width `n` and the
    /// given tile hint; zeroes the output block (the kernel accumulates
    /// into it).
    pub(crate) fn prepare(
        &mut self,
        rows: usize,
        d: usize,
        n: usize,
        f_tile: usize,
    ) {
        if self.gather.reshape_in_place(&[rows, d]) {
            self.growths += 1;
        }
        let need = rows * d;
        if need > self.out.capacity() {
            self.growths += 1;
        }
        if self.out.len() < need {
            self.out.resize(need, 0.0);
        }
        self.out[..need].fill(0.0);
        if self.scratch.ensure(n) {
            self.growths += 1;
        }
        self.scratch.f_tile = f_tile;
    }
    // lint: end
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f_tile_hint_respects_budget_and_bounds() {
        // Small widths are untiled (tile == f), large widths clamp to the
        // L1-derived tile, and degenerate budgets stay usable.
        let a = FfnArena::new();
        assert_eq!(a.f_tile(64), 64);
        assert_eq!(a.f_tile(128), 128);
        let big = a.f_tile(4096);
        assert!(big < 4096 && big >= 64, "{big}");
        assert_eq!(big % 16, 0);
        assert_eq!(pick_f_tile(8, 1), 8); // tiny f: tile = f
        assert_eq!(pick_f_tile(0, 1024), 1); // never zero
    }

    #[test]
    fn arena_growth_counter_settles_after_warmup() {
        let mut a = ExecArena::new();
        for _ in 0..3 {
            a.prepare_y(16, 8);
        }
        let warm = a.growths();
        assert!(warm >= 1);
        a.prepare_y(16, 8);
        a.prepare_y(4, 8); // smaller shapes never grow
        assert_eq!(a.growths(), warm);
        a.prepare_y(64, 8); // larger does
        assert!(a.growths() > warm);
    }

    #[test]
    fn tensor_pool_reuses_buffers_without_regrowing() {
        let mut p = TensorPool::new();
        // Warm-up: two concurrent buffers of the batch's largest shapes.
        let a = p.take(8, 4);
        let b = p.take(3, 4);
        assert_eq!(a.dims2(), (8, 4));
        assert_eq!(b.dims2(), (3, 4));
        let warm = p.growths;
        assert!(warm >= 2);
        p.put(a);
        p.put(b);
        // Steady state: the same take/put sequence grows nothing. The
        // free list is LIFO, so the second round pops (3,4) for the
        // (8,4) request — one more growth — after which every slot holds
        // the max shape and the counter is flat.
        for round in 0..3 {
            let a = p.take(8, 4);
            let b = p.take(3, 4);
            if round > 0 {
                assert_eq!(p.growths, warm + 1, "round {round}");
            }
            p.put(a);
            p.put(b);
        }
    }

    #[test]
    fn shard_buf_prepare_zeroes_only_the_active_rows() {
        let mut b = ShardBuf::new();
        b.prepare(3, 4, 8, 0);
        b.out[..12].fill(7.0);
        b.prepare(2, 4, 8, 0);
        assert!(b.out[..8].iter().all(|&v| v == 0.0));
        assert_eq!(b.gather.dims2(), (2, 4));
        // Second same-shape prepare grows nothing.
        let g = b.growths;
        b.prepare(3, 4, 8, 0);
        assert_eq!(b.growths, g);
    }

    #[test]
    fn quant_scratch_growth_is_counted_then_flat() {
        let mut b = ShardBuf::new();
        b.prepare(3, 4, 8, 0);
        let g0 = b.growths;
        b.prepare_quant(4, 8);
        assert!(b.growths > g0, "first quant sizing must count a growth");
        let g1 = b.growths;
        b.prepare_quant(4, 8);
        b.prepare_quant(2, 8); // smaller never grows
        assert_eq!(b.growths, g1);

        let mut a = FfnArena::new();
        a.prepare_serial_mixed(8, 4);
        let warm = a.growths;
        a.prepare_serial_mixed(8, 4);
        assert_eq!(a.growths, warm);
    }
}

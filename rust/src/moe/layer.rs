//! Native MoE++ layer forward: the direct (per-token) reference
//! implementation of the dispatch semantics shared with L2 (DESIGN.md §6).
//!
//! The serving engine in `coordinator/` implements the same semantics with
//! batching and queueing; this module is the oracle it is property-tested
//! against, and the compute model the cluster simulator runs. Expert
//! execution itself is delegated to the shared executor in [`moe::exec`]
//! (DESIGN.md §7) with the [`NativeSingle`] oracle backend, so the
//! route→dispatch→execute→combine semantics exists exactly once.
//!
//! [`moe::exec`]: crate::moe::exec
//! [`NativeSingle`]: crate::moe::exec::NativeSingle

use crate::config::MoeConfig;
use crate::coordinator::dispatch::DispatchPlan;
use crate::moe::arena::FfnArena;
use crate::moe::exec::{self, NativeSingle};
use crate::moe::router::{route, Routing};
use crate::moe::weights::MoeLayerWeights;
use crate::tensor::Tensor;
use crate::util::pool::Executor;

/// One surviving (token, expert) assignment after capacity filtering.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Assignment {
    pub token: usize,
    pub expert: usize,
    pub gate: f32,
    pub slot: usize, // which top-k slot produced it (0 = top-1)
}

/// Result of capacity-aware dispatch (before any expert compute).
#[derive(Clone, Debug)]
pub struct Dispatch {
    pub kept: Vec<Assignment>,
    pub dropped: Vec<Assignment>,
    /// Final per-expert load (kept assignments).
    pub load: Vec<usize>,
}

/// Apply heterogeneous capacity (Eq. 8) to a routing decision.
///
/// Priority is slot-major then token order: all top-1 assignments claim
/// capacity before any top-2 assignment — matching the L2 (GShard-style)
/// `_positions_in_expert` exactly.
pub fn dispatch(routing: &Routing, cfg: &MoeConfig, n_tokens: usize)
    -> Dispatch {
    let caps = cfg.capacity_vec(n_tokens);
    let n = cfg.n_experts();
    let mut load = vec![0usize; n];
    let mut kept = Vec::new();
    let mut dropped = Vec::new();
    for slot in 0..cfg.top_k {
        for (tok, tk) in routing.topk.iter().enumerate() {
            if let Some(&(e, g)) = tk.get(slot) {
                let a = Assignment { token: tok, expert: e, gate: g, slot };
                if load[e] < caps[e] {
                    load[e] += 1;
                    kept.push(a);
                } else {
                    dropped.push(a);
                }
            }
        }
    }
    Dispatch { kept, dropped, load }
}

/// Statistics of one layer forward (mirrors L2's MoELayerAux).
#[derive(Clone, Debug, Default)]
pub struct LayerStats {
    pub expert_counts: Vec<usize>, // pre-capacity
    pub dropped: usize,
    pub ffn_assignments: usize,
    pub zc_assignments: usize,
    pub ffn_per_token: f64,
    pub balance_loss: f64,
}

/// Full native layer forward: route -> dispatch -> expert compute -> combine.
///
/// Returns (y [T, D], routing, stats). `prev_scores` is the gating residual
/// input (None for layer 0).
pub fn layer_forward(
    weights: &MoeLayerWeights,
    x: &Tensor,
    prev_scores: Option<&Tensor>,
    cfg: &MoeConfig,
) -> (Tensor, Routing, LayerStats) {
    let (t, d) = x.dims2();
    let prev = if cfg.gating_residual { prev_scores } else { None };
    let routing = route(x, &weights.router, prev, cfg.top_k);
    let plan = DispatchPlan::build(&routing, cfg, t);
    let mut y = Tensor::zeros(&[t, d]);
    let mut backend =
        NativeSingle { layers: std::slice::from_ref(weights) };
    // The oracle is a per-call reference path, not a serving loop — a
    // throwaway arena keeps the shared executor signature without
    // threading reuse through every test call site.
    let mut arena = FfnArena::new();
    let ex = exec::execute_layer(
        &mut backend, 0, &plan, &routing, cfg, &weights.consts, x,
        &mut y, &mut arena, &Executor::serial(), None, 0,
    )
    .expect("native single-layer execution is infallible");
    (y, routing, ex.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExpertKind;
    use crate::util::proptest::{gen, Prop};
    use crate::util::rng::Rng;

    fn setup(seed: u64, t: usize, name: &str)
        -> (MoeConfig, MoeLayerWeights, Tensor) {
        let cfg = MoeConfig::preset(name);
        let mut rng = Rng::new(seed);
        let w = MoeLayerWeights::init(&mut rng, &cfg);
        let x = Tensor::randn(&mut rng, &[t, cfg.d_model], 1.0);
        (cfg, w, x)
    }

    #[test]
    fn dispatch_respects_capacity() {
        let (cfg, w, x) = setup(0, 64, "test");
        let routing = route(&x, &w.router, None, cfg.top_k);
        let d = dispatch(&routing, &cfg, 64);
        let caps = cfg.capacity_vec(64);
        for (e, &l) in d.load.iter().enumerate() {
            assert!(l <= caps[e], "expert {e}: load {l} > cap {}", caps[e]);
        }
        assert_eq!(d.kept.len() + d.dropped.len(), 64 * cfg.top_k);
    }

    #[test]
    fn top1_has_priority_over_top2() {
        // Build a routing where everyone's top-1 is expert 0 and token 63's
        // top-2 is also expert 0: all top-1s must be kept/dropped before
        // any top-2 assignment is considered.
        let cfg = MoeConfig::preset("test");
        let n = cfg.n_experts();
        let t = 40;
        let mut probs = Tensor::zeros(&[t, n]);
        let mut topk = Vec::new();
        for i in 0..t {
            probs.row_mut(i)[0] = 0.6;
            probs.row_mut(i)[1] = 0.3;
            topk.push(vec![(0usize, 0.6f32), (1usize, 0.3f32)]);
        }
        let routing = Routing {
            scores: Tensor::zeros(&[t, n]),
            probs,
            topk,
        };
        let d = dispatch(&routing, &cfg, t);
        let cap0 = cfg.capacity_vec(t)[0];
        // Kept expert-0 assignments are exactly the first cap0 tokens'
        // slot-0 entries.
        let kept0: Vec<_> =
            d.kept.iter().filter(|a| a.expert == 0).collect();
        assert_eq!(kept0.len(), cap0.min(t));
        assert!(kept0.iter().all(|a| a.slot == 0));
        assert!(kept0.windows(2).all(|w| w[0].token < w[1].token));
    }

    #[test]
    fn forward_matches_manual_combine() {
        let (cfg, w, x) = setup(1, 16, "test");
        let (y, routing, _) = layer_forward(&w, &x, None, &cfg);
        // Manual recomputation.
        let disp = dispatch(&routing, &cfg, 16);
        let d = cfg.d_model;
        let mut want = Tensor::zeros(&[16, d]);
        for a in &disp.kept {
            let xrow = x.row(a.token);
            let orow = &mut want.data[a.token * d..(a.token + 1) * d];
            match cfg.kind(a.expert) {
                ExpertKind::Ffn => w.ffn[a.expert]
                    .forward_token_into(xrow, a.gate, orow),
                ExpertKind::Zero => {}
                ExpertKind::Copy => {
                    crate::moe::experts::copy_expert_into(xrow, a.gate, orow)
                }
                ExpertKind::Constant => w.consts[cfg.const_index(a.expert)]
                    .forward_token_into(xrow, a.gate, orow),
            }
        }
        assert!(y.approx_eq(&want, 1e-5, 1e-5));
    }

    #[test]
    fn gating_residual_threads() {
        let (cfg, mut w, x) = setup(2, 16, "test");
        // identity-ish Wg so residual visibly shifts scores
        let n = cfg.n_experts();
        for i in 0..n {
            w.router.wg.data[i * n + i] = 1.0;
        }
        let (_, r0, _) = layer_forward(&w, &x, None, &cfg);
        let (_, r1, _) = layer_forward(&w, &x, Some(&r0.scores), &cfg);
        assert!(!r1.scores.approx_eq(&r0.scores, 1e-6, 0.0));
        // gating_residual=false ignores prev
        let mut cfg_off = cfg.clone();
        cfg_off.gating_residual = false;
        let (_, r2, _) = layer_forward(&w, &x, Some(&r0.scores), &cfg_off);
        assert!(r2.scores.approx_eq(&r0.scores, 1e-6, 0.0));
    }

    #[test]
    fn vanilla_layer_has_no_zc_assignments() {
        let (cfg, w, x) = setup(3, 32, "test:vanilla");
        let (_, _, stats) = layer_forward(&w, &x, None, &cfg);
        assert_eq!(stats.zc_assignments, 0);
        assert!(stats.ffn_per_token <= cfg.top_k as f64);
    }

    #[test]
    fn moepp_saves_ffn_work_vs_vanilla() {
        // The paper's central claim at the layer level: fewer FFN
        // assignments per token than vanilla top-2.
        let (cfg, w, x) = setup(4, 256, "test");
        let (_, _, s) = layer_forward(&w, &x, None, &cfg);
        let (vcfg, vw, _) = setup(4, 256, "test:vanilla");
        let (_, _, vs) = layer_forward(&vw, &x, None, &vcfg);
        assert!(s.ffn_per_token < vs.ffn_per_token,
                "{} vs {}", s.ffn_per_token, vs.ffn_per_token);
    }

    // ---------------------------------------------------------- properties

    #[test]
    fn prop_dispatch_conservation() {
        Prop::new("dispatch-conservation").cases(40).run(
            |rng| {
                let t = gen::usize_in(rng, 1, 96);
                let seed = rng.next_u64();
                (t, seed)
            },
            |&(t, seed)| {
                let (cfg, w, x) = setup(seed, t, "test");
                let routing = route(&x, &w.router, None, cfg.top_k);
                let d = dispatch(&routing, &cfg, t);
                // 1. every assignment is kept xor dropped
                if d.kept.len() + d.dropped.len() != t * cfg.top_k {
                    return Err("assignment count mismatch".into());
                }
                // 2. capacity never exceeded
                let caps = cfg.capacity_vec(t);
                for (e, &l) in d.load.iter().enumerate() {
                    if l > caps[e] {
                        return Err(format!("expert {e} over capacity"));
                    }
                }
                // 3. a token appears at most top_k times in kept
                let mut per_tok = vec![0usize; t];
                for a in &d.kept {
                    per_tok[a.token] += 1;
                }
                if per_tok.iter().any(|&c| c > cfg.top_k) {
                    return Err("token kept more than K times".into());
                }
                // 4. gates are the softmax probs (Eq. 1, no renorm)
                for a in &d.kept {
                    let p = routing.probs.row(a.token)[a.expert];
                    if (a.gate - p).abs() > 1e-6 {
                        return Err("gate != softmax prob".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_forward_gate_bound() {
        // Output row norm is bounded by sum of gate * per-expert output
        // norms — no expert contribution is double-counted.
        Prop::new("forward-bound").cases(15).run(
            |rng| rng.next_u64(),
            |&seed| {
                let (cfg, w, x) = setup(seed, 24, "test");
                let (y, routing, _) = layer_forward(&w, &x, None, &cfg);
                let disp = dispatch(&routing, &cfg, 24);
                for tok in 0..24 {
                    let yn = y.row(tok).iter().map(|v| v * v).sum::<f32>()
                        .sqrt();
                    let mut bound = 0.0f32;
                    for a in disp.kept.iter().filter(|a| a.token == tok) {
                        let xrow = x.row(a.token);
                        let mut tmp = vec![0.0; cfg.d_model];
                        match cfg.kind(a.expert) {
                            ExpertKind::Ffn => w.ffn[a.expert]
                                .forward_token_into(xrow, a.gate, &mut tmp),
                            ExpertKind::Zero => {}
                            ExpertKind::Copy =>
                                crate::moe::experts::copy_expert_into(
                                    xrow, a.gate, &mut tmp),
                            ExpertKind::Constant => {
                                w.consts[cfg.const_index(a.expert)]
                                    .forward_token_into(
                                        xrow, a.gate, &mut tmp)
                            }
                        }
                        bound += tmp.iter().map(|v| v * v).sum::<f32>()
                            .sqrt();
                    }
                    if yn > bound + 1e-4 {
                        return Err(format!(
                            "token {tok}: |y|={yn} > bound {bound}"));
                    }
                }
                Ok(())
            },
        );
    }
}

//! Pathway-aware router (paper Eq. 6): per-layer score matmul plus the
//! gating residual of the previous layer's raw scores.
//!
//! ```text
//! G(x^j) = W^j x^j                      (j = 1)
//! G(x^j) = W^j x^j + Wg^j G(x^{j-1})    (j > 1)
//! ```
//!
//! The router runs natively in Rust on the serving path — it is an [N, D]
//! matvec per token, negligible next to the FFN experts, and keeping it on
//! the coordinator lets routing decisions drive dispatch *before* any
//! tensor traffic happens.

use crate::tensor::ops::{
    matmul_bt_acc, matmul_bt_into, softmax_rows, topk_into,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RouterWeights {
    pub w: Tensor,  // [N, D]
    pub wg: Tensor, // [N, N]
}

impl RouterWeights {
    pub fn init(rng: &mut Rng, n: usize, d: usize) -> RouterWeights {
        RouterWeights {
            w: Tensor::randn(rng, &[n, d], (d as f32).powf(-0.5)),
            // Zero init: Eq. 6 reduces to W x at the start of training.
            wg: Tensor::zeros(&[n, n]),
        }
    }
}

/// Routing decision for a batch of tokens.
#[derive(Clone, Debug)]
pub struct Routing {
    /// Raw scores [T, N] — threaded to the next layer as the residual.
    pub scores: Tensor,
    /// Softmax probabilities [T, N].
    pub probs: Tensor,
    /// Per-token top-k (expert, gate) pairs, descending by gate.
    pub topk: Vec<Vec<(usize, f32)>>,
}

impl Routing {
    /// An empty routing shell for arena reuse — [`route_into`] shapes the
    /// buffers on every call, so the same `Routing` serves every layer
    /// and batch without reallocating (DESIGN.md §11).
    pub fn empty() -> Routing {
        Routing {
            scores: Tensor::zeros(&[0, 0]),
            probs: Tensor::zeros(&[0, 0]),
            topk: Vec::new(),
        }
    }
}

/// Compute Eq. 6 scores + softmax + top-k for a token batch.
///
/// `prev_scores` is the previous layer's raw scores (None for layer 0 or
/// when gating residuals are disabled).
pub fn route(
    x: &Tensor,
    weights: &RouterWeights,
    prev_scores: Option<&Tensor>,
    k: usize,
) -> Routing {
    let mut out = Routing::empty();
    let mut spare = Vec::new();
    let mut growths = 0u64;
    route_into(x, weights, prev_scores, k, &mut out, &mut spare,
               &mut growths);
    out
}

/// [`route`] into a reused [`Routing`]: scores/probs tensors are reshaped
/// in place and the per-token top-k vectors are reused, so steady-state
/// routing performs no heap allocation. When the batch shrinks, the
/// surplus per-token vectors are parked in `spare` (not dropped) and
/// revived when a larger batch returns — `Routing.topk.len()` must equal
/// the token count (consumers iterate it), so the pool is what keeps
/// oscillating batch sizes allocation-free. `growths` is incremented
/// whenever a buffer had to grow (arena accounting, DESIGN.md §11).
/// Numerically identical to [`route`] — same matmuls, same softmax, and
/// `topk_into` preserves the exact `lax.top_k` order.
// lint: no-alloc — steady-state routing: reshape-in-place and the parked
// top-k pool only; every growth is counted.
pub fn route_into(
    x: &Tensor,
    weights: &RouterWeights,
    prev_scores: Option<&Tensor>,
    k: usize,
    out: &mut Routing,
    spare: &mut Vec<Vec<(usize, f32)>>,
    growths: &mut u64,
) {
    let (t, _) = x.dims2();
    let n = weights.w.shape[0];
    if out.scores.reshape_in_place(&[t, n]) {
        *growths += 1;
    }
    matmul_bt_into(x, &weights.w, &mut out.scores); // [T, N]
    if let Some(prev) = prev_scores {
        matmul_bt_acc(prev, &weights.wg, &mut out.scores); // + prev @ Wg^T
    }
    if out.probs.reshape_in_place(&[t, n]) {
        *growths += 1;
    }
    out.probs.data.copy_from_slice(&out.scores.data);
    softmax_rows(&mut out.probs);
    if t > out.topk.capacity() {
        *growths += 1;
    }
    while out.topk.len() > t {
        spare.push(out.topk.pop().expect("len > t >= 0"));
    }
    while out.topk.len() < t {
        out.topk.push(spare.pop().unwrap_or_else(|| {
            *growths += 1; // a token count beyond any seen before
            Vec::with_capacity(k)
        }));
    }
    let Routing { probs, topk, .. } = out;
    for (i, tk) in topk.iter_mut().enumerate() {
        topk_into(probs.row(i), k, tk);
    }
}
// lint: end

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gates_are_softmax_values_without_renormalisation() {
        let mut rng = Rng::new(0);
        let w = RouterWeights::init(&mut rng, 6, 8);
        let x = Tensor::randn(&mut rng, &[4, 8], 1.0);
        let r = route(&x, &w, None, 2);
        for (i, tk) in r.topk.iter().enumerate() {
            assert_eq!(tk.len(), 2);
            // Gate values are the raw softmax entries (Eq. 1).
            for &(e, g) in tk {
                assert!((g - r.probs.row(i)[e]).abs() < 1e-6);
            }
            assert!(tk[0].1 >= tk[1].1);
            // Top-2 gates sum to < 1 (full-softmax, no renorm).
            assert!(tk[0].1 + tk[1].1 < 1.0 + 1e-6);
        }
    }

    #[test]
    fn route_into_reuse_is_bitwise_identical_and_stops_growing() {
        let mut rng = Rng::new(9);
        let mut w = RouterWeights::init(&mut rng, 7, 12);
        for i in 0..7 {
            w.wg.data[i * 7 + i] = 0.3; // make the residual term visible
        }
        let prev = Tensor::randn(&mut rng, &[6, 7], 1.0);
        let mut reused = Routing::empty();
        let mut spare = Vec::new();
        let mut growths = 0u64;
        for round in 0..3 {
            let x = Tensor::randn(&mut rng, &[6, 12], 1.0);
            let fresh = route(&x, &w, Some(&prev), 2);
            route_into(&x, &w, Some(&prev), 2, &mut reused, &mut spare,
                       &mut growths);
            assert_eq!(reused.scores.data, fresh.scores.data, "r{round}");
            assert_eq!(reused.probs.data, fresh.probs.data, "r{round}");
            assert_eq!(reused.topk, fresh.topk, "r{round}");
        }
        // All growth happened on the first same-shape call.
        let after_warm = growths;
        let x = Tensor::randn(&mut rng, &[6, 12], 1.0);
        route_into(&x, &w, None, 2, &mut reused, &mut spare, &mut growths);
        assert_eq!(growths, after_warm, "steady-state routing regrew");
        // A smaller batch must shrink the visible rows (no stale top-k
        // entries) while parking — not dropping — the surplus vectors.
        let small = Tensor::randn(&mut rng, &[2, 12], 1.0);
        route_into(&small, &w, None, 2, &mut reused, &mut spare,
                   &mut growths);
        assert_eq!(reused.topk.len(), 2);
        assert_eq!(reused.scores.dims2(), (2, 7));
        assert_eq!(spare.len(), 4, "surplus vectors must be pooled");
        // Oscillating back up revives the pooled vectors: zero growth.
        route_into(&x, &w, None, 2, &mut reused, &mut spare, &mut growths);
        assert_eq!(reused.topk.len(), 6);
        assert!(spare.is_empty());
        assert_eq!(growths, after_warm, "batch-size oscillation regrew");
    }

    #[test]
    fn zero_wg_means_residual_is_noop() {
        let mut rng = Rng::new(1);
        let w = RouterWeights::init(&mut rng, 5, 8); // wg starts at zero
        let x = Tensor::randn(&mut rng, &[3, 8], 1.0);
        let prev = Tensor::randn(&mut rng, &[3, 5], 10.0);
        let a = route(&x, &w, Some(&prev), 2);
        let b = route(&x, &w, None, 2);
        assert!(a.scores.approx_eq(&b.scores, 1e-6, 0.0));
    }

    #[test]
    fn identity_wg_adds_prev_scores() {
        let mut rng = Rng::new(2);
        let mut w = RouterWeights::init(&mut rng, 4, 8);
        // wg = I
        for i in 0..4 {
            w.wg.data[i * 4 + i] = 1.0;
        }
        let x = Tensor::randn(&mut rng, &[2, 8], 1.0);
        let prev = Tensor::randn(&mut rng, &[2, 4], 1.0);
        let with = route(&x, &w, Some(&prev), 1);
        let without = route(&x, &w, None, 1);
        for i in 0..with.scores.numel() {
            let want = without.scores.data[i] + prev.data[i];
            assert!((with.scores.data[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn residual_reduces_score_variance_when_wg_averages() {
        // Fig. 6's mechanism: a contractive Wg mixes pathway history into
        // scores, lowering per-layer variance vs. the no-residual router.
        let mut rng = Rng::new(3);
        let n = 8;
        let mut w = RouterWeights::init(&mut rng, n, 16);
        for i in 0..n {
            for j in 0..n {
                w.wg.data[i * n + j] = if i == j { 0.5 } else { 0.0 };
            }
        }
        let x = Tensor::randn(&mut rng, &[64, 16], 1.0);
        // Simulate 4 layers of threading.
        let mut prev: Option<Tensor> = None;
        let mut vars = Vec::new();
        for _ in 0..4 {
            let r = route(&x, &w, prev.as_ref(), 2);
            let mean: f32 =
                r.scores.data.iter().sum::<f32>() / r.scores.numel() as f32;
            let var: f32 = r.scores.data.iter()
                .map(|v| (v - mean) * (v - mean)).sum::<f32>()
                / r.scores.numel() as f32;
            vars.push(var);
            prev = Some(r.scores);
        }
        // Variance grows sub-linearly (contractive mixing), staying bounded.
        assert!(vars[3] < vars[0] * 4.0, "{vars:?}");
    }
}

//! Pathway-aware router (paper Eq. 6): per-layer score matmul plus the
//! gating residual of the previous layer's raw scores.
//!
//! ```text
//! G(x^j) = W^j x^j                      (j = 1)
//! G(x^j) = W^j x^j + Wg^j G(x^{j-1})    (j > 1)
//! ```
//!
//! The router runs natively in Rust on the serving path — it is an [N, D]
//! matvec per token, negligible next to the FFN experts, and keeping it on
//! the coordinator lets routing decisions drive dispatch *before* any
//! tensor traffic happens.

use crate::tensor::ops::{matmul_bt, softmax_rows, topk};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RouterWeights {
    pub w: Tensor,  // [N, D]
    pub wg: Tensor, // [N, N]
}

impl RouterWeights {
    pub fn init(rng: &mut Rng, n: usize, d: usize) -> RouterWeights {
        RouterWeights {
            w: Tensor::randn(rng, &[n, d], (d as f32).powf(-0.5)),
            // Zero init: Eq. 6 reduces to W x at the start of training.
            wg: Tensor::zeros(&[n, n]),
        }
    }
}

/// Routing decision for a batch of tokens.
#[derive(Clone, Debug)]
pub struct Routing {
    /// Raw scores [T, N] — threaded to the next layer as the residual.
    pub scores: Tensor,
    /// Softmax probabilities [T, N].
    pub probs: Tensor,
    /// Per-token top-k (expert, gate) pairs, descending by gate.
    pub topk: Vec<Vec<(usize, f32)>>,
}

/// Compute Eq. 6 scores + softmax + top-k for a token batch.
///
/// `prev_scores` is the previous layer's raw scores (None for layer 0 or
/// when gating residuals are disabled).
pub fn route(
    x: &Tensor,
    weights: &RouterWeights,
    prev_scores: Option<&Tensor>,
    k: usize,
) -> Routing {
    let mut scores = matmul_bt(x, &weights.w); // [T, N]
    if let Some(prev) = prev_scores {
        let res = matmul_bt(prev, &weights.wg); // prev @ Wg^T
        for (s, r) in scores.data.iter_mut().zip(&res.data) {
            *s += r;
        }
    }
    let mut probs = scores.clone();
    softmax_rows(&mut probs);
    let (t, _n) = probs.dims2();
    let topk_v = (0..t).map(|i| topk(probs.row(i), k)).collect();
    Routing { scores, probs, topk: topk_v }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gates_are_softmax_values_without_renormalisation() {
        let mut rng = Rng::new(0);
        let w = RouterWeights::init(&mut rng, 6, 8);
        let x = Tensor::randn(&mut rng, &[4, 8], 1.0);
        let r = route(&x, &w, None, 2);
        for (i, tk) in r.topk.iter().enumerate() {
            assert_eq!(tk.len(), 2);
            // Gate values are the raw softmax entries (Eq. 1).
            for &(e, g) in tk {
                assert!((g - r.probs.row(i)[e]).abs() < 1e-6);
            }
            assert!(tk[0].1 >= tk[1].1);
            // Top-2 gates sum to < 1 (full-softmax, no renorm).
            assert!(tk[0].1 + tk[1].1 < 1.0 + 1e-6);
        }
    }

    #[test]
    fn zero_wg_means_residual_is_noop() {
        let mut rng = Rng::new(1);
        let w = RouterWeights::init(&mut rng, 5, 8); // wg starts at zero
        let x = Tensor::randn(&mut rng, &[3, 8], 1.0);
        let prev = Tensor::randn(&mut rng, &[3, 5], 10.0);
        let a = route(&x, &w, Some(&prev), 2);
        let b = route(&x, &w, None, 2);
        assert!(a.scores.approx_eq(&b.scores, 1e-6, 0.0));
    }

    #[test]
    fn identity_wg_adds_prev_scores() {
        let mut rng = Rng::new(2);
        let mut w = RouterWeights::init(&mut rng, 4, 8);
        // wg = I
        for i in 0..4 {
            w.wg.data[i * 4 + i] = 1.0;
        }
        let x = Tensor::randn(&mut rng, &[2, 8], 1.0);
        let prev = Tensor::randn(&mut rng, &[2, 4], 1.0);
        let with = route(&x, &w, Some(&prev), 1);
        let without = route(&x, &w, None, 1);
        for i in 0..with.scores.numel() {
            let want = without.scores.data[i] + prev.data[i];
            assert!((with.scores.data[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn residual_reduces_score_variance_when_wg_averages() {
        // Fig. 6's mechanism: a contractive Wg mixes pathway history into
        // scores, lowering per-layer variance vs. the no-residual router.
        let mut rng = Rng::new(3);
        let n = 8;
        let mut w = RouterWeights::init(&mut rng, n, 16);
        for i in 0..n {
            for j in 0..n {
                w.wg.data[i * n + j] = if i == j { 0.5 } else { 0.0 };
            }
        }
        let x = Tensor::randn(&mut rng, &[64, 16], 1.0);
        // Simulate 4 layers of threading.
        let mut prev: Option<Tensor> = None;
        let mut vars = Vec::new();
        for _ in 0..4 {
            let r = route(&x, &w, prev.as_ref(), 2);
            let mean: f32 =
                r.scores.data.iter().sum::<f32>() / r.scores.numel() as f32;
            let var: f32 = r.scores.data.iter()
                .map(|v| (v - mean) * (v - mean)).sum::<f32>()
                / r.scores.numel() as f32;
            vars.push(var);
            prev = Some(r.scores);
        }
        // Variance grows sub-linearly (contractive mixing), staying bounded.
        assert!(vars[3] < vars[0] * 4.0, "{vars:?}");
    }
}

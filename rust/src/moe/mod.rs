//! Pure-Rust reference implementation of MoE++ and vanilla MoE: experts,
//! pathway-aware router, heterogeneous capacity/balance, and the Table 1
//! complexity model.
//!
//! This is (a) the native backend of the serving engine, (b) the oracle the
//! property tests check coordinator invariants against, and (c) the compute
//! model the cluster simulator runs on each simulated device.

pub mod arena;
pub mod balance;
pub mod complexity;
pub mod exec;
pub mod experts;
pub mod layer;
pub mod layerwise;
pub mod router;
pub mod weights;

//! The [`ServeBackend`] trait: what [`crate::serve::MoeService`] needs
//! from an execution substrate — one synchronous stack forward over a
//! concatenated token batch, with [`ForwardStats`] for accounting.
//!
//! This is deliberately a *batch*-level contract, one level above
//! [`crate::moe::exec::ExpertBackend`] (which plugs FFN strategies into a
//! single layer). Anything that can forward a [T, D] batch through the
//! MoE++ stack can front the service: the single-process engine (native
//! serial, native parallel-workers, PJRT buckets) and the expert-parallel
//! cluster simulator both implement it here, and future scaling backends
//! (multi-node dispatch, speculative ZC, quantized experts) plug in the
//! same way.

use std::sync::Arc;

use anyhow::Result;

use crate::cluster::sim::ClusterSim;
use crate::coordinator::engine::{Backend, MoeEngine};
use crate::fault::ClusterError;
use crate::moe::exec::ForwardStats;
use crate::obs::Obs;
use crate::tensor::Tensor;

/// A synchronous batch-forward substrate the serving scheduler can own.
///
/// Contract:
/// * `forward` runs the *whole* stack over `tokens` ([T, D]) and returns
///   outputs of the same shape plus the executor's [`ForwardStats`]
///   (whose `token_counts` rows must line up with the input rows — that
///   is what per-request stats slicing relies on);
/// * the backend is moved onto the scheduler thread, hence `Send`; it
///   owns its own execution resources — the `ExecArena` *and* the
///   persistent `ExecPool` (DESIGN.md §12) travel with it, so the
///   scheduler's steady-state loop allocates no buffers and spawns no
///   threads;
/// * determinism: for a fixed backend, equal input batches produce
///   bitwise-equal outputs (the serve equivalence test enforces this for
///   the native engine at any worker count and either executor).
pub trait ServeBackend: Send {
    /// Hidden dimension requests must match (admission-checked).
    fn d_model(&self) -> usize;

    /// Forward one concatenated batch through the stack.
    fn forward(&mut self, tokens: &Tensor) -> Result<(Tensor, ForwardStats)>;

    /// Human-readable backend label for reports.
    fn label(&self) -> String;

    /// Placement replans applied since last asked (the scheduler drains
    /// this after every batch into `ServingMetrics::replans`). Backends
    /// without online replanning report zero.
    fn take_replans(&mut self) -> u64 {
        0
    }

    /// Install an observability bundle (DESIGN.md §15): subsequent
    /// forwards stamp per-layer/per-shard records into it. Backends
    /// without instrumentation ignore it (default no-op).
    fn set_obs(&mut self, _obs: Arc<Obs>) {}

    /// The typed fault behind the most recent `forward` error, if any
    /// (DESIGN.md §16). The scheduler reads this after an `Err` to
    /// decide whether the batch is retryable (`WorkerLost`) or terminal.
    /// Taking clears it. Backends without fault tolerance report `None`.
    fn take_fault(&mut self) -> Option<ClusterError> {
        None
    }
}

impl ServeBackend for MoeEngine {
    fn d_model(&self) -> usize {
        self.cfg.d_model
    }

    fn forward(&mut self, tokens: &Tensor) -> Result<(Tensor, ForwardStats)> {
        MoeEngine::forward_stack(self, tokens)
    }

    fn label(&self) -> String {
        match &self.backend {
            Backend::Native { workers, partition } => format!(
                "engine:native(workers={workers},{})",
                partition.label()
            ),
            Backend::Pjrt { .. } => "engine:pjrt".to_string(),
        }
    }

    fn set_obs(&mut self, obs: Arc<Obs>) {
        MoeEngine::set_obs(self, obs);
    }
}

impl ServeBackend for ClusterSim {
    fn d_model(&self) -> usize {
        self.cfg.d_model
    }

    /// One served batch. Afterwards the batch's load histogram feeds the
    /// attached [`Replanner`] (if any), which may migrate FFN experts —
    /// so replanning happens strictly *between* batches, never while one
    /// is executing, and outputs stay bitwise placement-independent. The
    /// planner's local search itself runs on the sim's pool, not this
    /// scheduler thread: `note_batch` submits it when the observation
    /// window fills, then polls non-blockingly and applies it at the
    /// first boundary that finds it finished (DESIGN.md §12).
    ///
    /// [`Replanner`]: crate::placement::Replanner
    fn forward(&mut self, tokens: &Tensor) -> Result<(Tensor, ForwardStats)> {
        let (y, report) = ClusterSim::forward(self, tokens)?;
        self.note_batch(&report.stats);
        Ok((y, report.stats))
    }

    fn label(&self) -> String {
        format!("cluster(devices={})", self.topo.n_devices)
    }

    fn take_replans(&mut self) -> u64 {
        self.take_replan_count()
    }

    fn set_obs(&mut self, obs: Arc<Obs>) {
        ClusterSim::set_obs(self, obs);
    }

    fn take_fault(&mut self) -> Option<ClusterError> {
        ClusterSim::take_fault(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology::Topology;
    use crate::config::MoeConfig;
    use crate::util::rng::Rng;

    #[test]
    fn engine_and_cluster_both_serve() {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&mut rng, &[12, cfg.d_model], 1.0);
        let mut engine: Box<dyn ServeBackend> =
            Box::new(MoeEngine::native(cfg.clone(), 7));
        let mut sim: Box<dyn ServeBackend> = Box::new(ClusterSim::new(
            cfg.clone(),
            Topology::new(2),
            7,
        ));
        assert_eq!(engine.d_model(), cfg.d_model);
        assert_eq!(sim.d_model(), cfg.d_model);
        let (ye, se) = engine.forward(&x).unwrap();
        let (yc, sc) = sim.forward(&x).unwrap();
        // Same weights seed -> interchangeable outputs and accounting.
        assert!(yc.approx_eq(&ye, 1e-5, 1e-5));
        assert_eq!(se.total_counts(), sc.total_counts());
        assert!(engine.label().contains("native"));
        assert!(sim.label().contains("cluster"));
    }
}

//! [`MoeService`] — the continuous-batching serving front end.
//!
//! One background scheduler thread owns a [`Batcher`] and the
//! [`ServeBackend`]; callers on any thread `submit` requests and block (or
//! poll) on their [`ResponseHandle`]s. The scheduler loop is the
//! admission → batch → execute → scatter → complete lifecycle of
//! DESIGN.md §9:
//!
//! * **admission** — `submit` bounds the queue (token + request limits)
//!   and rejects with [`AdmissionError`] instead of buffering unboundedly
//!   (backpressure the caller can act on);
//! * **batch** — the scheduler refills the batcher one batch's worth at
//!   a time, priority-major ([`Priority::Interactive`] before `Standard`
//!   before `Bulk`, FIFO within a class) — backlog waits in the priority
//!   queues so late interactive arrivals leapfrog parked bulk work;
//!   cancellation and queue deadlines are honoured here;
//! * **execute** — batches flush on the batcher's size/deadline rules and
//!   run on the backend while new submissions keep arriving
//!   (continuous batching — admission never waits for execution). The
//!   backend moves onto this thread, bringing its `ExecArena` *and* its
//!   persistent `ExecPool` with it (DESIGN.md §11/§12): the pool's
//!   workers spawn once at the first batch, so the steady-state loop
//!   performs zero heap growths and zero thread spawns — and a
//!   replanning cluster backend's placement search runs on the pool,
//!   never here;
//! * **scatter/complete** — each request's rows and its slice of the
//!   batch's [`ForwardStats`] resolve the caller's handle.
//!
//! `shutdown` stops admission, drains everything in flight, then joins
//! the scheduler; dropping the service does the same.
//!
//! [`ForwardStats`]: crate::moe::exec::ForwardStats

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batch, Batcher, BatcherConfig, Request};
use crate::coordinator::metrics::{LatencyStats, ServingMetrics};
use crate::fault::ClusterError;
use crate::obs::{EventKind, Obs};
use crate::tensor::Tensor;

use super::backend::ServeBackend;
use super::handle::{
    RequestError, RequestStats, ResponseHandle, ServeResponse, Slot,
};

/// Scheduling class; lower classes are batched first when contending.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive traffic, batched before any queued backlog of
    /// the other classes (the batcher is refilled one batch at a time,
    /// so contending lower-priority work waits behind this class).
    Interactive,
    /// The default class.
    #[default]
    Standard,
    /// Throughput traffic, batched only after the other classes drain.
    Bulk,
}

const N_PRIORITIES: usize = 3;

impl Priority {
    fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Standard => 1,
            Priority::Bulk => 2,
        }
    }
}

/// One serving submission: token hidden-states plus scheduling knobs.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    /// [n_tokens, d_model] hidden states entering the stack.
    pub tokens: Tensor,
    /// Task tag (load-distribution figures).
    pub task: Option<String>,
    pub priority: Priority,
    /// Queue deadline: if the request's batch has not begun executing
    /// within this budget, the scheduler pulls the request back out of
    /// its queue or the batcher — it never executes — and the handle
    /// resolves [`RequestError::DeadlineExpired`]. The scheduler wakes
    /// at the earliest parked deadline, so expiry is detected promptly
    /// rather than at the batcher's flush deadline. Best-effort bound on
    /// time-to-execution-start: once the batch is dispatched the request
    /// completes normally.
    pub deadline: Option<Duration>,
}

impl ServeRequest {
    pub fn new(tokens: Tensor) -> ServeRequest {
        ServeRequest {
            tokens,
            task: None,
            priority: Priority::Standard,
            deadline: None,
        }
    }

    pub fn with_task(mut self, task: &str) -> ServeRequest {
        self.task = Some(task.to_string());
        self
    }

    pub fn with_priority(mut self, p: Priority) -> ServeRequest {
        self.priority = p;
        self
    }

    pub fn with_deadline(mut self, d: Duration) -> ServeRequest {
        self.deadline = Some(d);
        self
    }
}

/// Why `submit` refused a request (backpressure / validation).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    /// Token backlog (admission queue + batcher) is at the limit.
    QueueFull { queued_tokens: usize, limit: usize },
    /// Too many requests in flight.
    TooManyPending { pending: usize, limit: usize },
    /// `shutdown` has begun; no new work is accepted.
    ShuttingDown,
    /// Request hidden size does not match the backend.
    DimMismatch { expected: usize, got: Vec<usize> },
    /// Zero-token request.
    EmptyRequest,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { queued_tokens, limit } => write!(
                f,
                "queue full: {queued_tokens} tokens queued (limit {limit})"
            ),
            AdmissionError::TooManyPending { pending, limit } => write!(
                f,
                "too many pending requests: {pending} (limit {limit})"
            ),
            AdmissionError::ShuttingDown => {
                write!(f, "service is shutting down")
            }
            AdmissionError::DimMismatch { expected, got } => write!(
                f,
                "request shape {got:?} incompatible with d_model {expected}"
            ),
            AdmissionError::EmptyRequest => write!(f, "empty request"),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Service tuning knobs.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Batching policy of the scheduler's internal [`Batcher`].
    pub batcher: BatcherConfig,
    /// Admission bound on queued tokens (admission queue + batcher). A
    /// request larger than the limit is still admitted when the queue is
    /// empty, mirroring the batcher's oversized-request rule — otherwise
    /// it could never run.
    pub max_queued_tokens: usize,
    /// Admission bound on in-flight (submitted, uncompleted) requests.
    pub max_pending_requests: usize,
    /// Queue deadline applied to requests that do not carry their own.
    pub default_deadline: Option<Duration>,
    /// Observability bundle (DESIGN.md §15). When set, the service
    /// installs it on the backend at `start`, stamps the request
    /// lifecycle (admit → queue → batch-form → execute → deliver) into
    /// its trace, and mirrors every `ServingMetrics` update into its
    /// registry — so registry reads reconcile exactly with both the
    /// lock-guarded metrics and trace-derived aggregates.
    pub obs: Option<Arc<Obs>>,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            batcher: BatcherConfig::default(),
            max_queued_tokens: 4096,
            max_pending_requests: 1024,
            default_deadline: None,
            obs: None,
        }
    }
}

/// Current backlog snapshot (`queued_tokens` counts admission + batcher).
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueDepth {
    pub queued_tokens: usize,
    pub pending_requests: usize,
}

// ------------------------------------------------------------ internals

/// An admitted request waiting to enter the batcher.
struct Pending {
    id: u64,
    tokens: Tensor,
    task: Option<String>,
    slot: Arc<Slot>,
    submitted: Instant,
    deadline: Option<Instant>,
}

impl Pending {
    fn n_tokens(&self) -> usize {
        self.tokens.shape[0]
    }
}

/// Scheduler-side record of a request inside the batcher / a batch.
struct Inflight {
    slot: Arc<Slot>,
    submitted: Instant,
    deadline: Option<Instant>,
    /// Times this request's batch was lost to a worker fault and the
    /// request was resubmitted (DESIGN.md §16). At most 1: a second
    /// `WorkerLost` fails the handle instead of retrying forever.
    retries: u8,
}

/// Earliest deadline among requests sitting in the batcher (entries are
/// removed from `inflight` as their batch scatters, so between batches
/// this is exactly the parked set).
fn earliest_deadline(
    inflight: &HashMap<u64, Inflight>,
) -> Option<Instant> {
    inflight.values().filter_map(|m| m.deadline).min()
}

#[derive(Default)]
struct Inner {
    queues: [VecDeque<Pending>; N_PRIORITIES],
    /// Tokens in the admission queues (not yet in the batcher).
    queued_tokens: usize,
    /// Tokens currently inside the scheduler's batcher (mirror, updated
    /// under this lock so admission sees a consistent backlog).
    batcher_tokens: usize,
    /// Submitted and not yet retired. Released when the request's batch
    /// finishes executing (just before its handle is fulfilled, so a
    /// woken waiter never races a stale count) or when it resolves at
    /// the transfer stage (cancel/expiry).
    pending_requests: usize,
    stopping: bool,
    next_id: u64,
}

struct Shared {
    inner: Mutex<Inner>,
    cv: Condvar,
    metrics: Mutex<ServingMetrics>,
    latency: Mutex<LatencyStats>,
    cfg: ServiceConfig,
    d_model: usize,
    started: Instant,
}

/// Outcome of one admission-queue → batcher transfer.
#[derive(Default)]
struct TransferOutcome {
    cancelled: u64,
    expired: u64,
}

/// Refill the batcher from the admission queues, priority-major then
/// FIFO, resolving cancellations and expired queue deadlines on the way.
/// Stops once the batcher holds at least one full batch (`cap` tokens):
/// the rest of the backlog waits in the priority queues, which is what
/// lets a later Interactive arrival leapfrog parked Standard/Bulk work —
/// priority would be meaningless if the whole backlog were drafted into
/// the FIFO batcher eagerly.
/// Called with the `Inner` lock held; `inflight` is scheduler-private.
fn transfer_admissions(
    inner: &mut Inner,
    batcher: &mut Batcher,
    inflight: &mut HashMap<u64, Inflight>,
    now: Instant,
    cap: usize,
    obs: Option<&Obs>,
) -> TransferOutcome {
    let mut out = TransferOutcome::default();
    'refill: for q in 0..N_PRIORITIES {
        loop {
            if batcher.queued_tokens() >= cap {
                break 'refill;
            }
            let p = match inner.queues[q].pop_front() {
                Some(p) => p,
                None => break,
            };
            inner.queued_tokens -= p.n_tokens();
            if p.slot.is_cancelled() {
                p.slot.fulfill(Err(RequestError::Cancelled));
                inner.pending_requests -= 1;
                out.cancelled += 1;
                if let Some(o) = obs {
                    o.trace.push(EventKind::Cancel { req: p.id });
                }
                continue;
            }
            if p.deadline.map_or(false, |d| now >= d) {
                p.slot.fulfill(Err(RequestError::DeadlineExpired));
                inner.pending_requests -= 1;
                out.expired += 1;
                if let Some(o) = obs {
                    o.trace.push(EventKind::Expire { req: p.id });
                }
                continue;
            }
            if let Some(o) = obs {
                o.trace.push(EventKind::QueueDepart {
                    req: p.id,
                    wait_ns: now
                        .saturating_duration_since(p.submitted)
                        .as_nanos() as u64,
                });
            }
            inflight.insert(
                p.id,
                Inflight {
                    slot: p.slot,
                    submitted: p.submitted,
                    deadline: p.deadline,
                    retries: 0,
                },
            );
            batcher.push(Request {
                id: p.id,
                tokens: p.tokens,
                task: p.task,
            });
        }
    }
    inner.batcher_tokens = batcher.queued_tokens();
    out
}

/// Pull cancelled and deadline-expired requests back out of the batcher
/// — they must never execute, both so their compute is not wasted and so
/// batch-level metrics keep reconciling with the per-request stats that
/// are actually delivered. Runs between batches with the `Inner` lock
/// held; at that point every `inflight` entry is parked in the batcher
/// (mid-execution entries are removed at scatter), so a flagged entry
/// not found in the batcher is already executing: it completes normally
/// (cancellation is then handled at scatter; an expired deadline after
/// execution begins is a completion, not a failure).
fn sweep_parked(
    inner: &mut Inner,
    batcher: &mut Batcher,
    inflight: &mut HashMap<u64, Inflight>,
    now: Instant,
    obs: Option<&Obs>,
) -> TransferOutcome {
    let mut out = TransferOutcome::default();
    let ids: Vec<(u64, bool)> = inflight
        .iter()
        .filter_map(|(&id, m)| {
            if m.slot.is_cancelled() {
                Some((id, true))
            } else if m.deadline.map_or(false, |d| now >= d) {
                Some((id, false))
            } else {
                None
            }
        })
        .collect();
    for (id, is_cancel) in &ids {
        if batcher.remove(*id).is_some() {
            let meta = inflight.remove(id).expect("swept id is inflight");
            if *is_cancel {
                meta.slot.fulfill(Err(RequestError::Cancelled));
                out.cancelled += 1;
                if let Some(o) = obs {
                    o.trace.push(EventKind::Cancel { req: *id });
                }
            } else {
                meta.slot.fulfill(Err(RequestError::DeadlineExpired));
                out.expired += 1;
                if let Some(o) = obs {
                    o.trace.push(EventKind::Expire { req: *id });
                }
            }
            inner.pending_requests -= 1;
        }
    }
    if out.cancelled + out.expired > 0 {
        inner.batcher_tokens = batcher.queued_tokens();
    }
    out
}

/// Execute one batch on the backend and complete its member handles.
///
/// Fault containment (DESIGN.md §16): a backend failure fails only this
/// batch's handles, never the scheduler. When the typed fault behind the
/// error is [`ClusterError::WorkerLost`], each member request is
/// resubmitted through `batcher` exactly once (its admission slot is
/// re-taken); a request whose retry also hits a lost worker resolves
/// [`RequestError::WorkerLost`].
fn execute_batch(
    shared: &Shared,
    backend: &mut dyn ServeBackend,
    batch: &Batch,
    inflight: &mut HashMap<u64, Inflight>,
    batcher: &mut Batcher,
) {
    let obs = shared.cfg.obs.as_deref();
    if let Some(o) = obs {
        // The forward below claims `peek_batch()` as its id (the backend
        // shares this bundle), tying this event to the exec-layer trail.
        o.trace.push(EventKind::BatchForm {
            batch: o.peek_batch(),
            requests: batch.spans.len() as u32,
            tokens: batch.n_tokens() as u32,
        });
    }
    let t0 = Instant::now();
    let result = backend.forward(&batch.tokens);
    let exec = t0.elapsed();
    // Drained after forward: a replanning backend migrates experts
    // between batches, inside its forward hook.
    let replans = backend.take_replans();
    {
        let mut m = shared.metrics.lock().unwrap();
        if m.batches == 0 {
            m.time_to_first_batch_s =
                t0.duration_since(shared.started).as_secs_f64();
            if let Some(o) = obs {
                o.registry().set_gauge(
                    o.h.time_to_first_batch_ns,
                    t0.duration_since(shared.started).as_nanos() as u64,
                );
            }
        }
        m.batches += 1;
        m.replans += replans;
        if let Ok((_, stats)) = &result {
            m.merge_forward(stats);
        }
    }
    if let Some(o) = obs {
        let r = o.registry();
        r.inc(o.h.batches);
        r.add(o.h.replans, replans);
        r.record(o.h.batch_exec_ns, exec.as_nanos() as u64);
        r.record(o.h.batch_tokens, batch.n_tokens() as u64);
        o.trace.push(EventKind::BatchExec {
            batch: o.current_batch(),
            ns: exec.as_nanos() as u64,
        });
        if let Ok((_, stats)) = &result {
            // Mirror `merge_forward` term by term (same `as u64` casts,
            // same per-layer walk) so registry counters reconcile `==`
            // with the lock-guarded `ServingMetrics`.
            r.add(o.h.tokens, stats.tokens as u64);
            r.add(
                o.h.expert_forward_ns,
                (stats.expert_forward_s * 1e9) as u64,
            );
            r.add(o.h.routing_ns, (stats.routing_s * 1e9) as u64);
            for l in &stats.per_layer {
                r.add(o.h.dropped_assignments, l.dropped as u64);
                r.add(o.h.ffn_assignments, l.ffn_assignments as u64);
                r.add(o.h.zc_assignments, l.zc_assignments as u64);
            }
        }
    }
    // Release the members' admission slots *before* fulfilling their
    // handles: a caller woken by its completion must be able to submit
    // again without racing a stale pending_requests count.
    {
        let mut inner = shared.inner.lock().unwrap();
        inner.pending_requests -= batch.spans.len();
    }
    let mut cancelled = 0u64;
    let mut failed = 0u64;
    let mut retried = 0u64;
    let mut degraded = 0u64;
    match result {
        Ok((y, stats)) => {
            // Requests in a batch that lost all replicas of an expert
            // rode degraded (copy-expert) outputs — a request-level
            // quality signal operators alert on (DESIGN.md §16).
            let batch_degraded = stats.degraded_tokens > 0;
            let done = Instant::now();
            for ((id, span), (sid, out)) in
                batch.spans.iter().zip(batch.scatter(&y))
            {
                debug_assert_eq!(*id, sid);
                let meta = match inflight.remove(id) {
                    Some(m) => m,
                    None => continue,
                };
                if meta.slot.is_cancelled() {
                    meta.slot.fulfill(Err(RequestError::Cancelled));
                    cancelled += 1;
                    if let Some(o) = obs {
                        o.trace.push(EventKind::Cancel { req: *id });
                    }
                    continue;
                }
                let req_stats = RequestStats {
                    tokens: span.len(),
                    counts: stats.span_counts(span.clone()),
                    queue_wait: t0
                        .saturating_duration_since(meta.submitted),
                    service_time: done
                        .saturating_duration_since(meta.submitted),
                    batch_tokens: batch.n_tokens(),
                    batch_exec: exec,
                };
                if let Some(o) = obs {
                    let queue_ns =
                        req_stats.queue_wait.as_nanos() as u64;
                    let service_ns =
                        req_stats.service_time.as_nanos() as u64;
                    o.registry().record(o.h.queue_wait_ns, queue_ns);
                    o.registry().record(o.h.service_ns, service_ns);
                    o.trace.push(EventKind::Deliver {
                        req: *id,
                        tokens: span.len() as u32,
                        queue_ns,
                        service_ns,
                    });
                }
                if batch_degraded {
                    degraded += 1;
                }
                shared
                    .latency
                    .lock()
                    .unwrap()
                    .record(req_stats.service_time);
                meta.slot.fulfill(Ok(ServeResponse {
                    output: out,
                    stats: req_stats,
                }));
            }
        }
        Err(e) => {
            let fault = backend.take_fault();
            if let Some(ClusterError::WorkerLost { device, layer }) = fault
            {
                // Resubmit-once: the input rows are still in
                // `batch.tokens` — slice them back out per span and
                // requeue. The request keeps its id, slot, submit time
                // and deadline; only `retries` advances.
                let mut requeued = 0usize;
                for ((id, _), (sid, tokens)) in
                    batch.spans.iter().zip(batch.scatter(&batch.tokens))
                {
                    debug_assert_eq!(*id, sid);
                    let meta = match inflight.get_mut(id) {
                        Some(m) => m,
                        None => continue,
                    };
                    if meta.slot.is_cancelled() {
                        let meta = inflight.remove(id).unwrap();
                        meta.slot.fulfill(Err(RequestError::Cancelled));
                        cancelled += 1;
                        if let Some(o) = obs {
                            o.trace.push(EventKind::Cancel { req: *id });
                        }
                    } else if meta.retries == 0 {
                        meta.retries = 1;
                        batcher.push(Request {
                            id: *id,
                            tokens,
                            task: None,
                        });
                        requeued += 1;
                        retried += 1;
                    } else {
                        let meta = inflight.remove(id).unwrap();
                        meta.slot.fulfill(Err(
                            RequestError::WorkerLost { device, layer },
                        ));
                        failed += 1;
                        if let Some(o) = obs {
                            o.trace.push(EventKind::Fail { req: *id });
                        }
                    }
                }
                if requeued > 0 {
                    // Re-take the admission slots released above: the
                    // requeued requests are in flight again.
                    let mut inner = shared.inner.lock().unwrap();
                    inner.pending_requests += requeued;
                    inner.batcher_tokens = batcher.queued_tokens();
                }
            } else {
                let msg = format!("{e:#}");
                for (id, _) in &batch.spans {
                    if let Some(meta) = inflight.remove(id) {
                        meta.slot.fulfill(Err(RequestError::Backend(
                            msg.clone(),
                        )));
                        failed += 1;
                        if let Some(o) = obs {
                            o.trace.push(EventKind::Fail { req: *id });
                        }
                    }
                }
            }
        }
    }
    if cancelled > 0 || failed > 0 || retried > 0 || degraded > 0 {
        let mut m = shared.metrics.lock().unwrap();
        m.cancelled += cancelled;
        m.failed += failed;
        m.retried += retried;
        m.degraded += degraded;
        if let Some(o) = obs {
            o.registry().add(o.h.cancelled, cancelled);
            o.registry().add(o.h.failed, failed);
            o.registry().add(o.h.retried, retried);
            o.registry().add(o.h.degraded_requests, degraded);
        }
    }
    if retried > 0 || degraded > 0 {
        let mut l = shared.latency.lock().unwrap();
        l.retried += retried;
        l.degraded += degraded;
    }
}

/// The scheduler thread body: contain panics (a backend panic must not
/// strand callers blocked in `wait()`), then fail whatever is left.
fn scheduler_loop(shared: Arc<Shared>, mut backend: Box<dyn ServeBackend>) {
    let mut batcher =
        Batcher::new(shared.cfg.batcher.clone(), shared.d_model);
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        scheduler_run(&shared, backend.as_mut(), &mut batcher, &mut inflight)
    }));
    if run.is_err() {
        // The scheduler died mid-flight: stop admission and fail every
        // request still waiting in the admission queues. Recover the
        // lock even if the panic poisoned it — stranding callers would
        // be worse than reading the interrupted state.
        let mut inner = match shared.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        inner.stopping = true;
        for q in 0..N_PRIORITIES {
            while let Some(p) = inner.queues[q].pop_front() {
                inner.queued_tokens -= p.n_tokens();
                inner.pending_requests =
                    inner.pending_requests.saturating_sub(1);
                p.slot.fulfill(Err(RequestError::ServiceStopped));
            }
        }
    }
    // Normal drained shutdown leaves nothing here; after a panic this is
    // what keeps waiters from hanging forever.
    for (_, meta) in inflight.drain() {
        meta.slot.fulfill(Err(RequestError::ServiceStopped));
    }
}

/// The continuous-batching loop, until drained shutdown.
fn scheduler_run(
    shared: &Shared,
    backend: &mut dyn ServeBackend,
    batcher: &mut Batcher,
    inflight: &mut HashMap<u64, Inflight>,
) {
    loop {
        // Phase 1 — wait for work, then refill the batcher (one batch's
        // worth; the rest of the backlog waits in the priority queues)
        // and resolve cancellations.
        let draining;
        let outcome;
        let drained_dry;
        {
            let mut inner = shared.inner.lock().unwrap();
            loop {
                let has_new =
                    inner.queues.iter().any(|q| !q.is_empty());
                if has_new || inner.stopping || !batcher.is_empty() {
                    break;
                }
                inner = shared.cv.wait(inner).unwrap();
            }
            let now = Instant::now();
            let obs = shared.cfg.obs.as_deref();
            let mut o = transfer_admissions(
                &mut inner,
                batcher,
                inflight,
                now,
                shared.cfg.batcher.max_tokens,
                obs,
            );
            let swept =
                sweep_parked(&mut inner, batcher, inflight, now, obs);
            o.cancelled += swept.cancelled;
            o.expired += swept.expired;
            outcome = o;
            draining = inner.stopping;
            drained_dry =
                draining && batcher.is_empty() && inflight.is_empty();
        }
        if outcome.cancelled > 0 || outcome.expired > 0 {
            let mut m = shared.metrics.lock().unwrap();
            m.cancelled += outcome.cancelled;
            m.expired += outcome.expired;
            if let Some(o) = shared.cfg.obs.as_deref() {
                o.registry().add(o.h.cancelled, outcome.cancelled);
                o.registry().add(o.h.expired, outcome.expired);
            }
        }
        if drained_dry {
            break;
        }

        // Phase 2 — nothing due yet: sleep until the batcher's flush
        // deadline, the earliest parked request deadline (so the next
        // sweep can expire it), or the next submission/cancellation.
        // A deadline already in the past yields a zero timeout: the loop
        // comes straight back through the phase-1 sweep, which removes
        // the expired request, so no busy spin.
        let now = Instant::now();
        if !draining && !batcher.is_empty() && !batcher.ready(now) {
            let flush = batcher
                .next_deadline()
                .expect("non-empty batcher has a deadline");
            let wake = match earliest_deadline(inflight) {
                Some(d) => flush.min(d),
                None => flush,
            };
            let timeout = wake.saturating_duration_since(now);
            let inner = shared.inner.lock().unwrap();
            let has_new = inner.queues.iter().any(|q| !q.is_empty());
            // Cancellation is re-checked under the lock, and the waker
            // notifies under the same lock, so a cancel can never slip
            // between this predicate and the wait (no lost wakeup).
            let cancel_pending =
                inflight.values().any(|m| m.slot.is_cancelled());
            if !has_new && !inner.stopping && !cancel_pending {
                let _unused = shared
                    .cv
                    .wait_timeout(inner, timeout)
                    .unwrap();
            }
            continue;
        }

        // Phase 3 — flush every due batch (all of them when draining).
        while batcher.ready(Instant::now())
            || (draining && !batcher.is_empty())
        {
            let batch =
                batcher.next_batch().expect("due implies non-empty");
            {
                let mut inner = shared.inner.lock().unwrap();
                inner.batcher_tokens = batcher.queued_tokens();
            }
            execute_batch(shared, backend, &batch, inflight, batcher);
        }
    }
}

// ------------------------------------------------------------- service

/// The serving API: a continuous-batching scheduler over a
/// [`ServeBackend`]. See the module docs for the lifecycle.
pub struct MoeService {
    shared: Arc<Shared>,
    scheduler: Option<JoinHandle<()>>,
    backend_label: String,
    /// Installed on every slot so `ResponseHandle::cancel` can wake the
    /// scheduler out of its flush-deadline sleep.
    waker: Arc<dyn Fn() + Send + Sync>,
}

impl MoeService {
    /// Start a service over `backend` (moved onto the scheduler thread).
    /// When `cfg.obs` is set it is installed on the backend first, so the
    /// service's lifecycle stamps and the backend's per-layer stamps
    /// share one registry, trace and batch sequence.
    pub fn start<B: ServeBackend + 'static>(
        mut backend: B,
        cfg: ServiceConfig,
    ) -> MoeService {
        if let Some(obs) = cfg.obs.clone() {
            backend.set_obs(obs);
        }
        let backend_label = backend.label();
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
            metrics: Mutex::new(ServingMetrics::default()),
            latency: Mutex::new(LatencyStats::new(4096)),
            d_model: backend.d_model(),
            cfg,
            started: Instant::now(),
        });
        let thread_shared = shared.clone();
        let scheduler = std::thread::Builder::new()
            .name("moepp-serve-scheduler".to_string())
            .spawn(move || {
                scheduler_loop(thread_shared, Box::new(backend))
            })
            .expect("spawn serve scheduler");
        let waker = {
            let shared = shared.clone();
            // Notify while holding the inner lock: phase 2 re-checks the
            // cancelled flags under this lock right before waiting, so
            // pairing the notify with the lock makes "flag set but
            // scheduler sleeps the full flush deadline anyway" impossible.
            Arc::new(move || {
                let _guard = shared.inner.lock().unwrap();
                shared.cv.notify_all();
            }) as Arc<dyn Fn() + Send + Sync>
        };
        MoeService {
            shared,
            scheduler: Some(scheduler),
            backend_label,
            waker,
        }
    }

    /// Admit a request, or reject it under backpressure. On success the
    /// returned handle resolves exactly once via `wait`/`try_wait`.
    pub fn submit(
        &self,
        req: ServeRequest,
    ) -> Result<ResponseHandle, AdmissionError> {
        if req.tokens.rank() != 2
            || req.tokens.shape[1] != self.shared.d_model
        {
            return Err(AdmissionError::DimMismatch {
                expected: self.shared.d_model,
                got: req.tokens.shape.clone(),
            });
        }
        let n = req.tokens.shape[0];
        if n == 0 {
            return Err(AdmissionError::EmptyRequest);
        }
        let prio = req.priority.index() as u8;
        let cfg = &self.shared.cfg;
        let admitted = {
            let mut inner = self.shared.inner.lock().unwrap();
            if inner.stopping {
                Err(AdmissionError::ShuttingDown)
            } else if inner.pending_requests >= cfg.max_pending_requests {
                Err(AdmissionError::TooManyPending {
                    pending: inner.pending_requests,
                    limit: cfg.max_pending_requests,
                })
            } else {
                let backlog = inner.queued_tokens + inner.batcher_tokens;
                if backlog + n > cfg.max_queued_tokens && backlog > 0 {
                    Err(AdmissionError::QueueFull {
                        queued_tokens: backlog,
                        limit: cfg.max_queued_tokens,
                    })
                } else {
                    let id = inner.next_id;
                    inner.next_id += 1;
                    let slot = Slot::new();
                    slot.set_waker(self.waker.clone());
                    let now = Instant::now();
                    let deadline = req
                        .deadline
                        .or(cfg.default_deadline)
                        .map(|d| now + d);
                    inner.queues[req.priority.index()].push_back(
                        Pending {
                            id,
                            tokens: req.tokens,
                            task: req.task,
                            slot: slot.clone(),
                            submitted: now,
                            deadline,
                        },
                    );
                    inner.queued_tokens += n;
                    inner.pending_requests += 1;
                    let backlog =
                        inner.queued_tokens + inner.batcher_tokens;
                    Ok((ResponseHandle::new(slot, id), backlog, id))
                }
            }
        };
        match admitted {
            Ok((handle, backlog, id)) => {
                {
                    let mut m = self.shared.metrics.lock().unwrap();
                    m.requests += 1;
                    m.peak_queue_tokens =
                        m.peak_queue_tokens.max(backlog as u64);
                }
                if let Some(o) = self.shared.cfg.obs.as_deref() {
                    o.registry().inc(o.h.requests);
                    o.registry()
                        .max_gauge(o.h.peak_queue_tokens, backlog as u64);
                    o.trace.push(EventKind::Admit {
                        req: id,
                        prio,
                        tokens: n as u32,
                    });
                }
                self.shared.cv.notify_all();
                Ok(handle)
            }
            Err(e) => {
                // Only backpressure bounces count as `rejected` — the
                // metric an operator tunes queue limits against.
                if matches!(
                    e,
                    AdmissionError::QueueFull { .. }
                        | AdmissionError::TooManyPending { .. }
                ) {
                    self.shared.metrics.lock().unwrap().rejected += 1;
                    if let Some(o) = self.shared.cfg.obs.as_deref() {
                        o.registry().inc(o.h.rejected);
                        o.trace.push(EventKind::Reject {
                            prio,
                            tokens: n as u32,
                        });
                    }
                }
                Err(e)
            }
        }
    }

    /// Convenience: submit raw tokens with default scheduling.
    pub fn submit_tokens(
        &self,
        tokens: Tensor,
    ) -> Result<ResponseHandle, AdmissionError> {
        self.submit(ServeRequest::new(tokens))
    }

    /// Snapshot of the current backlog.
    pub fn queue_depth(&self) -> QueueDepth {
        let inner = self.shared.inner.lock().unwrap();
        QueueDepth {
            queued_tokens: inner.queued_tokens + inner.batcher_tokens,
            pending_requests: inner.pending_requests,
        }
    }

    /// Snapshot of the aggregate serving metrics.
    pub fn metrics(&self) -> ServingMetrics {
        self.shared.metrics.lock().unwrap().clone()
    }

    /// The installed observability bundle, if any.
    pub fn obs(&self) -> Option<&Arc<Obs>> {
        self.shared.cfg.obs.as_ref()
    }

    /// Rebuild [`ServingMetrics`] purely from registry reads — no service
    /// locks touched. `None` without an obs bundle. Counter fields
    /// reconcile `==` with [`MoeService::metrics`] at quiescence (the
    /// registry is mirrored at every metrics update site); the float
    /// second fields are derived from the integer-nanosecond twins.
    pub fn metrics_from_registry(&self) -> Option<ServingMetrics> {
        self.shared
            .cfg
            .obs
            .as_deref()
            .map(ServingMetrics::from_registry)
    }

    /// Snapshot of the request service-time distribution.
    pub fn latency(&self) -> LatencyStats {
        self.shared.latency.lock().unwrap().clone()
    }

    pub fn backend_label(&self) -> &str {
        &self.backend_label
    }

    /// Graceful shutdown: stop admission, drain all queued and in-flight
    /// work (every outstanding handle resolves), join the scheduler, and
    /// return the final metrics.
    pub fn shutdown(mut self) -> ServingMetrics {
        self.stop_and_join();
        let m = self.shared.metrics.lock().unwrap().clone();
        m
    }

    fn stop_and_join(&mut self) {
        {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.stopping = true;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MoeService {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MoeConfig;
    use crate::coordinator::engine::MoeEngine;
    use crate::util::rng::Rng;

    fn test_service(
        max_tokens: usize,
        max_wait: Duration,
        max_queued_tokens: usize,
    ) -> (MoeConfig, MoeService) {
        let cfg = MoeConfig::preset("test");
        let engine = MoeEngine::native(cfg.clone(), 0);
        let service = MoeService::start(
            engine,
            ServiceConfig {
                batcher: BatcherConfig { max_tokens, max_wait },
                max_queued_tokens,
                max_pending_requests: 64,
                default_deadline: None,
                obs: None,
            },
        );
        (cfg, service)
    }

    fn input(cfg: &MoeConfig, seed: u64, n: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(&mut rng, &[n, cfg.d_model], 1.0)
    }

    #[test]
    fn submit_wait_roundtrip_with_stats() {
        let (cfg, service) =
            test_service(64, Duration::from_millis(1), 4096);
        let x = input(&cfg, 3, 10);
        let h = service.submit_tokens(x.clone()).unwrap();
        let resp = h.wait().unwrap();
        assert_eq!(resp.output.shape, vec![10, cfg.d_model]);
        // Every routed assignment is accounted: T * K * L.
        assert_eq!(
            resp.stats.counts.total(),
            (10 * cfg.top_k * cfg.n_layers) as u64
        );
        assert_eq!(resp.stats.tokens, 10);
        assert!(resp.stats.batch_tokens >= 10);
        assert!(resp.stats.service_time >= resp.stats.queue_wait);
        let m = service.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.batches, 1);
        assert!(m.time_to_first_batch_s > 0.0);
        assert_eq!(
            m.ffn_assignments + m.zc_assignments + m.dropped_assignments,
            (10 * cfg.top_k * cfg.n_layers) as u64
        );
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let (cfg, service) =
            test_service(64, Duration::from_millis(1), 4096);
        let bad = Tensor::zeros(&[4, cfg.d_model + 1]);
        assert!(matches!(
            service.submit_tokens(bad),
            Err(AdmissionError::DimMismatch { .. })
        ));
        let empty = Tensor::zeros(&[0, cfg.d_model]);
        assert!(matches!(
            service.submit_tokens(empty),
            Err(AdmissionError::EmptyRequest)
        ));
        // Validation failures are not backpressure: the rejected counter
        // (what operators tune queue limits against) stays untouched.
        assert_eq!(service.metrics().rejected, 0);
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        // A huge max_wait + tiny token limit keeps the first request
        // queued so the second submission must bounce.
        let (cfg, service) =
            test_service(1024, Duration::from_secs(60), 8);
        let _h1 = service.submit_tokens(input(&cfg, 1, 6)).unwrap();
        let err = service
            .submit_tokens(input(&cfg, 2, 6))
            .expect_err("queue limit must reject");
        assert!(matches!(err, AdmissionError::QueueFull { .. }));
        let m = service.metrics();
        assert_eq!(m.rejected, 1);
        assert!(m.peak_queue_tokens >= 6);
        // Oversized-but-empty-queue admission still works after drain.
        let m = service.shutdown();
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn oversized_request_admitted_when_queue_empty() {
        let (cfg, service) =
            test_service(1024, Duration::from_millis(1), 8);
        // 20 tokens > 8-token limit, but the queue is empty: admitted
        // (otherwise it could never run), mirroring the batcher rule.
        let h = service.submit_tokens(input(&cfg, 4, 20)).unwrap();
        assert_eq!(h.wait().unwrap().output.shape[0], 20);
        service.shutdown();
    }

    #[test]
    fn cancel_resolves_promptly_without_executing() {
        let (cfg, service) =
            test_service(1024, Duration::from_secs(60), 4096);
        let h = service.submit_tokens(input(&cfg, 5, 4)).unwrap();
        h.cancel();
        assert_eq!(service.metrics().requests, 1);
        // cancel() wakes the scheduler, which pulls the request back out
        // of the admission queue or the batcher — so this resolves
        // immediately, long before the 60 s flush deadline, and the
        // request never executes (no batch runs).
        assert_eq!(h.wait(), Err(RequestError::Cancelled));
        let m = service.shutdown();
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.batches, 0, "cancelled request must not execute");
    }

    #[test]
    fn queue_deadline_expires_stale_requests() {
        let (cfg, service) =
            test_service(1024, Duration::from_secs(60), 4096);
        let req = ServeRequest::new(input(&cfg, 6, 4))
            .with_deadline(Duration::ZERO);
        let h = service.submit(req).unwrap();
        assert_eq!(h.wait(), Err(RequestError::DeadlineExpired));
        assert_eq!(service.shutdown().expired, 1);
    }

    #[test]
    fn deadline_expires_while_parked_in_batcher() {
        // Regression: deadlines must be enforced after the request enters
        // the batcher too — the scheduler wakes at the parked deadline,
        // sweeps the request back out (it never executes, keeping batch
        // metrics reconciled with delivered per-request stats) and
        // resolves DeadlineExpired, instead of serving it after the 60 s
        // batcher wait as if the deadline were cosmetic.
        let (cfg, service) =
            test_service(1024, Duration::from_secs(60), 4096);
        let a = service.submit_tokens(input(&cfg, 7, 4)).unwrap();
        let b = service
            .submit(
                ServeRequest::new(input(&cfg, 8, 4))
                    .with_deadline(Duration::from_millis(30)),
            )
            .unwrap();
        // Resolves within ~30ms on the parked path (or immediately at
        // transfer if the scheduler lagged past the deadline) — either
        // way long before the batcher's wait deadline.
        assert_eq!(b.wait(), Err(RequestError::DeadlineExpired));
        let m = service.shutdown();
        let resp = a.wait().unwrap();
        assert_eq!(resp.output.shape[0], 4);
        assert_eq!(m.expired, 1);
        assert_eq!(m.requests, 2);
        // Only the surviving request executed: the expired one's tokens
        // never reached the backend.
        assert_eq!(m.batches, 1);
        assert_eq!(m.tokens, 4);
        assert_eq!(m.ffn_assignments, resp.stats.counts.ffn);
    }

    #[test]
    fn completion_releases_admission_slot_before_handle_wakes() {
        // Regression: pending_requests must be released before the handle
        // is fulfilled, so a caller woken by wait() can immediately
        // submit again under max_pending_requests=1.
        let cfg = MoeConfig::preset("test");
        let service = MoeService::start(
            MoeEngine::native(cfg.clone(), 0),
            ServiceConfig {
                batcher: BatcherConfig {
                    max_tokens: 4,
                    max_wait: Duration::from_millis(1),
                },
                max_queued_tokens: 4096,
                max_pending_requests: 1,
                default_deadline: None,
                obs: None,
            },
        );
        for i in 0..8 {
            let h = service.submit_tokens(input(&cfg, i, 4)).unwrap();
            h.wait().unwrap_or_else(|e| {
                panic!("round {i} failed: {e}")
            });
        }
        let m = service.shutdown();
        assert_eq!(m.requests, 8);
        assert_eq!(m.rejected, 0, "no spurious TooManyPending");
    }

    #[test]
    fn shutdown_drains_in_flight_work() {
        let (cfg, service) =
            test_service(1024, Duration::from_secs(60), 4096);
        let handles: Vec<_> = (0..6)
            .map(|i| {
                service.submit_tokens(input(&cfg, 10 + i, 5)).unwrap()
            })
            .collect();
        // Nothing flushed yet (size threshold unmet, deadline far away);
        // shutdown must drain rather than drop.
        let m = service.shutdown();
        assert_eq!(m.requests, 6);
        assert!(m.batches >= 1);
        for h in handles {
            assert_eq!(h.wait().unwrap().output.shape[0], 5);
        }
    }

    #[test]
    fn submit_after_shutdown_begins_is_rejected() {
        let (cfg, mut service) =
            test_service(64, Duration::from_millis(1), 4096);
        {
            let mut inner = service.shared.inner.lock().unwrap();
            inner.stopping = true;
        }
        assert!(matches!(
            service.submit_tokens(input(&cfg, 8, 4)),
            Err(AdmissionError::ShuttingDown)
        ));
        service.stop_and_join();
    }

    #[test]
    fn transfer_orders_by_priority_class_then_fifo() {
        // Deterministic unit test of the transfer step (the e2e path
        // cannot pin down wake timing): Bulk, Standard and Interactive
        // requests admitted together must enter the batcher
        // Interactive → Standard → Bulk, FIFO within a class.
        let mut inner = Inner::default();
        let mut batcher = Batcher::new(
            BatcherConfig {
                max_tokens: 1024,
                max_wait: Duration::ZERO,
            },
            4,
        );
        let mut inflight = HashMap::new();
        let mut slots = Vec::new();
        for (id, prio) in [
            (0u64, Priority::Bulk),
            (1, Priority::Standard),
            (2, Priority::Interactive),
            (3, Priority::Bulk),
            (4, Priority::Interactive),
        ] {
            let slot = Slot::new();
            slots.push(slot.clone());
            inner.queues[prio.index()].push_back(Pending {
                id,
                tokens: Tensor::full(&[2, 4], id as f32),
                task: None,
                slot,
                submitted: Instant::now(),
                deadline: None,
            });
            inner.queued_tokens += 2;
            inner.pending_requests += 1;
        }
        let out = transfer_admissions(
            &mut inner,
            &mut batcher,
            &mut inflight,
            Instant::now(),
            1024,
            None,
        );
        assert_eq!(out.cancelled + out.expired, 0);
        assert_eq!(inner.queued_tokens, 0);
        assert_eq!(inner.batcher_tokens, 10);
        let batch = batcher.next_batch().unwrap();
        let order: Vec<u64> =
            batch.spans.iter().map(|(id, _)| *id).collect();
        assert_eq!(order, vec![2, 4, 1, 0, 3]);
        assert_eq!(inflight.len(), 5);
    }

    #[test]
    fn backlog_waits_in_priority_queues_so_interactive_leapfrogs() {
        // The refill cap keeps the batcher at ~one batch; backlog parks
        // in the priority queues, so an Interactive request arriving
        // behind a Standard backlog is still batched next.
        let pending = |id: u64| Pending {
            id,
            tokens: Tensor::full(&[4, 2], id as f32),
            task: None,
            slot: Slot::new(),
            submitted: Instant::now(),
            deadline: None,
        };
        let mut inner = Inner::default();
        let mut batcher = Batcher::new(
            BatcherConfig { max_tokens: 4, max_wait: Duration::ZERO },
            2,
        );
        let mut inflight = HashMap::new();
        for id in [0u64, 1] {
            inner.queues[Priority::Standard.index()]
                .push_back(pending(id));
            inner.queued_tokens += 4;
            inner.pending_requests += 1;
        }
        // First refill takes exactly one batch's worth; request 1 stays
        // in the Standard queue rather than being drafted FIFO.
        transfer_admissions(
            &mut inner, &mut batcher, &mut inflight, Instant::now(), 4,
            None,
        );
        assert_eq!(inner.batcher_tokens, 4);
        assert_eq!(
            inner.queues[Priority::Standard.index()].len(),
            1,
            "backlog must wait in the priority queues"
        );
        // Interactive arrives while the backlog waits.
        inner.queues[Priority::Interactive.index()]
            .push_back(pending(2));
        inner.queued_tokens += 4;
        inner.pending_requests += 1;
        // Flush the current batch, then refill: the interactive request
        // leapfrogs the parked standard one.
        let b0 = batcher.next_batch().unwrap();
        assert_eq!(b0.spans[0].0, 0);
        inner.batcher_tokens = batcher.queued_tokens();
        transfer_admissions(
            &mut inner, &mut batcher, &mut inflight, Instant::now(), 4,
            None,
        );
        let b1 = batcher.next_batch().unwrap();
        assert_eq!(
            b1.spans[0].0, 2,
            "interactive must be batched before the parked backlog"
        );
    }

    #[test]
    fn transfer_expires_and_cancels_in_queue() {
        let mut inner = Inner::default();
        let mut batcher =
            Batcher::new(BatcherConfig::default(), 4);
        let mut inflight = HashMap::new();
        let now = Instant::now();
        let cancelled_slot = Slot::new();
        ResponseHandle::new(cancelled_slot.clone(), 1).cancel();
        for (id, slot, deadline) in [
            (0u64, Slot::new(), Some(now - Duration::from_millis(1))),
            (1, cancelled_slot.clone(), None),
            (2, Slot::new(), None),
        ] {
            inner.queues[Priority::Standard.index()].push_back(Pending {
                id,
                tokens: Tensor::zeros(&[1, 4]),
                task: None,
                slot,
                submitted: now,
                deadline,
            });
            inner.queued_tokens += 1;
            inner.pending_requests += 1;
        }
        let out = transfer_admissions(
            &mut inner, &mut batcher, &mut inflight, now, 1024, None,
        );
        assert_eq!(out.expired, 1);
        assert_eq!(out.cancelled, 1);
        assert_eq!(inner.pending_requests, 1);
        assert_eq!(inflight.len(), 1);
        assert!(inflight.contains_key(&2));
    }
}

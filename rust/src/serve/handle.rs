//! Response-side of the serving API: the [`ResponseHandle`] a caller
//! holds between `submit` and completion, the typed [`ServeResponse`] it
//! resolves to, and the per-request [`RequestStats`] sliced out of the
//! executing batch's [`ForwardStats`].
//!
//! [`ForwardStats`]: crate::moe::exec::ForwardStats

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::moe::exec::AssignmentCounts;
use crate::tensor::Tensor;

/// Why a submitted request did not complete with an output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// The caller cancelled before the request reached a batch.
    Cancelled,
    /// The queue deadline passed before the request reached a batch.
    DeadlineExpired,
    /// The backend failed the batch this request rode in.
    Backend(String),
    /// A cluster worker died mid-batch and recovery could not complete
    /// (no surviving replica to redispatch to, or the redispatch target
    /// died too). The service retries the batch once before surfacing
    /// this (DESIGN.md §16).
    WorkerLost { device: usize, layer: usize },
    /// The service stopped without completing the request (should not
    /// happen under graceful shutdown — drain completes everything).
    ServiceStopped,
    /// `try_wait` already removed the result from this handle.
    ResultTaken,
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Cancelled => write!(f, "request cancelled"),
            RequestError::DeadlineExpired => {
                write!(f, "queue deadline expired before execution")
            }
            RequestError::Backend(e) => write!(f, "backend error: {e}"),
            RequestError::WorkerLost { device, layer } => write!(
                f,
                "worker lost on device {device} layer {layer} \
                 (retry exhausted)"
            ),
            RequestError::ServiceStopped => {
                write!(f, "service stopped before completion")
            }
            RequestError::ResultTaken => {
                write!(f, "result already taken via try_wait")
            }
        }
    }
}

impl std::error::Error for RequestError {}

/// Per-request accounting: this request's slice of the batch it executed
/// in — the paper's "simple tokens are cheap" cost model, observable per
/// caller (how many of *my* token-assignments hit FFN experts vs the
/// zero/copy/constant pathways).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestStats {
    /// Tokens this request contributed to its batch.
    pub tokens: usize,
    /// This request's assignment counts, summed over layers (slice of the
    /// batch-level `ForwardStats::token_counts`).
    pub counts: AssignmentCounts,
    /// Time spent queued (submit → batch dispatch).
    pub queue_wait: Duration,
    /// Total time submit → completion.
    pub service_time: Duration,
    /// Size of the batch this request rode in (continuous-batching
    /// co-tenants included).
    pub batch_tokens: usize,
    /// Wall time of that batch's stack forward.
    pub batch_exec: Duration,
}

impl RequestStats {
    /// Mean FFN assignments per token for this request — low values mean
    /// the router classified these tokens as "simple" (cheap pathways).
    pub fn ffn_per_token(&self) -> f64 {
        self.counts.ffn as f64 / self.tokens.max(1) as f64
    }
}

/// A completed request: stacked outputs for this request's rows plus its
/// per-request stats.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeResponse {
    /// [n_tokens, d_model] — this request's rows of the batch output.
    pub output: Tensor,
    pub stats: RequestStats,
}

pub(crate) type RequestResult = Result<ServeResponse, RequestError>;

enum SlotState {
    Pending,
    Ready(RequestResult),
    Taken,
}

/// Shared completion slot between a handle and the scheduler.
pub(crate) struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
    cancelled: AtomicBool,
    /// Wakes the scheduler when this request is cancelled, so a parked
    /// request resolves immediately instead of at the next flush
    /// deadline. Installed by the service at submit.
    waker: Mutex<Option<Arc<dyn Fn() + Send + Sync>>>,
}

impl Slot {
    pub(crate) fn new() -> Arc<Slot> {
        Arc::new(Slot {
            state: Mutex::new(SlotState::Pending),
            cv: Condvar::new(),
            cancelled: AtomicBool::new(false),
            waker: Mutex::new(None),
        })
    }

    pub(crate) fn set_waker(&self, w: Arc<dyn Fn() + Send + Sync>) {
        *self.waker.lock().unwrap() = Some(w);
    }

    pub(crate) fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Deliver the result and wake waiters. Idempotent-safe: the first
    /// fulfilment wins, later ones are dropped.
    pub(crate) fn fulfill(&self, r: RequestResult) {
        let mut st = self.state.lock().unwrap();
        if matches!(*st, SlotState::Pending) {
            *st = SlotState::Ready(r);
            self.cv.notify_all();
        }
        drop(st);
        // The waker can never be needed again; dropping it releases the
        // service state (`Arc<Shared>`) it captures, so retained handles
        // do not pin the whole service in memory after completion.
        *self.waker.lock().unwrap() = None;
    }
}

/// The caller's side of one in-flight request.
///
/// Obtained from [`MoeService::submit`]; resolves exactly once via
/// [`wait`](ResponseHandle::wait) (blocking) or
/// [`try_wait`](ResponseHandle::try_wait) (non-blocking, takes the result
/// on the call that observes completion). Dropping the handle does not
/// cancel the request — call [`cancel`](ResponseHandle::cancel) for that.
///
/// [`MoeService::submit`]: crate::serve::MoeService::submit
pub struct ResponseHandle {
    slot: Arc<Slot>,
    id: u64,
}

impl ResponseHandle {
    pub(crate) fn new(slot: Arc<Slot>, id: u64) -> ResponseHandle {
        ResponseHandle { slot, id }
    }

    /// Service-assigned request id (stable across metrics/log lines).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request completes (or fails) and take the result.
    pub fn wait(self) -> RequestResult {
        let mut st = self.slot.state.lock().unwrap();
        loop {
            match &*st {
                SlotState::Pending => {
                    st = self.slot.cv.wait(st).unwrap();
                }
                SlotState::Ready(_) => {
                    let prev =
                        std::mem::replace(&mut *st, SlotState::Taken);
                    match prev {
                        SlotState::Ready(r) => return r,
                        _ => unreachable!(),
                    }
                }
                SlotState::Taken => {
                    return Err(RequestError::ResultTaken);
                }
            }
        }
    }

    /// Non-blocking poll: `None` while in flight; `Some(result)` exactly
    /// once when complete (the result is taken by the observing call).
    pub fn try_wait(&self) -> Option<RequestResult> {
        let mut st = self.slot.state.lock().unwrap();
        match &*st {
            SlotState::Pending => None,
            SlotState::Ready(_) => {
                let prev = std::mem::replace(&mut *st, SlotState::Taken);
                match prev {
                    SlotState::Ready(r) => Some(r),
                    _ => unreachable!(),
                }
            }
            SlotState::Taken => Some(Err(RequestError::ResultTaken)),
        }
    }

    /// Cancel the request: if it has not begun executing, the scheduler
    /// is woken, pulls it back out of its queue/batcher (it never runs)
    /// and resolves the handle with [`RequestError::Cancelled`]. If its
    /// batch is already executing, the output is discarded in favour of
    /// `Cancelled` at scatter time.
    pub fn cancel(&self) {
        self.slot.cancelled.store(true, Ordering::Release);
        let waker = self.slot.waker.lock().unwrap().clone();
        if let Some(w) = waker {
            w();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(n: usize) -> ServeResponse {
        ServeResponse {
            output: Tensor::zeros(&[n, 2]),
            stats: RequestStats { tokens: n, ..Default::default() },
        }
    }

    #[test]
    fn wait_blocks_until_fulfilled() {
        let slot = Slot::new();
        let h = ResponseHandle::new(slot.clone(), 7);
        assert_eq!(h.id(), 7);
        let waiter = std::thread::spawn(move || h.wait());
        std::thread::sleep(Duration::from_millis(10));
        slot.fulfill(Ok(resp(3)));
        let got = waiter.join().unwrap().unwrap();
        assert_eq!(got.output.shape, vec![3, 2]);
        assert_eq!(got.stats.tokens, 3);
    }

    #[test]
    fn try_wait_takes_exactly_once() {
        let slot = Slot::new();
        let h = ResponseHandle::new(slot.clone(), 0);
        assert!(h.try_wait().is_none());
        slot.fulfill(Err(RequestError::Cancelled));
        assert_eq!(h.try_wait(), Some(Err(RequestError::Cancelled)));
        assert_eq!(h.try_wait(), Some(Err(RequestError::ResultTaken)));
    }

    #[test]
    fn first_fulfillment_wins() {
        let slot = Slot::new();
        let h = ResponseHandle::new(slot.clone(), 0);
        slot.fulfill(Ok(resp(1)));
        slot.fulfill(Err(RequestError::ServiceStopped));
        assert!(h.wait().is_ok());
    }

    #[test]
    fn cancel_sets_flag() {
        let slot = Slot::new();
        let h = ResponseHandle::new(slot.clone(), 0);
        assert!(!slot.is_cancelled());
        h.cancel();
        assert!(slot.is_cancelled());
    }
}

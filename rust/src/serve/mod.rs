//! The serving API (DESIGN.md §9) — the *only* public way to serve the
//! MoE++ stack.
//!
//! MoE++ makes per-token compute dynamic: zero-computation experts mean
//! "simple" tokens cost almost nothing while hard tokens pay for FFN
//! experts. A serving layer should therefore admit, batch and account for
//! requests continuously — not in the lock-step push/ready/next_batch
//! loop this crate used to expose. [`MoeService`] is that layer:
//!
//! * [`MoeService::submit`] admits a [`ServeRequest`] under bounded-queue
//!   backpressure ([`AdmissionError`] on overload) and returns a
//!   [`ResponseHandle`];
//! * a background scheduler thread runs a continuous-batching loop over
//!   the coordinator's [`Batcher`], honouring [`Priority`] classes,
//!   per-request queue deadlines and cancellation;
//! * every completion is a typed [`ServeResponse`] whose
//!   [`RequestStats`] slice the executing batch's `ForwardStats` down to
//!   *this* request's tokens — FFN vs zero/copy/constant assignments, the
//!   paper's "simple tokens are cheap" accounting observable per caller;
//! * [`ServeBackend`] decouples the service from execution: the same API
//!   fronts the single-process [`MoeEngine`] (native or PJRT) and the
//!   expert-parallel [`ClusterSim`], and is the plug-in point for future
//!   scaling backends.
//!
//! [`Batcher`]: crate::coordinator::batcher::Batcher
//! [`MoeEngine`]: crate::coordinator::engine::MoeEngine
//! [`ClusterSim`]: crate::cluster::sim::ClusterSim

pub mod backend;
pub mod handle;
pub mod service;

pub use backend::ServeBackend;
pub use handle::{
    RequestError, RequestStats, ResponseHandle, ServeResponse,
};
pub use service::{
    AdmissionError, MoeService, Priority, QueueDepth, ServeRequest,
    ServiceConfig,
};

//! The α–β placement cost model: score a [`PlacementPlan`] against an
//! observed [`LoadProfile`] without running the cluster.
//!
//! Per layer, the model charges every device
//! `compute_s_per_assignment / device_speed[d]` seconds per FFN
//! assignment it holds — compute cost is *seconds*, not FLOPs, so a
//! heterogeneous fleet (per-device `flops_per_s`) is planned correctly —
//! and prices the all-to-all with the same [`LinkModel`]/[`LayerTraffic`]
//! math the simulator uses, under a uniform-home assumption: a batch's
//! tokens are sharded evenly across devices, so `1/n_devices` of a
//! replica's slice is local and the rest arrives over the interconnect.
//! A multi-replica expert's load splits across its replicas with the
//! exact integral speed-weighted share ([`CostModel::device_share`],
//! built on [`weighted_share`] over [`speed_weight`]s) the runtime
//! dispatch uses, so the model and the simulator agree on per-device
//! work. Predicted makespan is `sum_l (max_d compute_d + comm_l)`.
//!
//! This is an *approximation* of [`SimReport::modeled_makespan`], not an
//! identity: the simulator charges comm for each token's actual
//! (contiguous-block) home rather than the uniform split, and a profile
//! aggregated over several batches bounds `sum_b max_d` by
//! `max_d sum_b` — so per-batch simulated figures can deviate a few
//! percent from the prediction even on the exact loads the profile was
//! captured from. Plan *comparisons* are what the model is for; the
//! never-worse planner guarantee is exact only under this model.
//!
//! [`SimReport::modeled_makespan`]: crate::cluster::sim::SimReport

use crate::cluster::comm::LayerTraffic;
use crate::cluster::topology::{LinkModel, Topology};
use crate::config::{MoeConfig, Precision};
use crate::moe::balance::load_cv;

use super::plan::{speed_weight, weighted_share, PlacementPlan};
use super::profile::LoadProfile;

/// Nominal FFN throughput of one simulated device. Only the *ratio* of
/// compute to comm matters for plan comparison; this pins the scale.
pub const DEVICE_FLOPS: f64 = 100e9;

/// What a (plan, profile) pair costs.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub link: LinkModel,
    /// Seconds of FFN compute per (token, expert) assignment.
    pub compute_s_per_assignment: f64,
    /// Bytes of one token's hidden state crossing a link (d_model * 4).
    pub token_bytes: u64,
    /// Bytes one expert slot costs a device **across the whole stack**:
    /// a plan's `owner[e]` applies to every layer, so placing (or
    /// migrating) expert `e` places `n_layers` per-layer weight copies.
    /// Memory budgets and migration pricing both use this stack-wide
    /// figure. Every *replica* occupies one slot of this size, and
    /// adding a replica is priced as one α–β transfer of it (drops are
    /// free — the source keeps its copy).
    pub expert_bytes: u64,
    /// Stack-wide bytes of one **int8** expert slot (codes + per-channel
    /// scales, × n_layers) — what a compressed replica charges a
    /// device's memory budget and what migrating one costs on the wire
    /// (DESIGN.md §17). Compute seconds stay precision-uniform (a host
    /// i32 MAC costs what an f32 MAC does): compression buys *bytes*,
    /// which buy replicas under the budget, which buy makespan.
    pub expert_bytes_int8: u64,
    /// Relative FFN throughput per device (`flops_per_s / DEVICE_FLOPS`).
    /// Empty means a uniform fleet: `speed(d)` of a missing device is
    /// 1.0, so the homogeneous model is the zero-config special case.
    pub device_speed: Vec<f64>,
}

impl CostModel {
    pub fn from_config(cfg: &MoeConfig) -> CostModel {
        CostModel {
            link: LinkModel::default(),
            compute_s_per_assignment: cfg.ffn_flops_per_token()
                / DEVICE_FLOPS,
            token_bytes: (cfg.d_model * 4) as u64,
            expert_bytes: cfg.ffn_expert_bytes()
                * cfg.n_layers.max(1) as u64,
            expert_bytes_int8: cfg
                .ffn_expert_bytes_at(Precision::Int8)
                * cfg.n_layers.max(1) as u64,
            device_speed: Vec::new(),
        }
    }

    /// Stack-wide slot bytes of an expert at precision `p` — the figure
    /// budgets charge per replica and migrations price per add.
    pub fn expert_bytes_for(&self, p: Precision) -> u64 {
        match p {
            Precision::F32 => self.expert_bytes,
            Precision::Int8 => self.expert_bytes_int8,
        }
    }

    /// Set per-device relative speeds (builder form).
    pub fn with_device_speeds(mut self, speeds: Vec<f64>) -> CostModel {
        assert!(
            speeds.iter().all(|&s| s > 0.0),
            "device speeds must be positive"
        );
        self.device_speed = speeds;
        self
    }

    /// Relative speed of device `d` (1.0 when unspecified).
    pub fn speed(&self, device: usize) -> f64 {
        self.device_speed.get(device).copied().unwrap_or(1.0)
    }

    /// Seconds of FFN compute per assignment *on device `d`*.
    pub fn compute_s_on(&self, device: usize) -> f64 {
        self.compute_s_per_assignment / self.speed(device)
    }

    /// α–β time to migrate `bytes` of expert weights between devices.
    pub fn migration_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.link.alpha_s + self.link.beta_s_per_byte * bytes as f64
    }

    /// Integer split weight of device `d` — the quantised relative
    /// speed the runtime dispatch feeds [`crate::placement::replica_slices`],
    /// shared here so planner shares match runtime slices exactly.
    pub fn replica_weight(&self, device: usize) -> u64 {
        speed_weight(self.speed(device))
    }

    /// Integral load share of replica `j` of the (sorted) replica device
    /// list `devs` under speed-weighted apportionment — exactly
    /// `replica_slices(load, weights)[j].len()` for the same devices.
    pub fn device_share(&self, load: u64, devs: &[usize], j: usize)
        -> u64 {
        let total: u64 =
            devs.iter().map(|&d| self.replica_weight(d)).sum();
        let prefix: u64 =
            devs[..j].iter().map(|&d| self.replica_weight(d)).sum();
        weighted_share(load, total, prefix, self.replica_weight(devs[j]))
    }

    /// Rounded uniform-home bytes of an integral assignment `share`. The
    /// single expression both [`CostModel::score`] and [`DeltaScorer`]
    /// price traffic with — shared so they stay bitwise-equal. For a
    /// single replica this reduces to the historical
    /// `round(load / n_dev * token_bytes)`.
    fn bytes_of_share(&self, share: u64, n_dev: usize) -> u64 {
        (share as f64 / n_dev as f64 * self.token_bytes as f64).round()
            as u64
    }

    /// [`Self::bytes_of_share`] of replica `j`'s [`Self::device_share`].
    fn share_bytes(&self, load: u64, devs: &[usize], j: usize,
        n_dev: usize) -> u64 {
        self.bytes_of_share(self.device_share(load, devs, j), n_dev)
    }

    /// Score `plan` against `profile` (accumulated over its batches).
    pub fn score(&self, plan: &PlacementPlan, profile: &LoadProfile)
        -> PlanScore {
        assert_eq!(
            plan.n_ffn_experts(),
            profile.n_ffn_experts(),
            "plan and profile expert counts differ"
        );
        let n_dev = plan.n_devices();
        let mut topo = Topology::new(n_dev);
        topo.link = self.link.clone();
        let mut score = PlanScore {
            device_assignments: vec![0; n_dev],
            ..PlanScore::default()
        };
        for l in 0..profile.n_layers() {
            let loads = profile.layer(l);
            let mut device_load = vec![0u64; n_dev];
            for (e, &load) in loads.iter().enumerate() {
                let reps = plan.replicas(e);
                for (j, &d) in reps.iter().enumerate() {
                    device_load[d] += self.device_share(load, reps, j);
                }
            }
            // Bottleneck device in *seconds*: a fast device absorbs more
            // assignments per wall-second. f64 max over device index
            // order — the identical fold `DeltaScorer` uses.
            let mut compute_s = 0.0f64;
            for (d, &load) in device_load.iter().enumerate() {
                compute_s = compute_s
                    .max(load as f64 * self.compute_s_on(d));
            }

            // Uniform-home all-to-all: each replica's slice of expert
            // e's load arrives evenly from every device; the 1/n_dev
            // share homed on the replica itself is local (diagonal,
            // free). Splitting a hot expert thus also splits its
            // incast: no single device receives the whole micro-batch.
            let mut traffic = LayerTraffic::new(n_dev);
            for (e, &load) in loads.iter().enumerate() {
                if load == 0 {
                    continue;
                }
                let reps = plan.replicas(e);
                for (j, &dev) in reps.iter().enumerate() {
                    let bytes = self.share_bytes(load, reps, j, n_dev);
                    if bytes == 0 {
                        continue;
                    }
                    for home in 0..n_dev {
                        if home != dev {
                            traffic.dispatch.add(home, dev, bytes);
                            traffic.combine.add(dev, home, bytes);
                        }
                    }
                }
            }
            let comm_s = traffic.total_time(&topo);
            let counts: Vec<usize> =
                device_load.iter().map(|&l| l as usize).collect();
            score.compute_s += compute_s;
            score.comm_s += comm_s;
            score.comm_bytes += traffic.total_bytes();
            score.makespan_s += compute_s + comm_s;
            score.load_cv_sum += load_cv(&counts);
            score.layers += 1;
            for (acc, c) in
                score.device_assignments.iter_mut().zip(&counts)
            {
                *acc += c;
            }
        }
        score
    }
}

// ---------------------------------------------------------- delta score

/// A candidate local-search step over a (possibly replicated) plan.
///
/// `Move`/`Swap` reassign *single-replica* experts — the historical
/// owner-map moves; the planner never proposes them for a replicated
/// expert (it drops replicas first). `Replicate`/`Drop` grow or shrink
/// one expert's replica set by one device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Edit {
    /// Move single-replica `expert` to device `to`.
    Move { expert: usize, to: usize },
    /// Swap the owners of single-replica experts `a` and `b`.
    Swap { a: usize, b: usize },
    /// Add a replica of `expert` on device `on`.
    Replicate { expert: usize, on: usize },
    /// Drop `expert`'s replica on device `on` (not its last).
    Drop { expert: usize, on: usize },
}

/// Incremental rescoring for the planner's local search (the ROADMAP
/// "incremental plan scoring" follow-on): a candidate [`Edit`] only
/// changes the contributions of one or two experts, so it is evaluated
/// from maintained per-layer, per-device aggregates instead of
/// re-walking every expert.
///
/// **Exactness.** All maintained state is integral (u64 loads, u64 share
/// bytes); every evaluation re-derives the float makespan from those
/// integers with the same expressions, in the same layer order,
/// [`CostModel::score`] uses — the compute term is the identical f64 max
/// fold of `load_d × compute_s_on(d)` over device index order, the
/// uniform-home traffic matrix has `dispatch[h][d] = combine[d][h] = B_d`
/// (the byte total of device `d`'s resident replica slices) for
/// `h != d`, and u64 sums are order-independent. Replica-set changes
/// re-split an expert's load, so an edit's per-device delta subtracts
/// the expert's speed-weighted [`CostModel::device_share`] contributions
/// under the old set and adds them under the new set — weighted prefix
/// sums over the sorted set, no allocation per evaluation. So `eval`
/// equals a full `score()` of
/// the mutated plan **bitwise**, which the planner property test pins
/// down across moves, swaps, replications and drops.
pub struct DeltaScorer<'a> {
    cost: &'a CostModel,
    profile: &'a LoadProfile,
    plan: PlacementPlan,
    topo: Topology,
    /// `device_load[l][d]` — FFN assignment shares resident on device
    /// `d` in layer `l` (replica slices, not whole experts).
    device_load: Vec<Vec<u64>>,
    /// `device_bytes[l][d]` — uniform-home share bytes of `d`'s slices.
    device_bytes: Vec<Vec<u64>>,
    /// Scratch traffic matrix reused across evaluations.
    scratch: LayerTraffic,
}

impl<'a> DeltaScorer<'a> {
    pub fn new(
        cost: &'a CostModel,
        profile: &'a LoadProfile,
        plan: PlacementPlan,
    ) -> DeltaScorer<'a> {
        assert_eq!(
            plan.n_ffn_experts(),
            profile.n_ffn_experts(),
            "plan and profile expert counts differ"
        );
        let n_dev = plan.n_devices();
        let mut topo = Topology::new(n_dev);
        topo.link = cost.link.clone();
        let n_layers = profile.n_layers();
        let mut device_load = vec![vec![0u64; n_dev]; n_layers];
        let mut device_bytes = vec![vec![0u64; n_dev]; n_layers];
        for l in 0..n_layers {
            for (e, &load) in profile.layer(l).iter().enumerate() {
                let reps = plan.replicas(e);
                for (j, &d) in reps.iter().enumerate() {
                    device_load[l][d] += cost.device_share(load, reps, j);
                    if load > 0 {
                        device_bytes[l][d] +=
                            cost.share_bytes(load, reps, j, n_dev);
                    }
                }
            }
        }
        DeltaScorer {
            cost,
            profile,
            plan,
            topo,
            device_load,
            device_bytes,
            scratch: LayerTraffic::new(n_dev),
        }
    }

    pub fn plan(&self) -> &PlacementPlan {
        &self.plan
    }

    pub fn into_plan(self) -> PlacementPlan {
        self.plan
    }

    pub fn device_counts(&self) -> Vec<usize> {
        self.plan.device_counts()
    }

    /// Current plan's makespan — bitwise equal to
    /// `cost.score(&plan, profile).makespan_s`.
    pub fn makespan(&mut self) -> f64 {
        self.makespan_with(&[])
    }

    /// Makespan if `edit` were committed (state unchanged).
    pub fn eval(&mut self, edit: Edit) -> f64 {
        match edit {
            Edit::Swap { a, b } => self.eval_swap(a, b),
            e => self.makespan_with(&[e]),
        }
    }

    /// Makespan if `expert` moved to device `to` (state unchanged).
    pub fn eval_move(&mut self, expert: usize, to: usize) -> f64 {
        self.makespan_with(&[Edit::Move { expert, to }])
    }

    /// Makespan if experts `a` and `b` swapped owners (state unchanged).
    pub fn eval_swap(&mut self, a: usize, b: usize) -> f64 {
        let (da, db) = (self.plan.owner(a), self.plan.owner(b));
        self.makespan_with(&[
            Edit::Move { expert: a, to: db },
            Edit::Move { expert: b, to: da },
        ])
    }

    /// Commit `edit`, updating the integral aggregates exactly.
    pub fn apply(&mut self, edit: Edit) {
        match edit {
            Edit::Move { expert, to } => self.apply_move(expert, to),
            Edit::Swap { a, b } => self.apply_swap(a, b),
            Edit::Replicate { expert, on } => {
                let old = self.plan.replicas(expert).to_vec();
                if old.contains(&on) {
                    return;
                }
                self.plan.add_replica(expert, on);
                let new = self.plan.replicas(expert).to_vec();
                self.reindex_expert(expert, &old, &new);
            }
            Edit::Drop { expert, on } => {
                let old = self.plan.replicas(expert).to_vec();
                self.plan.remove_replica(expert, on);
                let new = self.plan.replicas(expert).to_vec();
                self.reindex_expert(expert, &old, &new);
            }
        }
    }

    /// Commit a move of single-replica `expert` to `to`.
    pub fn apply_move(&mut self, expert: usize, to: usize) {
        assert_eq!(
            self.plan.replica_count(expert),
            1,
            "move applies to single-replica experts only"
        );
        let from = self.plan.owner(expert);
        if from == to {
            return;
        }
        let old = [from];
        let new = [to];
        self.plan.set_owner(expert, to);
        self.reindex_expert(expert, &old, &new);
    }

    /// Commit a swap of `a` and `b`'s owners.
    pub fn apply_swap(&mut self, a: usize, b: usize) {
        let (da, db) = (self.plan.owner(a), self.plan.owner(b));
        self.apply_move(a, db);
        self.apply_move(b, da);
    }

    /// Exactly transfer `expert`'s per-device contributions from replica
    /// set `old` to replica set `new` in every layer's aggregates.
    fn reindex_expert(
        &mut self,
        expert: usize,
        old: &[usize],
        new: &[usize],
    ) {
        let n_dev = self.plan.n_devices();
        for l in 0..self.device_load.len() {
            let load = self.profile.layer(l)[expert];
            for (j, &d) in old.iter().enumerate() {
                self.device_load[l][d] -=
                    self.cost.device_share(load, old, j);
                if load > 0 {
                    self.device_bytes[l][d] -=
                        self.cost.share_bytes(load, old, j, n_dev);
                }
            }
            for (j, &d) in new.iter().enumerate() {
                self.device_load[l][d] +=
                    self.cost.device_share(load, new, j);
                if load > 0 {
                    self.device_bytes[l][d] +=
                        self.cost.share_bytes(load, new, j, n_dev);
                }
            }
        }
    }

    /// `expert`'s hypothetical (load, bytes) contribution delta on
    /// device `dv` in layer `l` if `edit` were applied — weighted prefix
    /// sums over the sorted replica set, no allocation. `Swap` is
    /// expanded into two `Move`s before reaching here.
    fn edit_delta(&self, l: usize, edit: Edit, dv: usize) -> (i64, i64) {
        let n_dev = self.plan.n_devices();
        let (expert, reps) = match edit {
            Edit::Move { expert, .. }
            | Edit::Replicate { expert, .. }
            | Edit::Drop { expert, .. } => {
                (expert, self.plan.replicas(expert))
            }
            Edit::Swap { .. } => {
                unreachable!("swap is expanded into moves")
            }
        };
        let load = self.profile.layer(l)[expert];
        let wt = |d: usize| self.cost.replica_weight(d);
        // Weight of the current set's first `k` replicas / whole set.
        let prefix_w =
            |k: usize| -> u64 { reps[..k].iter().map(|&d| wt(d)).sum() };
        let total_cur = prefix_w(reps.len());
        // (share, bytes) of a replica weighing `w` after `prefix` of
        // `total` in a hypothetical enumeration — the same
        // `weighted_share` the aggregates were built from.
        let contrib = |total: u64, prefix: u64, w: u64| -> (i64, i64) {
            let share = weighted_share(load, total, prefix, w);
            let bytes = if load > 0 {
                self.cost.bytes_of_share(share, n_dev) as i64
            } else {
                0
            };
            (share as i64, bytes)
        };
        // Contribution `dv` currently receives from this expert.
        let old = match reps.binary_search(&dv) {
            Ok(j) => contrib(total_cur, prefix_w(j), wt(dv)),
            Err(_) => (0, 0),
        };
        // Contribution `dv` would receive under the edited replica set.
        let new = match edit {
            Edit::Move { to, .. } => {
                debug_assert_eq!(reps.len(), 1);
                if dv == to {
                    contrib(wt(to), 0, wt(to))
                } else {
                    (0, 0)
                }
            }
            Edit::Replicate { on, .. } => {
                match reps.binary_search(&on) {
                    Ok(_) => old, // already present: no-op edit
                    Err(p) => {
                        let total = total_cur + wt(on);
                        if dv == on {
                            contrib(total, prefix_w(p), wt(on))
                        } else {
                            match reps.binary_search(&dv) {
                                // `on` slots in at p: replicas past it
                                // gain its weight in their prefix.
                                Ok(j) if j < p => {
                                    contrib(total, prefix_w(j), wt(dv))
                                }
                                Ok(j) => contrib(
                                    total,
                                    prefix_w(j) + wt(on),
                                    wt(dv),
                                ),
                                Err(_) => (0, 0),
                            }
                        }
                    }
                }
            }
            Edit::Drop { on, .. } => {
                let p = reps
                    .binary_search(&on)
                    .expect("dropping a replica that does not exist");
                debug_assert!(
                    reps.len() > 1,
                    "cannot drop the last replica"
                );
                let total = total_cur - wt(on);
                if dv == on {
                    (0, 0)
                } else {
                    match reps.binary_search(&dv) {
                        Ok(j) if j < p => {
                            contrib(total, prefix_w(j), wt(dv))
                        }
                        Ok(j) => contrib(
                            total,
                            prefix_w(j) - wt(on),
                            wt(dv),
                        ),
                        Err(_) => (0, 0),
                    }
                }
            }
            Edit::Swap { .. } => unreachable!(),
        };
        (new.0 - old.0, new.1 - old.1)
    }

    /// Makespan of the current plan with up to two hypothetical edits
    /// applied on the fly (owners read *before* any edit, which is what
    /// the swap expansion relies on).
    fn makespan_with(&mut self, edits: &[Edit]) -> f64 {
        let n_dev = self.plan.n_devices();
        let mut total = 0.0;
        for l in 0..self.device_load.len() {
            let mut compute_s = 0.0f64;
            for dv in 0..n_dev {
                let mut load = self.device_load[l][dv] as i64;
                for &edit in edits {
                    load += self.edit_delta(l, edit, dv).0;
                }
                debug_assert!(load >= 0);
                compute_s = compute_s
                    .max(load as u64 as f64 * self.cost.compute_s_on(dv));
            }

            self.scratch.clear();
            for dv in 0..n_dev {
                let mut bytes = self.device_bytes[l][dv] as i64;
                for &edit in edits {
                    bytes += self.edit_delta(l, edit, dv).1;
                }
                debug_assert!(bytes >= 0);
                let bytes = bytes as u64;
                if bytes == 0 {
                    continue;
                }
                for h in 0..n_dev {
                    if h != dv {
                        self.scratch.dispatch.add(h, dv, bytes);
                        self.scratch.combine.add(dv, h, bytes);
                    }
                }
            }
            let comm_s = self.scratch.total_time(&self.topo);
            total += compute_s + comm_s;
        }
        total
    }
}

/// Predicted cost of one plan over one profile.
#[derive(Clone, Debug, Default)]
pub struct PlanScore {
    /// `sum_l (max-device compute + comm)` — the objective the planner
    /// minimises.
    pub makespan_s: f64,
    /// Bottleneck-device compute summed over layers.
    pub compute_s: f64,
    /// Analytic all-to-all time summed over layers.
    pub comm_s: f64,
    /// Predicted off-device bytes (dispatch + combine).
    pub comm_bytes: u64,
    /// Aggregate FFN assignments per device (all layers).
    pub device_assignments: Vec<usize>,
    load_cv_sum: f64,
    layers: usize,
}

impl PlanScore {
    /// Mean per-layer coefficient of variation of device load.
    pub fn mean_load_cv(&self) -> f64 {
        if self.layers == 0 {
            0.0
        } else {
            self.load_cv_sum / self.layers as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::from_config(&MoeConfig::preset("test"))
    }

    #[test]
    fn balanced_plan_scores_below_collapsed_plan() {
        let profile = LoadProfile::from_counts(vec![vec![100, 100, 0, 0]])
            .unwrap();
        let cost = model();
        let collapsed =
            PlacementPlan::from_owner(vec![0, 0, 1, 1], 2).unwrap();
        let spread =
            PlacementPlan::from_owner(vec![0, 1, 0, 1], 2).unwrap();
        let s_col = cost.score(&collapsed, &profile);
        let s_spr = cost.score(&spread, &profile);
        assert!(s_spr.makespan_s < s_col.makespan_s,
                "{} vs {}", s_spr.makespan_s, s_col.makespan_s);
        assert!(s_spr.mean_load_cv() < s_col.mean_load_cv());
        // Collapsed: device 0 computes all 200 assignments.
        assert_eq!(s_col.device_assignments, vec![200, 0]);
        assert_eq!(s_spr.device_assignments, vec![100, 100]);
    }

    #[test]
    fn single_device_has_no_comm() {
        let profile =
            LoadProfile::from_counts(vec![vec![10, 20], vec![5, 5]])
                .unwrap();
        let cost = model();
        let plan = PlacementPlan::round_robin(2, 1);
        let s = cost.score(&plan, &profile);
        assert_eq!(s.comm_bytes, 0);
        assert_eq!(s.comm_s, 0.0);
        assert!(s.makespan_s > 0.0);
        assert_eq!(s.mean_load_cv(), 0.0);
    }

    #[test]
    fn makespan_is_compute_plus_comm() {
        let profile =
            LoadProfile::from_counts(vec![vec![8, 4], vec![2, 2]]).unwrap();
        let cost = model();
        let plan = PlacementPlan::round_robin(2, 2);
        let s = cost.score(&plan, &profile);
        assert!((s.makespan_s - (s.compute_s + s.comm_s)).abs() < 1e-15);
    }

    #[test]
    fn migration_time_is_alpha_beta() {
        let cost = model();
        assert_eq!(cost.migration_s(0), 0.0);
        let want = cost.link.alpha_s + cost.link.beta_s_per_byte * 1e6;
        assert!((cost.migration_s(1_000_000) - want).abs() < 1e-15);
    }

    #[test]
    fn per_precision_slot_bytes_track_config() {
        let cfg = MoeConfig::preset("test");
        let cost = model();
        let n_l = cfg.n_layers as u64;
        assert_eq!(
            cost.expert_bytes_for(Precision::F32),
            cfg.ffn_expert_bytes() * n_l
        );
        assert_eq!(
            cost.expert_bytes_for(Precision::Int8),
            cfg.ffn_expert_bytes_at(Precision::Int8) * n_l
        );
        assert!(cost.expert_bytes_int8 < cost.expert_bytes);
        // Scoring is precision-blind: the same replica layout scores
        // identically whatever the plan's precision map says (compute
        // seconds are uniform across precisions; bytes only gate
        // budgets and migrations).
        let profile =
            LoadProfile::from_counts(vec![vec![50, 10, 10, 10]]).unwrap();
        let plan = PlacementPlan::round_robin(4, 2);
        let mut quantized = plan.clone();
        quantized.set_precision(0, Precision::Int8);
        let a = cost.score(&plan, &profile);
        let b = cost.score(&quantized, &profile);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.comm_bytes, b.comm_bytes);
    }

    #[test]
    fn heterogeneous_fleet_loads_fast_device_proportionally_more() {
        // ISSUE 6 acceptance: one device with 2× flops_per_s. Four
        // equal-load experts on 2 devices: in *seconds*, the 3/1 split
        // onto the fast device beats the FLOP-balanced 2/2 split
        // (150·c vs 200·c compute), so a seconds-aware model must prefer
        // it and must load the fast device strictly more.
        let profile =
            LoadProfile::from_counts(vec![vec![100, 100, 100, 100]])
                .unwrap();
        let cost = model().with_device_speeds(vec![2.0, 1.0]);
        assert_eq!(cost.speed(0), 2.0);
        assert_eq!(cost.speed(1), 1.0);
        assert_eq!(cost.speed(7), 1.0, "missing devices default to 1.0");
        assert!(
            (cost.compute_s_on(0) - cost.compute_s_per_assignment / 2.0)
                .abs()
                < 1e-18
        );
        let fast_heavy =
            PlacementPlan::from_owner(vec![0, 0, 0, 1], 2).unwrap();
        let flop_balanced =
            PlacementPlan::from_owner(vec![0, 1, 0, 1], 2).unwrap();
        let s_fast = cost.score(&fast_heavy, &profile);
        let s_bal = cost.score(&flop_balanced, &profile);
        assert!(
            s_fast.compute_s < s_bal.compute_s,
            "{} vs {}",
            s_fast.compute_s,
            s_bal.compute_s
        );
        assert!(s_fast.makespan_s < s_bal.makespan_s);
        assert_eq!(s_fast.device_assignments, vec![300, 100]);
        assert!(
            s_fast.device_assignments[0] > s_fast.device_assignments[1],
            "fast device must hold proportionally more load"
        );
        // A uniform fleet still prefers the balanced split.
        let uniform = model();
        assert!(
            uniform.score(&flop_balanced, &profile).makespan_s
                < uniform.score(&fast_heavy, &profile).makespan_s
        );
    }

    #[test]
    fn replicating_a_hot_expert_splits_its_load_and_cost() {
        // One hot expert, two devices: replicating it halves the
        // bottleneck compute (the model charges integral replica_share
        // splits) and splits the incast across both replicas.
        let profile =
            LoadProfile::from_counts(vec![vec![100, 0, 0, 0]]).unwrap();
        let cost = model();
        let single = PlacementPlan::round_robin(4, 2);
        let mut replicated = single.clone();
        replicated.add_replica(0, 1);
        let s_one = cost.score(&single, &profile);
        let s_two = cost.score(&replicated, &profile);
        assert_eq!(s_one.device_assignments, vec![100, 0]);
        assert_eq!(s_two.device_assignments, vec![50, 50]);
        assert!(
            s_two.makespan_s < s_one.makespan_s,
            "{} vs {}",
            s_two.makespan_s,
            s_one.makespan_s
        );
        assert!(s_two.compute_s < s_one.compute_s);
    }

    #[test]
    fn replica_split_is_speed_weighted() {
        // A 3× device holding one of two replicas takes 3/4 of the hot
        // expert's load — the model mirrors the runtime's weighted split.
        let profile =
            LoadProfile::from_counts(vec![vec![100, 0, 0, 0]]).unwrap();
        let cost = model().with_device_speeds(vec![3.0, 1.0]);
        let mut replicated = PlacementPlan::round_robin(4, 2);
        replicated.add_replica(0, 1);
        let s = cost.score(&replicated, &profile);
        assert_eq!(s.device_assignments, vec![75, 25]);
        assert_eq!(cost.device_share(100, &[0, 1], 0), 75);
        assert_eq!(cost.device_share(100, &[0, 1], 1), 25);
    }

    #[test]
    fn delta_scorer_replica_edits_match_full_rescore_bitwise() {
        let profile = LoadProfile::from_counts(vec![
            vec![40, 7, 0, 13, 100, 3],
            vec![0, 21, 9, 2, 55, 55],
        ])
        .unwrap();
        let cost = model().with_device_speeds(vec![2.0, 1.0, 1.0]);
        let plan = PlacementPlan::round_robin(6, 3);
        let mut ds = DeltaScorer::new(&cost, &profile, plan.clone());
        assert_eq!(ds.makespan(), cost.score(&plan, &profile).makespan_s);
        let edits = [
            Edit::Replicate { expert: 4, on: 0 },
            Edit::Replicate { expert: 4, on: 2 },
            Edit::Move { expert: 3, to: 2 },
            Edit::Drop { expert: 4, on: 1 },
            Edit::Swap { a: 0, b: 5 },
            Edit::Replicate { expert: 5, on: 1 },
        ];
        for edit in edits {
            // eval must predict the post-edit full rescore bitwise,
            // and apply must land the state exactly there.
            let predicted = ds.eval(edit);
            ds.apply(edit);
            let full =
                cost.score(ds.plan(), &profile).makespan_s;
            assert_eq!(predicted, full, "eval diverged on {edit:?}");
            assert_eq!(ds.makespan(), full, "state diverged on {edit:?}");
        }
        assert_eq!(ds.plan().replicas(4), &[0, 2]);
        assert_eq!(ds.plan().replicas(5), &[0, 1]);
    }
}

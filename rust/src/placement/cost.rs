//! The α–β placement cost model: score a [`PlacementPlan`] against an
//! observed [`LoadProfile`] without running the cluster.
//!
//! Per layer, the model charges every device `compute_s_per_assignment`
//! seconds per FFN assignment it owns, and prices the all-to-all with the
//! same [`LinkModel`]/[`LayerTraffic`] math the simulator uses, under a
//! uniform-home assumption: a batch's tokens are sharded evenly across
//! devices, so `1/n_devices` of an expert's load is local and the rest
//! arrives over the interconnect. Predicted makespan is
//! `sum_l (max_d compute_d + comm_l)`.
//!
//! This is an *approximation* of [`SimReport::modeled_makespan`], not an
//! identity: the simulator charges comm for each token's actual
//! (contiguous-block) home rather than the uniform split, and a profile
//! aggregated over several batches bounds `sum_b max_d` by
//! `max_d sum_b` — so per-batch simulated figures can deviate a few
//! percent from the prediction even on the exact loads the profile was
//! captured from. Plan *comparisons* are what the model is for; the
//! never-worse planner guarantee is exact only under this model.
//!
//! [`SimReport::modeled_makespan`]: crate::cluster::sim::SimReport

use crate::cluster::comm::LayerTraffic;
use crate::cluster::topology::{LinkModel, Topology};
use crate::config::MoeConfig;
use crate::moe::balance::load_cv;

use super::plan::PlacementPlan;
use super::profile::LoadProfile;

/// Nominal FFN throughput of one simulated device. Only the *ratio* of
/// compute to comm matters for plan comparison; this pins the scale.
pub const DEVICE_FLOPS: f64 = 100e9;

/// What a (plan, profile) pair costs.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub link: LinkModel,
    /// Seconds of FFN compute per (token, expert) assignment.
    pub compute_s_per_assignment: f64,
    /// Bytes of one token's hidden state crossing a link (d_model * 4).
    pub token_bytes: u64,
    /// Bytes one expert slot costs a device **across the whole stack**:
    /// a plan's `owner[e]` applies to every layer, so placing (or
    /// migrating) expert `e` places `n_layers` per-layer weight copies.
    /// Memory budgets and migration pricing both use this stack-wide
    /// figure.
    pub expert_bytes: u64,
}

impl CostModel {
    pub fn from_config(cfg: &MoeConfig) -> CostModel {
        CostModel {
            link: LinkModel::default(),
            compute_s_per_assignment: cfg.ffn_flops_per_token()
                / DEVICE_FLOPS,
            token_bytes: (cfg.d_model * 4) as u64,
            expert_bytes: cfg.ffn_expert_bytes()
                * cfg.n_layers.max(1) as u64,
        }
    }

    /// α–β time to migrate `bytes` of expert weights between devices.
    pub fn migration_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.link.alpha_s + self.link.beta_s_per_byte * bytes as f64
    }

    /// Score `plan` against `profile` (accumulated over its batches).
    pub fn score(&self, plan: &PlacementPlan, profile: &LoadProfile)
        -> PlanScore {
        assert_eq!(
            plan.n_ffn_experts(),
            profile.n_ffn_experts(),
            "plan and profile expert counts differ"
        );
        let n_dev = plan.n_devices();
        let mut topo = Topology::new(n_dev);
        topo.link = self.link.clone();
        let mut score = PlanScore {
            device_assignments: vec![0; n_dev],
            ..PlanScore::default()
        };
        for l in 0..profile.n_layers() {
            let loads = profile.layer(l);
            let mut device_load = vec![0u64; n_dev];
            for (e, &load) in loads.iter().enumerate() {
                device_load[plan.owner(e)] += load;
            }
            let max_load =
                device_load.iter().copied().max().unwrap_or(0);
            let compute_s =
                max_load as f64 * self.compute_s_per_assignment;

            // Uniform-home all-to-all: expert e's load arrives evenly
            // from every device; the 1/n_dev share homed on the owner is
            // local (diagonal, free).
            let mut traffic = LayerTraffic::new(n_dev);
            for (e, &load) in loads.iter().enumerate() {
                if load == 0 {
                    continue;
                }
                let owner = plan.owner(e);
                let share = load as f64 / n_dev as f64;
                let bytes =
                    (share * self.token_bytes as f64).round() as u64;
                if bytes == 0 {
                    continue;
                }
                for home in 0..n_dev {
                    if home != owner {
                        traffic.dispatch.add(home, owner, bytes);
                        traffic.combine.add(owner, home, bytes);
                    }
                }
            }
            let comm_s = traffic.total_time(&topo);
            let counts: Vec<usize> =
                device_load.iter().map(|&l| l as usize).collect();
            score.compute_s += compute_s;
            score.comm_s += comm_s;
            score.comm_bytes += traffic.total_bytes();
            score.makespan_s += compute_s + comm_s;
            score.load_cv_sum += load_cv(&counts);
            score.layers += 1;
            for (acc, c) in
                score.device_assignments.iter_mut().zip(&counts)
            {
                *acc += c;
            }
        }
        score
    }
}

/// Predicted cost of one plan over one profile.
#[derive(Clone, Debug, Default)]
pub struct PlanScore {
    /// `sum_l (max-device compute + comm)` — the objective the planner
    /// minimises.
    pub makespan_s: f64,
    /// Bottleneck-device compute summed over layers.
    pub compute_s: f64,
    /// Analytic all-to-all time summed over layers.
    pub comm_s: f64,
    /// Predicted off-device bytes (dispatch + combine).
    pub comm_bytes: u64,
    /// Aggregate FFN assignments per device (all layers).
    pub device_assignments: Vec<usize>,
    load_cv_sum: f64,
    layers: usize,
}

impl PlanScore {
    /// Mean per-layer coefficient of variation of device load.
    pub fn mean_load_cv(&self) -> f64 {
        if self.layers == 0 {
            0.0
        } else {
            self.load_cv_sum / self.layers as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::from_config(&MoeConfig::preset("test"))
    }

    #[test]
    fn balanced_plan_scores_below_collapsed_plan() {
        let profile = LoadProfile::from_counts(vec![vec![100, 100, 0, 0]])
            .unwrap();
        let cost = model();
        let collapsed =
            PlacementPlan::from_owner(vec![0, 0, 1, 1], 2).unwrap();
        let spread =
            PlacementPlan::from_owner(vec![0, 1, 0, 1], 2).unwrap();
        let s_col = cost.score(&collapsed, &profile);
        let s_spr = cost.score(&spread, &profile);
        assert!(s_spr.makespan_s < s_col.makespan_s,
                "{} vs {}", s_spr.makespan_s, s_col.makespan_s);
        assert!(s_spr.mean_load_cv() < s_col.mean_load_cv());
        // Collapsed: device 0 computes all 200 assignments.
        assert_eq!(s_col.device_assignments, vec![200, 0]);
        assert_eq!(s_spr.device_assignments, vec![100, 100]);
    }

    #[test]
    fn single_device_has_no_comm() {
        let profile =
            LoadProfile::from_counts(vec![vec![10, 20], vec![5, 5]])
                .unwrap();
        let cost = model();
        let plan = PlacementPlan::round_robin(2, 1);
        let s = cost.score(&plan, &profile);
        assert_eq!(s.comm_bytes, 0);
        assert_eq!(s.comm_s, 0.0);
        assert!(s.makespan_s > 0.0);
        assert_eq!(s.mean_load_cv(), 0.0);
    }

    #[test]
    fn makespan_is_compute_plus_comm() {
        let profile =
            LoadProfile::from_counts(vec![vec![8, 4], vec![2, 2]]).unwrap();
        let cost = model();
        let plan = PlacementPlan::round_robin(2, 2);
        let s = cost.score(&plan, &profile);
        assert!((s.makespan_s - (s.compute_s + s.comm_s)).abs() < 1e-15);
    }

    #[test]
    fn migration_time_is_alpha_beta() {
        let cost = model();
        assert_eq!(cost.migration_s(0), 0.0);
        let want = cost.link.alpha_s + cost.link.beta_s_per_byte * 1e6;
        assert!((cost.migration_s(1_000_000) - want).abs() < 1e-15);
    }
}

//! The α–β placement cost model: score a [`PlacementPlan`] against an
//! observed [`LoadProfile`] without running the cluster.
//!
//! Per layer, the model charges every device `compute_s_per_assignment`
//! seconds per FFN assignment it owns, and prices the all-to-all with the
//! same [`LinkModel`]/[`LayerTraffic`] math the simulator uses, under a
//! uniform-home assumption: a batch's tokens are sharded evenly across
//! devices, so `1/n_devices` of an expert's load is local and the rest
//! arrives over the interconnect. Predicted makespan is
//! `sum_l (max_d compute_d + comm_l)`.
//!
//! This is an *approximation* of [`SimReport::modeled_makespan`], not an
//! identity: the simulator charges comm for each token's actual
//! (contiguous-block) home rather than the uniform split, and a profile
//! aggregated over several batches bounds `sum_b max_d` by
//! `max_d sum_b` — so per-batch simulated figures can deviate a few
//! percent from the prediction even on the exact loads the profile was
//! captured from. Plan *comparisons* are what the model is for; the
//! never-worse planner guarantee is exact only under this model.
//!
//! [`SimReport::modeled_makespan`]: crate::cluster::sim::SimReport

use crate::cluster::comm::LayerTraffic;
use crate::cluster::topology::{LinkModel, Topology};
use crate::config::MoeConfig;
use crate::moe::balance::load_cv;

use super::plan::PlacementPlan;
use super::profile::LoadProfile;

/// Nominal FFN throughput of one simulated device. Only the *ratio* of
/// compute to comm matters for plan comparison; this pins the scale.
pub const DEVICE_FLOPS: f64 = 100e9;

/// What a (plan, profile) pair costs.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub link: LinkModel,
    /// Seconds of FFN compute per (token, expert) assignment.
    pub compute_s_per_assignment: f64,
    /// Bytes of one token's hidden state crossing a link (d_model * 4).
    pub token_bytes: u64,
    /// Bytes one expert slot costs a device **across the whole stack**:
    /// a plan's `owner[e]` applies to every layer, so placing (or
    /// migrating) expert `e` places `n_layers` per-layer weight copies.
    /// Memory budgets and migration pricing both use this stack-wide
    /// figure.
    pub expert_bytes: u64,
}

impl CostModel {
    pub fn from_config(cfg: &MoeConfig) -> CostModel {
        CostModel {
            link: LinkModel::default(),
            compute_s_per_assignment: cfg.ffn_flops_per_token()
                / DEVICE_FLOPS,
            token_bytes: (cfg.d_model * 4) as u64,
            expert_bytes: cfg.ffn_expert_bytes()
                * cfg.n_layers.max(1) as u64,
        }
    }

    /// α–β time to migrate `bytes` of expert weights between devices.
    pub fn migration_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.link.alpha_s + self.link.beta_s_per_byte * bytes as f64
    }

    /// Score `plan` against `profile` (accumulated over its batches).
    pub fn score(&self, plan: &PlacementPlan, profile: &LoadProfile)
        -> PlanScore {
        assert_eq!(
            plan.n_ffn_experts(),
            profile.n_ffn_experts(),
            "plan and profile expert counts differ"
        );
        let n_dev = plan.n_devices();
        let mut topo = Topology::new(n_dev);
        topo.link = self.link.clone();
        let mut score = PlanScore {
            device_assignments: vec![0; n_dev],
            ..PlanScore::default()
        };
        for l in 0..profile.n_layers() {
            let loads = profile.layer(l);
            let mut device_load = vec![0u64; n_dev];
            for (e, &load) in loads.iter().enumerate() {
                device_load[plan.owner(e)] += load;
            }
            let max_load =
                device_load.iter().copied().max().unwrap_or(0);
            let compute_s =
                max_load as f64 * self.compute_s_per_assignment;

            // Uniform-home all-to-all: expert e's load arrives evenly
            // from every device; the 1/n_dev share homed on the owner is
            // local (diagonal, free).
            let mut traffic = LayerTraffic::new(n_dev);
            for (e, &load) in loads.iter().enumerate() {
                if load == 0 {
                    continue;
                }
                let owner = plan.owner(e);
                let share = load as f64 / n_dev as f64;
                let bytes =
                    (share * self.token_bytes as f64).round() as u64;
                if bytes == 0 {
                    continue;
                }
                for home in 0..n_dev {
                    if home != owner {
                        traffic.dispatch.add(home, owner, bytes);
                        traffic.combine.add(owner, home, bytes);
                    }
                }
            }
            let comm_s = traffic.total_time(&topo);
            let counts: Vec<usize> =
                device_load.iter().map(|&l| l as usize).collect();
            score.compute_s += compute_s;
            score.comm_s += comm_s;
            score.comm_bytes += traffic.total_bytes();
            score.makespan_s += compute_s + comm_s;
            score.load_cv_sum += load_cv(&counts);
            score.layers += 1;
            for (acc, c) in
                score.device_assignments.iter_mut().zip(&counts)
            {
                *acc += c;
            }
        }
        score
    }
}

// ---------------------------------------------------------- delta score

/// Incremental rescoring for the planner's local search (the ROADMAP
/// "incremental plan scoring" follow-on): a single-expert move (or a
/// pairwise swap) only changes two devices' compute and the moved
/// experts' traffic, so candidates are evaluated from maintained
/// per-layer, per-device aggregates instead of re-walking every expert.
///
/// **Exactness.** All maintained state is integral (u64 loads, u64 share
/// bytes); every evaluation re-derives the float makespan from those
/// integers with the same expressions, in the same layer order,
/// [`CostModel::score`] uses — the uniform-home traffic matrix has
/// `dispatch[h][o] = combine[o][h] = B_o` (the byte total of device `o`'s
/// owned experts) for `h != o`, and u64 sums are order-independent. So
/// `eval_move`/`eval_swap` equal a full `score()` of the mutated plan
/// **bitwise**, which the planner property test pins down; the local
/// search therefore walks the identical trajectory the full-rescore
/// implementation did, only cheaper: O(D²) per candidate instead of
/// O(L·E + D²), with E·D + E² candidates per round.
pub struct DeltaScorer<'a> {
    cost: &'a CostModel,
    profile: &'a LoadProfile,
    plan: PlacementPlan,
    topo: Topology,
    /// `device_load[l][d]` — FFN assignments device `d` owns in layer `l`.
    device_load: Vec<Vec<u64>>,
    /// `device_bytes[l][d]` — uniform-home share bytes of `d`'s experts.
    device_bytes: Vec<Vec<u64>>,
    /// `expert_bytes[l][e]` — the rounded per-home share bytes of `e`.
    expert_bytes: Vec<Vec<u64>>,
    /// Scratch traffic matrix reused across evaluations.
    scratch: LayerTraffic,
}

impl<'a> DeltaScorer<'a> {
    pub fn new(
        cost: &'a CostModel,
        profile: &'a LoadProfile,
        plan: PlacementPlan,
    ) -> DeltaScorer<'a> {
        assert_eq!(
            plan.n_ffn_experts(),
            profile.n_ffn_experts(),
            "plan and profile expert counts differ"
        );
        let n_dev = plan.n_devices();
        let mut topo = Topology::new(n_dev);
        topo.link = cost.link.clone();
        let n_layers = profile.n_layers();
        let mut device_load = vec![vec![0u64; n_dev]; n_layers];
        let mut device_bytes = vec![vec![0u64; n_dev]; n_layers];
        let mut expert_bytes =
            vec![vec![0u64; profile.n_ffn_experts()]; n_layers];
        for l in 0..n_layers {
            for (e, &load) in profile.layer(l).iter().enumerate() {
                let owner = plan.owner(e);
                device_load[l][owner] += load;
                if load == 0 {
                    continue;
                }
                let share = load as f64 / n_dev as f64;
                let bytes =
                    (share * cost.token_bytes as f64).round() as u64;
                expert_bytes[l][e] = bytes;
                device_bytes[l][owner] += bytes;
            }
        }
        DeltaScorer {
            cost,
            profile,
            plan,
            topo,
            device_load,
            device_bytes,
            expert_bytes,
            scratch: LayerTraffic::new(n_dev),
        }
    }

    pub fn plan(&self) -> &PlacementPlan {
        &self.plan
    }

    pub fn into_plan(self) -> PlacementPlan {
        self.plan
    }

    pub fn device_counts(&self) -> Vec<usize> {
        self.plan.device_counts()
    }

    /// Current plan's makespan — bitwise equal to
    /// `cost.score(&plan, profile).makespan_s`.
    pub fn makespan(&mut self) -> f64 {
        self.makespan_with(&[])
    }

    /// Makespan if `expert` moved to device `to` (state unchanged).
    pub fn eval_move(&mut self, expert: usize, to: usize) -> f64 {
        self.makespan_with(&[(expert, to)])
    }

    /// Makespan if experts `a` and `b` swapped owners (state unchanged).
    pub fn eval_swap(&mut self, a: usize, b: usize) -> f64 {
        let (da, db) = (self.plan.owner(a), self.plan.owner(b));
        self.makespan_with(&[(a, db), (b, da)])
    }

    /// Commit a move, updating the integral aggregates exactly.
    pub fn apply_move(&mut self, expert: usize, to: usize) {
        let from = self.plan.owner(expert);
        if from == to {
            return;
        }
        for l in 0..self.device_load.len() {
            let load = self.profile.layer(l)[expert];
            self.device_load[l][from] -= load;
            self.device_load[l][to] += load;
            let bytes = self.expert_bytes[l][expert];
            self.device_bytes[l][from] -= bytes;
            self.device_bytes[l][to] += bytes;
        }
        self.plan.set_owner(expert, to);
    }

    /// Commit a swap of `a` and `b`'s owners.
    pub fn apply_swap(&mut self, a: usize, b: usize) {
        let (da, db) = (self.plan.owner(a), self.plan.owner(b));
        self.apply_move(a, db);
        self.apply_move(b, da);
    }

    /// Makespan of the current plan with up to two hypothetical
    /// reassignments applied on the fly (owners read *before* any of the
    /// moves, which is what `eval_swap` relies on).
    fn makespan_with(&mut self, moves: &[(usize, usize)]) -> f64 {
        let n_dev = self.plan.n_devices();
        let mut total = 0.0;
        for l in 0..self.device_load.len() {
            let mut max_load = 0u64;
            for dv in 0..n_dev {
                let mut load = self.device_load[l][dv];
                for &(e, to) in moves {
                    let from = self.plan.owner(e);
                    if to == from {
                        continue;
                    }
                    if dv == from {
                        load -= self.profile.layer(l)[e];
                    }
                    if dv == to {
                        load += self.profile.layer(l)[e];
                    }
                }
                max_load = max_load.max(load);
            }
            let compute_s =
                max_load as f64 * self.cost.compute_s_per_assignment;

            self.scratch.clear();
            for o in 0..n_dev {
                let mut bytes = self.device_bytes[l][o];
                for &(e, to) in moves {
                    let from = self.plan.owner(e);
                    if to == from {
                        continue;
                    }
                    if o == from {
                        bytes -= self.expert_bytes[l][e];
                    }
                    if o == to {
                        bytes += self.expert_bytes[l][e];
                    }
                }
                if bytes == 0 {
                    continue;
                }
                for h in 0..n_dev {
                    if h != o {
                        self.scratch.dispatch.add(h, o, bytes);
                        self.scratch.combine.add(o, h, bytes);
                    }
                }
            }
            let comm_s = self.scratch.total_time(&self.topo);
            total += compute_s + comm_s;
        }
        total
    }
}

/// Predicted cost of one plan over one profile.
#[derive(Clone, Debug, Default)]
pub struct PlanScore {
    /// `sum_l (max-device compute + comm)` — the objective the planner
    /// minimises.
    pub makespan_s: f64,
    /// Bottleneck-device compute summed over layers.
    pub compute_s: f64,
    /// Analytic all-to-all time summed over layers.
    pub comm_s: f64,
    /// Predicted off-device bytes (dispatch + combine).
    pub comm_bytes: u64,
    /// Aggregate FFN assignments per device (all layers).
    pub device_assignments: Vec<usize>,
    load_cv_sum: f64,
    layers: usize,
}

impl PlanScore {
    /// Mean per-layer coefficient of variation of device load.
    pub fn mean_load_cv(&self) -> f64 {
        if self.layers == 0 {
            0.0
        } else {
            self.load_cv_sum / self.layers as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::from_config(&MoeConfig::preset("test"))
    }

    #[test]
    fn balanced_plan_scores_below_collapsed_plan() {
        let profile = LoadProfile::from_counts(vec![vec![100, 100, 0, 0]])
            .unwrap();
        let cost = model();
        let collapsed =
            PlacementPlan::from_owner(vec![0, 0, 1, 1], 2).unwrap();
        let spread =
            PlacementPlan::from_owner(vec![0, 1, 0, 1], 2).unwrap();
        let s_col = cost.score(&collapsed, &profile);
        let s_spr = cost.score(&spread, &profile);
        assert!(s_spr.makespan_s < s_col.makespan_s,
                "{} vs {}", s_spr.makespan_s, s_col.makespan_s);
        assert!(s_spr.mean_load_cv() < s_col.mean_load_cv());
        // Collapsed: device 0 computes all 200 assignments.
        assert_eq!(s_col.device_assignments, vec![200, 0]);
        assert_eq!(s_spr.device_assignments, vec![100, 100]);
    }

    #[test]
    fn single_device_has_no_comm() {
        let profile =
            LoadProfile::from_counts(vec![vec![10, 20], vec![5, 5]])
                .unwrap();
        let cost = model();
        let plan = PlacementPlan::round_robin(2, 1);
        let s = cost.score(&plan, &profile);
        assert_eq!(s.comm_bytes, 0);
        assert_eq!(s.comm_s, 0.0);
        assert!(s.makespan_s > 0.0);
        assert_eq!(s.mean_load_cv(), 0.0);
    }

    #[test]
    fn makespan_is_compute_plus_comm() {
        let profile =
            LoadProfile::from_counts(vec![vec![8, 4], vec![2, 2]]).unwrap();
        let cost = model();
        let plan = PlacementPlan::round_robin(2, 2);
        let s = cost.score(&plan, &profile);
        assert!((s.makespan_s - (s.compute_s + s.comm_s)).abs() < 1e-15);
    }

    #[test]
    fn migration_time_is_alpha_beta() {
        let cost = model();
        assert_eq!(cost.migration_s(0), 0.0);
        let want = cost.link.alpha_s + cost.link.beta_s_per_byte * 1e6;
        assert!((cost.migration_s(1_000_000) - want).abs() < 1e-15);
    }
}

//! Placement search: round-robin baseline, greedy LPT bin-packing on
//! observed load, and local-search swap/move refinement — all under an
//! optional per-device parameter-memory budget.
//!
//! **Never-worse guarantee** (DESIGN.md §10): `plan()` scores every
//! candidate with the [`CostModel`] and returns the round-robin baseline
//! whenever a heuristic loses to it, so LPT and refined plans never score
//! worse than round-robin on the profile they were planned from — the
//! invariant the placement property test pins down. (Greedy LPT alone has
//! no such guarantee: an adversarial load vector can make modulo layout
//! beat it.)

use anyhow::Result;

use super::cost::{CostModel, DeltaScorer};
use super::plan::PlacementPlan;
use super::profile::LoadProfile;

/// Local-search iteration cap (each iteration applies the single best
/// improving move or swap; termination well before this in practice).
const REFINE_MAX_ROUNDS: usize = 128;

/// Relative improvement below which local search stops (guards against
/// chasing float dust).
const REFINE_MIN_GAIN: f64 = 1e-9;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// `e % n_devices` — the historical baseline.
    RoundRobin,
    /// Longest-processing-time greedy: heaviest expert onto the
    /// least-loaded device with memory headroom.
    Lpt,
    /// LPT seed + best-improvement move/swap local search.
    Refined,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Strategy> {
        match s {
            "rr" | "round-robin" | "roundrobin" => Ok(Strategy::RoundRobin),
            "lpt" | "greedy" => Ok(Strategy::Lpt),
            "refined" | "refine" | "local-search" => Ok(Strategy::Refined),
            other => anyhow::bail!(
                "unknown placement strategy '{other}' \
                 (expected rr|lpt|refined)"
            ),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Strategy::RoundRobin => "round-robin",
            Strategy::Lpt => "lpt",
            Strategy::Refined => "refined",
        }
    }

    pub fn all() -> [Strategy; 3] {
        [Strategy::RoundRobin, Strategy::Lpt, Strategy::Refined]
    }
}

/// Plans FFN-expert placement from a load profile.
#[derive(Clone, Debug)]
pub struct Planner {
    pub cost: CostModel,
    /// Per-device FFN parameter budget; `None` = unbounded.
    pub mem_budget_bytes: Option<u64>,
}

impl Planner {
    pub fn new(cost: CostModel) -> Planner {
        Planner { cost, mem_budget_bytes: None }
    }

    pub fn with_budget(mut self, bytes: u64) -> Planner {
        self.mem_budget_bytes = Some(bytes);
        self
    }

    /// Max FFN experts one device can hold under the memory budget.
    fn max_experts_per_device(&self) -> Option<usize> {
        self.mem_budget_bytes
            .map(|b| (b / self.cost.expert_bytes.max(1)) as usize)
    }

    /// Produce a plan for `n_devices` from `profile`.
    pub fn plan(
        &self,
        strategy: Strategy,
        n_devices: usize,
        profile: &LoadProfile,
    ) -> Result<PlacementPlan> {
        anyhow::ensure!(n_devices > 0, "planner needs >= 1 device");
        let n_ffn = profile.n_ffn_experts();
        let cap = self.max_experts_per_device().unwrap_or(n_ffn.max(1));
        anyhow::ensure!(
            cap * n_devices >= n_ffn,
            "memory budget infeasible: {n_ffn} FFN experts, \
             {n_devices} devices x {cap} experts/device"
        );
        anyhow::ensure!(
            cap >= n_ffn.div_ceil(n_devices),
            "memory budget below the balanced minimum \
             ({} experts/device needed, budget allows {cap})",
            n_ffn.div_ceil(n_devices)
        );
        let rr = PlacementPlan::round_robin(n_ffn, n_devices);
        match strategy {
            Strategy::RoundRobin => Ok(rr),
            Strategy::Lpt => {
                let lpt = self.lpt(n_devices, profile, cap);
                Ok(self.best_of(vec![rr, lpt], profile))
            }
            Strategy::Refined => {
                let lpt = self.lpt(n_devices, profile, cap);
                let seed = self.best_of(vec![rr, lpt], profile);
                Ok(self.refine(seed, profile, cap))
            }
        }
    }

    /// Lowest-makespan plan, earliest wins ties (keeps the baseline when
    /// a heuristic merely matches it).
    fn best_of(
        &self,
        candidates: Vec<PlacementPlan>,
        profile: &LoadProfile,
    ) -> PlacementPlan {
        let mut best: Option<(f64, PlacementPlan)> = None;
        for plan in candidates {
            let m = self.cost.score(&plan, profile).makespan_s;
            let better = match &best {
                None => true,
                Some((bm, _)) => m < *bm,
            };
            if better {
                best = Some((m, plan));
            }
        }
        best.expect("non-empty candidate list").1
    }

    /// Greedy LPT: experts by total load descending (index ascending on
    /// ties), each onto the least-loaded device with headroom.
    fn lpt(
        &self,
        n_devices: usize,
        profile: &LoadProfile,
        cap: usize,
    ) -> PlacementPlan {
        let totals = profile.expert_totals();
        let n_ffn = totals.len();
        let mut order: Vec<usize> = (0..n_ffn).collect();
        order.sort_by_key(|&e| (std::cmp::Reverse(totals[e]), e));
        let mut owner = vec![0usize; n_ffn];
        let mut dev_load = vec![0u64; n_devices];
        let mut dev_count = vec![0usize; n_devices];
        for &e in &order {
            let dev = (0..n_devices)
                .filter(|&d| dev_count[d] < cap)
                .min_by_key(|&d| (dev_load[d], d))
                .expect("feasibility checked in plan()");
            owner[e] = dev;
            dev_load[dev] += totals[e];
            dev_count[dev] += 1;
        }
        PlacementPlan::from_owner(owner, n_devices)
            .expect("lpt produces valid owners")
    }

    /// Best-improvement local search over single-expert moves and
    /// pairwise swaps, scored by the full cost model (so comm effects,
    /// not just the load sum, steer refinement). Monotone: only strictly
    /// improving steps are taken, hence never worse than its seed.
    ///
    /// Candidates are evaluated with [`DeltaScorer`] — bitwise equal to a
    /// full rescore (property-tested below), so the search walks exactly
    /// the trajectory the old clone-and-rescore implementation did, but a
    /// candidate no longer pays O(L·E) to re-walk every expert (the
    /// ROADMAP "incremental plan scoring" item).
    fn refine(
        &self,
        seed: PlacementPlan,
        profile: &LoadProfile,
        cap: usize,
    ) -> PlacementPlan {
        let n_ffn = seed.n_ffn_experts();
        let n_dev = seed.n_devices();
        let mut scorer = DeltaScorer::new(&self.cost, profile, seed);
        let mut cur = scorer.makespan();
        for _ in 0..REFINE_MAX_ROUNDS {
            let counts = scorer.device_counts();
            // (new makespan, expert a, target device / swap partner b,
            //  is_swap)
            let mut best: Option<(f64, usize, usize, bool)> = None;
            let consider =
                |m: f64, a: usize, b: usize, swap: bool,
                 best: &mut Option<(f64, usize, usize, bool)>| {
                    let better = match best {
                        None => true,
                        Some((bm, ..)) => m < *bm,
                    };
                    if better {
                        *best = Some((m, a, b, swap));
                    }
                };
            for e in 0..n_ffn {
                let from = scorer.plan().owner(e);
                for d in 0..n_dev {
                    if d == from || counts[d] >= cap {
                        continue;
                    }
                    let m = scorer.eval_move(e, d);
                    consider(m, e, d, false, &mut best);
                }
            }
            for a in 0..n_ffn {
                for b in (a + 1)..n_ffn {
                    let (da, db) =
                        (scorer.plan().owner(a), scorer.plan().owner(b));
                    if da == db {
                        continue;
                    }
                    let m = scorer.eval_swap(a, b);
                    consider(m, a, b, true, &mut best);
                }
            }
            match best {
                Some((m, a, b, swap))
                    if m < cur * (1.0 - REFINE_MIN_GAIN) =>
                {
                    if swap {
                        scorer.apply_swap(a, b);
                    } else {
                        scorer.apply_move(a, b);
                    }
                    cur = m;
                }
                _ => break,
            }
        }
        scorer.into_plan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MoeConfig;
    use crate::util::proptest::{gen, Prop};

    fn planner() -> Planner {
        Planner::new(CostModel::from_config(&MoeConfig::preset("test")))
    }

    #[test]
    fn lpt_splits_colliding_hot_experts() {
        // Experts 0 and 2 are hot and collide on device 0 under
        // round-robin; LPT and refined must separate them.
        let profile =
            LoadProfile::from_counts(vec![vec![100, 1, 100, 1]]).unwrap();
        let p = planner();
        let rr = p.plan(Strategy::RoundRobin, 2, &profile).unwrap();
        let lpt = p.plan(Strategy::Lpt, 2, &profile).unwrap();
        let refined = p.plan(Strategy::Refined, 2, &profile).unwrap();
        let cost = &p.cost;
        let m_rr = cost.score(&rr, &profile).makespan_s;
        let m_lpt = cost.score(&lpt, &profile).makespan_s;
        let m_ref = cost.score(&refined, &profile).makespan_s;
        assert!(m_lpt < m_rr, "{m_lpt} vs {m_rr}");
        assert!(m_ref <= m_lpt + 1e-15);
        assert_ne!(lpt.owner(0), lpt.owner(2), "hot experts must split");
    }

    #[test]
    fn budget_caps_experts_per_device() {
        let profile = LoadProfile::from_counts(vec![vec![50, 40, 30, 20,
                                                         10, 5]])
            .unwrap();
        let base = planner();
        let cap2 = Planner {
            mem_budget_bytes: Some(base.cost.expert_bytes * 2),
            ..base.clone()
        };
        for strat in Strategy::all() {
            let plan = cap2.plan(strat, 3, &profile).unwrap();
            assert!(
                plan.device_counts().iter().all(|&c| c <= 2),
                "{strat:?} violated budget: {:?}",
                plan.device_counts()
            );
        }
        // One expert per device cannot hold 6 experts on 3 devices.
        let cap1 = Planner {
            mem_budget_bytes: Some(base.cost.expert_bytes),
            ..base
        };
        assert!(cap1.plan(Strategy::Lpt, 3, &profile).is_err());
    }

    #[test]
    fn strategy_parse_and_labels() {
        assert_eq!(Strategy::parse("rr").unwrap(), Strategy::RoundRobin);
        assert_eq!(Strategy::parse("lpt").unwrap(), Strategy::Lpt);
        assert_eq!(
            Strategy::parse("refined").unwrap(),
            Strategy::Refined
        );
        assert!(Strategy::parse("bogus").is_err());
        assert_eq!(Strategy::Refined.label(), "refined");
    }

    #[test]
    fn property_delta_score_equals_full_rescore() {
        // The incremental scorer must agree with CostModel::score
        // *bitwise* on random profiles, plans and candidate move/swap
        // sequences — that is what lets refine() use it without changing
        // the search trajectory.
        let p = planner();
        Prop::new("delta-equals-full-rescore").cases(40).run(
            |rng| {
                let n_dev = gen::usize_in(rng, 1, 5);
                let n_ffn = gen::usize_in(rng, n_dev.max(2), 16);
                let n_layers = gen::usize_in(rng, 1, 3);
                let layers: Vec<Vec<u64>> = (0..n_layers)
                    .map(|_| {
                        (0..n_ffn)
                            .map(|_| rng.below(300) as u64)
                            .collect()
                    })
                    .collect();
                let owner: Vec<usize> =
                    (0..n_ffn).map(|_| rng.below(n_dev)).collect();
                let steps: Vec<(bool, usize, usize)> = (0..12)
                    .map(|_| {
                        (
                            rng.next_f32() < 0.5,
                            rng.below(n_ffn),
                            rng.below(n_ffn.max(n_dev)),
                        )
                    })
                    .collect();
                (n_dev, layers, owner, steps)
            },
            |(n_dev, layers, owner, steps)| {
                let profile =
                    LoadProfile::from_counts(layers.clone()).unwrap();
                let plan = PlacementPlan::from_owner(
                    owner.clone(),
                    *n_dev,
                )
                .unwrap();
                let mut scorer =
                    DeltaScorer::new(&p.cost, &profile, plan.clone());
                let full =
                    p.cost.score(&plan, &profile).makespan_s;
                if scorer.makespan() != full {
                    return Err(format!(
                        "base: delta {} != full {full}",
                        scorer.makespan()
                    ));
                }
                for &(is_swap, a, b) in steps {
                    if is_swap {
                        let b = b % scorer.plan().n_ffn_experts();
                        if a == b {
                            continue;
                        }
                        let delta = scorer.eval_swap(a, b);
                        let mut cand = scorer.plan().clone();
                        let (da, db) = (cand.owner(a), cand.owner(b));
                        cand.set_owner(a, db);
                        cand.set_owner(b, da);
                        let full =
                            p.cost.score(&cand, &profile).makespan_s;
                        if delta != full {
                            return Err(format!(
                                "swap({a},{b}): {delta} != {full}"
                            ));
                        }
                        // Commit and re-check the maintained state.
                        scorer.apply_swap(a, b);
                        if scorer.makespan() != full {
                            return Err("state after swap".into());
                        }
                    } else {
                        let to = b % *n_dev;
                        let delta = scorer.eval_move(a, to);
                        let mut cand = scorer.plan().clone();
                        cand.set_owner(a, to);
                        let full =
                            p.cost.score(&cand, &profile).makespan_s;
                        if delta != full {
                            return Err(format!(
                                "move({a}->{to}): {delta} != {full}"
                            ));
                        }
                        scorer.apply_move(a, to);
                        if scorer.makespan() != full {
                            return Err("state after move".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_heuristics_never_score_worse_than_round_robin() {
        // The satellite property test: for any seeded load profile, LPT
        // and refined plans never score worse than round-robin under the
        // cost model, every plan places each FFN expert exactly once,
        // and device counts respect the (generated) memory budget.
        let p = planner();
        Prop::new("placement-never-worse").cases(48).run(
            |rng| {
                let n_dev = gen::usize_in(rng, 1, 6);
                let n_ffn = gen::usize_in(rng, n_dev.max(2), 24);
                let n_layers = gen::usize_in(rng, 1, 4);
                let layers: Vec<Vec<u64>> = (0..n_layers)
                    .map(|_| {
                        (0..n_ffn)
                            .map(|_| {
                                // Heavy-tailed: many cold, a few hot.
                                if rng.next_f32() < 0.3 {
                                    rng.below(500) as u64
                                } else {
                                    rng.below(20) as u64
                                }
                            })
                            .collect()
                    })
                    .collect();
                let slack = gen::usize_in(rng, 0, n_ffn);
                (n_dev, layers, slack)
            },
            |(n_dev, layers, slack)| {
                let profile =
                    LoadProfile::from_counts(layers.clone()).unwrap();
                let n_ffn = profile.n_ffn_experts();
                let cap = n_ffn.div_ceil(*n_dev) + slack;
                let planner = Planner {
                    mem_budget_bytes: Some(
                        p.cost.expert_bytes * cap as u64,
                    ),
                    ..p.clone()
                };
                let rr = planner
                    .plan(Strategy::RoundRobin, *n_dev, &profile)
                    .map_err(|e| e.to_string())?;
                let m_rr =
                    planner.cost.score(&rr, &profile).makespan_s;
                for strat in [Strategy::Lpt, Strategy::Refined] {
                    let plan = planner
                        .plan(strat, *n_dev, &profile)
                        .map_err(|e| e.to_string())?;
                    plan.validate().map_err(|e| e.to_string())?;
                    // Exactly-once placement: owners partition experts.
                    if plan.n_ffn_experts() != n_ffn {
                        return Err("plan lost experts".into());
                    }
                    let counts = plan.device_counts();
                    if counts.iter().sum::<usize>() != n_ffn {
                        return Err(format!(
                            "device counts {counts:?} != {n_ffn}"
                        ));
                    }
                    if counts.iter().any(|&c| c > cap) {
                        return Err(format!(
                            "{strat:?} violated budget cap {cap}: \
                             {counts:?}"
                        ));
                    }
                    let m =
                        planner.cost.score(&plan, &profile).makespan_s;
                    if m > m_rr * (1.0 + 1e-12) {
                        return Err(format!(
                            "{strat:?} makespan {m} worse than \
                             round-robin {m_rr}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

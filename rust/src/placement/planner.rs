//! Placement search: round-robin baseline, greedy LPT bin-packing on
//! observed load, local-search swap/move refinement, and replicated
//! refinement that additionally grows/shrinks hot experts' replica sets —
//! all under an optional per-device parameter-memory budget (every
//! replica occupies one budget slot).
//!
//! **Never-worse guarantee** (DESIGN.md §10/§13): `plan()` scores every
//! candidate with the [`CostModel`] and returns the round-robin baseline
//! whenever a heuristic loses to it, so LPT and refined plans never score
//! worse than round-robin on the profile they were planned from — the
//! invariant the placement property test pins down. (Greedy LPT alone has
//! no such guarantee: an adversarial load vector can make modulo layout
//! beat it.) The replicated search is seeded with the *refined* plan and
//! only takes strictly improving steps, so a replicated plan never scores
//! worse than the best single-owner plan under the same budget either.

use anyhow::Result;

use super::cost::{CostModel, DeltaScorer, Edit};
use super::plan::PlacementPlan;
use super::profile::LoadProfile;
use crate::config::Precision;

/// Local-search iteration cap (each iteration applies the single best
/// improving move or swap; termination well before this in practice).
const REFINE_MAX_ROUNDS: usize = 128;

/// Relative improvement below which local search stops (guards against
/// chasing float dust).
const REFINE_MIN_GAIN: f64 = 1e-9;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// `e % n_devices` — the historical baseline.
    RoundRobin,
    /// Longest-processing-time greedy: heaviest expert onto the device
    /// with the earliest projected *finish time* (seconds, so fast
    /// devices absorb more) among those with memory headroom.
    Lpt,
    /// LPT seed + best-improvement move/swap local search.
    Refined,
    /// Refined seed + replicate/drop steps: hot experts may be split
    /// across up to `max_replicas` devices (never worse than refined).
    Replicated,
    /// Replicated seed + compressed-replica steps (DESIGN.md §17):
    /// byte-exact accounting lets a hot expert gain an *int8* replica —
    /// demoting it to `Precision::Int8` stack-wide — on a device where
    /// a full-precision copy does not fit the memory budget. Never
    /// worse than replicated (strictly improving steps only); without
    /// a budget it is identical to replicated.
    Compressed,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Strategy> {
        match s {
            "rr" | "round-robin" | "roundrobin" => Ok(Strategy::RoundRobin),
            "lpt" | "greedy" => Ok(Strategy::Lpt),
            "refined" | "refine" | "local-search" => Ok(Strategy::Refined),
            "replicated" | "replicate" | "replicas" => {
                Ok(Strategy::Replicated)
            }
            "compressed" | "compress" | "int8" => {
                Ok(Strategy::Compressed)
            }
            other => anyhow::bail!(
                "unknown placement strategy '{other}' \
                 (expected rr|lpt|refined|replicated|compressed)"
            ),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Strategy::RoundRobin => "round-robin",
            Strategy::Lpt => "lpt",
            Strategy::Refined => "refined",
            Strategy::Replicated => "replicated",
            Strategy::Compressed => "compressed",
        }
    }

    pub fn all() -> [Strategy; 5] {
        [
            Strategy::RoundRobin,
            Strategy::Lpt,
            Strategy::Refined,
            Strategy::Replicated,
            Strategy::Compressed,
        ]
    }
}

/// Plans FFN-expert placement from a load profile.
#[derive(Clone, Debug)]
pub struct Planner {
    pub cost: CostModel,
    /// Per-device FFN parameter budget; `None` = unbounded. Every
    /// replica occupies one `expert_bytes` slot against it.
    pub mem_budget_bytes: Option<u64>,
    /// Replica-set size cap for [`Strategy::Replicated`] (1 disables
    /// replication and makes it identical to refined).
    pub max_replicas: usize,
    /// Quarantined devices (DESIGN.md §16): no strategy places a
    /// replica on them — the round-robin baseline is repaired onto the
    /// healthy devices, LPT/refine skip them as candidates, and
    /// feasibility is judged on the healthy count. Empty (the default)
    /// reproduces the historical planner bit-for-bit.
    pub down_devices: Vec<usize>,
}

impl Planner {
    pub fn new(cost: CostModel) -> Planner {
        Planner {
            cost,
            mem_budget_bytes: None,
            max_replicas: 2,
            down_devices: Vec::new(),
        }
    }

    pub fn with_budget(mut self, bytes: u64) -> Planner {
        self.mem_budget_bytes = Some(bytes);
        self
    }

    pub fn with_max_replicas(mut self, max_replicas: usize) -> Planner {
        assert!(max_replicas >= 1, "max_replicas must be >= 1");
        self.max_replicas = max_replicas;
        self
    }

    pub fn with_down_devices(mut self, down: Vec<usize>) -> Planner {
        self.down_devices = down;
        self
    }

    fn is_down(&self, dev: usize) -> bool {
        self.down_devices.contains(&dev)
    }

    /// Max FFN experts one device can hold under the memory budget.
    fn max_experts_per_device(&self) -> Option<usize> {
        self.mem_budget_bytes
            .map(|b| (b / self.cost.expert_bytes.max(1)) as usize)
    }

    /// Produce a plan for `n_devices` from `profile`.
    pub fn plan(
        &self,
        strategy: Strategy,
        n_devices: usize,
        profile: &LoadProfile,
    ) -> Result<PlacementPlan> {
        anyhow::ensure!(n_devices > 0, "planner needs >= 1 device");
        let n_ffn = profile.n_ffn_experts();
        let cap = self.max_experts_per_device().unwrap_or(n_ffn.max(1));
        // Feasibility is judged on the *healthy* fleet: quarantined
        // devices hold no replicas.
        let healthy: Vec<usize> = (0..n_devices)
            .filter(|&d| !self.is_down(d))
            .collect();
        let n_healthy = healthy.len();
        anyhow::ensure!(
            n_healthy > 0,
            "every device is quarantined: nowhere to place experts"
        );
        anyhow::ensure!(
            cap * n_healthy >= n_ffn,
            "memory budget infeasible: {n_ffn} FFN experts, \
             {n_healthy} healthy devices x {cap} experts/device"
        );
        anyhow::ensure!(
            cap >= n_ffn.div_ceil(n_healthy),
            "memory budget below the balanced minimum \
             ({} experts/device needed, budget allows {cap})",
            n_ffn.div_ceil(n_healthy)
        );
        // The baseline: plain round-robin on a whole fleet (the
        // historical layout, bit-for-bit), repaired round-robin over
        // the healthy devices when some are quarantined.
        let rr = if n_healthy == n_devices {
            PlacementPlan::round_robin(n_ffn, n_devices)
        } else {
            let owner: Vec<usize> =
                (0..n_ffn).map(|e| healthy[e % n_healthy]).collect();
            PlacementPlan::from_owner(owner, n_devices)
                .expect("healthy round-robin produces valid owners")
        };
        match strategy {
            Strategy::RoundRobin => Ok(rr),
            Strategy::Lpt => {
                let lpt = self.lpt(n_devices, profile, cap);
                Ok(self.best_of(vec![rr, lpt], profile))
            }
            Strategy::Refined => {
                let lpt = self.lpt(n_devices, profile, cap);
                let seed = self.best_of(vec![rr, lpt], profile);
                Ok(self.refine(seed, profile, cap, 1))
            }
            Strategy::Replicated => {
                // Seed with the fully refined single-owner plan, then
                // let strictly improving replicate/drop (and further
                // move/swap) steps grow replica sets: monotone seeding
                // makes replicated >= refined >= best(rr, lpt)
                // impossible to violate by construction.
                let lpt = self.lpt(n_devices, profile, cap);
                let seed = self.best_of(vec![rr, lpt], profile);
                let refined = self.refine(seed, profile, cap, 1);
                Ok(self.refine(
                    refined,
                    profile,
                    cap,
                    self.max_replicas.min(n_devices),
                ))
            }
            Strategy::Compressed => {
                // Extend the replicated chain: monotone seeding again,
                // so compressed >= replicated >= refined by
                // construction.
                let lpt = self.lpt(n_devices, profile, cap);
                let seed = self.best_of(vec![rr, lpt], profile);
                let refined = self.refine(seed, profile, cap, 1);
                let replicated = self.refine(
                    refined,
                    profile,
                    cap,
                    self.max_replicas.min(n_devices),
                );
                Ok(self.compress(replicated, profile, n_devices))
            }
        }
    }

    /// Parameter bytes resident on each device under `plan`'s
    /// per-expert precision map (every replica of expert `e` costs
    /// [`CostModel::expert_bytes_for`] at `plan.precision(e)`). This is
    /// the byte-exact accounting [`Strategy::Compressed`] refines
    /// under, in contrast to the slot-based `budget / expert_bytes` cap
    /// the full-precision strategies use.
    pub fn device_bytes(&self, plan: &PlacementPlan) -> Vec<u64> {
        (0..plan.n_devices())
            .map(|d| {
                plan.device_experts(d)
                    .iter()
                    .map(|&e| {
                        self.cost.expert_bytes_for(plan.precision(e))
                    })
                    .sum()
            })
            .collect()
    }

    /// Compressed-replica refinement (DESIGN.md §17): greedy replicate
    /// steps under *byte-exact* per-device accounting. Each candidate
    /// places a replica of expert `e` on device `d`; when the replica
    /// fits at `e`'s current precision it is taken as-is, and when only
    /// the int8 footprint fits, `e` is demoted to `Precision::Int8`
    /// *stack-wide* (precision is per-expert, never per-replica — the
    /// bitwise-determinism contract of DESIGN.md §17) and the replica
    /// is placed at quantized bytes. Demotion frees bytes on every
    /// device already holding `e` and leaves the modeled makespan
    /// untouched (the cost model prices int8 and f32 MACs identically;
    /// the win is bytes -> replicas -> load splitting), so candidates
    /// are scored by the plain [`Edit::Replicate`] delta. Strictly
    /// improving steps only: never worse than its replicated seed, and
    /// with no memory budget it returns the seed unchanged (an
    /// unbounded fleet never needs to trade accuracy for bytes).
    fn compress(
        &self,
        seed: PlacementPlan,
        profile: &LoadProfile,
        n_devices: usize,
    ) -> PlacementPlan {
        let Some(budget) = self.mem_budget_bytes else {
            return seed;
        };
        let n_ffn = seed.n_ffn_experts();
        let max_replicas = self.max_replicas.min(n_devices);
        let mut precision: Vec<Precision> = seed.precisions().to_vec();
        let mut scorer = DeltaScorer::new(&self.cost, profile, seed);
        let mut cur = scorer.makespan();
        for _ in 0..REFINE_MAX_ROUNDS {
            // Per-device resident bytes under the current precision
            // map. Recomputed each round: a demotion in round k frees
            // bytes every later round gets to spend.
            let used: Vec<u64> = (0..n_devices)
                .map(|d| {
                    scorer
                        .plan()
                        .device_experts(d)
                        .iter()
                        .map(|&e| self.cost.expert_bytes_for(precision[e]))
                        .sum()
                })
                .collect();
            // (makespan, expert, device, demote-to-int8-first)
            let mut best: Option<(f64, usize, usize, bool)> = None;
            for e in 0..n_ffn {
                if scorer.plan().replica_count(e) >= max_replicas {
                    continue;
                }
                let p = precision[e];
                for d in 0..n_devices {
                    if self.is_down(d)
                        || scorer
                            .plan()
                            .replicas(e)
                            .binary_search(&d)
                            .is_ok()
                    {
                        continue;
                    }
                    // `used[d]` is unaffected by demoting `e`: `d`
                    // does not hold `e` yet, and demotion only frees
                    // bytes on devices that do.
                    let fits_as_is = used[d]
                        + self.cost.expert_bytes_for(p)
                        <= budget;
                    let fits_demoted = p == Precision::F32
                        && used[d]
                            + self
                                .cost
                                .expert_bytes_for(Precision::Int8)
                            <= budget;
                    if !fits_as_is && !fits_demoted {
                        continue;
                    }
                    let m =
                        scorer.eval(Edit::Replicate { expert: e, on: d });
                    let better = match best {
                        None => true,
                        Some((bm, ..)) => m < bm,
                    };
                    if better {
                        // Full precision is preferred whenever it
                        // fits; demotion is the fallback that makes
                        // the replica affordable.
                        best = Some((m, e, d, !fits_as_is));
                    }
                }
            }
            match best {
                Some((m, e, d, demote))
                    if m < cur * (1.0 - REFINE_MIN_GAIN) =>
                {
                    if demote {
                        precision[e] = Precision::Int8;
                    }
                    scorer.apply(Edit::Replicate { expert: e, on: d });
                    cur = m;
                }
                _ => break,
            }
        }
        let mut plan = scorer.into_plan();
        for (e, &p) in precision.iter().enumerate() {
            plan.set_precision(e, p);
        }
        plan
    }

    /// Lowest-makespan plan, earliest wins ties (keeps the baseline when
    /// a heuristic merely matches it).
    fn best_of(
        &self,
        candidates: Vec<PlacementPlan>,
        profile: &LoadProfile,
    ) -> PlacementPlan {
        let mut best: Option<(f64, PlacementPlan)> = None;
        for plan in candidates {
            let m = self.cost.score(&plan, profile).makespan_s;
            let better = match &best {
                None => true,
                Some((bm, _)) => m < *bm,
            };
            if better {
                best = Some((m, plan));
            }
        }
        best.expect("non-empty candidate list").1
    }

    /// Greedy LPT: experts by total load descending (index ascending on
    /// ties), each onto the device with the earliest projected finish
    /// time in *seconds* among those with headroom — on a uniform fleet
    /// this is exactly "least loaded", on a heterogeneous one a 2× device
    /// absorbs proportionally more load (ISSUE 6 acceptance). Ties break
    /// on device index, keeping the search deterministic.
    fn lpt(
        &self,
        n_devices: usize,
        profile: &LoadProfile,
        cap: usize,
    ) -> PlacementPlan {
        let totals = profile.expert_totals();
        let n_ffn = totals.len();
        let mut order: Vec<usize> = (0..n_ffn).collect();
        order.sort_by_key(|&e| (std::cmp::Reverse(totals[e]), e));
        let mut owner = vec![0usize; n_ffn];
        let mut dev_load = vec![0u64; n_devices];
        let mut dev_count = vec![0usize; n_devices];
        for &e in &order {
            let dev = (0..n_devices)
                .filter(|&d| dev_count[d] < cap && !self.is_down(d))
                .min_by(|&a, &b| {
                    let fa = (dev_load[a] + totals[e]) as f64
                        * self.cost.compute_s_on(a);
                    let fb = (dev_load[b] + totals[e]) as f64
                        * self.cost.compute_s_on(b);
                    fa.partial_cmp(&fb)
                        .expect("finite finish times")
                        .then(a.cmp(&b))
                })
                .expect("feasibility checked in plan()");
            owner[e] = dev;
            dev_load[dev] += totals[e];
            dev_count[dev] += 1;
        }
        PlacementPlan::from_owner(owner, n_devices)
            .expect("lpt produces valid owners")
    }

    /// Best-improvement local search over single-expert moves, pairwise
    /// swaps and — when `max_replicas > 1` — replicate/drop steps that
    /// grow or shrink a hot expert's replica set, scored by the full
    /// cost model (so comm effects, not just the load sum, steer
    /// refinement). Monotone: only strictly improving steps are taken,
    /// hence never worse than its seed. Moves and swaps only touch
    /// single-replica experts — a replicated expert is reshaped through
    /// replicate/drop steps, which keeps every step a well-defined
    /// [`Edit`] — and every replica counts against the per-device cap,
    /// so replication never exceeds the memory budget.
    ///
    /// Candidates are evaluated with [`DeltaScorer`] — bitwise equal to a
    /// full rescore (property-tested below), so the search walks exactly
    /// the trajectory the old clone-and-rescore implementation did, but a
    /// candidate no longer pays O(L·E) to re-walk every expert (the
    /// ROADMAP "incremental plan scoring" item).
    fn refine(
        &self,
        seed: PlacementPlan,
        profile: &LoadProfile,
        cap: usize,
        max_replicas: usize,
    ) -> PlacementPlan {
        let n_ffn = seed.n_ffn_experts();
        let n_dev = seed.n_devices();
        let mut scorer = DeltaScorer::new(&self.cost, profile, seed);
        let mut cur = scorer.makespan();
        for _ in 0..REFINE_MAX_ROUNDS {
            let counts = scorer.device_counts();
            let mut best: Option<(f64, Edit)> = None;
            let consider =
                |m: f64, edit: Edit, best: &mut Option<(f64, Edit)>| {
                    let better = match best {
                        None => true,
                        Some((bm, _)) => m < *bm,
                    };
                    if better {
                        *best = Some((m, edit));
                    }
                };
            for e in 0..n_ffn {
                if scorer.plan().replica_count(e) != 1 {
                    continue;
                }
                let from = scorer.plan().owner(e);
                for d in 0..n_dev {
                    if d == from || counts[d] >= cap || self.is_down(d) {
                        continue;
                    }
                    let edit = Edit::Move { expert: e, to: d };
                    let m = scorer.eval(edit);
                    consider(m, edit, &mut best);
                }
            }
            for a in 0..n_ffn {
                if scorer.plan().replica_count(a) != 1 {
                    continue;
                }
                for b in (a + 1)..n_ffn {
                    if scorer.plan().replica_count(b) != 1 {
                        continue;
                    }
                    let (da, db) =
                        (scorer.plan().owner(a), scorer.plan().owner(b));
                    if da == db {
                        continue;
                    }
                    let edit = Edit::Swap { a, b };
                    let m = scorer.eval(edit);
                    consider(m, edit, &mut best);
                }
            }
            if max_replicas > 1 {
                for e in 0..n_ffn {
                    let r = scorer.plan().replica_count(e);
                    if r < max_replicas {
                        for d in 0..n_dev {
                            if counts[d] >= cap
                                || self.is_down(d)
                                || scorer
                                    .plan()
                                    .replicas(e)
                                    .binary_search(&d)
                                    .is_ok()
                            {
                                continue;
                            }
                            let edit =
                                Edit::Replicate { expert: e, on: d };
                            let m = scorer.eval(edit);
                            consider(m, edit, &mut best);
                        }
                    }
                    if r > 1 {
                        for j in 0..r {
                            let d = scorer.plan().replicas(e)[j];
                            let edit = Edit::Drop { expert: e, on: d };
                            let m = scorer.eval(edit);
                            consider(m, edit, &mut best);
                        }
                    }
                }
            }
            match best {
                Some((m, edit))
                    if m < cur * (1.0 - REFINE_MIN_GAIN) =>
                {
                    scorer.apply(edit);
                    cur = m;
                }
                _ => break,
            }
        }
        scorer.into_plan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MoeConfig;
    use crate::util::proptest::{gen, Prop};

    fn planner() -> Planner {
        Planner::new(CostModel::from_config(&MoeConfig::preset("test")))
    }

    #[test]
    fn lpt_splits_colliding_hot_experts() {
        // Experts 0 and 2 are hot and collide on device 0 under
        // round-robin; LPT and refined must separate them.
        let profile =
            LoadProfile::from_counts(vec![vec![100, 1, 100, 1]]).unwrap();
        let p = planner();
        let rr = p.plan(Strategy::RoundRobin, 2, &profile).unwrap();
        let lpt = p.plan(Strategy::Lpt, 2, &profile).unwrap();
        let refined = p.plan(Strategy::Refined, 2, &profile).unwrap();
        let cost = &p.cost;
        let m_rr = cost.score(&rr, &profile).makespan_s;
        let m_lpt = cost.score(&lpt, &profile).makespan_s;
        let m_ref = cost.score(&refined, &profile).makespan_s;
        assert!(m_lpt < m_rr, "{m_lpt} vs {m_rr}");
        assert!(m_ref <= m_lpt + 1e-15);
        assert_ne!(lpt.owner(0), lpt.owner(2), "hot experts must split");
    }

    #[test]
    fn budget_caps_experts_per_device() {
        let profile = LoadProfile::from_counts(vec![vec![50, 40, 30, 20,
                                                         10, 5]])
            .unwrap();
        let base = planner();
        let cap2 = Planner {
            mem_budget_bytes: Some(base.cost.expert_bytes * 2),
            ..base.clone()
        };
        for strat in Strategy::all() {
            let plan = cap2.plan(strat, 3, &profile).unwrap();
            assert!(
                plan.device_counts().iter().all(|&c| c <= 2),
                "{strat:?} violated budget: {:?}",
                plan.device_counts()
            );
        }
        // One expert per device cannot hold 6 experts on 3 devices.
        let cap1 = Planner {
            mem_budget_bytes: Some(base.cost.expert_bytes),
            ..base
        };
        assert!(cap1.plan(Strategy::Lpt, 3, &profile).is_err());
    }

    #[test]
    fn strategy_parse_and_labels() {
        assert_eq!(Strategy::parse("rr").unwrap(), Strategy::RoundRobin);
        assert_eq!(Strategy::parse("lpt").unwrap(), Strategy::Lpt);
        assert_eq!(
            Strategy::parse("refined").unwrap(),
            Strategy::Refined
        );
        assert_eq!(
            Strategy::parse("replicated").unwrap(),
            Strategy::Replicated
        );
        assert_eq!(
            Strategy::parse("compressed").unwrap(),
            Strategy::Compressed
        );
        assert!(Strategy::parse("bogus").is_err());
        assert_eq!(Strategy::Refined.label(), "refined");
        assert_eq!(Strategy::Replicated.label(), "replicated");
        assert_eq!(Strategy::Compressed.label(), "compressed");
        assert_eq!(Strategy::all().len(), 5);
    }

    #[test]
    fn replicated_splits_a_hot_expert_across_devices() {
        // One dominant expert: no single-owner layout can relieve its
        // device, but a second replica halves the bottleneck. The
        // replicated plan must actually replicate and strictly beat the
        // refined single-owner plan.
        let profile = LoadProfile::from_counts(vec![vec![
            1000, 10, 10, 10, 10, 10, 10, 10,
        ]])
        .unwrap();
        let p = planner();
        let refined = p.plan(Strategy::Refined, 4, &profile).unwrap();
        let repl = p.plan(Strategy::Replicated, 4, &profile).unwrap();
        assert!(!refined.is_replicated());
        assert!(repl.is_replicated(), "hot expert must gain a replica");
        assert!(repl.replica_count(0) > 1);
        let m_ref = p.cost.score(&refined, &profile).makespan_s;
        let m_rep = p.cost.score(&repl, &profile).makespan_s;
        assert!(m_rep < m_ref, "{m_rep} vs {m_ref}");
        // max_replicas = 1 disables replication entirely.
        let single = p
            .clone()
            .with_max_replicas(1)
            .plan(Strategy::Replicated, 4, &profile)
            .unwrap();
        assert!(!single.is_replicated());
    }

    #[test]
    fn replication_respects_the_memory_budget() {
        // cap = 3 slots/device on 2 devices with 4 experts: at most 2
        // extra replica slots exist fleet-wide, and no device may exceed
        // its cap even when replication would pay.
        let profile =
            LoadProfile::from_counts(vec![vec![900, 5, 5, 5]]).unwrap();
        let base = planner();
        let p = Planner {
            mem_budget_bytes: Some(base.cost.expert_bytes * 3),
            ..base
        }
        .with_max_replicas(4);
        let plan = p.plan(Strategy::Replicated, 2, &profile).unwrap();
        assert!(
            plan.device_counts().iter().all(|&c| c <= 3),
            "budget violated: {:?}",
            plan.device_counts()
        );
    }

    #[test]
    fn compressed_replica_beats_full_precision_under_tight_budget() {
        // The ISSUE 10 acceptance scenario: a skewed workload whose hot
        // expert wants a second replica, under a per-device byte budget
        // with room for two f32 experts plus *one int8 copy* — too
        // tight for a third full-precision slot. Every full-precision
        // strategy is stuck (the slot cap is 2 and both devices are
        // full), so the best full-precision plan is the replicated one
        // (== refined here). Compressed demotes the hot expert to int8
        // stack-wide, places the cheap replica, and strictly beats the
        // best full-precision makespan while staying inside the byte
        // budget.
        let profile =
            LoadProfile::from_counts(vec![vec![1000, 10, 10, 10]])
                .unwrap();
        let base = planner();
        let f32b = base.cost.expert_bytes;
        let i8b = base.cost.expert_bytes_int8;
        assert!(i8b < f32b);
        let budget = 2 * f32b + i8b;
        let p = Planner {
            mem_budget_bytes: Some(budget),
            ..base
        };
        let mut m_full = f64::INFINITY;
        for strat in [
            Strategy::RoundRobin,
            Strategy::Lpt,
            Strategy::Refined,
            Strategy::Replicated,
        ] {
            let plan = p.plan(strat, 2, &profile).unwrap();
            assert!(
                !plan.is_replicated(),
                "{strat:?}: no f32 replica can fit a 2-slot device"
            );
            assert!(!plan.is_mixed_precision());
            let m = p.cost.score(&plan, &profile).makespan_s;
            m_full = m_full.min(m);
        }
        let comp = p.plan(Strategy::Compressed, 2, &profile).unwrap();
        comp.validate().unwrap();
        assert!(comp.is_mixed_precision());
        assert_eq!(comp.precision(0), Precision::Int8);
        assert!(
            comp.replica_count(0) > 1,
            "hot expert must gain the compressed replica"
        );
        let m_comp = p.cost.score(&comp, &profile).makespan_s;
        assert!(
            m_comp < m_full,
            "compressed {m_comp} must beat best full-precision {m_full}"
        );
        // Byte-exact accounting holds even though a device now carries
        // more replicas than the f32 slot cap allows.
        let bytes = p.device_bytes(&comp);
        assert!(
            bytes.iter().all(|&b| b <= budget),
            "byte budget {budget} violated: {bytes:?}"
        );
        assert!(comp.device_counts().iter().any(|&c| c > 2));
    }

    #[test]
    fn compressed_without_budget_is_replicated() {
        // Unbounded memory never trades accuracy for bytes: the
        // compressed chain returns the replicated plan unchanged, all
        // experts at full precision.
        let profile = LoadProfile::from_counts(vec![vec![
            1000, 10, 10, 10, 10, 10, 10, 10,
        ]])
        .unwrap();
        let p = planner();
        let repl = p.plan(Strategy::Replicated, 4, &profile).unwrap();
        let comp = p.plan(Strategy::Compressed, 4, &profile).unwrap();
        assert_eq!(comp, repl);
        assert!(!comp.is_mixed_precision());
    }

    #[test]
    fn quarantined_devices_hold_no_replicas() {
        // DESIGN.md §16: every strategy (the repaired round-robin
        // baseline included) must route around a down device.
        let profile =
            LoadProfile::from_counts(vec![vec![100, 1, 100, 1]]).unwrap();
        let p = planner().with_down_devices(vec![1]);
        for strat in Strategy::all() {
            let plan = p.plan(strat, 3, &profile).unwrap();
            plan.validate().unwrap();
            for e in 0..4 {
                assert!(
                    !plan.replicas(e).contains(&1),
                    "{strat:?} placed expert {e} on the down device"
                );
            }
        }
        // An empty mask reproduces the historical baseline exactly.
        let rr = planner()
            .plan(Strategy::RoundRobin, 3, &profile)
            .unwrap();
        assert_eq!(rr, PlacementPlan::round_robin(4, 3));
        // A fully-quarantined fleet is infeasible.
        let dead = planner().with_down_devices(vec![0, 1]);
        assert!(dead.plan(Strategy::Refined, 2, &profile).is_err());
    }

    #[test]
    fn heterogeneous_lpt_loads_fast_device_more() {
        // ISSUE 6 acceptance: 4 equal experts, one 2x-speed device. The
        // seconds-aware greedy lands 3 experts on the fast device (150·c
        // makespan) instead of the FLOP-balanced 2/2 split (200·c).
        let profile =
            LoadProfile::from_counts(vec![vec![100, 100, 100, 100]])
                .unwrap();
        let cost = CostModel::from_config(&MoeConfig::preset("test"))
            .with_device_speeds(vec![2.0, 1.0]);
        let p = Planner::new(cost);
        let plan = p.plan(Strategy::Lpt, 2, &profile).unwrap();
        let counts = p.cost.score(&plan, &profile).device_assignments;
        assert_eq!(
            counts,
            vec![300, 100],
            "fast device must absorb proportionally more"
        );
    }

    #[test]
    fn property_delta_score_equals_full_rescore() {
        // The incremental scorer must agree with CostModel::score
        // *bitwise* on random profiles, plans and candidate
        // move/swap/replicate/drop sequences — on heterogeneous fleets
        // too — that is what lets refine() use it without changing the
        // search trajectory.
        Prop::new("delta-equals-full-rescore").cases(40).run(
            |rng| {
                let n_dev = gen::usize_in(rng, 1, 5);
                let n_ffn = gen::usize_in(rng, n_dev.max(2), 16);
                let n_layers = gen::usize_in(rng, 1, 3);
                let layers: Vec<Vec<u64>> = (0..n_layers)
                    .map(|_| {
                        (0..n_ffn)
                            .map(|_| rng.below(300) as u64)
                            .collect()
                    })
                    .collect();
                let owner: Vec<usize> =
                    (0..n_ffn).map(|_| rng.below(n_dev)).collect();
                let steps: Vec<(usize, usize, usize)> = (0..16)
                    .map(|_| {
                        (
                            rng.below(4),
                            rng.below(n_ffn),
                            rng.below(n_ffn.max(n_dev)),
                        )
                    })
                    .collect();
                (n_dev, layers, owner, steps)
            },
            |(n_dev, layers, owner, steps)| {
                let profile =
                    LoadProfile::from_counts(layers.clone()).unwrap();
                // A deterministic mixed fleet: exercises the per-device
                // seconds fold, not just uniform speeds.
                let speeds: Vec<f64> = (0..*n_dev)
                    .map(|d| 1.0 + (d % 3) as f64 * 0.5)
                    .collect();
                let cost =
                    CostModel::from_config(&MoeConfig::preset("test"))
                        .with_device_speeds(speeds);
                let plan = PlacementPlan::from_owner(
                    owner.clone(),
                    *n_dev,
                )
                .unwrap();
                let mut scorer =
                    DeltaScorer::new(&cost, &profile, plan.clone());
                let full = cost.score(&plan, &profile).makespan_s;
                if scorer.makespan() != full {
                    return Err(format!(
                        "base: delta {} != full {full}",
                        scorer.makespan()
                    ));
                }
                for &(kind, a, b) in steps {
                    // Interpret the raw tuple as the first legal edit of
                    // its kind, mirroring the planner's own gating.
                    let edit = match kind {
                        0 => {
                            if scorer.plan().replica_count(a) != 1 {
                                continue;
                            }
                            Edit::Move { expert: a, to: b % *n_dev }
                        }
                        1 => {
                            let b = b % scorer.plan().n_ffn_experts();
                            if a == b
                                || scorer.plan().replica_count(a) != 1
                                || scorer.plan().replica_count(b) != 1
                            {
                                continue;
                            }
                            Edit::Swap { a, b }
                        }
                        2 => {
                            let on = b % *n_dev;
                            if scorer
                                .plan()
                                .replicas(a)
                                .contains(&on)
                            {
                                continue;
                            }
                            Edit::Replicate { expert: a, on }
                        }
                        _ => {
                            let r = scorer.plan().replica_count(a);
                            if r < 2 {
                                continue;
                            }
                            let on = scorer.plan().replicas(a)[b % r];
                            Edit::Drop { expert: a, on }
                        }
                    };
                    let predicted = scorer.eval(edit);
                    // Build the mutated plan independently and rescore
                    // it from scratch.
                    let mut cand = scorer.plan().clone();
                    match edit {
                        Edit::Move { expert, to } => {
                            cand.set_owner(expert, to)
                        }
                        Edit::Swap { a, b } => {
                            let (da, db) = (cand.owner(a), cand.owner(b));
                            cand.set_owner(a, db);
                            cand.set_owner(b, da);
                        }
                        Edit::Replicate { expert, on } => {
                            cand.add_replica(expert, on);
                        }
                        Edit::Drop { expert, on } => {
                            cand.remove_replica(expert, on)
                        }
                    }
                    let full = cost.score(&cand, &profile).makespan_s;
                    if predicted != full {
                        return Err(format!(
                            "{edit:?}: {predicted} != {full}"
                        ));
                    }
                    // Commit and re-check the maintained state.
                    scorer.apply(edit);
                    if scorer.makespan() != full {
                        return Err(format!("state after {edit:?}"));
                    }
                    if scorer.plan() != &cand {
                        return Err(format!("plan after {edit:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_heuristics_never_score_worse_than_round_robin() {
        // The satellite property test: for any seeded load profile, LPT
        // and refined plans never score worse than round-robin under the
        // cost model, every plan places each FFN expert exactly once,
        // and device counts respect the (generated) memory budget.
        let p = planner();
        Prop::new("placement-never-worse").cases(48).run(
            |rng| {
                let n_dev = gen::usize_in(rng, 1, 6);
                let n_ffn = gen::usize_in(rng, n_dev.max(2), 24);
                let n_layers = gen::usize_in(rng, 1, 4);
                let layers: Vec<Vec<u64>> = (0..n_layers)
                    .map(|_| {
                        (0..n_ffn)
                            .map(|_| {
                                // Heavy-tailed: many cold, a few hot.
                                if rng.next_f32() < 0.3 {
                                    rng.below(500) as u64
                                } else {
                                    rng.below(20) as u64
                                }
                            })
                            .collect()
                    })
                    .collect();
                let slack = gen::usize_in(rng, 0, n_ffn);
                (n_dev, layers, slack)
            },
            |(n_dev, layers, slack)| {
                let profile =
                    LoadProfile::from_counts(layers.clone()).unwrap();
                let n_ffn = profile.n_ffn_experts();
                let cap = n_ffn.div_ceil(*n_dev) + slack;
                let planner = Planner {
                    mem_budget_bytes: Some(
                        p.cost.expert_bytes * cap as u64,
                    ),
                    ..p.clone()
                };
                let rr = planner
                    .plan(Strategy::RoundRobin, *n_dev, &profile)
                    .map_err(|e| e.to_string())?;
                let m_rr =
                    planner.cost.score(&rr, &profile).makespan_s;
                let mut m_refined = f64::INFINITY;
                let mut m_replicated = f64::INFINITY;
                for strat in [
                    Strategy::Lpt,
                    Strategy::Refined,
                    Strategy::Replicated,
                    Strategy::Compressed,
                ] {
                    let plan = planner
                        .plan(strat, *n_dev, &profile)
                        .map_err(|e| e.to_string())?;
                    plan.validate().map_err(|e| e.to_string())?;
                    // Every expert stays placed; only the replicated
                    // strategy may occupy extra slots.
                    if plan.n_ffn_experts() != n_ffn {
                        return Err("plan lost experts".into());
                    }
                    let counts = plan.device_counts();
                    let slots: usize = counts.iter().sum();
                    if matches!(
                        strat,
                        Strategy::Replicated | Strategy::Compressed
                    ) {
                        if slots < n_ffn {
                            return Err(format!(
                                "replica slots {slots} < {n_ffn}"
                            ));
                        }
                    } else if slots != n_ffn {
                        return Err(format!(
                            "device counts {counts:?} != {n_ffn}"
                        ));
                    }
                    if strat == Strategy::Compressed {
                        // Compressed refines under byte-exact
                        // accounting: replicas may exceed the f32
                        // slot cap, never the byte budget.
                        let budget =
                            planner.mem_budget_bytes.unwrap();
                        let bytes = planner.device_bytes(&plan);
                        if bytes.iter().any(|&b| b > budget) {
                            return Err(format!(
                                "compressed broke the byte budget \
                                 {budget}: {bytes:?}"
                            ));
                        }
                    } else if counts.iter().any(|&c| c > cap) {
                        return Err(format!(
                            "{strat:?} violated budget cap {cap}: \
                             {counts:?}"
                        ));
                    }
                    let m =
                        planner.cost.score(&plan, &profile).makespan_s;
                    if m > m_rr * (1.0 + 1e-12) {
                        return Err(format!(
                            "{strat:?} makespan {m} worse than \
                             round-robin {m_rr}"
                        ));
                    }
                    if strat == Strategy::Refined {
                        m_refined = m;
                    }
                    // The satellite property: replication never scores
                    // worse than the best single-owner plan under the
                    // same budget (monotone seeding from refined).
                    if strat == Strategy::Replicated {
                        m_replicated = m;
                        if m > m_refined * (1.0 + 1e-12) {
                            return Err(format!(
                                "replicated makespan {m} worse than \
                                 refined {m_refined}"
                            ));
                        }
                    }
                    // And the compressed chain extends it: never
                    // worse than replicated under the same budget.
                    if strat == Strategy::Compressed
                        && m > m_replicated * (1.0 + 1e-12)
                    {
                        return Err(format!(
                            "compressed makespan {m} worse than \
                             replicated {m_replicated}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

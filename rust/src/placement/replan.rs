//! Online replanning: watch per-batch load histograms, propose a
//! migration when — and only when — the predicted gain clears the
//! migration cost with hysteresis (DESIGN.md §10).
//!
//! The [`Replanner`] accumulates a [`LoadProfile`] from each executed
//! batch's [`ForwardStats`]; [`Replanner::maybe_replan`] re-plans with the
//! configured strategy and gates the proposal on three conditions:
//!
//! 1. **interval** — at least `min_interval_batches` observed in the
//!    current window before planning is attempted, so bursty noise
//!    cannot thrash placement and a stable workload pays the planner's
//!    search cost at most once per interval, never per batch. The window
//!    restarts on every commit *and on every failed attempt*: gates must
//!    judge *recent* load, or a long-stable server's ever-growing
//!    profile would dilute later skew below the relative-gain and
//!    payback thresholds forever (window starvation);
//! 2. **relative gain** — predicted makespan must improve by at least
//!    `min_gain_frac`;
//! 3. **payback** — the per-batch predicted gain must repay the α–β
//!    migration cost within `payback_batches` batches.
//!
//! On the serving path the search itself runs **off-thread**: once
//! [`Replanner::ready`] the cluster snapshots a [`PlanTask`]
//! (planner + window profile + current plan) and submits it to its
//! worker pool; the next batch boundary joins the finished task and
//! applies the gated proposal (DESIGN.md §12). [`Replanner::maybe_replan`]
//! is the synchronous form of the identical, deterministic search.

use crate::config::MoeConfig;
use crate::moe::exec::ForwardStats;

use super::plan::PlacementPlan;
use super::planner::{Planner, Strategy};
use super::profile::LoadProfile;

/// Hysteresis knobs for online replanning.
#[derive(Clone, Debug)]
pub struct ReplanConfig {
    pub strategy: Strategy,
    /// Batches that must be observed before a proposal can fire.
    pub min_interval_batches: usize,
    /// Minimum relative predicted-makespan gain (0.05 = 5%).
    pub min_gain_frac: f64,
    /// The migration cost must be repaid within this many batches of
    /// predicted per-batch gain.
    pub payback_batches: f64,
    /// An off-thread proposal still unfinished after this many batch
    /// boundaries is *stale* — it was planned against a load profile the
    /// fleet has since outgrown, so the owner drops the handle and
    /// resets the window instead of applying it
    /// ([`Replanner::proposal_stale`]).
    pub max_proposal_age_batches: usize,
}

impl Default for ReplanConfig {
    fn default() -> ReplanConfig {
        ReplanConfig {
            strategy: Strategy::Refined,
            min_interval_batches: 8,
            min_gain_frac: 0.05,
            payback_batches: 32.0,
            max_proposal_age_batches: 4,
        }
    }
}

/// Whether a replica is being added or dropped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaKind {
    /// A new replica: its weights cross the interconnect.
    Add,
    /// A replica is freed: no transfer, the source keeps nothing to
    /// send — dropping is how an owner *move* (add elsewhere + drop
    /// here) charges only one copy.
    Drop,
}

/// One replica-set change inside a [`MigrationPlan`]: add or drop the
/// replica of `expert` on `device`. A historical single-owner move
/// decomposes into one `Add` (priced at the expert's footprint *in the
/// proposed plan's precision* — an int8 compressed replica ships a
/// quarter of the f32 bytes) plus one `Drop` (free).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpertMove {
    pub expert: usize,
    pub device: usize,
    pub kind: DeltaKind,
    pub bytes: u64,
}

/// A proposed placement change: what moves, what it costs, what it buys.
#[derive(Clone, Debug)]
pub struct MigrationPlan {
    pub plan: PlacementPlan,
    pub moves: Vec<ExpertMove>,
    /// Expert-parameter bytes that must cross the interconnect.
    pub migration_bytes: u64,
    /// α–β time to move them.
    pub migration_s: f64,
    /// Predicted makespan of the *current* plan over the observed
    /// profile (accumulated across the window's batches).
    pub predicted_makespan_before_s: f64,
    /// Predicted makespan of the proposed plan over the same profile.
    pub predicted_makespan_after_s: f64,
    /// Batches in the observation window the prediction is based on.
    pub window_batches: usize,
}

impl MigrationPlan {
    pub fn predicted_gain_s(&self) -> f64 {
        self.predicted_makespan_before_s - self.predicted_makespan_after_s
    }

    pub fn predicted_gain_frac(&self) -> f64 {
        self.predicted_gain_s()
            / self.predicted_makespan_before_s.max(1e-12)
    }

    /// Predicted makespan saved per batch.
    pub fn gain_per_batch_s(&self) -> f64 {
        self.predicted_gain_s() / self.window_batches.max(1) as f64
    }

    /// Predicted relative gain in parts-per-million — the integer form
    /// the observability trace carries ([`crate::obs::EventKind`]'s
    /// `ReplanProposed`), so the replan trail stays float-free.
    pub fn gain_ppm(&self) -> u64 {
        (self.predicted_gain_frac().max(0.0) * 1e6) as u64
    }
}

/// Accumulates load observations and proposes gated migrations.
#[derive(Clone, Debug)]
pub struct Replanner {
    pub cfg: ReplanConfig,
    planner: Planner,
    profile: LoadProfile,
    n_ffn_experts: usize,
    /// Committed replans so far.
    pub replans: usize,
}

impl Replanner {
    pub fn new(
        planner: Planner,
        cfg: ReplanConfig,
        n_ffn_experts: usize,
    ) -> Replanner {
        Replanner {
            cfg,
            planner,
            profile: LoadProfile::new(n_ffn_experts),
            n_ffn_experts,
            replans: 0,
        }
    }

    pub fn profile(&self) -> &LoadProfile {
        &self.profile
    }

    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    /// Record one executed batch's per-layer FFN loads.
    pub fn observe_loads(&mut self, loads: &[Vec<u64>]) {
        self.profile.observe_loads(loads);
    }

    /// Update the planner's quarantine mask (DESIGN.md §16): subsequent
    /// proposals place no replica on `down` devices. The cluster calls
    /// this whenever its [`DeviceHealth`] table changes — on loss *and*
    /// on rejoin (with the shrunken mask), so a restored device becomes
    /// a candidate again.
    ///
    /// [`DeviceHealth`]: crate::fault::DeviceHealth
    pub fn set_down_devices(&mut self, down: Vec<usize>) {
        self.planner.down_devices = down;
    }

    /// Record one executed batch from its forward stats.
    pub fn observe(&mut self, stats: &ForwardStats, cfg: &MoeConfig) {
        self.profile.observe_stats(stats, cfg);
    }

    /// True once the observation window holds a full interval — the
    /// point at which planning should be attempted (synchronously via
    /// [`Replanner::maybe_replan`], or off-thread by submitting
    /// [`Replanner::plan_task`] to a worker pool).
    pub fn ready(&self) -> bool {
        self.profile.batches >= self.cfg.min_interval_batches.max(1)
    }

    /// Restart the observation window after a failed (or stale) planning
    /// attempt, so gates always judge *recent* load — see the module
    /// docs on window starvation. [`Replanner::committed`] performs the
    /// same reset on the success path.
    pub fn window_reset(&mut self) {
        self.profile = LoadProfile::new(self.n_ffn_experts);
    }

    /// Is an in-flight proposal that has aged `age_batches` boundaries
    /// since submission too old to apply? A stale proposal was computed
    /// against loads the fleet has since outgrown; the owner abandons it
    /// (drop the handle, [`Replanner::window_reset`]) rather than
    /// migrate toward a dead profile.
    pub fn proposal_stale(&self, age_batches: usize) -> bool {
        age_batches > self.cfg.max_proposal_age_batches
    }

    /// Snapshot everything one detached planning attempt needs — the
    /// planner, the window's profile and the current plan — so the
    /// local search can run on another thread ([`PlanTask::run`]) while
    /// the scheduler keeps serving (DESIGN.md §12). The caller owns the
    /// submit → poll → apply-at-boundary protocol: on completion, apply
    /// the proposal and call [`Replanner::committed`], or call
    /// [`Replanner::window_reset`] when the gates held.
    pub fn plan_task(&self, current: &PlacementPlan) -> PlanTask {
        PlanTask {
            planner: self.planner.clone(),
            cfg: self.cfg.clone(),
            profile: self.profile.clone(),
            current: current.clone(),
            forced: false,
        }
    }

    /// A planning attempt that bypasses the hysteresis gates (gain,
    /// payback — interval too: the caller already decided to plan).
    /// Used after a device loss (DESIGN.md §16): evacuating a
    /// quarantined device is mandatory even when the cost model calls
    /// the migration a loss, so only plan-equals-current suppresses the
    /// proposal.
    pub fn plan_task_forced(&self, current: &PlacementPlan) -> PlanTask {
        PlanTask { forced: true, ..self.plan_task(current) }
    }

    /// Propose a migration away from `current`, or `None` while the
    /// hysteresis gates hold. Call [`Replanner::committed`] once a
    /// returned migration has been applied.
    ///
    /// Planning is attempted only once the window holds at least
    /// `min_interval_batches` (the local-search planner is far too
    /// expensive to run on every served batch), and a failed attempt
    /// restarts the window — so the next attempt is another full
    /// interval away *and* is judged on fresh loads, never against a
    /// stale accumulation of the whole uptime. This is the synchronous
    /// form; the serving path runs the identical search off-thread
    /// through [`Replanner::plan_task`] (the search is deterministic, so
    /// both produce the same proposal for the same window).
    pub fn maybe_replan(
        &mut self,
        current: &PlacementPlan,
    ) -> Option<MigrationPlan> {
        if !self.ready() {
            return None;
        }
        let proposal = self.plan_task(current).run();
        if proposal.is_none() {
            self.window_reset();
        }
        proposal
    }

    /// The proposed migration was applied: start a fresh observation
    /// window (this is the hysteresis — another replan cannot fire for
    /// at least `min_interval_batches` more batches).
    pub fn committed(&mut self) {
        self.window_reset();
        self.replans += 1;
    }
}

/// One self-contained, ungated planning attempt over a snapshotted
/// window: the payload a [`Replanner`] hands to a worker pool so the
/// local search never runs on the serving scheduler thread. Owns clones
/// of everything it reads — the live replanner keeps observing new
/// batches while this runs.
pub struct PlanTask {
    planner: Planner,
    cfg: ReplanConfig,
    profile: LoadProfile,
    current: PlacementPlan,
    /// Bypass the gain/payback gates ([`Replanner::plan_task_forced`]).
    forced: bool,
}

impl PlanTask {
    /// Run the strategy's search and apply the hysteresis gates; `None`
    /// when no worthwhile migration exists. Deterministic: equal
    /// snapshots produce equal proposals on any thread.
    pub fn run(&self) -> Option<MigrationPlan> {
        let proposed = self
            .planner
            .plan(
                self.cfg.strategy,
                self.current.n_devices(),
                &self.profile,
            )
            .ok()?;
        if proposed == self.current {
            return None;
        }
        let before = self
            .planner
            .cost
            .score(&self.current, &self.profile)
            .makespan_s;
        let after =
            self.planner.cost.score(&proposed, &self.profile).makespan_s;
        // Replica-set deltas: adds ship weights (α–β priced), drops are
        // free. A plain owner move therefore costs exactly one
        // expert-copy, as before; pure replication costs its adds and
        // nothing on the (kept) source. Adds are priced at the
        // *proposed* precision's footprint — a compressed int8 replica
        // crosses the interconnect at quantized bytes — while
        // stack-wide demotions of already-resident replicas are free:
        // requantization is local to the holding device
        // ([`PlacementPlan::diff_precision`]).
        let delta = self.current.delta(&proposed);
        let moves: Vec<ExpertMove> = delta
            .adds
            .iter()
            .map(|&(expert, device)| ExpertMove {
                expert,
                device,
                kind: DeltaKind::Add,
                bytes: self
                    .planner
                    .cost
                    .expert_bytes_for(proposed.precision(expert)),
            })
            .chain(delta.drops.iter().map(|&(expert, device)| {
                ExpertMove {
                    expert,
                    device,
                    kind: DeltaKind::Drop,
                    bytes: 0,
                }
            }))
            .collect();
        let migration_bytes: u64 = moves.iter().map(|m| m.bytes).sum();
        let mig = MigrationPlan {
            plan: proposed,
            moves,
            migration_bytes,
            migration_s: self.planner.cost.migration_s(migration_bytes),
            predicted_makespan_before_s: before,
            predicted_makespan_after_s: after,
            window_batches: self.profile.batches,
        };
        if self.forced {
            // Health-forced replans migrate regardless of predicted
            // gain: the alternative is serving degraded outputs.
            return Some(mig);
        }
        if mig.predicted_gain_s() <= 0.0 {
            return None;
        }
        if mig.predicted_gain_frac() < self.cfg.min_gain_frac {
            return None;
        }
        if mig.gain_per_batch_s() * self.cfg.payback_batches
            <= mig.migration_s
        {
            return None;
        }
        Some(mig)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cost::CostModel;

    fn replanner(min_interval: usize) -> Replanner {
        let cost = CostModel::from_config(&MoeConfig::preset("test"));
        Replanner::new(
            Planner::new(cost),
            ReplanConfig {
                min_interval_batches: min_interval,
                ..ReplanConfig::default()
            },
            4,
        )
    }

    /// A load pattern whose hot experts collide under round-robin on two
    /// devices (experts 0 and 2 both map to device 0).
    fn colliding_loads() -> Vec<Vec<u64>> {
        vec![vec![400, 2, 400, 2], vec![380, 4, 420, 2]]
    }

    #[test]
    fn fires_after_interval_and_resets_on_commit() {
        let mut rp = replanner(3);
        let current = PlacementPlan::round_robin(4, 2);
        for _ in 0..2 {
            rp.observe_loads(&colliding_loads());
            assert!(
                rp.maybe_replan(&current).is_none(),
                "must hold until the interval is observed"
            );
        }
        rp.observe_loads(&colliding_loads());
        let mig = rp
            .maybe_replan(&current)
            .expect("skewed profile past interval must fire");
        assert!(mig.predicted_gain_s() > 0.0);
        assert!(mig.predicted_gain_frac() >= rp.cfg.min_gain_frac);
        assert!(!mig.moves.is_empty());
        // Only Add deltas ship weights; Drops are free.
        let adds = mig
            .moves
            .iter()
            .filter(|m| m.kind == DeltaKind::Add)
            .count() as u64;
        assert!(adds > 0);
        assert_eq!(
            mig.migration_bytes,
            adds * rp.planner.cost.expert_bytes
        );
        assert!(mig
            .moves
            .iter()
            .all(|m| (m.kind == DeltaKind::Add)
                == (m.bytes == rp.planner.cost.expert_bytes)));
        // Hot experts separated in the proposal.
        assert_ne!(mig.plan.owner(0), mig.plan.owner(2));
        // Commit starts a fresh window: the gate closes again.
        rp.committed();
        assert_eq!(rp.replans, 1);
        assert!(rp.maybe_replan(&mig.plan).is_none());
        // A failed attempt (balanced window -> proposal == current)
        // restarts the window, so gates always judge recent load and a
        // long-stable server cannot be starved out of ever replanning.
        for _ in 0..3 {
            rp.observe_loads(&[vec![50, 50, 50, 50],
                               vec![50, 50, 50, 50]]);
        }
        assert!(rp.maybe_replan(&current).is_none());
        assert_eq!(rp.profile().batches, 0, "failed attempt must reset");
        // Skew returning after the reset clears the gates within one
        // fresh interval — undiluted by the balanced history.
        for _ in 0..3 {
            rp.observe_loads(&colliding_loads());
        }
        assert!(rp.maybe_replan(&current).is_some());
    }

    #[test]
    fn balanced_load_never_fires() {
        let mut rp = replanner(1);
        let current = PlacementPlan::round_robin(4, 2);
        for _ in 0..10 {
            rp.observe_loads(&[vec![100, 100, 100, 100]]);
        }
        assert!(rp.maybe_replan(&current).is_none());
        assert_eq!(rp.replans, 0);
    }

    #[test]
    fn small_gain_is_suppressed_by_min_gain_frac() {
        let mut rp = replanner(1);
        rp.cfg.min_gain_frac = 0.5; // demand an (unachievable) 50% win
        let current = PlacementPlan::round_robin(4, 2);
        rp.observe_loads(&colliding_loads());
        assert!(rp.maybe_replan(&current).is_none());
        // The failed attempt reset the window; with the default
        // threshold a fresh skewed window fires.
        rp.cfg.min_gain_frac = 0.05;
        rp.observe_loads(&colliding_loads());
        assert!(rp.maybe_replan(&current).is_some());
    }

    #[test]
    fn payback_gate_blocks_tiny_windows_with_big_migrations() {
        let mut rp = replanner(1);
        rp.cfg.payback_batches = 0.0; // nothing can ever repay
        let current = PlacementPlan::round_robin(4, 2);
        rp.observe_loads(&colliding_loads());
        assert!(rp.maybe_replan(&current).is_none());
    }

    #[test]
    fn proposal_equal_to_current_is_not_a_migration() {
        let mut rp = replanner(1);
        let current = PlacementPlan::round_robin(4, 2);
        rp.observe_loads(&colliding_loads());
        let mig = rp.maybe_replan(&current).unwrap();
        // Once on the proposed plan, the same profile proposes no move.
        assert!(rp.maybe_replan(&mig.plan).is_none());
    }

    #[test]
    fn forced_plan_task_bypasses_gates_to_evacuate_a_down_device() {
        // Interval not met, load balanced, migration gain negative:
        // every hysteresis gate would hold — but a health-forced task
        // must still move experts off the quarantined device.
        let mut rp = replanner(8);
        rp.set_down_devices(vec![0]);
        rp.observe_loads(&[vec![10, 10, 10, 10]]);
        let current = PlacementPlan::round_robin(4, 2);
        let mig = rp
            .plan_task_forced(&current)
            .run()
            .expect("evacuation must fire regardless of gain");
        for e in 0..4 {
            assert!(
                !mig.plan.replicas(e).contains(&0),
                "expert {e} left on the down device"
            );
        }
        // The ungated path still suppresses a no-op proposal.
        assert!(rp.plan_task_forced(&mig.plan).run().is_none());
        // The normal (gated) task keeps holding under the same window.
        assert!(rp.plan_task(&current).run().is_none());
    }

    #[test]
    fn stale_proposals_are_flagged_by_age() {
        let rp = replanner(1);
        assert_eq!(rp.cfg.max_proposal_age_batches, 4);
        assert!(!rp.proposal_stale(0));
        assert!(!rp.proposal_stale(4));
        assert!(rp.proposal_stale(5), "age past the bound is stale");
        let mut tight = replanner(1);
        tight.cfg.max_proposal_age_batches = 0;
        assert!(!tight.proposal_stale(0));
        assert!(tight.proposal_stale(1));
    }

    #[test]
    fn replicated_strategy_migration_prices_adds_only() {
        // A single dominant expert: the replicated planner's proposal
        // grows its replica set, and only the Add deltas are priced —
        // one expert copy per new replica — while kept sources ship
        // nothing.
        let cost = CostModel::from_config(&MoeConfig::preset("test"));
        let mut rp = Replanner::new(
            Planner::new(cost),
            ReplanConfig {
                strategy: Strategy::Replicated,
                min_interval_batches: 1,
                ..ReplanConfig::default()
            },
            4,
        );
        let current = PlacementPlan::round_robin(4, 2);
        rp.observe_loads(&[vec![1000, 2, 2, 2], vec![1000, 2, 2, 2]]);
        let mig = rp
            .maybe_replan(&current)
            .expect("hot expert must justify replication");
        assert!(mig.plan.is_replicated());
        let adds = mig
            .moves
            .iter()
            .filter(|m| m.kind == DeltaKind::Add)
            .count() as u64;
        assert_eq!(
            mig.migration_bytes,
            adds * rp.planner().cost.expert_bytes
        );
        assert!(mig.migration_s > 0.0);
    }

    #[test]
    fn compressed_strategy_prices_int8_adds_at_quantized_bytes() {
        // Under a budget with headroom for one int8 copy but no third
        // f32 slot, the compressed proposal demotes the hot expert and
        // ships its new replica at quantized bytes; full-precision adds
        // (plain moves the chain also found) still price at f32 bytes,
        // and the stack-wide demotion of resident copies is free.
        use crate::config::Precision;
        let cost = CostModel::from_config(&MoeConfig::preset("test"));
        let f32b = cost.expert_bytes;
        let i8b = cost.expert_bytes_int8;
        let planner = Planner::new(cost).with_budget(2 * f32b + i8b);
        let mut rp = Replanner::new(
            planner,
            ReplanConfig {
                strategy: Strategy::Compressed,
                min_interval_batches: 1,
                ..ReplanConfig::default()
            },
            4,
        );
        let current = PlacementPlan::round_robin(4, 2);
        rp.observe_loads(&[vec![1000, 2, 2, 2], vec![1000, 2, 2, 2]]);
        let mig = rp
            .maybe_replan(&current)
            .expect("hot expert must justify a compressed replica");
        assert!(mig.plan.is_mixed_precision());
        assert_eq!(mig.plan.precision(0), Precision::Int8);
        assert!(mig.plan.replica_count(0) > 1);
        let add_bytes: Vec<u64> = mig
            .moves
            .iter()
            .filter(|m| m.kind == DeltaKind::Add)
            .map(|m| m.bytes)
            .collect();
        assert!(
            add_bytes.contains(&i8b),
            "int8 replica must ship at quantized bytes: {add_bytes:?}"
        );
        assert!(add_bytes.iter().all(|&b| b == i8b || b == f32b));
        assert_eq!(
            mig.migration_bytes,
            add_bytes.iter().sum::<u64>()
        );
    }
}

//! [`PlacementPlan`] — the FFN-expert → device map.
//!
//! The plan only ever places **FFN** experts: zero-computation experts are
//! structurally replicated on every device (paper Sec. 3.4), so they never
//! appear in a plan and never migrate. Invariants (DESIGN.md §10):
//!
//! * every FFN expert is placed on exactly one device (the `owner` vector
//!   representation makes duplicates impossible by construction);
//! * every owner is a valid device index;
//! * a plan is pure *layout*: applying any valid plan never changes model
//!   outputs — the cluster combine order is placement-independent.

use anyhow::Result;

use crate::util::json::Json;

/// Where each FFN expert lives. ZC experts are implicitly replicated on
/// all devices and are not part of the plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacementPlan {
    n_devices: usize,
    /// `owner[e]` = device holding FFN expert `e`.
    owner: Vec<usize>,
}

impl PlacementPlan {
    /// The historical default: expert `e` lives on device `e % n_devices`.
    pub fn round_robin(n_ffn_experts: usize, n_devices: usize)
        -> PlacementPlan {
        assert!(n_devices > 0, "placement needs at least one device");
        PlacementPlan {
            n_devices,
            owner: (0..n_ffn_experts).map(|e| e % n_devices).collect(),
        }
    }

    /// Build from an explicit owner vector, validating the invariants.
    pub fn from_owner(owner: Vec<usize>, n_devices: usize)
        -> Result<PlacementPlan> {
        let plan = PlacementPlan { n_devices, owner };
        plan.validate()?;
        Ok(plan)
    }

    /// Check the plan invariants (device count positive, every owner in
    /// range). Expert uniqueness is inherent in the representation.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n_devices > 0, "plan has no devices");
        for (e, &d) in self.owner.iter().enumerate() {
            anyhow::ensure!(
                d < self.n_devices,
                "expert {e} placed on device {d} (n_devices {})",
                self.n_devices
            );
        }
        Ok(())
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn n_ffn_experts(&self) -> usize {
        self.owner.len()
    }

    /// Owner device of FFN expert `e`.
    pub fn owner(&self, expert: usize) -> usize {
        self.owner[expert]
    }

    pub fn owners(&self) -> &[usize] {
        &self.owner
    }

    /// Reassign one expert (planner-internal moves go through here so the
    /// invariants cannot be broken by construction).
    pub fn set_owner(&mut self, expert: usize, device: usize) {
        assert!(device < self.n_devices, "device {device} out of range");
        self.owner[expert] = device;
    }

    /// FFN experts living on `device`, ascending.
    pub fn device_experts(&self, device: usize) -> Vec<usize> {
        (0..self.owner.len())
            .filter(|&e| self.owner[e] == device)
            .collect()
    }

    /// Number of FFN experts per device.
    pub fn device_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_devices];
        for &d in &self.owner {
            counts[d] += 1;
        }
        counts
    }

    pub fn is_round_robin(&self) -> bool {
        self.owner.iter().enumerate().all(|(e, &d)| d == e % self.n_devices)
    }

    /// Experts whose owner differs between `self` and `to`:
    /// `(expert, from_device, to_device)`.
    pub fn diff(&self, to: &PlacementPlan) -> Vec<(usize, usize, usize)> {
        assert_eq!(self.owner.len(), to.owner.len(), "plan size mismatch");
        self.owner
            .iter()
            .zip(&to.owner)
            .enumerate()
            .filter(|(_, (a, b))| a != b)
            .map(|(e, (&a, &b))| (e, a, b))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_devices", Json::num(self.n_devices as f64)),
            (
                "owner",
                Json::Arr(
                    self.owner.iter().map(|&d| Json::num(d as f64)).collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<PlacementPlan> {
        let n_devices = j
            .get("n_devices")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("plan json: missing n_devices"))?;
        let owner = j
            .get("owner")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("plan json: missing owner"))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("plan json: bad owner"))
            })
            .collect::<Result<Vec<usize>>>()?;
        PlacementPlan::from_owner(owner, n_devices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_matches_modulo() {
        let p = PlacementPlan::round_robin(10, 4);
        assert!(p.is_round_robin());
        for e in 0..10 {
            assert_eq!(p.owner(e), e % 4);
        }
        assert_eq!(p.device_counts(), vec![3, 3, 2, 2]);
        assert_eq!(p.device_experts(1), vec![1, 5, 9]);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn from_owner_rejects_out_of_range() {
        assert!(PlacementPlan::from_owner(vec![0, 1, 2], 3).is_ok());
        assert!(PlacementPlan::from_owner(vec![0, 3], 3).is_err());
        assert!(PlacementPlan::from_owner(vec![], 0).is_err());
    }

    #[test]
    fn diff_lists_moved_experts() {
        let a = PlacementPlan::round_robin(4, 2); // [0,1,0,1]
        let b = PlacementPlan::from_owner(vec![0, 1, 1, 0], 2).unwrap();
        assert_eq!(a.diff(&b), vec![(2, 0, 1), (3, 1, 0)]);
        assert!(a.diff(&a).is_empty());
        assert!(!b.is_round_robin());
    }

    #[test]
    fn json_roundtrip() {
        let p = PlacementPlan::from_owner(vec![2, 0, 1, 1], 3).unwrap();
        let back = PlacementPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        // Parse through the text form too.
        let txt = p.to_json().to_string();
        let back2 =
            PlacementPlan::from_json(&Json::parse(&txt).unwrap()).unwrap();
        assert_eq!(p, back2);
    }
}

//! [`PlacementPlan`] — the FFN-expert → device-set map.
//!
//! The plan only ever places **FFN** experts: zero-computation experts are
//! structurally replicated on every device (paper Sec. 3.4), so they never
//! appear in a plan and never migrate. Since ISSUE 6 an FFN expert may
//! live on *several* devices (multi-replica placement for hot experts);
//! the historical owner-vector plan is the special case where every
//! replica set has size one. Invariants (DESIGN.md §10/§13):
//!
//! * every FFN expert has a non-empty replica set; sets are sorted
//!   ascending and duplicate-free, so a given (expert, device) replica
//!   exists at most once and replica *index* is a canonical notion;
//! * every replica device is a valid device index;
//! * a plan is pure *layout*: applying any valid plan never changes model
//!   outputs — the cluster combine order is placement-independent and the
//!   token → replica split below is a deterministic function of the
//!   expert's micro-batch and the replica devices' speed weights alone
//!   (DESIGN.md §13); speeds shift slice *boundaries*, never row order.

use std::ops::Range;

use anyhow::Result;

use crate::config::Precision;
use crate::util::json::Json;

/// Where each FFN expert lives. ZC experts are implicitly replicated on
/// all devices and are not part of the plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacementPlan {
    n_devices: usize,
    /// `replicas[e]` = sorted, deduplicated, non-empty devices holding
    /// FFN expert `e`. `replicas[e][0]` is the *primary* (the historical
    /// single owner).
    replicas: Vec<Vec<usize>>,
    /// `precision[e]` = the stack-wide serving precision of FFN expert
    /// `e` (DESIGN.md §17). Uniform across every replica and every
    /// layer: replicas of one expert never mix precisions, so the
    /// token → replica split stays output-invariant. Defaults to f32.
    precision: Vec<Precision>,
}

/// The replica-set difference between two plans, as per-(expert, device)
/// deltas. An owner *move* decomposes into one add plus one drop; adds
/// are what cost interconnect bytes (replication keeps the source, a
/// drop just frees memory).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReplicaDelta {
    /// `(expert, device)` replicas present in `to` but not in `self`.
    pub adds: Vec<(usize, usize)>,
    /// `(expert, device)` replicas present in `self` but not in `to`.
    pub drops: Vec<(usize, usize)>,
}

impl ReplicaDelta {
    pub fn is_empty(&self) -> bool {
        self.adds.is_empty() && self.drops.is_empty()
    }
}

/// Deterministic integer weight of a relative device speed, used to
/// apportion a replicated expert's micro-batch. Quantised to 1/1024ths
/// (rounded, floored at 1) so the split is pure integer arithmetic —
/// bitwise-reproducible across platforms — and a uniform fleet (all
/// speeds 1.0) degenerates to equal weights.
pub fn speed_weight(speed: f64) -> u64 {
    debug_assert!(speed > 0.0, "device speed must be positive");
    ((speed * 1024.0).round() as u64).max(1)
}

/// Core weighted-apportionment primitive: the integral share of a
/// replica with weight `w` whose predecessors (in canonical replica
/// order) weigh `prefix_w` of `total_w`, splitting `load` rows on the
/// cumulative boundaries `floor(load · prefix / total)`. Boundaries are
/// monotone and end at `load`, so shares are non-negative and sum to
/// `load` exactly; u128 intermediates make the products overflow-proof.
pub fn weighted_share(load: u64, total_w: u64, prefix_w: u64, w: u64)
    -> u64 {
    debug_assert!(w > 0 && prefix_w + w <= total_w);
    let hi = (load as u128 * (prefix_w + w) as u128 / total_w as u128)
        as u64;
    let lo = (load as u128 * prefix_w as u128 / total_w as u128) as u64;
    hi - lo
}

/// Deterministic token → replica split: `n_rows` micro-batch rows over
/// one contiguous slice per replica, sized in proportion to the
/// replica's [`speed_weight`] (equal weights split as evenly as
/// possible, any remainder rows landing at the end). The slice a row
/// lands in depends only on (row index, row count, replica weights) —
/// never on workers, partitioning or where replicas live — and
/// concatenating the slices in replica order reproduces the original
/// micro-batch row order, which is what keeps replicated combine bitwise
/// identical (DESIGN.md §13).
pub fn replica_slices(n_rows: usize, weights: &[u64])
    -> Vec<Range<usize>> {
    assert!(!weights.is_empty(), "expert with empty replica set");
    assert!(weights.iter().all(|&w| w > 0), "replica weight of zero");
    let total: u64 = weights.iter().sum();
    let mut prefix = 0u64;
    let mut start = 0usize;
    weights
        .iter()
        .map(|&w| {
            let end = start
                + weighted_share(n_rows as u64, total, prefix, w)
                    as usize;
            prefix += w;
            let r = start..end;
            start = end;
            r
        })
        .collect()
}

/// Integral load share of the replica at index `j` of `weights` for a
/// total load of `load` assignments — exactly
/// `replica_slices(load, weights)[j].len()`, so the cost model's
/// per-replica accounting matches the runtime split.
pub fn replica_share(load: u64, weights: &[u64], j: usize) -> u64 {
    debug_assert!(j < weights.len());
    let total: u64 = weights.iter().sum();
    let prefix: u64 = weights[..j].iter().sum();
    weighted_share(load, total, prefix, weights[j])
}

impl PlacementPlan {
    /// The historical default: expert `e` lives (only) on device
    /// `e % n_devices`.
    pub fn round_robin(n_ffn_experts: usize, n_devices: usize)
        -> PlacementPlan {
        assert!(n_devices > 0, "placement needs at least one device");
        PlacementPlan {
            n_devices,
            replicas: (0..n_ffn_experts)
                .map(|e| vec![e % n_devices])
                .collect(),
            precision: vec![Precision::F32; n_ffn_experts],
        }
    }

    /// Build a single-replica plan from an explicit owner vector,
    /// validating the invariants.
    pub fn from_owner(owner: Vec<usize>, n_devices: usize)
        -> Result<PlacementPlan> {
        PlacementPlan::from_replicas(
            owner.into_iter().map(|d| vec![d]).collect(),
            n_devices,
        )
    }

    /// Build from explicit replica sets, validating the invariants.
    pub fn from_replicas(
        replicas: Vec<Vec<usize>>,
        n_devices: usize,
    ) -> Result<PlacementPlan> {
        let precision = vec![Precision::F32; replicas.len()];
        let plan = PlacementPlan { n_devices, replicas, precision };
        plan.validate()?;
        Ok(plan)
    }

    /// Check the plan invariants: device count positive, every replica
    /// set non-empty, strictly ascending (sorted + deduplicated) and in
    /// device range.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n_devices > 0, "plan has no devices");
        for (e, reps) in self.replicas.iter().enumerate() {
            anyhow::ensure!(
                !reps.is_empty(),
                "expert {e} has an empty replica set"
            );
            for (j, &d) in reps.iter().enumerate() {
                anyhow::ensure!(
                    d < self.n_devices,
                    "expert {e} placed on device {d} (n_devices {})",
                    self.n_devices
                );
                anyhow::ensure!(
                    j == 0 || reps[j - 1] < d,
                    "expert {e} replica set {reps:?} is not strictly \
                     ascending"
                );
            }
        }
        anyhow::ensure!(
            self.precision.len() == self.replicas.len(),
            "precision map length {} != expert count {}",
            self.precision.len(),
            self.replicas.len()
        );
        Ok(())
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn n_ffn_experts(&self) -> usize {
        self.replicas.len()
    }

    /// Primary (first-replica) device of FFN expert `e` — the historical
    /// single owner for single-replica plans.
    pub fn owner(&self, expert: usize) -> usize {
        self.replicas[expert][0]
    }

    /// Primary device per expert (for display/diagnostics; replicated
    /// plans carry more than this).
    pub fn owners(&self) -> Vec<usize> {
        self.replicas.iter().map(|r| r[0]).collect()
    }

    /// Sorted replica devices of FFN expert `e`.
    pub fn replicas(&self, expert: usize) -> &[usize] {
        &self.replicas[expert]
    }

    pub fn replica_count(&self, expert: usize) -> usize {
        self.replicas[expert].len()
    }

    /// Does any expert have more than one replica?
    pub fn is_replicated(&self) -> bool {
        self.replicas.iter().any(|r| r.len() > 1)
    }

    /// Stack-wide serving precision of FFN expert `e`.
    pub fn precision(&self, expert: usize) -> Precision {
        self.precision[expert]
    }

    /// The full per-expert precision map (what the engine/cluster feed
    /// into [`crate::moe::weights::QuantStackWeights::build`]).
    pub fn precisions(&self) -> &[Precision] {
        &self.precision
    }

    /// Set the stack-wide precision of `expert` — every replica of it,
    /// in every layer, serves at `p` from the next (re)spawn on.
    pub fn set_precision(&mut self, expert: usize, p: Precision) {
        self.precision[expert] = p;
    }

    /// Does any expert serve at a non-f32 precision?
    pub fn is_mixed_precision(&self) -> bool {
        self.precision.iter().any(|&p| p != Precision::F32)
    }

    /// Experts whose precision differs between `self` and `to`. A
    /// precision change re-encodes the device-resident weights (no
    /// interconnect traffic — the f32 master copy is local), but the
    /// holding devices must still swap kernels/replicas, so the cluster
    /// treats these like replica-set diffs when respawning.
    pub fn diff_precision(&self, to: &PlacementPlan) -> Vec<usize> {
        assert_eq!(
            self.precision.len(),
            to.precision.len(),
            "plan size mismatch"
        );
        (0..self.precision.len())
            .filter(|&e| self.precision[e] != to.precision[e])
            .collect()
    }

    /// Replace `expert`'s whole replica set with the single `device`
    /// (planner-internal single-owner moves go through here so the
    /// invariants cannot be broken by construction).
    pub fn set_owner(&mut self, expert: usize, device: usize) {
        assert!(device < self.n_devices, "device {device} out of range");
        self.replicas[expert].clear();
        self.replicas[expert].push(device);
    }

    /// Add a replica of `expert` on `device` (no-op if already present).
    /// Returns whether the set grew.
    pub fn add_replica(&mut self, expert: usize, device: usize) -> bool {
        assert!(device < self.n_devices, "device {device} out of range");
        match self.replicas[expert].binary_search(&device) {
            Ok(_) => false,
            Err(i) => {
                self.replicas[expert].insert(i, device);
                true
            }
        }
    }

    /// Drop `expert`'s replica on `device`. Panics if it would leave the
    /// expert unplaced (the non-empty invariant is structural).
    pub fn remove_replica(&mut self, expert: usize, device: usize) {
        let reps = &mut self.replicas[expert];
        assert!(
            reps.len() > 1,
            "cannot drop expert {expert}'s last replica"
        );
        match reps.binary_search(&device) {
            Ok(i) => {
                reps.remove(i);
            }
            Err(_) => panic!(
                "expert {expert} has no replica on device {device}"
            ),
        }
    }

    /// FFN experts with a replica on `device`, ascending.
    pub fn device_experts(&self, device: usize) -> Vec<usize> {
        (0..self.replicas.len())
            .filter(|&e| self.replicas[e].contains(&device))
            .collect()
    }

    /// FFN expert *slots* per device — every replica occupies one slot,
    /// so these are what a per-device memory budget constrains.
    pub fn device_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_devices];
        for reps in &self.replicas {
            for &d in reps {
                counts[d] += 1;
            }
        }
        counts
    }

    pub fn is_round_robin(&self) -> bool {
        self.replicas
            .iter()
            .enumerate()
            .all(|(e, r)| r.len() == 1 && r[0] == e % self.n_devices)
    }

    /// Experts whose replica set differs between `self` and `to`.
    pub fn diff_experts(&self, to: &PlacementPlan) -> Vec<usize> {
        assert_eq!(
            self.replicas.len(),
            to.replicas.len(),
            "plan size mismatch"
        );
        (0..self.replicas.len())
            .filter(|&e| self.replicas[e] != to.replicas[e])
            .collect()
    }

    /// Per-(expert, device) replica deltas turning `self` into `to`.
    /// Both sets are sorted, so this is a linear merge per expert.
    pub fn delta(&self, to: &PlacementPlan) -> ReplicaDelta {
        assert_eq!(
            self.replicas.len(),
            to.replicas.len(),
            "plan size mismatch"
        );
        let mut delta = ReplicaDelta::default();
        for (e, (a, b)) in
            self.replicas.iter().zip(&to.replicas).enumerate()
        {
            for &d in b {
                if !a.contains(&d) {
                    delta.adds.push((e, d));
                }
            }
            for &d in a {
                if !b.contains(&d) {
                    delta.drops.push((e, d));
                }
            }
        }
        delta
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_devices", Json::num(self.n_devices as f64)),
            (
                "replicas",
                Json::Arr(
                    self.replicas
                        .iter()
                        .map(|reps| {
                            Json::Arr(
                                reps.iter()
                                    .map(|&d| Json::num(d as f64))
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "precision",
                Json::Arr(
                    self.precision
                        .iter()
                        .map(|p| Json::Str(p.label().to_string()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse either the replica-set form written by [`Self::to_json`] or
    /// the legacy single-owner `{"owner": [..]}` form (profiles captured
    /// before multi-replica placement stay loadable).
    pub fn from_json(j: &Json) -> Result<PlacementPlan> {
        let n_devices = j
            .get("n_devices")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("plan json: missing n_devices"))?;
        if let Some(reps) = j.get("replicas").and_then(Json::as_arr) {
            let replicas = reps
                .iter()
                .map(|set| {
                    set.as_arr()
                        .ok_or_else(|| {
                            anyhow::anyhow!("plan json: bad replica set")
                        })?
                        .iter()
                        .map(|v| {
                            v.as_usize().ok_or_else(|| {
                                anyhow::anyhow!("plan json: bad replica")
                            })
                        })
                        .collect::<Result<Vec<usize>>>()
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            let mut plan =
                PlacementPlan::from_replicas(replicas, n_devices)?;
            // Precision map: optional — plans captured before
            // mixed-precision placement parse as all-f32.
            if let Some(prec) = j.get("precision").and_then(Json::as_arr)
            {
                anyhow::ensure!(
                    prec.len() == plan.precision.len(),
                    "plan json: precision length {} != expert count {}",
                    prec.len(),
                    plan.precision.len()
                );
                for (e, v) in prec.iter().enumerate() {
                    let s = v.as_str().ok_or_else(|| {
                        anyhow::anyhow!("plan json: bad precision entry")
                    })?;
                    plan.precision[e] =
                        Precision::parse(s).ok_or_else(|| {
                            anyhow::anyhow!(
                                "plan json: unknown precision '{s}'"
                            )
                        })?;
                }
            }
            return Ok(plan);
        }
        let owner = j
            .get("owner")
            .and_then(Json::as_arr)
            .ok_or_else(|| {
                anyhow::anyhow!("plan json: missing replicas/owner")
            })?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("plan json: bad owner"))
            })
            .collect::<Result<Vec<usize>>>()?;
        PlacementPlan::from_owner(owner, n_devices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_matches_modulo() {
        let p = PlacementPlan::round_robin(10, 4);
        assert!(p.is_round_robin());
        assert!(!p.is_replicated());
        for e in 0..10 {
            assert_eq!(p.owner(e), e % 4);
            assert_eq!(p.replicas(e), &[e % 4]);
        }
        assert_eq!(p.device_counts(), vec![3, 3, 2, 2]);
        assert_eq!(p.device_experts(1), vec![1, 5, 9]);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn from_owner_rejects_out_of_range() {
        assert!(PlacementPlan::from_owner(vec![0, 1, 2], 3).is_ok());
        assert!(PlacementPlan::from_owner(vec![0, 3], 3).is_err());
        assert!(PlacementPlan::from_owner(vec![], 0).is_err());
    }

    #[test]
    fn replica_set_invariants() {
        // Sorted, deduped, non-empty, in range.
        assert!(PlacementPlan::from_replicas(
            vec![vec![0, 1], vec![1]], 2).is_ok());
        assert!(PlacementPlan::from_replicas(vec![vec![]], 2).is_err());
        assert!(PlacementPlan::from_replicas(
            vec![vec![1, 0]], 2).is_err()); // unsorted
        assert!(PlacementPlan::from_replicas(
            vec![vec![0, 0]], 2).is_err()); // duplicate
        assert!(PlacementPlan::from_replicas(
            vec![vec![0, 2]], 2).is_err()); // out of range
    }

    #[test]
    fn add_and_remove_replicas_keep_sets_sorted() {
        let mut p = PlacementPlan::round_robin(4, 3); // [0],[1],[2],[0]
        assert!(p.add_replica(1, 0));
        assert!(!p.add_replica(1, 0)); // idempotent
        assert!(p.add_replica(1, 2));
        assert_eq!(p.replicas(1), &[0, 1, 2]);
        assert!(p.is_replicated());
        assert_eq!(p.owner(1), 0, "primary is the smallest device");
        assert_eq!(p.device_counts(), vec![3, 1, 2]);
        p.remove_replica(1, 1);
        assert_eq!(p.replicas(1), &[0, 2]);
        assert!(p.validate().is_ok());
        // set_owner collapses back to a single replica.
        p.set_owner(1, 1);
        assert_eq!(p.replicas(1), &[1]);
        assert!(!p.is_replicated());
    }

    #[test]
    #[should_panic]
    fn removing_the_last_replica_panics() {
        let mut p = PlacementPlan::round_robin(2, 2);
        p.remove_replica(0, 0);
    }

    #[test]
    fn delta_lists_replica_adds_and_drops() {
        let a = PlacementPlan::round_robin(4, 2); // [0],[1],[0],[1]
        let b = PlacementPlan::from_owner(vec![0, 1, 1, 0], 2).unwrap();
        let d = a.delta(&b);
        assert_eq!(d.adds, vec![(2, 1), (3, 0)]);
        assert_eq!(d.drops, vec![(2, 0), (3, 1)]);
        assert_eq!(a.diff_experts(&b), vec![2, 3]);
        assert!(a.delta(&a).is_empty());
        assert!(!b.is_round_robin());
        // Pure replication: adds only, no drops.
        let mut c = a.clone();
        c.add_replica(0, 1);
        let d = a.delta(&c);
        assert_eq!(d.adds, vec![(0, 1)]);
        assert!(d.drops.is_empty());
        assert_eq!(c.delta(&a).drops, vec![(0, 1)]);
    }

    #[test]
    fn replica_slices_are_balanced_contiguous_and_exhaustive() {
        // Uniform weights: as even as possible, remainder at the end.
        assert_eq!(replica_slices(10, &[1]), vec![0..10]);
        assert_eq!(replica_slices(10, &[1, 1, 1]), vec![0..3, 3..6, 6..10]);
        assert_eq!(replica_slices(2, &[1, 1, 1]), vec![0..0, 0..1, 1..2]);
        assert_eq!(replica_slices(0, &[1, 1]), vec![0..0, 0..0]);
        // Speed-weighted: a 3× replica takes three quarters of the rows.
        assert_eq!(
            replica_slices(8, &[speed_weight(3.0), speed_weight(1.0)]),
            vec![0..6, 6..8]
        );
        let weight_sets: &[&[u64]] = &[
            &[1, 1, 1, 1],
            &[2048, 1024, 1024, 512],
            &[speed_weight(0.5), speed_weight(2.0), speed_weight(1.0)],
            &[3],
            &[7, 1, 1, 1, 1, 1, 100],
        ];
        for &weights in weight_sets {
            for n in [0usize, 1, 4, 17, 100] {
                let slices = replica_slices(n, weights);
                assert_eq!(slices.len(), weights.len());
                let mut next = 0;
                for (j, s) in slices.iter().enumerate() {
                    assert_eq!(s.start, next, "slices must be contiguous");
                    next = s.end;
                    assert_eq!(
                        s.len() as u64,
                        replica_share(n as u64, weights, j),
                        "cost-model share must match the runtime split"
                    );
                }
                assert_eq!(next, n, "slices must cover every row");
            }
        }
        // Heavier weight never gets fewer rows when loads are large
        // enough to split.
        let s = replica_slices(1000, &[speed_weight(2.0), 1024]);
        assert!(s[0].len() > s[1].len());
        assert_eq!(s[0].len(), 666, "floor(1000·2048/3072)");
    }

    #[test]
    fn precision_map_defaults_diffs_and_roundtrips() {
        let mut p = PlacementPlan::round_robin(4, 2);
        assert!(!p.is_mixed_precision());
        assert!(p.precisions().iter().all(|&x| x == Precision::F32));
        p.set_precision(2, Precision::Int8);
        assert!(p.is_mixed_precision());
        assert_eq!(p.precision(2), Precision::Int8);
        assert!(p.validate().is_ok());
        // diff_precision catches precision-only changes that
        // diff_experts (replica sets) cannot see.
        let base = PlacementPlan::round_robin(4, 2);
        assert_eq!(base.diff_precision(&p), vec![2]);
        assert!(base.diff_experts(&p).is_empty());
        assert_ne!(base, p, "precision is part of plan identity");
        // JSON roundtrip preserves the map.
        let back = PlacementPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        // Pre-precision JSON (no "precision" key) parses as all-f32.
        let legacy = Json::parse(
            "{\"n_devices\": 2, \"replicas\": [[0], [1], [0], [1]]}",
        )
        .unwrap();
        let old = PlacementPlan::from_json(&legacy).unwrap();
        assert!(!old.is_mixed_precision());
        assert_eq!(old, base);
        // Bad precision entries are rejected.
        let bad = Json::parse(
            "{\"n_devices\": 2, \"replicas\": [[0]], \
             \"precision\": [\"fp4\"]}",
        )
        .unwrap();
        assert!(PlacementPlan::from_json(&bad).is_err());
    }

    #[test]
    fn speed_weights_quantise_and_floor() {
        assert_eq!(speed_weight(1.0), 1024);
        assert_eq!(speed_weight(2.0), 2048);
        assert_eq!(speed_weight(0.5), 512);
        // Sub-quantum speeds still get a positive weight.
        assert_eq!(speed_weight(1e-9), 1);
    }

    #[test]
    fn json_roundtrip_and_legacy_owner_form() {
        let p = PlacementPlan::from_owner(vec![2, 0, 1, 1], 3).unwrap();
        let back = PlacementPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        // A replicated plan roundtrips through the text form too.
        let mut r = p.clone();
        r.add_replica(0, 1);
        r.add_replica(3, 2);
        let txt = r.to_json().to_string();
        let back2 =
            PlacementPlan::from_json(&Json::parse(&txt).unwrap()).unwrap();
        assert_eq!(r, back2);
        // Legacy owner-vector JSON still parses.
        let legacy = Json::parse(
            "{\"n_devices\": 3, \"owner\": [2, 0, 1, 1]}",
        )
        .unwrap();
        assert_eq!(PlacementPlan::from_json(&legacy).unwrap(), p);
        // Invalid replica sets are rejected at parse time.
        let bad = Json::parse(
            "{\"n_devices\": 2, \"replicas\": [[1, 0]]}",
        )
        .unwrap();
        assert!(PlacementPlan::from_json(&bad).is_err());
    }
}

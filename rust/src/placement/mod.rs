//! Load-aware expert placement (DESIGN.md §10) — the planning layer
//! behind the paper's deployment-friendliness claim (Sec. 3.4).
//!
//! MoE++ replicates the near-zero-parameter zero/copy/constant experts on
//! every device and shards only the FFN experts, so *where* each FFN
//! expert lives is the dominant lever on expert-parallel makespan: a hot
//! expert colliding with another hot expert on one device stalls the
//! whole step. This module owns that decision:
//!
//! * [`plan::PlacementPlan`] — the FFN expert → device map (ZC experts
//!   are structurally replicated and never planned or migrated);
//! * [`profile::LoadProfile`] — observed per-layer per-expert token
//!   loads, recovered exactly from [`ForwardStats`] capacity accounting;
//! * [`cost::CostModel`] — α–β + per-assignment compute scoring of a
//!   plan against a profile, reusing the cluster's [`LinkModel`] /
//!   [`LayerTraffic`] math;
//! * [`planner::Planner`] — round-robin baseline, greedy LPT bin-packing
//!   and local-search refinement under a per-device memory budget, with a
//!   never-worse-than-baseline guarantee;
//! * [`replan::Replanner`] — online replanning with hysteresis: proposes
//!   a [`replan::MigrationPlan`] (experts to move, bytes, predicted
//!   makespan delta) only when the predicted gain clears the migration
//!   cost.
//!
//! Placement is pure layout: [`cluster::Topology`] consumes a plan (round
//! robin remains the default, bitwise-unchanged), and the cluster combine
//! order is placement-independent, so **no plan ever changes model
//! outputs** — enforced by `rust/tests/cluster_placement.rs`.
//!
//! [`ForwardStats`]: crate::moe::exec::ForwardStats
//! [`LinkModel`]: crate::cluster::topology::LinkModel
//! [`LayerTraffic`]: crate::cluster::comm::LayerTraffic
//! [`cluster::Topology`]: crate::cluster::topology::Topology

pub mod cost;
pub mod plan;
pub mod planner;
pub mod profile;
pub mod replan;

pub use cost::{CostModel, DeltaScorer, PlanScore};
pub use plan::PlacementPlan;
pub use planner::{Planner, Strategy};
pub use profile::LoadProfile;
pub use replan::{
    ExpertMove, MigrationPlan, PlanTask, ReplanConfig, Replanner,
};

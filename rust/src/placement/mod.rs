//! Load-aware expert placement (DESIGN.md §10, §13) — the planning layer
//! behind the paper's deployment-friendliness claim (Sec. 3.4).
//!
//! MoE++ replicates the near-zero-parameter zero/copy/constant experts on
//! every device and shards only the FFN experts, so *where* each FFN
//! expert lives is the dominant lever on expert-parallel makespan: a hot
//! expert colliding with another hot expert on one device stalls the
//! whole step. This module owns that decision:
//!
//! * [`plan::PlacementPlan`] — the FFN expert → device *replica set* map
//!   (ZC experts are structurally replicated and never planned or
//!   migrated). A multi-replica expert's token micro-batch is split
//!   across its replicas in deterministic contiguous slices weighted by
//!   per-device speed ([`plan::replica_slices`] / [`plan::replica_share`]
//!   over [`plan::speed_weight`]s — a 2× device gets ~2× the rows);
//! * [`profile::LoadProfile`] — observed per-layer per-expert token
//!   loads, recovered exactly from [`ForwardStats`] capacity accounting;
//! * [`cost::CostModel`] — α–β + per-assignment compute scoring of a
//!   plan against a profile on a possibly heterogeneous fleet
//!   (per-device speeds), reusing the cluster's [`LinkModel`] /
//!   [`LayerTraffic`] math; [`cost::DeltaScorer`] re-scores single
//!   [`cost::Edit`]s (move/swap/replicate/drop) incrementally,
//!   bitwise-equal to a full rescore;
//! * [`planner::Planner`] — round-robin baseline, speed-aware greedy LPT
//!   bin-packing, local-search refinement and a replicate-hottest
//!   refinement stage, all under the same per-device memory budget
//!   (every replica occupies a slot), with a never-worse-than-baseline
//!   guarantee — the replicated plan never scores worse than the best
//!   single-owner plan;
//! * [`replan::Replanner`] — online replanning with hysteresis: proposes
//!   a [`replan::MigrationPlan`] (replica adds/drops, bytes, predicted
//!   makespan delta) only when the predicted gain clears the migration
//!   cost, and flags in-flight proposals as stale past a batch-age
//!   bound.
//!
//! Placement is pure layout: [`cluster::Topology`] consumes a plan (round
//! robin remains the default, bitwise-unchanged), and the cluster combine
//! order is placement-independent — within an expert each token is a
//! distinct output row, so even load-split replication cannot reorder
//! any float sum — so **no plan ever changes model outputs** — enforced
//! by `rust/tests/cluster_placement.rs`.
//!
//! [`ForwardStats`]: crate::moe::exec::ForwardStats
//! [`LinkModel`]: crate::cluster::topology::LinkModel
//! [`LayerTraffic`]: crate::cluster::comm::LayerTraffic
//! [`cluster::Topology`]: crate::cluster::topology::Topology

pub mod cost;
pub mod plan;
pub mod planner;
pub mod profile;
pub mod replan;

pub use cost::{CostModel, DeltaScorer, Edit, PlanScore, DEVICE_FLOPS};
pub use plan::{
    replica_share, replica_slices, speed_weight, weighted_share,
    PlacementPlan, ReplicaDelta,
};
pub use planner::{Planner, Strategy};
pub use profile::LoadProfile;
pub use replan::{
    DeltaKind, ExpertMove, MigrationPlan, PlanTask, ReplanConfig,
    Replanner,
};

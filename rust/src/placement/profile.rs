//! [`LoadProfile`] — observed (or synthetic) per-layer, per-FFN-expert
//! token loads, the input every planner strategy and the cost model score
//! against.
//!
//! Loads are **post-capacity** FFN assignment counts: the work that
//! actually executes on a device. `ForwardStats` records pre-capacity
//! per-expert counts; since Eq. 8 capacity clipping keeps
//! `min(count, capacity)` assignments per expert (order only decides
//! *which* assignments survive, never how many), the executed load is
//! recovered exactly without re-running dispatch.

use anyhow::Result;

use crate::config::MoeConfig;
use crate::moe::exec::ForwardStats;
use crate::util::json::Json;

/// Accumulated FFN-expert load histogram across observed batches.
#[derive(Clone, Debug)]
pub struct LoadProfile {
    n_ffn_experts: usize,
    /// `layers[l][e]` = FFN assignments executed by expert `e` in layer
    /// `l`, summed over all observed batches.
    layers: Vec<Vec<u64>>,
    /// How many batches have been accumulated.
    pub batches: usize,
}

/// Executed (post-capacity) FFN loads of one forward, per layer.
pub fn ffn_loads(stats: &ForwardStats, cfg: &MoeConfig) -> Vec<Vec<u64>> {
    let (ffn_cap, _) = cfg.capacities(stats.tokens);
    stats
        .per_layer
        .iter()
        .map(|l| {
            (0..cfg.n_ffn_experts)
                .map(|e| l.expert_counts[e].min(ffn_cap) as u64)
                .collect()
        })
        .collect()
}

impl LoadProfile {
    /// Empty profile; layer rows materialise on first observation.
    pub fn new(n_ffn_experts: usize) -> LoadProfile {
        LoadProfile { n_ffn_experts, layers: Vec::new(), batches: 0 }
    }

    /// Build directly from explicit per-layer loads (tests, synthetic
    /// workload studies, captured files).
    pub fn from_counts(layers: Vec<Vec<u64>>) -> Result<LoadProfile> {
        anyhow::ensure!(!layers.is_empty(), "profile needs >= 1 layer");
        let n = layers[0].len();
        anyhow::ensure!(
            layers.iter().all(|l| l.len() == n),
            "ragged load profile"
        );
        Ok(LoadProfile { n_ffn_experts: n, layers, batches: 1 })
    }

    pub fn n_ffn_experts(&self) -> usize {
        self.n_ffn_experts
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, l: usize) -> &[u64] {
        &self.layers[l]
    }

    /// Accumulate one batch's executed per-layer FFN loads.
    pub fn observe_loads(&mut self, loads: &[Vec<u64>]) {
        while self.layers.len() < loads.len() {
            self.layers.push(vec![0; self.n_ffn_experts]);
        }
        for (row, batch) in self.layers.iter_mut().zip(loads) {
            assert_eq!(
                batch.len(),
                self.n_ffn_experts,
                "load row does not match profile expert count"
            );
            for (acc, &l) in row.iter_mut().zip(batch) {
                *acc += l;
            }
        }
        self.batches += 1;
    }

    /// Accumulate one forward's stats (cluster sim or engine).
    pub fn observe_stats(&mut self, stats: &ForwardStats, cfg: &MoeConfig) {
        let loads = ffn_loads(stats, cfg);
        self.observe_loads(&loads);
    }

    /// Per-expert load summed over layers — what LPT packs on.
    pub fn expert_totals(&self) -> Vec<u64> {
        let mut totals = vec![0u64; self.n_ffn_experts];
        for row in &self.layers {
            for (t, &l) in totals.iter_mut().zip(row) {
                *t += l;
            }
        }
        totals
    }

    pub fn total(&self) -> u64 {
        self.expert_totals().iter().sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_ffn_experts", Json::num(self.n_ffn_experts as f64)),
            ("batches", Json::num(self.batches as f64)),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|row| {
                            Json::Arr(
                                row.iter()
                                    .map(|&l| Json::num(l as f64))
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<LoadProfile> {
        let n = j
            .get("n_ffn_experts")
            .and_then(Json::as_usize)
            .ok_or_else(|| {
                anyhow::anyhow!("profile json: missing n_ffn_experts")
            })?;
        let batches =
            j.get("batches").and_then(Json::as_usize).unwrap_or(1);
        let layers = j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("profile json: missing layers"))?
            .iter()
            .map(|row| {
                row.as_arr()
                    .ok_or_else(|| {
                        anyhow::anyhow!("profile json: layer not an array")
                    })?
                    .iter()
                    .map(|v| {
                        v.as_f64().map(|f| f as u64).ok_or_else(|| {
                            anyhow::anyhow!("profile json: bad load")
                        })
                    })
                    .collect::<Result<Vec<u64>>>()
            })
            .collect::<Result<Vec<Vec<u64>>>>()?;
        anyhow::ensure!(
            layers.iter().all(|l| l.len() == n),
            "profile json: layer width != n_ffn_experts"
        );
        Ok(LoadProfile {
            n_ffn_experts: n,
            layers,
            batches: batches.max(1),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MoeEngine;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn accumulates_and_totals() {
        let mut p = LoadProfile::new(3);
        p.observe_loads(&[vec![1, 2, 3], vec![4, 0, 0]]);
        p.observe_loads(&[vec![1, 0, 0], vec![0, 0, 6]]);
        assert_eq!(p.batches, 2);
        assert_eq!(p.n_layers(), 2);
        assert_eq!(p.layer(0), &[2, 2, 3]);
        assert_eq!(p.expert_totals(), vec![6, 2, 9]);
        assert_eq!(p.total(), 17);
    }

    #[test]
    fn observed_loads_match_executed_ffn_assignments() {
        // The capacity-clip reconstruction must equal what actually ran:
        // per layer, sum_e min(count_e, cap) == ffn_assignments.
        let cfg = MoeConfig::preset("test");
        let mut engine = MoeEngine::native(cfg.clone(), 3);
        let mut rng = Rng::new(17);
        let x = Tensor::randn(&mut rng, &[96, cfg.d_model], 1.0);
        let (_, stats) = engine.forward_stack(&x).unwrap();
        let loads = ffn_loads(&stats, &cfg);
        assert_eq!(loads.len(), stats.per_layer.len());
        for (row, l) in loads.iter().zip(&stats.per_layer) {
            let total: u64 = row.iter().sum();
            assert_eq!(total, l.ffn_assignments as u64);
        }
        let mut p = LoadProfile::new(cfg.n_ffn_experts);
        p.observe_stats(&stats, &cfg);
        let executed: usize =
            stats.per_layer.iter().map(|l| l.ffn_assignments).sum();
        assert_eq!(p.total(), executed as u64);
    }

    #[test]
    fn json_roundtrip() {
        let p =
            LoadProfile::from_counts(vec![vec![5, 0, 7], vec![1, 2, 3]])
                .unwrap();
        let txt = p.to_json().to_string();
        let back =
            LoadProfile::from_json(&Json::parse(&txt).unwrap()).unwrap();
        assert_eq!(back.n_ffn_experts(), 3);
        assert_eq!(back.layer(0), p.layer(0));
        assert_eq!(back.layer(1), p.layer(1));
        assert_eq!(back.batches, 1);
    }

    #[test]
    fn from_counts_rejects_ragged() {
        assert!(LoadProfile::from_counts(vec![vec![1], vec![1, 2]])
            .is_err());
        assert!(LoadProfile::from_counts(vec![]).is_err());
    }
}

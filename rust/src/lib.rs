//! # MoE++ — heterogeneous Mixture-of-Experts with zero-computation experts
//!
//! A from-scratch reproduction of *MoE++: Accelerating Mixture-of-Experts
//! Methods with Zero-Computation Experts* (ICLR 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (expert FFN, pathway-aware router, constant
//!   expert), authored in `python/compile/kernels/` and AOT-lowered.
//! * **L2** — the MoE++ transformer LM in JAX (`python/compile/`), lowered
//!   once to HLO text artifacts (`make artifacts`).
//! * **L3** — this crate: the async serving API ([`serve`]), the serving
//!   coordinator, expert-parallel cluster simulator, PJRT runtime, trainer
//!   driver and analysis/bench harnesses. Python is never on the request
//!   path. All serving goes through [`serve::MoeService`] (continuous
//!   batching, backpressure, per-request stats — DESIGN.md §9).
//!
//! The paper's three claims map onto L3 as follows:
//!
//! * **Low computing overhead** — [`coordinator`] short-circuits
//!   zero-computation experts (zero → skip, copy → memcpy, constant → a
//!   2×D matvec) so they never enter the FFN queue; `moepp bench table3`
//!   measures the resulting expert-forward speedup.
//! * **High performance** — the trainer ([`training`]) reproduces the
//!   quality-side comparisons on a synthetic corpus (Tables 3–6, Fig. 3).
//! * **Deployment friendly** — [`cluster`] replicates ZC experts on every
//!   simulated device, so ZC-routed tokens incur zero all-to-all traffic;
//!   [`placement`] plans *where* the sharded FFN experts live (load-aware
//!   LPT/local-search under a cost model, online replanning with
//!   hysteresis — DESIGN.md §10).
//!
//! This environment is offline: the only dependencies are vendored in
//! `rust/vendor/` (a minimal `anyhow` and a stub of the `xla` PJRT bridge
//! whose client fails cleanly, disabling artifact paths); every other
//! substrate (JSON codec, CLI parser, RNG, thread pool, bench statistics,
//! property-testing harness) is implemented in [`util`] and [`bench`].
//! The shared execution layer all forward paths delegate to lives in
//! [`moe::exec`] — see DESIGN.md §7 for the backend contract.
//! Observability (metrics registry, span traces, Prometheus/JSON
//! exporters) lives in [`obs`] — see DESIGN.md §15; recording is
//! infallible, bitwise-neutral and allocation-free in steady state.
//! Deterministic fault injection and worker-loss recovery live in
//! [`fault`] and [`cluster`] — see DESIGN.md §16; a lost worker's work
//! is redispatched to surviving replicas (bitwise-identical results) or
//! degraded to copy-expert semantics when no replica remains.

pub mod analyze;
pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod moe;
pub mod obs;
pub mod placement;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod tensor;
pub mod training;
pub mod util;

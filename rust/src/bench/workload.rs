//! Workload generators for the table/figure benchmarks: token-batch
//! streams (hidden-state batches for the expert-forward benches) and
//! serving request traces with arrival patterns.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A stream of [T, D] hidden-state batches (the expert-forward workload).
pub fn hidden_batches(rng: &mut Rng, n_batches: usize, t: usize, d: usize)
    -> Vec<Tensor> {
    (0..n_batches)
        .map(|_| Tensor::randn(rng, &[t, d], 1.0))
        .collect()
}

/// Serving trace: request sizes drawn from a bounded log-ish distribution
/// (mix of short decode-like and long prefill-like requests).
pub fn request_sizes(rng: &mut Rng, n: usize, max: usize) -> Vec<usize> {
    (0..n)
        .map(|_| {
            if rng.next_f32() < 0.7 {
                1 + rng.below(8.min(max)) // decode-ish
            } else {
                1 + rng.below(max) // prefill-ish
            }
        })
        .collect()
}

/// Mixture weights biased token stream: scales hidden rows so different
/// "tasks" prefer different experts (Fig. 4 workload).
pub fn task_streams(rng: &mut Rng, tasks: &[&str], t: usize, d: usize)
    -> Vec<(String, Tensor)> {
    tasks
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let mut x = Tensor::randn(rng, &[t, d], 1.0);
            // Shift a task-specific subspace so routing differs by task.
            for row in 0..t {
                for j in 0..d / 4 {
                    x.data[row * d + (j + i * (d / 4)) % d] += 1.5;
                }
            }
            (name.to_string(), x)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_shapes() {
        let mut rng = Rng::new(0);
        let b = hidden_batches(&mut rng, 3, 16, 8);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].shape, vec![16, 8]);
    }

    #[test]
    fn request_sizes_bounded() {
        let mut rng = Rng::new(1);
        let sizes = request_sizes(&mut rng, 1000, 64);
        assert!(sizes.iter().all(|&s| (1..=64).contains(&s)));
        // Mostly short.
        let short = sizes.iter().filter(|&&s| s <= 8).count();
        assert!(short > 500);
    }

    #[test]
    fn task_streams_distinct() {
        let mut rng = Rng::new(2);
        let s = task_streams(&mut rng, &["a", "b"], 8, 16);
        assert_eq!(s.len(), 2);
        assert_ne!(s[0].1.data, s[1].1.data);
    }
}

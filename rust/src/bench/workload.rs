//! Workload generators for the table/figure benchmarks: token-batch
//! streams (hidden-state batches for the expert-forward benches) and
//! serving request traces with arrival patterns.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A stream of [T, D] hidden-state batches (the expert-forward workload).
pub fn hidden_batches(rng: &mut Rng, n_batches: usize, t: usize, d: usize)
    -> Vec<Tensor> {
    (0..n_batches)
        .map(|_| Tensor::randn(rng, &[t, d], 1.0))
        .collect()
}

/// Hidden-state batches with *routing skew*: most rows are small
/// perturbations of a few zipf-weighted prototype rows, so the router
/// concentrates FFN load on a handful of hot experts — the adversarial
/// workload the placement planner exists for. (Which experts get hot
/// depends on the router weights; the skew itself does not.)
pub fn skewed_batches(rng: &mut Rng, n_batches: usize, t: usize, d: usize)
    -> Vec<Tensor> {
    let protos: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..d).map(|_| rng.next_normal() * 2.0).collect())
        .collect();
    let weights = [0.45f32, 0.30, 0.15, 0.10];
    (0..n_batches)
        .map(|_| {
            let mut x = Tensor::zeros(&[t, d]);
            for row in 0..t {
                let p = rng.categorical(&weights);
                for j in 0..d {
                    x.data[row * d + j] =
                        protos[p][j] + rng.next_normal() * 0.05;
                }
            }
            x
        })
        .collect()
}

/// Serving trace: request sizes drawn from a bounded log-ish distribution
/// (mix of short decode-like and long prefill-like requests).
pub fn request_sizes(rng: &mut Rng, n: usize, max: usize) -> Vec<usize> {
    (0..n)
        .map(|_| {
            if rng.next_f32() < 0.7 {
                1 + rng.below(8.min(max)) // decode-ish
            } else {
                1 + rng.below(max) // prefill-ish
            }
        })
        .collect()
}

/// Mixture weights biased token stream: scales hidden rows so different
/// "tasks" prefer different experts (Fig. 4 workload).
pub fn task_streams(rng: &mut Rng, tasks: &[&str], t: usize, d: usize)
    -> Vec<(String, Tensor)> {
    tasks
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let mut x = Tensor::randn(rng, &[t, d], 1.0);
            // Shift a task-specific subspace so routing differs by task.
            for row in 0..t {
                for j in 0..d / 4 {
                    x.data[row * d + (j + i * (d / 4)) % d] += 1.5;
                }
            }
            (name.to_string(), x)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_shapes() {
        let mut rng = Rng::new(0);
        let b = hidden_batches(&mut rng, 3, 16, 8);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].shape, vec![16, 8]);
    }

    #[test]
    fn skewed_batches_concentrate_rows() {
        let mut rng = Rng::new(7);
        let b = skewed_batches(&mut rng, 2, 64, 16);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].shape, vec![64, 16]);
        // Rows cluster around few prototypes: many near-duplicate pairs
        // (distance far below what independent gaussians would give).
        let x = &b[0];
        let mut close_pairs = 0;
        for i in 0..32 {
            for j in (i + 1)..32 {
                let d2: f32 = x
                    .row(i)
                    .iter()
                    .zip(x.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d2 < 1.0 {
                    close_pairs += 1;
                }
            }
        }
        assert!(close_pairs > 50, "only {close_pairs} close pairs");
    }

    #[test]
    fn request_sizes_bounded() {
        let mut rng = Rng::new(1);
        let sizes = request_sizes(&mut rng, 1000, 64);
        assert!(sizes.iter().all(|&s| (1..=64).contains(&s)));
        // Mostly short.
        let short = sizes.iter().filter(|&&s| s <= 8).count();
        assert!(short > 500);
    }

    #[test]
    fn task_streams_distinct() {
        let mut rng = Rng::new(2);
        let s = task_streams(&mut rng, &["a", "b"], 8, 16);
        assert_eq!(s.len(), 2);
        assert_ne!(s[0].1.data, s[1].1.data);
    }
}

//! Benchmark harness substrate (no criterion offline): warmup + timed
//! iterations with mean/median/p95 statistics, plus the workload
//! generators shared by the table/figure reproduction binaries.

pub mod harness;
pub mod quality;
pub mod tables;
pub mod workload;

//! Reproduction harnesses for the paper's throughput tables (Tab. 1,
//! Tab. 3 timing columns) and the cluster/deployment figures.
//!
//! Quality-side tables (3's benchmark columns, 4, 5, 6, Fig. 3) live in
//! [`super::quality`] — they train model variants via artifacts.

use std::time::Duration;

use anyhow::Result;

use super::harness::{bench, BenchResult};
use super::workload::hidden_batches;
use crate::cluster::sim::ClusterSim;
use crate::cluster::topology::Topology;
use crate::config::MoeConfig;
use crate::coordinator::engine::{ForwardStats, MoeEngine};
use crate::moe::complexity;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One row of the Table 3 timing reproduction.
#[derive(Clone, Debug)]
pub struct ThroughputRow {
    pub model: String,
    pub tau: f64,
    pub expert_forward_ms: f64,
    pub throughput_increase_pct: Option<f64>,
    pub ffn_per_token: f64,
    pub ideal_increase_pct: f64,
}

/// Measure mean expert-forward time of an engine over a workload.
pub fn measure_expert_forward(
    engine: &mut MoeEngine,
    batches: &[Tensor],
) -> Result<(f64, ForwardStats)> {
    // Warm.
    let _ = engine.forward_stack(&batches[0])?;
    let mut total = 0.0;
    let mut last = ForwardStats::default();
    for b in batches {
        let (_, stats) = engine.forward_stack(b)?;
        total += stats.expert_forward_s;
        last = stats;
    }
    Ok((total / batches.len() as f64, last))
}

/// Table 3 (timing columns): for each preset, vanilla MoE vs MoE++ across
/// the paper's tau sweep. Shapes reproduced: MoE++ expert-forward time
/// decreases monotonically as tau decreases; throughput increase vs
/// vanilla is positive everywhere and largest at small tau.
pub fn table3_rows(
    presets: &[&str],
    taus: &[f64],
    tokens: usize,
    n_batches: usize,
    seed: u64,
) -> Result<Vec<ThroughputRow>> {
    let mut rows = Vec::new();
    for preset in presets {
        let vcfg = MoeConfig::preset(&format!("{preset}:vanilla"));
        let mut rng = Rng::new(seed);
        let batches =
            hidden_batches(&mut rng, n_batches, tokens, vcfg.d_model);
        let mut vengine = MoeEngine::native(vcfg.clone(), seed);
        let (v_time, v_stats) =
            measure_expert_forward(&mut vengine, &batches)?;
        rows.push(ThroughputRow {
            model: format!("MoE {preset}"),
            tau: f64::NAN,
            expert_forward_ms: v_time * 1e3,
            throughput_increase_pct: None,
            ffn_per_token: v_stats.mean_ffn_per_token(),
            ideal_increase_pct: 0.0,
        });
        for &tau in taus {
            let cfg = MoeConfig { tau, ..MoeConfig::preset(preset) };
            let mut engine = MoeEngine::native(cfg.clone(), seed);
            let (t, stats) =
                measure_expert_forward(&mut engine, &batches)?;
            rows.push(ThroughputRow {
                model: format!("MoE++ {preset}"),
                tau,
                expert_forward_ms: t * 1e3,
                throughput_increase_pct: Some((v_time / t - 1.0) * 100.0),
                ffn_per_token: stats.mean_ffn_per_token(),
                ideal_increase_pct: complexity::ideal_throughput_increase(
                    &cfg, tokens,
                ) * 100.0,
            });
        }
    }
    Ok(rows)
}

pub fn render_table3(rows: &[ThroughputRow]) -> String {
    let mut s = format!(
        "{:<18} {:>5} {:>16} {:>12} {:>10} {:>10}\n",
        "model", "tau", "expert fwd (ms)", "tput incr", "ideal", "ffn/tok"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<18} {:>5} {:>16.3} {:>12} {:>9.1}% {:>10.2}\n",
            r.model,
            if r.tau.is_nan() { "-".into() } else { format!("{}", r.tau) },
            r.expert_forward_ms,
            r.throughput_increase_pct
                .map(|p| format!("{p:+.1}%"))
                .unwrap_or_else(|| "-".into()),
            r.ideal_increase_pct,
            r.ffn_per_token,
        ));
    }
    s
}

/// Table 1: analytic complexity ratio vs measured FFN-assignment ratio.
#[derive(Clone, Debug)]
pub struct ComplexityRow {
    pub preset: String,
    pub tau: f64,
    pub analytic_ratio: f64,
    pub measured_ratio: f64,
}

pub fn table1_rows(preset: &str, taus: &[f64], tokens: usize, seed: u64)
    -> Result<Vec<ComplexityRow>> {
    let vcfg = MoeConfig::preset(&format!("{preset}:vanilla"));
    let mut rng = Rng::new(seed);
    let x = Tensor::randn(&mut rng, &[tokens, vcfg.d_model], 1.0);
    let mut vengine = MoeEngine::native(vcfg, seed);
    let (_, vstats) = vengine.forward_stack(&x)?;
    let v_ffn: usize =
        vstats.per_layer.iter().map(|l| l.ffn_assignments).sum();
    let mut rows = Vec::new();
    for &tau in taus {
        let cfg = MoeConfig { tau, ..MoeConfig::preset(preset) };
        let mut engine = MoeEngine::native(cfg.clone(), seed);
        let (_, stats) = engine.forward_stack(&x)?;
        let ffn: usize =
            stats.per_layer.iter().map(|l| l.ffn_assignments).sum();
        rows.push(ComplexityRow {
            preset: preset.to_string(),
            tau,
            analytic_ratio: complexity::complexity_ratio(&cfg, tokens),
            measured_ratio: ffn as f64 / v_ffn as f64,
        });
    }
    Ok(rows)
}

pub fn render_table1(rows: &[ComplexityRow]) -> String {
    let mut s = format!(
        "{:<10} {:>5} {:>22} {:>22}\n",
        "preset", "tau", "analytic tauN/(tauN+Z)", "measured ffn ratio"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<10} {:>5} {:>22.3} {:>22.3}\n",
            r.preset, r.tau, r.analytic_ratio, r.measured_ratio
        ));
    }
    s
}

/// Deployment comparison on the simulated cluster: all-to-all bytes, comm
/// time, device-load imbalance, makespan — MoE++ vs vanilla.
#[derive(Clone, Debug)]
pub struct ClusterRow {
    pub model: String,
    pub devices: usize,
    pub comm_mib: f64,
    pub comm_ms: f64,
    pub makespan_ms: f64,
    pub load_cv: f64,
}

pub fn cluster_rows(preset: &str, devices: &[usize], tokens: usize,
                    seed: u64) -> Result<Vec<ClusterRow>> {
    let mut rows = Vec::new();
    for &nd in devices {
        for variant in ["", ":vanilla"] {
            let cfg = MoeConfig::preset(&format!("{preset}{variant}"));
            let mut rng = Rng::new(seed);
            let x = Tensor::randn(&mut rng, &[tokens, cfg.d_model], 1.0);
            let mut sim =
                ClusterSim::new(cfg.clone(), Topology::new(nd), seed);
            let (_, rep) = sim.forward(&x)?;
            rows.push(ClusterRow {
                model: if variant.is_empty() {
                    format!("MoE++ {preset}")
                } else {
                    format!("MoE   {preset}")
                },
                devices: nd,
                comm_mib: rep.total_comm_bytes() as f64 / (1 << 20) as f64,
                comm_ms: rep.total_comm_s() * 1e3,
                makespan_ms: rep.total_makespan() * 1e3,
                load_cv: rep.mean_load_cv(),
            });
        }
    }
    Ok(rows)
}

pub fn render_cluster(rows: &[ClusterRow]) -> String {
    let mut s = format!(
        "{:<16} {:>8} {:>12} {:>10} {:>12} {:>9}\n",
        "model", "devices", "a2a (MiB)", "comm (ms)", "makespan", "load cv"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<16} {:>8} {:>12.3} {:>10.3} {:>10.3}ms {:>9.3}\n",
            r.model, r.devices, r.comm_mib, r.comm_ms, r.makespan_ms,
            r.load_cv
        ));
    }
    s
}

/// Micro-bench of a single engine forward, criterion-style.
pub fn bench_engine(name: &str, engine: &mut MoeEngine, tokens: usize,
                    seed: u64) -> Result<BenchResult> {
    let mut rng = Rng::new(seed);
    let x = Tensor::randn(&mut rng, &[tokens, engine.cfg.d_model], 1.0);
    let r = bench(name, 2, 5, Duration::from_millis(400), || {
        let _ = engine.forward_stack(&x).unwrap();
    });
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_measured_tracks_analytic() {
        let rows = table1_rows("test", &[0.25, 0.75], 512, 0).unwrap();
        for r in &rows {
            // The measured FFN ratio should track the analytic model within
            // routing noise (untrained router => noisy; generous band).
            assert!((r.measured_ratio - r.analytic_ratio).abs() < 0.35,
                    "{r:?}");
        }
        // Monotone in tau.
        assert!(rows[0].measured_ratio < rows[1].measured_ratio + 0.1);
    }

    #[test]
    fn table3_moepp_faster_than_vanilla() {
        let rows =
            table3_rows(&["test"], &[0.1, 0.75], 256, 2, 0).unwrap();
        assert_eq!(rows.len(), 3);
        let v = &rows[0];
        for r in &rows[1..] {
            assert!(r.expert_forward_ms < v.expert_forward_ms,
                    "MoE++ must beat vanilla: {r:?} vs {v:?}");
            assert!(r.throughput_increase_pct.unwrap() > 0.0);
        }
        let s = render_table3(&rows);
        assert!(s.contains("MoE++ test"));
    }

    #[test]
    fn cluster_moepp_less_traffic() {
        let rows = cluster_rows("test", &[4], 128, 0).unwrap();
        let moepp = rows.iter().find(|r| r.model.contains("++")).unwrap();
        let vanilla =
            rows.iter().find(|r| !r.model.contains("++")).unwrap();
        assert!(moepp.comm_mib < vanilla.comm_mib);
        let s = render_cluster(&rows);
        assert!(s.contains("devices"));
    }
}

//! Timing harness: adaptive iteration count, warmup, robust statistics —
//! plus the serving-trace driver used by `moepp serve` and the serving
//! benches (all serving measurement goes through [`MoeService`], never
//! through a hand-driven batcher loop).
//!
//! [`MoeService`]: crate::serve::MoeService

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::moe::exec::AssignmentCounts;
use crate::serve::{
    AdmissionError, MoeService, Priority, ResponseHandle, ServeRequest,
};
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>10} /iter  (median {}, p95 {}, min {}, n={})",
            self.name,
            crate::util::human_duration(self.mean_s),
            crate::util::human_duration(self.median_s),
            crate::util::human_duration(self.p95_s),
            crate::util::human_duration(self.min_s),
            self.iters
        )
    }
}

/// Benchmark `f`, aiming for ~`target` total measured time (at least
/// `min_iters` iterations), after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize,
                         target: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // Estimate a single-iter time to size the run.
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target.as_secs_f64() / est) as usize)
        .clamp(min_iters.max(1), 10_000);
    let mut samples = Vec::with_capacity(iters + 1);
    samples.push(est);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    summarize(name, samples)
}

/// Summarise externally-collected per-iteration samples.
pub fn summarize(name: &str, mut samples: Vec<f64>) -> BenchResult {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        median_s: samples[n / 2],
        p95_s: samples[((n - 1) as f64 * 0.95).round() as usize],
        min_s: samples[0],
    }
}

// ------------------------------------------------------------ serving

/// Outcome of driving one request trace through a [`MoeService`].
#[derive(Clone, Debug)]
pub struct ServeTraceReport {
    /// Requests that completed with an output.
    pub completed: usize,
    /// Admission bounces absorbed by the retry loop (backpressure events,
    /// not failures — every request eventually ran).
    pub backpressure_retries: u64,
    /// Submit-first to last-completion wall time.
    pub wall_s: f64,
    /// Completed-request service-time distribution.
    pub per_request: BenchResult,
    /// Sum of every request's per-request assignment counts — reconciles
    /// against the service's batch-level metrics.
    pub counts: AssignmentCounts,
}

impl ServeTraceReport {
    pub fn requests_per_s(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-12)
    }
}

/// Drive `inputs` through the service as a closed-loop trace with
/// backpressure handling: submissions that bounce on a full queue wait
/// for the oldest outstanding response, then retry — the canonical
/// caller-side reaction to [`AdmissionError::QueueFull`].
///
/// A slice of the trace is tagged [`Priority::Interactive`] (every 5th
/// request) and [`Priority::Bulk`] (every 11th) so the scheduler's
/// priority classes see real traffic.
pub fn run_serve_trace(
    service: &MoeService,
    inputs: Vec<Tensor>,
) -> Result<ServeTraceReport> {
    anyhow::ensure!(!inputs.is_empty(), "empty serve trace");
    let t0 = Instant::now();
    let mut handles: Vec<ResponseHandle> = Vec::new();
    let mut samples = Vec::new();
    let mut counts = AssignmentCounts::default();
    let mut completed = 0usize;
    let mut retries = 0u64;
    let drain_oldest =
        |handles: &mut Vec<ResponseHandle>,
         samples: &mut Vec<f64>,
         counts: &mut AssignmentCounts,
         completed: &mut usize|
         -> Result<()> {
            let resp = handles.remove(0).wait().map_err(|e| {
                anyhow::anyhow!("serve trace request failed: {e}")
            })?;
            samples.push(resp.stats.service_time.as_secs_f64());
            counts.add(&resp.stats.counts);
            *completed += 1;
            Ok(())
        };
    for (i, tokens) in inputs.into_iter().enumerate() {
        let priority = if i % 5 == 0 {
            Priority::Interactive
        } else if i % 11 == 0 {
            Priority::Bulk
        } else {
            Priority::Standard
        };
        let req = ServeRequest::new(tokens).with_priority(priority);
        loop {
            match service.submit(req.clone()) {
                Ok(h) => {
                    handles.push(h);
                    break;
                }
                Err(
                    AdmissionError::QueueFull { .. }
                    | AdmissionError::TooManyPending { .. },
                ) => {
                    retries += 1;
                    anyhow::ensure!(
                        !handles.is_empty(),
                        "admission rejected with nothing in flight"
                    );
                    drain_oldest(
                        &mut handles,
                        &mut samples,
                        &mut counts,
                        &mut completed,
                    )?;
                }
                Err(e) => anyhow::bail!("serve trace admission: {e}"),
            }
        }
    }
    while !handles.is_empty() {
        drain_oldest(
            &mut handles,
            &mut samples,
            &mut counts,
            &mut completed,
        )?;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(ServeTraceReport {
        completed,
        backpressure_retries: retries,
        wall_s,
        per_request: summarize("serve-request", samples),
        counts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut x = 0u64;
        let r = bench("spin", 1, 5, Duration::from_millis(10), || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.p95_s);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn summarize_stats() {
        let r = summarize("s", vec![3.0, 1.0, 2.0]);
        assert_eq!(r.min_s, 1.0);
        assert_eq!(r.median_s, 2.0);
        assert!((r.mean_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn serve_trace_completes_and_reconciles_with_service_metrics() {
        use crate::config::MoeConfig;
        use crate::coordinator::batcher::BatcherConfig;
        use crate::coordinator::engine::MoeEngine;
        use crate::serve::ServiceConfig;
        use crate::util::rng::Rng;

        let cfg = MoeConfig::preset("test");
        let service = MoeService::start(
            MoeEngine::native(cfg.clone(), 0),
            ServiceConfig {
                batcher: BatcherConfig {
                    max_tokens: 32,
                    max_wait: Duration::from_millis(1),
                },
                max_queued_tokens: 64,
                max_pending_requests: 128,
                default_deadline: None,
            },
        );
        let mut rng = Rng::new(9);
        let inputs: Vec<Tensor> = (0..20)
            .map(|_| {
                let n = 1 + (rng.next_u64() % 8) as usize;
                Tensor::randn(&mut rng, &[n, cfg.d_model], 1.0)
            })
            .collect();
        let report = run_serve_trace(&service, inputs).unwrap();
        assert_eq!(report.completed, 20);
        assert!(report.wall_s > 0.0);
        assert!(report.requests_per_s() > 0.0);
        assert_eq!(report.per_request.iters, 20);
        // Per-request assignment counts summed over the trace must equal
        // the service's batch-level forward accounting exactly.
        let m = service.shutdown();
        assert_eq!(report.counts.ffn, m.ffn_assignments);
        assert_eq!(report.counts.zc(), m.zc_assignments);
        assert_eq!(report.counts.dropped, m.dropped_assignments);
        // Every input was admitted exactly once; bounces only ever
        // incremented the reject counter.
        assert_eq!(m.requests, 20);
        assert_eq!(m.rejected, report.backpressure_retries);
    }
}

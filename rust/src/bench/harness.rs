//! Timing harness: adaptive iteration count, warmup, robust statistics.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>10} /iter  (median {}, p95 {}, min {}, n={})",
            self.name,
            crate::util::human_duration(self.mean_s),
            crate::util::human_duration(self.median_s),
            crate::util::human_duration(self.p95_s),
            crate::util::human_duration(self.min_s),
            self.iters
        )
    }
}

/// Benchmark `f`, aiming for ~`target` total measured time (at least
/// `min_iters` iterations), after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize,
                         target: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // Estimate a single-iter time to size the run.
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target.as_secs_f64() / est) as usize)
        .clamp(min_iters.max(1), 10_000);
    let mut samples = Vec::with_capacity(iters + 1);
    samples.push(est);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    summarize(name, samples)
}

/// Summarise externally-collected per-iteration samples.
pub fn summarize(name: &str, mut samples: Vec<f64>) -> BenchResult {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        median_s: samples[n / 2],
        p95_s: samples[((n - 1) as f64 * 0.95).round() as usize],
        min_s: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut x = 0u64;
        let r = bench("spin", 1, 5, Duration::from_millis(10), || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.p95_s);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn summarize_stats() {
        let r = summarize("s", vec![3.0, 1.0, 2.0]);
        assert_eq!(r.min_s, 1.0);
        assert_eq!(r.median_s, 2.0);
        assert!((r.mean_s - 2.0).abs() < 1e-12);
    }
}

//! Timing harness: adaptive iteration count, warmup, robust statistics —
//! plus the serving-trace driver used by `moepp serve` and the serving
//! benches (all serving measurement goes through [`MoeService`], never
//! through a hand-driven batcher loop).
//!
//! [`MoeService`]: crate::serve::MoeService

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cluster::sim::{ClusterSim, SimReport};
use crate::cluster::topology::Topology;
use crate::config::{MoeConfig, Precision};
use crate::coordinator::engine::{ExecutorKind, MoeEngine, Partition};
use crate::moe::exec::AssignmentCounts;
use crate::placement::{
    CostModel, LoadProfile, PlacementPlan, Planner, Strategy,
};
use crate::serve::{
    AdmissionError, MoeService, Priority, ResponseHandle, ServeRequest,
};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::quality::QuantErrorStats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>10} /iter  (median {}, p95 {}, min {}, n={})",
            self.name,
            crate::util::human_duration(self.mean_s),
            crate::util::human_duration(self.median_s),
            crate::util::human_duration(self.p95_s),
            crate::util::human_duration(self.min_s),
            self.iters
        )
    }
}

/// Benchmark `f`, aiming for ~`target` total measured time (at least
/// `min_iters` iterations), after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize,
                         target: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // Estimate a single-iter time to size the run.
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target.as_secs_f64() / est) as usize)
        .clamp(min_iters.max(1), 10_000);
    let mut samples = Vec::with_capacity(iters + 1);
    samples.push(est);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    summarize(name, samples)
}

/// Summarise externally-collected per-iteration samples.
pub fn summarize(name: &str, mut samples: Vec<f64>) -> BenchResult {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        median_s: samples[n / 2],
        p95_s: samples[((n - 1) as f64 * 0.95).round() as usize],
        min_s: samples[0],
    }
}

// ------------------------------------------------------- bench output

/// Write a machine-readable benchmark payload to `BENCH_<name>.json` in
/// the working directory, so the repo's perf trajectory is tracked across
/// PRs. Returns the path written. Every sweep that prints a table should
/// also go through here.
pub fn write_bench_json(name: &str, payload: &Json) -> Result<String> {
    let path = format!("BENCH_{name}.json");
    std::fs::write(&path, format!("{payload}\n"))?;
    Ok(path)
}

// ----------------------------------------------------------- precision

/// Expand a `--precision` CLI spec into the stack-wide per-expert map
/// the engines and plans consume (DESIGN.md §17): `"f32"` and `"int8"`
/// set every FFN expert uniformly; `"mixed"` demotes every odd-indexed
/// expert to int8 — a deterministic half-and-half split that exercises
/// the mixed-precision backend without per-expert flags.
pub fn precision_map(spec: &str, n_ffn: usize) -> Result<Vec<Precision>> {
    match spec {
        "mixed" => Ok((0..n_ffn)
            .map(|e| {
                if e % 2 == 1 {
                    Precision::Int8
                } else {
                    Precision::F32
                }
            })
            .collect()),
        one => match Precision::parse(one) {
            Some(p) => Ok(vec![p; n_ffn]),
            None => anyhow::bail!(
                "--precision expects f32|int8|mixed, got '{one}'"
            ),
        },
    }
}

// ------------------------------------------------------ expert forward

/// One configuration's row in the expert-forward sweep.
#[derive(Clone, Debug)]
pub struct ForwardSweepRow {
    pub preset: String,
    /// "uniform" (i.i.d. gaussian batches) or "skewed" (zipf prototype
    /// batches that pile FFN load onto few hot experts).
    pub workload: String,
    /// "batch" (old batch-per-worker fan-out) or "shard" (token-parallel).
    pub partition: String,
    /// "pool" (persistent worker pool) or "scoped" (spawn-per-call).
    pub executor: String,
    pub workers: usize,
    /// Mean expert-forward time per batch (the Table 3 metric).
    pub expert_forward_ms: f64,
    /// Expert-forward throughput over the measured batches.
    pub tokens_per_s: f64,
    /// Arena growths after the measured run — should equal the warmup's
    /// (steady state allocates nothing; reported for the perf trajectory).
    pub arena_growths: u64,
    /// Pool worker threads spawned over the measured run (zero for the
    /// scoped executor, `workers - 1` paid once for the pool — the
    /// thread-spawn twin of `arena_growths`).
    pub pool_spawns: u64,
}

/// The expert-forward sweep behind `moepp bench forward` and
/// `BENCH_forward.json`: presets × {uniform, skewed} routing ×
/// partition strategies × executors × worker counts, measured on
/// identical batches (same workload rng per preset/workload, same weight
/// seed), so the shard-vs-batch and pool-vs-scoped ratios isolate one
/// axis each — outputs are bitwise-identical across every cell by the
/// §7/§11/§12 equivalence contract, only the schedule changes.
/// `precision`: optional `--precision f32|int8|mixed` spec expanded per
/// preset by [`precision_map`] and installed on every measured engine;
/// the §7/§17 equivalence contract holds per map, so outputs stay
/// bitwise-identical across cells for any fixed map.
/// `obs`: optional observability bundle
/// (DESIGN.md §15) installed on every measured engine, so `moepp bench
/// forward --trace-out` captures the per-layer dispatch/shard trail of a
/// real sweep. Bitwise-neutral: rows and outputs are identical with or
/// without it.
pub fn run_forward_sweep(
    presets: &[&str],
    workers_list: &[usize],
    partitions: &[Partition],
    executors: &[ExecutorKind],
    tokens: usize,
    n_batches: usize,
    seed: u64,
    precision: Option<&str>,
    obs: Option<&std::sync::Arc<crate::obs::Obs>>,
) -> Result<Vec<ForwardSweepRow>> {
    anyhow::ensure!(n_batches > 0, "forward sweep needs >= 1 batch");
    anyhow::ensure!(
        !workers_list.is_empty()
            && !partitions.is_empty()
            && !executors.is_empty(),
        "forward sweep needs >= 1 worker count, partition and executor"
    );
    let mut rows = Vec::new();
    for preset in presets {
        let cfg = MoeConfig::preset(preset);
        for (workload, skewed) in [("uniform", false), ("skewed", true)] {
            let mut rng = Rng::new(seed ^ 0xF0D5);
            let batches = if skewed {
                super::workload::skewed_batches(
                    &mut rng, n_batches, tokens, cfg.d_model,
                )
            } else {
                super::workload::hidden_batches(
                    &mut rng, n_batches, tokens, cfg.d_model,
                )
            };
            for &partition in partitions {
                for &executor in executors {
                    for &workers in workers_list {
                        let mut engine = MoeEngine::native_with_workers(
                            cfg.clone(),
                            seed,
                            workers,
                        )
                        .with_partition(partition)
                        .with_executor(executor);
                        if let Some(spec) = precision {
                            engine = engine.with_precision(
                                precision_map(spec, cfg.n_ffn_experts)?,
                            );
                        }
                        if let Some(o) = obs {
                            engine.set_obs(o.clone());
                        }
                        // Warm: arena growth, routing caches and the
                        // pool's one-time worker spawns settle here.
                        let _ = engine.forward_stack(&batches[0])?;
                        let mut expert_s = 0.0;
                        for b in &batches {
                            let (_, stats) = engine.forward_stack(b)?;
                            expert_s += stats.expert_forward_s;
                        }
                        rows.push(ForwardSweepRow {
                            preset: preset.to_string(),
                            workload: workload.to_string(),
                            partition: partition.label().to_string(),
                            executor: executor.label().to_string(),
                            workers,
                            expert_forward_ms: expert_s * 1e3
                                / n_batches as f64,
                            tokens_per_s: (tokens * n_batches) as f64
                                / expert_s.max(1e-12),
                            arena_growths: engine.arena_growths(),
                            pool_spawns: engine.pool_spawns(),
                        });
                    }
                }
            }
        }
    }
    Ok(rows)
}

/// A comparison axis of the forward sweep (the dimension a ratio column
/// varies while all the others are held fixed).
#[derive(Clone, Copy, PartialEq, Eq)]
enum SweepAxis {
    Partition,
    Executor,
}

fn axis_value(r: &ForwardSweepRow, axis: SweepAxis) -> &str {
    match axis {
        SweepAxis::Partition => &r.partition,
        SweepAxis::Executor => &r.executor,
    }
}

/// Throughput ratio of `r` against its baseline twin: the row agreeing
/// with `r` on every sweep axis except `axis`, where the twin holds
/// `base`. `None` when `r` is itself a baseline row or no twin was
/// measured. One matcher serves every ratio column, so a new sweep
/// axis added to [`ForwardSweepRow`] only needs teaching here once.
fn speedup_vs(
    rows: &[ForwardSweepRow],
    r: &ForwardSweepRow,
    axis: SweepAxis,
    base: &str,
) -> Option<f64> {
    if axis_value(r, axis) == base {
        return None;
    }
    rows.iter()
        .find(|b| {
            axis_value(b, axis) == base
                && b.preset == r.preset
                && b.workload == r.workload
                && b.workers == r.workers
                && (axis == SweepAxis::Partition
                    || b.partition == r.partition)
                && (axis == SweepAxis::Executor
                    || b.executor == r.executor)
        })
        .map(|b| r.tokens_per_s / b.tokens_per_s.max(1e-12))
}

/// Shard-over-batch throughput ratio for a row's (preset, workload,
/// executor, workers) cell, when both partitions were measured.
fn shard_speedup(rows: &[ForwardSweepRow], r: &ForwardSweepRow)
    -> Option<f64> {
    speedup_vs(rows, r, SweepAxis::Partition, "batch")
}

/// Pool-over-scoped throughput ratio for a row's (preset, workload,
/// partition, workers) cell — the persistent-executor win the §12
/// refactor targets (largest at small batches, where per-layer thread
/// spawns dominated). Present when both executors were measured.
fn pool_speedup(rows: &[ForwardSweepRow], r: &ForwardSweepRow)
    -> Option<f64> {
    speedup_vs(rows, r, SweepAxis::Executor, "scoped")
}

pub fn render_forward_sweep(rows: &[ForwardSweepRow]) -> String {
    let mut s = format!(
        "{:<8} {:<8} {:<6} {:<6} {:>7} {:>14} {:>12} {:>9} {:>10}\n",
        "preset", "workload", "part", "exec", "workers",
        "expert fwd(ms)", "tokens/s", "vs batch", "vs scoped"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<8} {:<8} {:<6} {:<6} {:>7} {:>14.3} {:>12.0} {:>9} {:>10}\n",
            r.preset,
            r.workload,
            r.partition,
            r.executor,
            r.workers,
            r.expert_forward_ms,
            r.tokens_per_s,
            shard_speedup(rows, r)
                .map(|x| format!("{x:.2}x"))
                .unwrap_or_else(|| "-".into()),
            pool_speedup(rows, r)
                .map(|x| format!("{x:.2}x"))
                .unwrap_or_else(|| "-".into()),
        ));
    }
    s
}

/// JSON payload for `BENCH_forward.json`.
pub fn forward_sweep_json(
    tokens: usize,
    n_batches: usize,
    rows: &[ForwardSweepRow],
) -> Json {
    Json::obj(vec![
        ("bench", Json::str("forward")),
        ("tokens", Json::num(tokens as f64)),
        ("batches", Json::num(n_batches as f64)),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        let mut fields = vec![
                            ("preset", Json::str(r.preset.clone())),
                            ("workload", Json::str(r.workload.clone())),
                            (
                                "partition",
                                Json::str(r.partition.clone()),
                            ),
                            ("executor", Json::str(r.executor.clone())),
                            ("workers", Json::num(r.workers as f64)),
                            (
                                "expert_forward_ms",
                                Json::num(r.expert_forward_ms),
                            ),
                            ("tokens_per_s", Json::num(r.tokens_per_s)),
                            (
                                "arena_growths",
                                Json::num(r.arena_growths as f64),
                            ),
                            (
                                "pool_spawns",
                                Json::num(r.pool_spawns as f64),
                            ),
                        ];
                        if let Some(x) = shard_speedup(rows, r) {
                            fields.push((
                                "speedup_vs_batch",
                                Json::num(x),
                            ));
                        }
                        if let Some(x) = pool_speedup(rows, r) {
                            fields.push((
                                "speedup_vs_scoped",
                                Json::num(x),
                            ));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

// ---------------------------------------------------------- placement

/// One strategy's row in the placement sweep.
#[derive(Clone, Debug)]
pub struct PlacementSweepRow {
    pub strategy: String,
    /// Cost-model makespan on the captured profile (prediction).
    pub predicted_makespan_ms: f64,
    /// Deterministic analytic makespan of the actual simulated runs.
    pub modeled_makespan_ms: f64,
    /// Wall-clock simulated makespan (noisy; reported, never asserted).
    pub measured_makespan_ms: f64,
    pub comm_mib: f64,
    pub load_cv: f64,
    /// Experts whose replica set differs from the round-robin baseline.
    pub moved_experts: usize,
    /// Replica slots beyond one-per-expert (0 for single-owner plans) —
    /// what separates the replicated row from the owner-only rows.
    pub extra_replicas: usize,
}

/// The placement sweep: capture a load profile by running the workload on
/// the round-robin cluster, plan with every strategy, then re-simulate
/// each plan on the *same* workload (same weights seed, so routing and
/// outputs are identical — placement only moves work between devices).
/// `skewed` selects the adversarial prototype workload; otherwise i.i.d.
/// gaussian batches. `budget_bytes` is the optional per-device parameter
/// budget handed to the planner (stack-wide per expert slot). Identical
/// plans are simulated once (refined often equals its LPT seed).
///
/// `max_replicas` bounds the replicated strategy's per-expert replica
/// count; `device_speeds` (relative flops, 1.0 = baseline, missing
/// devices default to 1.0) makes the fleet heterogeneous — it reaches
/// the cost model, the simulated workers and the modeled makespan alike,
/// so every row is priced and simulated on the same fleet.
///
/// `precision` (optional `--precision f32|int8|mixed` spec, expanded by
/// [`precision_map`]) is a stack-wide precision *floor*: every expert
/// the spec marks int8 is demoted in every plan before simulation —
/// experts the compressed strategy demotes on its own stay demoted too.
/// The baseline capture runs on the same map, so all rows simulate the
/// identical quantized stack and differ only in replica layout.
pub fn run_placement_sweep(
    preset: &str,
    n_devices: usize,
    tokens: usize,
    n_batches: usize,
    skewed: bool,
    seed: u64,
    budget_bytes: Option<u64>,
    max_replicas: usize,
    device_speeds: &[f64],
    precision: Option<&str>,
) -> Result<(LoadProfile, Vec<PlacementSweepRow>)> {
    anyhow::ensure!(n_batches > 0, "placement sweep needs >= 1 batch");
    anyhow::ensure!(max_replicas >= 1, "max_replicas must be >= 1");
    let speeds: Vec<f64> = (0..n_devices)
        .map(|d| device_speeds.get(d).copied().unwrap_or(1.0))
        .collect();
    let cfg = MoeConfig::preset(preset);
    let forced: Vec<usize> = match precision {
        Some(spec) => precision_map(spec, cfg.n_ffn_experts)?
            .iter()
            .enumerate()
            .filter(|(_, &p)| p == Precision::Int8)
            .map(|(e, _)| e)
            .collect(),
        None => Vec::new(),
    };
    let mut rng = Rng::new(seed ^ 0x9E37);
    let workload = if skewed {
        super::workload::skewed_batches(
            &mut rng, n_batches, tokens, cfg.d_model)
    } else {
        super::workload::hidden_batches(
            &mut rng, n_batches, tokens, cfg.d_model)
    };

    // Capture the profile under the default round-robin placement,
    // keeping the reports: they double as the round-robin row's
    // simulation (same seed, same workload — re-running would measure
    // the identical configuration twice).
    let mut profile = LoadProfile::new(cfg.n_ffn_experts);
    let baseline_reports: Vec<SimReport> = {
        let mut topo =
            Topology::new(n_devices).with_device_speeds(speeds.clone());
        if !forced.is_empty() {
            let mut rr = PlacementPlan::round_robin(
                cfg.n_ffn_experts,
                n_devices,
            );
            for &e in &forced {
                rr.set_precision(e, Precision::Int8);
            }
            topo = topo.with_placement(rr);
        }
        let mut sim = ClusterSim::new(cfg.clone(), topo, seed);
        workload
            .iter()
            .map(|b| {
                let (_, rep) = sim
                    .forward(b)
                    .expect("no fault injector installed");
                profile.observe_stats(&rep.stats, &cfg);
                rep
            })
            .collect()
    };

    let cost =
        CostModel::from_config(&cfg).with_device_speeds(speeds.clone());
    let mut planner =
        Planner::new(cost.clone()).with_max_replicas(max_replicas);
    if let Some(bytes) = budget_bytes {
        planner = planner.with_budget(bytes);
    }
    let rr = PlacementPlan::round_robin(cfg.n_ffn_experts, n_devices);
    let mut rows = Vec::new();
    let mut simulated: Vec<(PlacementPlan, Vec<SimReport>)> = Vec::new();
    for strategy in Strategy::all() {
        let mut plan = planner.plan(strategy, n_devices, &profile)?;
        // The CLI precision floor: forced demotions stack on top of
        // whatever the compressed strategy demoted on its own.
        for &e in &forced {
            plan.set_precision(e, Precision::Int8);
        }
        let predicted = cost.score(&plan, &profile);
        let reports: &[SimReport] = if plan.is_round_robin() {
            &baseline_reports
        } else if let Some(i) =
            simulated.iter().position(|(p, _)| *p == plan)
        {
            &simulated[i].1
        } else {
            let mut sim = ClusterSim::new(
                cfg.clone(),
                Topology::new(n_devices)
                    .with_device_speeds(speeds.clone())
                    .with_placement(plan.clone()),
                seed,
            );
            let reps = workload
                .iter()
                .map(|b| {
                    sim.forward(b)
                        .expect("no fault injector installed")
                        .1
                })
                .collect();
            simulated.push((plan.clone(), reps));
            &simulated.last().expect("just pushed").1
        };
        let (mut modeled, mut measured, mut cv) = (0.0, 0.0, 0.0);
        let mut comm_bytes = 0u64;
        for rep in reports {
            modeled += rep.modeled_makespan_on(
                cost.compute_s_per_assignment,
                &speeds,
            );
            measured += rep.total_makespan();
            comm_bytes += rep.total_comm_bytes();
            cv += rep.mean_load_cv();
        }
        let extra_replicas = (0..cfg.n_ffn_experts)
            .map(|e| plan.replica_count(e))
            .sum::<usize>()
            - cfg.n_ffn_experts;
        rows.push(PlacementSweepRow {
            strategy: strategy.label().to_string(),
            predicted_makespan_ms: predicted.makespan_s * 1e3,
            modeled_makespan_ms: modeled * 1e3,
            measured_makespan_ms: measured * 1e3,
            comm_mib: comm_bytes as f64 / (1 << 20) as f64,
            load_cv: cv / n_batches as f64,
            moved_experts: rr.diff_experts(&plan).len(),
            extra_replicas,
        });
    }
    Ok((profile, rows))
}

pub fn render_placement_sweep(rows: &[PlacementSweepRow]) -> String {
    let mut s = format!(
        "{:<12} {:>14} {:>13} {:>13} {:>10} {:>8} {:>6} {:>9}\n",
        "strategy", "predicted(ms)", "modeled(ms)", "measured(ms)",
        "a2a (MiB)", "load cv", "moved", "replicas+"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<12} {:>14.3} {:>13.3} {:>13.3} {:>10.3} {:>8.3} {:>6} \
             {:>9}\n",
            r.strategy,
            r.predicted_makespan_ms,
            r.modeled_makespan_ms,
            r.measured_makespan_ms,
            r.comm_mib,
            r.load_cv,
            r.moved_experts,
            r.extra_replicas,
        ));
    }
    s
}

/// JSON payload for `BENCH_placement.json`.
pub fn placement_sweep_json(
    preset: &str,
    n_devices: usize,
    tokens: usize,
    rows: &[PlacementSweepRow],
) -> Json {
    Json::obj(vec![
        ("bench", Json::str("placement")),
        ("preset", Json::str(preset)),
        ("devices", Json::num(n_devices as f64)),
        ("tokens", Json::num(tokens as f64)),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("strategy", Json::str(r.strategy.clone())),
                            (
                                "predicted_makespan_ms",
                                Json::num(r.predicted_makespan_ms),
                            ),
                            (
                                "modeled_makespan_ms",
                                Json::num(r.modeled_makespan_ms),
                            ),
                            (
                                "measured_makespan_ms",
                                Json::num(r.measured_makespan_ms),
                            ),
                            ("comm_mib", Json::num(r.comm_mib)),
                            ("load_cv", Json::num(r.load_cv)),
                            (
                                "moved_experts",
                                Json::num(r.moved_experts as f64),
                            ),
                            (
                                "extra_replicas",
                                Json::num(r.extra_replicas as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

// ----------------------------------------------------------- quantized

/// One cell of the quantized-backend sweep.
#[derive(Clone, Debug)]
pub struct QuantSweepRow {
    pub preset: String,
    /// "f32" (full-precision backend) or "int8" ([`NativeQuant`]).
    ///
    /// [`NativeQuant`]: crate::moe::exec::ExpertBackend::NativeQuant
    pub precision: String,
    pub workers: usize,
    /// Mean expert-forward time per batch.
    pub expert_forward_ms: f64,
    pub tokens_per_s: f64,
    /// Stack-wide parameter bytes of one expert slot at this row's
    /// precision — the placement budget currency (DESIGN.md §17).
    pub expert_bytes: u64,
    /// Arena growths after the measured run. Steady state allocates
    /// nothing on the int8 path too: its quantized scratch is
    /// arena-owned, so this should match the f32 twin's count.
    pub arena_growths: u64,
}

/// The quantized-backend sweep behind `moepp bench quant` and
/// `BENCH_quant.json`: per preset, the f32 stack against an all-int8
/// twin (same weight seed, same batches) across worker counts, plus the
/// oracle-vs-quantized error statistics measured once per preset through
/// [`super::quality::quant_error_stats`]. Throughput rows isolate the
/// backend axis; the error block is what the §17 tolerance gates bound.
pub fn run_quant_sweep(
    presets: &[&str],
    workers_list: &[usize],
    tokens: usize,
    n_batches: usize,
    seed: u64,
) -> Result<(Vec<QuantSweepRow>, Vec<(String, QuantErrorStats)>)> {
    anyhow::ensure!(n_batches > 0, "quant sweep needs >= 1 batch");
    anyhow::ensure!(
        !workers_list.is_empty(),
        "quant sweep needs >= 1 worker count"
    );
    let mut rows = Vec::new();
    let mut errors = Vec::new();
    for preset in presets {
        let cfg = MoeConfig::preset(preset);
        errors.push((
            preset.to_string(),
            super::quality::quant_error_stats(&cfg, seed, tokens)?,
        ));
        let cost = CostModel::from_config(&cfg);
        let mut rng = Rng::new(seed ^ 0x0115);
        let batches = super::workload::hidden_batches(
            &mut rng, n_batches, tokens, cfg.d_model,
        );
        for precision in [Precision::F32, Precision::Int8] {
            for &workers in workers_list {
                let mut engine = MoeEngine::native_with_workers(
                    cfg.clone(),
                    seed,
                    workers,
                )
                .with_precision(vec![precision; cfg.n_ffn_experts]);
                // Warm: arena growth and routing caches settle here.
                let _ = engine.forward_stack(&batches[0])?;
                let mut expert_s = 0.0;
                for b in &batches {
                    let (_, stats) = engine.forward_stack(b)?;
                    expert_s += stats.expert_forward_s;
                }
                rows.push(QuantSweepRow {
                    preset: preset.to_string(),
                    precision: precision.label().to_string(),
                    workers,
                    expert_forward_ms: expert_s * 1e3
                        / n_batches as f64,
                    tokens_per_s: (tokens * n_batches) as f64
                        / expert_s.max(1e-12),
                    expert_bytes: cost.expert_bytes_for(precision),
                    arena_growths: engine.arena_growths(),
                });
            }
        }
    }
    Ok((rows, errors))
}

/// Int8-over-f32 throughput ratio for a row's (preset, workers) cell,
/// when both precisions were measured. `None` for f32 rows.
fn quant_speedup(rows: &[QuantSweepRow], r: &QuantSweepRow)
    -> Option<f64> {
    if r.precision == "f32" {
        return None;
    }
    rows.iter()
        .find(|b| {
            b.precision == "f32"
                && b.preset == r.preset
                && b.workers == r.workers
        })
        .map(|b| r.tokens_per_s / b.tokens_per_s.max(1e-12))
}

pub fn render_quant_sweep(
    rows: &[QuantSweepRow],
    errors: &[(String, QuantErrorStats)],
) -> String {
    let mut s = format!(
        "{:<8} {:<5} {:>7} {:>14} {:>12} {:>12} {:>8}\n",
        "preset", "prec", "workers", "expert fwd(ms)", "tokens/s",
        "bytes/slot", "vs f32"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<8} {:<5} {:>7} {:>14.3} {:>12.0} {:>12} {:>8}\n",
            r.preset,
            r.precision,
            r.workers,
            r.expert_forward_ms,
            r.tokens_per_s,
            r.expert_bytes,
            quant_speedup(rows, r)
                .map(|x| format!("{x:.2}x"))
                .unwrap_or_else(|| "-".into()),
        ));
    }
    for (preset, e) in errors {
        s.push_str(&format!(
            "{preset}: int8 vs f32 oracle  max|err| {:.4}  \
             max rel {:.4}  frob rel {:.4}\n",
            e.max_abs, e.max_rel, e.frob_rel
        ));
    }
    s
}

/// JSON payload for `BENCH_quant.json`.
pub fn quant_sweep_json(
    tokens: usize,
    n_batches: usize,
    rows: &[QuantSweepRow],
    errors: &[(String, QuantErrorStats)],
) -> Json {
    Json::obj(vec![
        ("bench", Json::str("quant")),
        ("tokens", Json::num(tokens as f64)),
        ("batches", Json::num(n_batches as f64)),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        let mut fields = vec![
                            ("preset", Json::str(r.preset.clone())),
                            (
                                "precision",
                                Json::str(r.precision.clone()),
                            ),
                            ("workers", Json::num(r.workers as f64)),
                            (
                                "expert_forward_ms",
                                Json::num(r.expert_forward_ms),
                            ),
                            ("tokens_per_s", Json::num(r.tokens_per_s)),
                            (
                                "expert_bytes",
                                Json::num(r.expert_bytes as f64),
                            ),
                            (
                                "arena_growths",
                                Json::num(r.arena_growths as f64),
                            ),
                        ];
                        if let Some(x) = quant_speedup(rows, r) {
                            fields.push((
                                "speedup_vs_f32",
                                Json::num(x),
                            ));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
        (
            "errors",
            Json::Arr(
                errors
                    .iter()
                    .map(|(p, e)| {
                        Json::obj(vec![
                            ("preset", Json::str(p.clone())),
                            ("max_abs", Json::num(e.max_abs as f64)),
                            ("max_rel", Json::num(e.max_rel as f64)),
                            (
                                "frob_rel",
                                Json::num(e.frob_rel as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

// ------------------------------------------------------------ serving

/// Outcome of driving one request trace through a [`MoeService`].
#[derive(Clone, Debug)]
pub struct ServeTraceReport {
    /// Requests that completed with an output.
    pub completed: usize,
    /// Admission bounces absorbed by the retry loop (backpressure events,
    /// not failures — every request eventually ran).
    pub backpressure_retries: u64,
    /// Submit-first to last-completion wall time.
    pub wall_s: f64,
    /// Completed-request service-time distribution.
    pub per_request: BenchResult,
    /// Sum of every request's per-request assignment counts — reconciles
    /// against the service's batch-level metrics.
    pub counts: AssignmentCounts,
}

impl ServeTraceReport {
    pub fn requests_per_s(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-12)
    }
}

/// Drive `inputs` through the service as a closed-loop trace with
/// backpressure handling: submissions that bounce on a full queue wait
/// for the oldest outstanding response, then retry — the canonical
/// caller-side reaction to [`AdmissionError::QueueFull`].
///
/// A slice of the trace is tagged [`Priority::Interactive`] (every 5th
/// request) and [`Priority::Bulk`] (every 11th) so the scheduler's
/// priority classes see real traffic.
pub fn run_serve_trace(
    service: &MoeService,
    inputs: Vec<Tensor>,
) -> Result<ServeTraceReport> {
    anyhow::ensure!(!inputs.is_empty(), "empty serve trace");
    let t0 = Instant::now();
    let mut handles: Vec<ResponseHandle> = Vec::new();
    let mut samples = Vec::new();
    let mut counts = AssignmentCounts::default();
    let mut completed = 0usize;
    let mut retries = 0u64;
    let drain_oldest =
        |handles: &mut Vec<ResponseHandle>,
         samples: &mut Vec<f64>,
         counts: &mut AssignmentCounts,
         completed: &mut usize|
         -> Result<()> {
            let resp = handles.remove(0).wait().map_err(|e| {
                anyhow::anyhow!("serve trace request failed: {e}")
            })?;
            samples.push(resp.stats.service_time.as_secs_f64());
            counts.add(&resp.stats.counts);
            *completed += 1;
            Ok(())
        };
    for (i, tokens) in inputs.into_iter().enumerate() {
        let priority = if i % 5 == 0 {
            Priority::Interactive
        } else if i % 11 == 0 {
            Priority::Bulk
        } else {
            Priority::Standard
        };
        let req = ServeRequest::new(tokens).with_priority(priority);
        loop {
            match service.submit(req.clone()) {
                Ok(h) => {
                    handles.push(h);
                    break;
                }
                Err(
                    AdmissionError::QueueFull { .. }
                    | AdmissionError::TooManyPending { .. },
                ) => {
                    retries += 1;
                    anyhow::ensure!(
                        !handles.is_empty(),
                        "admission rejected with nothing in flight"
                    );
                    drain_oldest(
                        &mut handles,
                        &mut samples,
                        &mut counts,
                        &mut completed,
                    )?;
                }
                Err(e) => anyhow::bail!("serve trace admission: {e}"),
            }
        }
    }
    while !handles.is_empty() {
        drain_oldest(
            &mut handles,
            &mut samples,
            &mut counts,
            &mut completed,
        )?;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(ServeTraceReport {
        completed,
        backpressure_retries: retries,
        wall_s,
        per_request: summarize("serve-request", samples),
        counts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut x = 0u64;
        let r = bench("spin", 1, 5, Duration::from_millis(10), || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.p95_s);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn summarize_stats() {
        let r = summarize("s", vec![3.0, 1.0, 2.0]);
        assert_eq!(r.min_s, 1.0);
        assert_eq!(r.median_s, 2.0);
        assert!((r.mean_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn forward_sweep_covers_grid_and_reports_speedups() {
        let rows = run_forward_sweep(
            &["test"],
            &[1, 2],
            &Partition::all(),
            &ExecutorKind::all(),
            32,
            2,
            5,
            None,
            None,
        )
        .unwrap();
        // 1 preset x 2 workloads x 2 partitions x 2 executors x
        // 2 worker counts.
        assert_eq!(rows.len(), 16);
        for r in &rows {
            assert!(r.tokens_per_s > 0.0, "{r:?}");
            assert!(r.expert_forward_ms > 0.0, "{r:?}");
            if r.executor == "scoped" {
                assert_eq!(r.pool_spawns, 0, "{r:?}");
            } else {
                assert_eq!(r.pool_spawns, r.workers as u64 - 1, "{r:?}");
            }
        }
        let rendered = render_forward_sweep(&rows);
        assert!(rendered.contains("skewed"));
        assert!(rendered.contains("pool") && rendered.contains("scoped"));
        let j = forward_sweep_json(32, 2, &rows);
        let back = Json::parse(&j.to_string()).unwrap();
        let jrows = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(jrows.len(), 16);
        // Every shard row carries a speedup ratio against its batch twin
        // (same executor), every pool row one against its scoped twin.
        let shard_rows: Vec<_> = jrows
            .iter()
            .filter(|r| {
                r.get("partition").and_then(Json::as_str)
                    == Some("shard")
            })
            .collect();
        assert!(!shard_rows.is_empty());
        for r in shard_rows {
            assert!(
                r.get("speedup_vs_batch")
                    .and_then(Json::as_f64)
                    .is_some(),
                "missing speedup field"
            );
        }
        let pool_rows: Vec<_> = jrows
            .iter()
            .filter(|r| {
                r.get("executor").and_then(Json::as_str) == Some("pool")
            })
            .collect();
        assert_eq!(pool_rows.len(), 8);
        for r in pool_rows {
            assert!(
                r.get("speedup_vs_scoped")
                    .and_then(Json::as_f64)
                    .is_some(),
                "missing pool-vs-scoped field"
            );
        }
    }

    #[test]
    fn placement_sweep_is_internally_consistent() {
        let (profile, rows) = run_placement_sweep(
            "test", 2, 64, 2, true, 3, None, 2, &[], None,
        )
        .unwrap();
        assert_eq!(profile.batches, 2);
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].strategy, "round-robin");
        assert_eq!(rows[0].moved_experts, 0);
        assert_eq!(rows[0].extra_replicas, 0);
        assert_eq!(rows[3].strategy, "replicated");
        // Without a memory budget the compressed strategy has nothing to
        // compress against and returns the replicated plan verbatim.
        assert_eq!(rows[4].strategy, "compressed");
        assert_eq!(rows[4].extra_replicas, rows[3].extra_replicas);
        // The never-worse guarantee is exact on the aggregated profile
        // (predicted); the per-batch modeled sum optimises per-batch
        // maxima the planner never saw, so it gets a small slack band.
        for r in &rows[1..] {
            assert!(
                r.predicted_makespan_ms
                    <= rows[0].predicted_makespan_ms * (1.0 + 1e-9),
                "{r:?} vs {:?}",
                rows[0]
            );
            assert!(
                r.modeled_makespan_ms
                    <= rows[0].modeled_makespan_ms * 1.10,
                "{r:?} vs {:?}",
                rows[0]
            );
        }
        let s = render_placement_sweep(&rows);
        assert!(s.contains("round-robin"));
        assert!(s.contains("replicated"));
        let j = placement_sweep_json("test", 2, 64, &rows);
        // Round-trips through the writer/parser.
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            back.get("rows").unwrap().as_arr().unwrap().len(),
            5
        );
        assert_eq!(back.get("devices").unwrap().as_usize(), Some(2));
        assert!(back.get("rows").unwrap().as_arr().unwrap()[3]
            .get("extra_replicas")
            .is_some());
    }

    #[test]
    fn placement_sweep_runs_on_a_heterogeneous_fleet() {
        // Device speeds thread end to end: cost model, simulated
        // workers and modeled makespan all see the same fleet, and the
        // never-worse guarantee holds on it just like on the uniform
        // one.
        let (_, rows) = run_placement_sweep(
            "test", 2, 48, 1, true, 7, None, 2, &[2.0, 1.0], None,
        )
        .unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(r.modeled_makespan_ms > 0.0, "{r:?}");
        }
        for r in &rows[1..] {
            assert!(
                r.predicted_makespan_ms
                    <= rows[0].predicted_makespan_ms * (1.0 + 1e-9),
                "{r:?} vs {:?}",
                rows[0]
            );
        }
    }

    #[test]
    fn placement_sweep_with_budget_simulates_compressed_plans() {
        // A budget with headroom for one int8 slot beyond two f32 slots:
        // the compressed strategy may go mixed-precision where the other
        // four cannot, and its plan still simulates (the cluster spawns
        // int8 workers from the precision map) and never scores worse
        // than the replicated row.
        let cfg = MoeConfig::preset("test");
        let cost = CostModel::from_config(&cfg);
        let budget = 2 * cost.expert_bytes
            + cost.expert_bytes_for(Precision::Int8);
        let (_, rows) = run_placement_sweep(
            "test", 2, 64, 2, true, 3, Some(budget), 2, &[], None,
        )
        .unwrap();
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[4].strategy, "compressed");
        assert!(
            rows[4].predicted_makespan_ms
                <= rows[3].predicted_makespan_ms * (1.0 + 1e-9),
            "{:?} vs {:?}",
            rows[4],
            rows[3]
        );
        for r in &rows {
            assert!(r.modeled_makespan_ms > 0.0, "{r:?}");
        }
    }

    #[test]
    fn precision_map_expands_specs() {
        assert_eq!(
            precision_map("f32", 4).unwrap(),
            vec![Precision::F32; 4]
        );
        assert_eq!(
            precision_map("int8", 3).unwrap(),
            vec![Precision::Int8; 3]
        );
        assert_eq!(
            precision_map("mixed", 4).unwrap(),
            vec![
                Precision::F32,
                Precision::Int8,
                Precision::F32,
                Precision::Int8
            ]
        );
        assert!(precision_map("fp16", 4).is_err());
    }

    #[test]
    fn placement_sweep_honors_precision_floor() {
        // A mixed-precision floor reaches every simulated plan: the
        // sweep still covers all strategies, the quantized stack runs
        // end to end, and the round-robin baseline simulates on the
        // same map as every other row.
        let (_, rows) = run_placement_sweep(
            "test", 2, 48, 1, true, 7, None, 2, &[], Some("mixed"),
        )
        .unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(r.modeled_makespan_ms > 0.0, "{r:?}");
        }
        for r in &rows[1..] {
            assert!(
                r.predicted_makespan_ms
                    <= rows[0].predicted_makespan_ms * (1.0 + 1e-9),
                "{r:?} vs {:?}",
                rows[0]
            );
        }
    }

    #[test]
    fn quant_sweep_reports_rows_and_error_stats() {
        let (rows, errors) =
            run_quant_sweep(&["test"], &[1, 2], 32, 2, 11).unwrap();
        // 1 preset x 2 precisions x 2 worker counts.
        assert_eq!(rows.len(), 4);
        assert_eq!(errors.len(), 1);
        for r in &rows {
            assert!(r.tokens_per_s > 0.0, "{r:?}");
            assert!(r.expert_forward_ms > 0.0, "{r:?}");
        }
        // Int8 rows carry the compressed footprint and a throughput
        // ratio against their f32 twin.
        let f32_bytes = rows
            .iter()
            .find(|r| r.precision == "f32")
            .unwrap()
            .expert_bytes;
        let int8_rows: Vec<_> =
            rows.iter().filter(|r| r.precision == "int8").collect();
        assert_eq!(int8_rows.len(), 2);
        for r in int8_rows {
            assert!(r.expert_bytes < f32_bytes, "{r:?}");
            assert!(quant_speedup(&rows, r).is_some(), "{r:?}");
        }
        // The measured error block passes the default §17 gates.
        crate::bench::quality::QuantGates::default()
            .check(&errors[0].1)
            .unwrap();
        let rendered = render_quant_sweep(&rows, &errors);
        assert!(rendered.contains("int8"));
        assert!(rendered.contains("frob rel"));
        let j = quant_sweep_json(32, 2, &rows, &errors);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            back.get("rows").unwrap().as_arr().unwrap().len(),
            4
        );
        let jerr = back.get("errors").unwrap().as_arr().unwrap();
        assert_eq!(jerr.len(), 1);
        assert!(jerr[0].get("frob_rel").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn write_bench_json_emits_parseable_file() {
        let payload =
            Json::obj(vec![("bench", Json::str("x")),
                           ("v", Json::num(1.5))]);
        let path = write_bench_json("smoketest", &payload).unwrap();
        assert_eq!(path, "BENCH_smoketest.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let back = Json::parse(text.trim()).unwrap();
        assert_eq!(back.get("v").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn serve_trace_completes_and_reconciles_with_service_metrics() {
        use crate::config::MoeConfig;
        use crate::coordinator::batcher::BatcherConfig;
        use crate::coordinator::engine::MoeEngine;
        use crate::serve::ServiceConfig;
        use crate::util::rng::Rng;

        let cfg = MoeConfig::preset("test");
        let service = MoeService::start(
            MoeEngine::native(cfg.clone(), 0),
            ServiceConfig {
                batcher: BatcherConfig {
                    max_tokens: 32,
                    max_wait: Duration::from_millis(1),
                },
                max_queued_tokens: 64,
                max_pending_requests: 128,
                default_deadline: None,
                obs: None,
            },
        );
        let mut rng = Rng::new(9);
        let inputs: Vec<Tensor> = (0..20)
            .map(|_| {
                let n = 1 + (rng.next_u64() % 8) as usize;
                Tensor::randn(&mut rng, &[n, cfg.d_model], 1.0)
            })
            .collect();
        let report = run_serve_trace(&service, inputs).unwrap();
        assert_eq!(report.completed, 20);
        assert!(report.wall_s > 0.0);
        assert!(report.requests_per_s() > 0.0);
        assert_eq!(report.per_request.iters, 20);
        // Per-request assignment counts summed over the trace must equal
        // the service's batch-level forward accounting exactly.
        let m = service.shutdown();
        assert_eq!(report.counts.ffn, m.ffn_assignments);
        assert_eq!(report.counts.zc(), m.zc_assignments);
        assert_eq!(report.counts.dropped, m.dropped_assignments);
        // Every input was admitted exactly once; bounces only ever
        // incremented the reject counter.
        assert_eq!(m.requests, 20);
        assert_eq!(m.rejected, report.backpressure_retries);
    }
}

//! Quality-side reproductions: train model-variant artifacts on the
//! synthetic corpus at matched budget, evaluate held-out perplexity.
//!
//! Covers Table 3's benchmark columns (tau sweep), Table 4 (vs dense models
//! of equal/greater activated params), Table 5 (expert-type ablation),
//! Table 6 (gating residuals), and Fig. 3 (n_const sweep).

use anyhow::Result;

use crate::config::{MoeConfig, Precision};
use crate::coordinator::engine::MoeEngine;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::training::data::Corpus;
use crate::training::trainer::Trainer;
use crate::util::rng::Rng;

/// Result of one trained variant.
#[derive(Clone, Debug)]
pub struct QualityRow {
    pub tag: String,
    pub steps: usize,
    pub final_loss: f64,
    pub eval_ce: f64,
    pub eval_ppl: f64,
    pub mean_ffn_per_token: f64,
    pub mean_drop: f64,
    pub activated_frac: f64,
}

/// Train `tag` for `steps` on the shared corpus; eval on held-out batches.
pub fn train_and_eval(
    rt: &Runtime,
    tag: &str,
    steps: usize,
    seed: u64,
) -> Result<QualityRow> {
    let mut trainer = Trainer::new(rt, tag, seed as i32)?;
    let cfg = rt
        .manifest
        .configs
        .get(tag)
        .ok_or_else(|| anyhow::anyhow!("no config for tag {tag}"))?;
    let corpus = Corpus::new(cfg.vocab_size, 4, 1234);
    let mut rng = Rng::new(seed ^ 0xDA7A);
    let history = trainer.train(&corpus, steps, &mut rng, steps / 5)?;
    // Held-out eval: fresh RNG stream disjoint from training.
    let mut eval_rng = Rng::new(0xE7A1);
    let (ce, ppl) = trainer.eval(&corpus, 8, &mut eval_rng)?;
    let tail = &history[history.len().saturating_sub(10)..];
    let mean = |f: fn(&crate::training::trainer::StepMetrics) -> f64| {
        tail.iter().map(f).sum::<f64>() / tail.len() as f64
    };
    Ok(QualityRow {
        tag: tag.to_string(),
        steps,
        final_loss: mean(|m| m.loss),
        eval_ce: ce,
        eval_ppl: ppl,
        mean_ffn_per_token: mean(|m| m.ffn_per_token),
        mean_drop: mean(|m| m.dropped),
        activated_frac: cfg.ffn_token_fraction(),
    })
}

pub fn render_quality(title: &str, rows: &[QualityRow]) -> String {
    let mut s = format!("== {title} ==\n");
    s.push_str(&format!(
        "{:<34} {:>6} {:>10} {:>10} {:>9} {:>8}\n",
        "variant", "steps", "final loss", "eval ppl", "ffn/tok", "drop"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<34} {:>6} {:>10.4} {:>10.3} {:>9.2} {:>8.1}\n",
            r.tag, r.steps, r.final_loss, r.eval_ppl,
            r.mean_ffn_per_token, r.mean_drop
        ));
    }
    s
}

/// Error statistics of an all-int8 stack against the f32 oracle on one
/// deterministic batch (ISSUE 10 acceptance: the quantized path stays
/// within tested tolerance of the f32 oracle).
#[derive(Clone, Copy, Debug)]
pub struct QuantErrorStats {
    /// Largest elementwise |quant - oracle| over the output tensor.
    pub max_abs: f32,
    /// Largest elementwise relative error, floored at |oracle| >= 1 so
    /// near-zero entries do not dominate.
    pub max_rel: f32,
    /// Global relative Frobenius error ||quant - oracle|| / ||oracle||.
    pub frob_rel: f32,
}

/// Tolerance gates for [`QuantErrorStats`]. Stack-level and therefore
/// *generous* (DESIGN.md §17): quantization perturbs the residual
/// stream, so a later layer's top-k may flip and route a token through
/// a genuinely different expert — an O(1) output change that is real
/// model divergence, not kernel error. The per-kernel bound lives in
/// `moe::experts` (routing-free, per-row ~0.15 relative); these gates
/// bound the end-to-end drift a serving deployment actually sees.
#[derive(Clone, Copy, Debug)]
pub struct QuantGates {
    pub max_abs: f32,
    pub frob_rel: f32,
}

impl Default for QuantGates {
    fn default() -> QuantGates {
        QuantGates { max_abs: 3.0, frob_rel: 0.5 }
    }
}

impl QuantGates {
    pub fn check(&self, s: &QuantErrorStats) -> Result<()> {
        anyhow::ensure!(
            s.max_abs <= self.max_abs,
            "quantized stack max abs error {} exceeds gate {}",
            s.max_abs,
            self.max_abs
        );
        anyhow::ensure!(
            s.frob_rel <= self.frob_rel,
            "quantized stack relative error {} exceeds gate {}",
            s.frob_rel,
            self.frob_rel
        );
        Ok(())
    }
}

/// Forward one deterministic batch through the f32 oracle engine and an
/// all-int8 twin (same weight seed) and measure the divergence. Routing
/// runs live on both stacks — flipped assignments downstream of the
/// quantized layer-0 residuals are included in the error, which is what
/// the generous [`QuantGates`] are calibrated for.
pub fn quant_error_stats(
    cfg: &MoeConfig,
    seed: u64,
    n_tokens: usize,
) -> Result<QuantErrorStats> {
    let mut oracle = MoeEngine::native(cfg.clone(), seed);
    let mut quant = MoeEngine::native(cfg.clone(), seed).with_precision(
        vec![Precision::Int8; cfg.n_ffn_experts],
    );
    let mut rng = Rng::new(seed ^ 0x51A7);
    let x = Tensor::randn(&mut rng, &[n_tokens, cfg.d_model], 1.0);
    let (y_f, _) = oracle.forward_stack(&x)?;
    let (y_q, _) = quant.forward_stack(&x)?;
    let mut max_abs = 0f32;
    let mut max_rel = 0f32;
    let (mut num, mut den) = (0f64, 0f64);
    for (a, b) in y_q.data.iter().zip(&y_f.data) {
        let d = (a - b).abs();
        max_abs = max_abs.max(d);
        max_rel = max_rel.max(d / b.abs().max(1.0));
        num += (d as f64) * (d as f64);
        den += (*b as f64) * (*b as f64);
    }
    Ok(QuantErrorStats {
        max_abs,
        max_rel,
        frob_rel: (num / den.max(1e-12)).sqrt() as f32,
    })
}

/// Tags for the Table 5 expert-subset ablation (vanilla baseline + 7
/// subsets + full model), matching the paper's 8 rows.
pub fn table5_tags() -> Vec<(&'static str, &'static str)> {
    vec![
        ("test_vanilla", "baseline (no ZC experts)"),
        ("test_moepp_nz1_nk0_nc0", "zero only"),
        ("test_moepp_nz0_nk1_nc0", "copy only"),
        ("test_moepp_nz0_nk0_nc1", "const only"),
        ("test_moepp_nz1_nk1_nc0", "zero+copy"),
        ("test_moepp_nz1_nk0_nc1", "zero+const"),
        ("test_moepp_nz0_nk1_nc1", "copy+const"),
        ("test_moepp", "zero+copy+const (full)"),
    ]
}

/// Tags for the Table 3 tau sweep (quality columns).
pub fn table3_quality_tags() -> Vec<String> {
    let mut v: Vec<String> = [0.1, 0.25, 0.5, 1.0]
        .iter()
        .map(|t| format!("test_moepp_tau{t}"))
        .collect();
    v.push("test_moepp".to_string()); // tau = 0.75 default
    v.insert(0, "test_vanilla".to_string());
    v
}

/// Tags for Fig. 3 (n_const sweep; nc=2 is the base model).
pub fn fig3_tags() -> Vec<(usize, String)> {
    vec![
        (1, "test_moepp_nc1".into()),
        (2, "test_moepp".into()),
        (4, "test_moepp_nc4".into()),
        (6, "test_moepp_nc6".into()),
        (8, "test_moepp_nc8".into()),
    ]
}

/// Tags for Table 4: MoE++ vs dense models of growing activated params.
pub fn table4_tags() -> Vec<(&'static str, &'static str)> {
    vec![
        ("test_vanilla_nf1_k1_ff64", "dense ~1x activated"),
        ("test_vanilla_nf1_k1_ff128", "dense ~2x activated"),
        ("test_vanilla_nf1_k1_ff224", "dense ~3.5x activated"),
        ("test_moepp", "MoE++ (<=1x activated)"),
    ]
}

/// Table 6 tags.
pub fn table6_tags() -> Vec<(&'static str, &'static str)> {
    vec![
        ("test_moepp_gr0", "MoE++ w/o gating residuals"),
        ("test_moepp", "MoE++ w/ gating residuals"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantized_stack_stays_within_tolerance_gates() {
        let cfg = MoeConfig::preset("test");
        let stats = quant_error_stats(&cfg, 17, 64).unwrap();
        QuantGates::default().check(&stats).unwrap();
        // Sanity on the measurement itself: the int8 stack genuinely
        // diverges from the oracle (a zero error would mean the
        // quantized backend never ran).
        assert!(stats.frob_rel > 0.0);
        assert!(stats.max_abs > 0.0);
        assert!(stats.max_rel >= 0.0);
        // And a tightened gate detects real drift.
        let tight = QuantGates { max_abs: 0.0, frob_rel: 0.0 };
        assert!(tight.check(&stats).is_err());
    }
}

//! Quality-side reproductions: train model-variant artifacts on the
//! synthetic corpus at matched budget, evaluate held-out perplexity.
//!
//! Covers Table 3's benchmark columns (tau sweep), Table 4 (vs dense models
//! of equal/greater activated params), Table 5 (expert-type ablation),
//! Table 6 (gating residuals), and Fig. 3 (n_const sweep).

use anyhow::Result;

use crate::runtime::Runtime;
use crate::training::data::Corpus;
use crate::training::trainer::Trainer;
use crate::util::rng::Rng;

/// Result of one trained variant.
#[derive(Clone, Debug)]
pub struct QualityRow {
    pub tag: String,
    pub steps: usize,
    pub final_loss: f64,
    pub eval_ce: f64,
    pub eval_ppl: f64,
    pub mean_ffn_per_token: f64,
    pub mean_drop: f64,
    pub activated_frac: f64,
}

/// Train `tag` for `steps` on the shared corpus; eval on held-out batches.
pub fn train_and_eval(
    rt: &Runtime,
    tag: &str,
    steps: usize,
    seed: u64,
) -> Result<QualityRow> {
    let mut trainer = Trainer::new(rt, tag, seed as i32)?;
    let cfg = rt
        .manifest
        .configs
        .get(tag)
        .ok_or_else(|| anyhow::anyhow!("no config for tag {tag}"))?;
    let corpus = Corpus::new(cfg.vocab_size, 4, 1234);
    let mut rng = Rng::new(seed ^ 0xDA7A);
    let history = trainer.train(&corpus, steps, &mut rng, steps / 5)?;
    // Held-out eval: fresh RNG stream disjoint from training.
    let mut eval_rng = Rng::new(0xE7A1);
    let (ce, ppl) = trainer.eval(&corpus, 8, &mut eval_rng)?;
    let tail = &history[history.len().saturating_sub(10)..];
    let mean = |f: fn(&crate::training::trainer::StepMetrics) -> f64| {
        tail.iter().map(f).sum::<f64>() / tail.len() as f64
    };
    Ok(QualityRow {
        tag: tag.to_string(),
        steps,
        final_loss: mean(|m| m.loss),
        eval_ce: ce,
        eval_ppl: ppl,
        mean_ffn_per_token: mean(|m| m.ffn_per_token),
        mean_drop: mean(|m| m.dropped),
        activated_frac: cfg.ffn_token_fraction(),
    })
}

pub fn render_quality(title: &str, rows: &[QualityRow]) -> String {
    let mut s = format!("== {title} ==\n");
    s.push_str(&format!(
        "{:<34} {:>6} {:>10} {:>10} {:>9} {:>8}\n",
        "variant", "steps", "final loss", "eval ppl", "ffn/tok", "drop"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<34} {:>6} {:>10.4} {:>10.3} {:>9.2} {:>8.1}\n",
            r.tag, r.steps, r.final_loss, r.eval_ppl,
            r.mean_ffn_per_token, r.mean_drop
        ));
    }
    s
}

/// Tags for the Table 5 expert-subset ablation (vanilla baseline + 7
/// subsets + full model), matching the paper's 8 rows.
pub fn table5_tags() -> Vec<(&'static str, &'static str)> {
    vec![
        ("test_vanilla", "baseline (no ZC experts)"),
        ("test_moepp_nz1_nk0_nc0", "zero only"),
        ("test_moepp_nz0_nk1_nc0", "copy only"),
        ("test_moepp_nz0_nk0_nc1", "const only"),
        ("test_moepp_nz1_nk1_nc0", "zero+copy"),
        ("test_moepp_nz1_nk0_nc1", "zero+const"),
        ("test_moepp_nz0_nk1_nc1", "copy+const"),
        ("test_moepp", "zero+copy+const (full)"),
    ]
}

/// Tags for the Table 3 tau sweep (quality columns).
pub fn table3_quality_tags() -> Vec<String> {
    let mut v: Vec<String> = [0.1, 0.25, 0.5, 1.0]
        .iter()
        .map(|t| format!("test_moepp_tau{t}"))
        .collect();
    v.push("test_moepp".to_string()); // tau = 0.75 default
    v.insert(0, "test_vanilla".to_string());
    v
}

/// Tags for Fig. 3 (n_const sweep; nc=2 is the base model).
pub fn fig3_tags() -> Vec<(usize, String)> {
    vec![
        (1, "test_moepp_nc1".into()),
        (2, "test_moepp".into()),
        (4, "test_moepp_nc4".into()),
        (6, "test_moepp_nc6".into()),
        (8, "test_moepp_nc8".into()),
    ]
}

/// Tags for Table 4: MoE++ vs dense models of growing activated params.
pub fn table4_tags() -> Vec<(&'static str, &'static str)> {
    vec![
        ("test_vanilla_nf1_k1_ff64", "dense ~1x activated"),
        ("test_vanilla_nf1_k1_ff128", "dense ~2x activated"),
        ("test_vanilla_nf1_k1_ff224", "dense ~3.5x activated"),
        ("test_moepp", "MoE++ (<=1x activated)"),
    ]
}

/// Table 6 tags.
pub fn table6_tags() -> Vec<(&'static str, &'static str)> {
    vec![
        ("test_moepp_gr0", "MoE++ w/o gating residuals"),
        ("test_moepp", "MoE++ w/ gating residuals"),
    ]
}

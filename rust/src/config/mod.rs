//! Configuration system: the Rust mirror of `python/compile/configs.py`
//! plus runtime/serving settings. Presets replicate the paper's Table 2
//! structure at reproduction scale; `MoeConfig::from_json` loads the
//! authoritative copy the AOT pipeline wrote into `artifacts/manifest.json`
//! so L2 and L3 can never drift.

use crate::util::json::Json;

/// Expert kinds in an MoE++ layer (paper Sec. 3.1). Order within a layer is
/// always: FFN experts, zero, copy, constant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ExpertKind {
    Ffn,
    Zero,
    Copy,
    Constant,
}

impl ExpertKind {
    pub fn is_zero_computation(self) -> bool {
        !matches!(self, ExpertKind::Ffn)
    }

    pub fn label(self) -> &'static str {
        match self {
            ExpertKind::Ffn => "ffn",
            ExpertKind::Zero => "zero",
            ExpertKind::Copy => "copy",
            ExpertKind::Constant => "const",
        }
    }
}

/// Numeric precision of one FFN expert's stored weights. Precision is
/// per-expert and **stack-wide**: every layer's copy of expert `e`, and
/// every replica of it, carries the same precision (DESIGN.md §17).
/// Routing, capacities, and the canonical combine order are
/// precision-blind, so a plan's precision vector never affects which
/// tokens go where — only the bytes a slot costs and which kernel runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl Precision {
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" => Some(Precision::F32),
            "int8" => Some(Precision::Int8),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

/// Model + MoE hyper-parameters (mirror of python MoEConfig).
#[derive(Clone, Debug, PartialEq)]
pub struct MoeConfig {
    pub name: String,
    pub vocab_size: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub n_ffn_experts: usize,
    pub n_zero: usize,
    pub n_copy: usize,
    pub n_const: usize,
    pub top_k: usize,
    pub tau: f64,
    pub capacity_factor: f64,
    pub balance_coef: f64,
    pub gating_residual: bool,
    pub vanilla: bool,
}

impl Default for MoeConfig {
    fn default() -> Self {
        // = python preset("sm-8e"), the scaled MoE++ 0.6B/(8+4)E.
        MoeConfig {
            name: "sm-8e".into(),
            vocab_size: 512,
            n_layers: 4,
            d_model: 128,
            d_ff: 352,
            n_heads: 4,
            seq_len: 128,
            n_ffn_experts: 8,
            n_zero: 1,
            n_copy: 1,
            n_const: 2,
            top_k: 2,
            tau: 0.75,
            capacity_factor: 1.1,
            balance_coef: 0.01,
            gating_residual: true,
            vanilla: false,
        }
    }
}

impl MoeConfig {
    /// Named presets — must stay in sync with python/compile/configs.py
    /// (cross-checked by the integration test against manifest.json).
    pub fn preset(name: &str) -> MoeConfig {
        let (base, variant) = match name.split_once(':') {
            Some((b, v)) => (b, v),
            None => (name, "moepp"),
        };
        let mut cfg = match base {
            "sm-8e" => MoeConfig::default(),
            "sm-16e" => MoeConfig {
                name: "sm-16e".into(),
                n_ffn_experts: 16,
                ..MoeConfig::default()
            },
            "sm-32e" => MoeConfig {
                name: "sm-32e".into(),
                n_ffn_experts: 32,
                n_const: 6,
                ..MoeConfig::default()
            },
            "md-16e" => MoeConfig {
                name: "md-16e".into(),
                n_layers: 8,
                d_model: 256,
                d_ff: 704,
                n_heads: 8,
                n_ffn_experts: 16,
                ..MoeConfig::default()
            },
            "e2e" => MoeConfig {
                name: "e2e".into(),
                vocab_size: 2048,
                n_layers: 6,
                d_model: 256,
                d_ff: 704,
                n_heads: 8,
                n_ffn_experts: 8,
                ..MoeConfig::default()
            },
            "test" => MoeConfig {
                name: "test".into(),
                vocab_size: 64,
                n_layers: 2,
                d_model: 32,
                d_ff: 64,
                n_heads: 2,
                seq_len: 16,
                n_ffn_experts: 4,
                ..MoeConfig::default()
            },
            other => panic!("unknown preset '{other}'"),
        };
        if variant == "vanilla" {
            cfg.vanilla = true;
            cfg.n_zero = 0;
            cfg.n_copy = 0;
            cfg.n_const = 0;
        }
        cfg
    }

    /// Parse from a manifest `configs` entry (written by aot.py).
    pub fn from_json(j: &Json) -> anyhow::Result<MoeConfig> {
        let g = |k: &str| -> anyhow::Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing config key '{k}'"))
        };
        Ok(MoeConfig {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unnamed")
                .to_string(),
            vocab_size: g("vocab_size")? as usize,
            n_layers: g("n_layers")? as usize,
            d_model: g("d_model")? as usize,
            d_ff: g("d_ff")? as usize,
            n_heads: g("n_heads")? as usize,
            seq_len: g("seq_len")? as usize,
            n_ffn_experts: g("n_ffn_experts")? as usize,
            n_zero: g("n_zero")? as usize,
            n_copy: g("n_copy")? as usize,
            n_const: g("n_const")? as usize,
            top_k: g("top_k")? as usize,
            tau: g("tau")?,
            capacity_factor: g("capacity_factor")?,
            balance_coef: g("balance_coef")?,
            gating_residual: j
                .get("gating_residual")
                .and_then(Json::as_bool)
                .unwrap_or(true),
            vanilla: j.get("variant").and_then(Json::as_str)
                == Some("vanilla"),
        })
    }

    pub fn n_zc(&self) -> usize {
        if self.vanilla {
            0
        } else {
            self.n_zero + self.n_copy + self.n_const
        }
    }

    pub fn n_experts(&self) -> usize {
        self.n_ffn_experts + self.n_zc()
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Kind of expert index `i` (layer-local).
    pub fn kind(&self, i: usize) -> ExpertKind {
        let nf = self.n_ffn_experts;
        if i < nf {
            ExpertKind::Ffn
        } else if i < nf + self.n_zero {
            ExpertKind::Zero
        } else if i < nf + self.n_zero + self.n_copy {
            ExpertKind::Copy
        } else {
            assert!(i < self.n_experts(), "expert index {i} out of range");
            ExpertKind::Constant
        }
    }

    /// Index of expert `i` into the layer's constant-expert table — the
    /// single implementation of constant-expert index arithmetic (every
    /// execution path goes through here; see DESIGN.md §6).
    pub fn const_index(&self, i: usize) -> usize {
        debug_assert_eq!(
            self.kind(i),
            ExpertKind::Constant,
            "const_index on non-constant expert {i}"
        );
        i - self.n_ffn_experts - self.n_zero - self.n_copy
    }

    /// Heterogeneous expert capacity, Eq. 8 (scaled by K as in the L2
    /// implementation — total capacity covers all T*K assignments).
    pub fn capacities(&self, n_tokens: usize) -> (usize, usize) {
        let (gamma, tau, k) =
            (self.capacity_factor, self.tau, self.top_k as f64);
        let t = n_tokens as f64;
        if self.vanilla {
            let cap =
                (gamma * k * t / self.n_experts() as f64) as usize + 1;
            return (cap, 0);
        }
        let denom = tau * self.n_ffn_experts as f64 + self.n_zc() as f64;
        let ffn = (gamma * k * tau * t / denom) as usize + 1;
        let zc = (gamma * k * t / denom) as usize + 1;
        (ffn, zc)
    }

    /// Per-expert capacity vector for a batch of `n_tokens`.
    pub fn capacity_vec(&self, n_tokens: usize) -> Vec<usize> {
        let (fc, zc) = self.capacities(n_tokens);
        (0..self.n_experts())
            .map(|i| if self.kind(i) == ExpertKind::Ffn { fc } else { zc })
            .collect()
    }

    /// Eq. 7's eta weight for expert i.
    pub fn eta(&self, i: usize) -> f64 {
        if self.kind(i) == ExpertKind::Ffn {
            1.0
        } else {
            self.tau
        }
    }

    /// FLOPs of one FFN expert applied to one token (2*3*D*F MACs).
    pub fn ffn_flops_per_token(&self) -> f64 {
        6.0 * self.d_model as f64 * self.d_ff as f64
    }

    /// Bytes of one FFN expert's parameters in ONE layer (w1/w3/w2,
    /// f32). Placement accounting multiplies by `n_layers`: a placement
    /// owner applies stack-wide, so each expert slot stores (and each
    /// migration moves) one copy per layer.
    pub fn ffn_expert_bytes(&self) -> u64 {
        self.ffn_expert_bytes_at(Precision::F32)
    }

    /// Bytes of one FFN expert's parameters in ONE layer at the given
    /// precision. Int8 stores one byte per weight plus f32 per-output-
    /// channel scales for each of the three projections (w1/w3 have
    /// `d_ff` output channels each, w2 has `d_model`) — must agree with
    /// `QuantFfnExpert::bytes()`.
    pub fn ffn_expert_bytes_at(&self, p: Precision) -> u64 {
        let n_params = 3 * self.d_model * self.d_ff;
        match p {
            Precision::F32 => (n_params * 4) as u64,
            Precision::Int8 => {
                (n_params + (2 * self.d_ff + self.d_model) * 4) as u64
            }
        }
    }

    /// Table 1: expected fraction of top-K slots landing on FFN experts
    /// under balanced routing: tau*N_F / (tau*N_F + N_Z).
    pub fn ffn_token_fraction(&self) -> f64 {
        if self.vanilla {
            return 1.0;
        }
        let nf = self.n_ffn_experts as f64;
        let nz = self.n_zc() as f64;
        self.tau * nf / (self.tau * nf + nz)
    }
}

/// Paper Eq. 10: adaptive number of constant experts.
pub fn adaptive_n_const(n_ffn: usize, n_zero: usize, n_copy: usize) -> usize {
    ((n_ffn / 4).saturating_sub(n_zero + n_copy)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_mirror_table2_ratios() {
        let c = MoeConfig::preset("sm-32e");
        assert_eq!((c.n_zero, c.n_copy, c.n_const), (1, 1, 6));
        assert_eq!(c.n_experts(), 40);
        let v = MoeConfig::preset("sm-32e:vanilla");
        assert_eq!(v.n_experts(), 32);
        assert!(v.vanilla);
    }

    #[test]
    fn expert_kind_ordering() {
        let c = MoeConfig::preset("sm-8e");
        assert_eq!(c.kind(0), ExpertKind::Ffn);
        assert_eq!(c.kind(7), ExpertKind::Ffn);
        assert_eq!(c.kind(8), ExpertKind::Zero);
        assert_eq!(c.kind(9), ExpertKind::Copy);
        assert_eq!(c.kind(10), ExpertKind::Constant);
        assert_eq!(c.kind(11), ExpertKind::Constant);
    }

    #[test]
    #[should_panic]
    fn kind_out_of_range_panics() {
        MoeConfig::preset("sm-8e").kind(12);
    }

    #[test]
    fn const_index_is_table_local() {
        let c = MoeConfig::preset("sm-8e"); // 8 FFN, 1 zero, 1 copy, 2 const
        assert_eq!(c.const_index(10), 0);
        assert_eq!(c.const_index(11), 1);
        let c32 = MoeConfig::preset("sm-32e"); // 32 FFN + 1 + 1 + 6
        assert_eq!(c32.const_index(34), 0);
        assert_eq!(c32.const_index(39), 5);
    }

    #[test]
    fn capacities_match_eq8() {
        let c = MoeConfig::preset("sm-8e");
        let t = 1000;
        let (fc, zc) = c.capacities(t);
        let denom = c.tau * 8.0 + 4.0;
        assert_eq!(fc, (1.1 * 2.0 * c.tau * 1000.0 / denom) as usize + 1);
        assert_eq!(zc, (1.1 * 2.0 * 1000.0 / denom) as usize + 1);
        // smaller tau shifts capacity towards ZC experts
        let mut c2 = c.clone();
        c2.tau = 0.1;
        let (fc2, zc2) = c2.capacities(t);
        assert!((zc2 as f64 / fc2 as f64) > (zc as f64 / fc as f64));
    }

    #[test]
    fn ffn_fraction_matches_table1() {
        let c = MoeConfig::preset("sm-8e"); // tau=0.75, 8 FFN, 4 ZC
        let want = 0.75 * 8.0 / (0.75 * 8.0 + 4.0);
        assert!((c.ffn_token_fraction() - want).abs() < 1e-12);
        assert_eq!(MoeConfig::preset("sm-8e:vanilla").ffn_token_fraction(),
                   1.0);
    }

    #[test]
    fn ffn_expert_bytes_counts_three_projections() {
        let c = MoeConfig::preset("test"); // d_model 32, d_ff 64
        assert_eq!(c.ffn_expert_bytes(), (3 * 32 * 64 * 4) as u64);
    }

    #[test]
    fn int8_expert_bytes_are_codes_plus_scales() {
        let c = MoeConfig::preset("test"); // d_model 32, d_ff 64
        assert_eq!(
            c.ffn_expert_bytes_at(Precision::Int8),
            (3 * 32 * 64 + (2 * 64 + 32) * 4) as u64
        );
        assert_eq!(c.ffn_expert_bytes_at(Precision::F32),
                   c.ffn_expert_bytes());
        // int8 is strictly cheaper — the whole point of compression.
        assert!(c.ffn_expert_bytes_at(Precision::Int8)
                < c.ffn_expert_bytes());
    }

    #[test]
    fn precision_parse_roundtrip() {
        for p in [Precision::F32, Precision::Int8] {
            assert_eq!(Precision::parse(p.label()), Some(p));
        }
        assert_eq!(Precision::parse("fp16"), None);
        assert_eq!(Precision::default(), Precision::F32);
    }

    #[test]
    fn eq10_adaptive_const() {
        assert_eq!(adaptive_n_const(8, 1, 1), 1); // not 0: max(..., 1)
        assert_eq!(adaptive_n_const(16, 1, 1), 2);
        assert_eq!(adaptive_n_const(32, 1, 1), 6);
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"name":"x","vocab_size":64,"n_layers":2,"d_model":32,
                "d_ff":64,"n_heads":2,"seq_len":16,"n_ffn_experts":4,
                "n_zero":1,"n_copy":1,"n_const":2,"top_k":2,"tau":0.75,
                "capacity_factor":1.1,"balance_coef":0.01,
                "gating_residual":true,"variant":"moepp"}"#,
        )
        .unwrap();
        let c = MoeConfig::from_json(&j).unwrap();
        assert_eq!(c.n_experts(), 8);
        assert!(!c.vanilla);
    }
}

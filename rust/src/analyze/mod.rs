//! Self-hosted static analysis (DESIGN.md §14) — `moepp analyze`.
//!
//! A dependency-free lint pass that machine-checks the invariants this
//! codebase argues for in prose: unsafety confined and justified,
//! steady-state paths allocation-free, thread creation centralised,
//! relaxed atomics justified, and hash-order iteration kept out of the
//! determinism-critical modules. The analyzer runs over its own crate
//! in CI (`./ci.sh` invokes `moepp analyze` against `rust/src/`), so
//! every invariant holds for the analyzer itself too.
//!
//! Structure:
//!
//! * [`lexer::SourceModel`] — a hand-rolled lexical projection of each
//!   file into per-line code / comment channels plus a `#[cfg(test)]`
//!   mask, so lints never fire inside literals, comments or test
//!   fixtures;
//! * [`lints`] — the five lints and their annotation grammar
//!   (`SAFETY:`, `alloc-ok:`, `ordering:`, `det-ok:`, and the
//!   `lint: no-alloc` / `lint: end` region markers);
//! * [`analyze_dir`] — the recursive `.rs` walker, deterministic
//!   (paths sorted) so finding order is stable run to run.
//!
//! Exit contract: `moepp analyze` prints one diagnostic per finding
//! (`file:line: [lint] message` plus the offending source line) and
//! exits nonzero iff any finding exists; `--json` emits the findings
//! as a machine-readable array instead.

pub mod lexer;
pub mod lints;

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

pub use lints::{SPAWN_ALLOWLIST, UNSAFE_ALLOWLIST};

/// One diagnostic: where, which lint, why, and the offending line.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the analyzed root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub lint: &'static str,
    pub message: String,
    /// The original source line, trimmed.
    pub snippet: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!(
            "{}:{}: [{}] {}\n    {}",
            self.file, self.line, self.lint, self.message, self.snippet
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("file", Json::str(self.file.as_str())),
            ("line", Json::num(self.line as f64)),
            ("lint", Json::str(self.lint)),
            ("message", Json::str(self.message.as_str())),
            ("snippet", Json::str(self.snippet.as_str())),
        ])
    }
}

/// Render a finding list as a JSON array (the `--json` output).
pub fn findings_json(findings: &[Finding]) -> Json {
    Json::Arr(findings.iter().map(Finding::to_json).collect())
}

/// Lint one file's text. `rel_path` should be repo-relative with `/`
/// separators — the allowlists match on its suffix.
pub fn analyze_source(rel_path: &str, text: &str) -> Vec<Finding> {
    let model = lexer::SourceModel::parse(text);
    lints::lint_file(rel_path, &model)
}

/// Recursively lint every `.rs` file under `root`. Files are visited
/// in sorted path order so output is deterministic.
pub fn analyze_dir(root: &Path) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        out.extend(analyze_source(&rel, &text));
    }
    Ok(out)
}

fn collect_rs_files(
    dir: &Path,
    out: &mut Vec<std::path::PathBuf>,
) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_source_has_no_findings() {
        let src = "fn main() {\n    let v = vec![1, 2];\n    println!(\"{v:?}\");\n}\n";
        assert!(analyze_source("src/bin/x.rs", src).is_empty());
    }

    #[test]
    fn findings_render_and_serialize() {
        let src = "let p = unsafe { *q };\n";
        let f = analyze_source("src/moe/exec.rs", src);
        assert_eq!(f.len(), 2, "allowlist + missing SAFETY");
        let human = f[0].render();
        assert!(human.contains("src/moe/exec.rs:1:"));
        assert!(human.contains("[unsafe-audit]"));
        assert!(human.contains("unsafe { *q }"));
        let js = findings_json(&f).to_string();
        let parsed = Json::parse(&js).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(
            arr[0].get("file").unwrap().as_str(),
            Some("src/moe/exec.rs")
        );
        assert_eq!(arr[0].get("line").unwrap().as_usize(), Some(1));
        assert_eq!(
            arr[0].get("lint").unwrap().as_str(),
            Some("unsafe-audit")
        );
    }

    #[test]
    fn analyze_dir_walks_and_relativizes() {
        let dir = std::env::temp_dir().join("moepp_analyze_walk_test");
        let sub = dir.join("moe");
        std::fs::create_dir_all(&sub).unwrap();
        std::fs::write(
            sub.join("exec.rs"),
            "std::thread::spawn(|| {});\n",
        )
        .unwrap();
        std::fs::write(dir.join("clean.rs"), "fn ok() {}\n").unwrap();
        std::fs::write(dir.join("notes.txt"), "unsafe\n").unwrap();
        let findings = analyze_dir(&dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].file, "moe/exec.rs");
        assert_eq!(findings[0].lint, "spawn-sites");
    }
}

//! The five project-invariant lints (DESIGN.md §14), run over the
//! lexical [`SourceModel`] so string literals, comments and
//! `#[cfg(test)]` fixtures can never trip them.
//!
//! Every lint suppresses through an *annotation*: a justification
//! comment on the offending line or in the contiguous comment block
//! directly above it. Annotations are the static twin of the runtime
//! counters (`ExecArena::growths`, `ExecPool::spawns`): the reviewer
//! reads the justification, CI only checks it exists where required.

use super::lexer::SourceModel;
use super::Finding;

/// Files (path suffixes) allowed to contain `unsafe`. The crate's only
/// unsafety is the disjoint-&mut dispatch in `Executor::for_each_mut`
/// and the pool's lifetime-erased job handoff — both in `util/pool.rs`.
/// A second entry here should be a load-bearing design decision.
pub const UNSAFE_ALLOWLIST: &[&str] = &["util/pool.rs"];

/// Files (path suffixes) allowed to spawn OS threads. Everything else
/// must run on the persistent [`crate::util::pool::ExecPool`] or the
/// scoped helpers — steady-state serving spawns nothing.
pub const SPAWN_ALLOWLIST: &[&str] = &[
    "util/pool.rs",
    "util/threadpool.rs",
    "cluster/worker.rs",
    "serve/service.rs",
];

/// Allocating calls forbidden inside `no-alloc` regions unless
/// annotated. Lexical patterns, matched with identifier boundaries.
const ALLOC_PATTERNS: &[&str] = &[
    "Vec::new",
    "vec!",
    "to_vec",
    "Box::new",
    "String::from",
    ".clone()",
];

const SPAWN_PATTERNS: &[&str] =
    &["thread::spawn", "thread::Builder", "thread::scope"];

/// Run every lint over one file. `path` is the repo-relative path with
/// `/` separators — allowlists and scopes match on its suffix.
pub fn lint_file(path: &str, model: &SourceModel) -> Vec<Finding> {
    let mut out = Vec::new();
    unsafe_audit(path, model, &mut out);
    no_alloc_regions(path, model, &mut out);
    spawn_sites(path, model, &mut out);
    atomics_ordering(path, model, &mut out);
    determinism(path, model, &mut out);
    out
}

fn push(
    out: &mut Vec<Finding>,
    path: &str,
    model: &SourceModel,
    line_idx: usize,
    lint: &'static str,
    message: String,
) {
    out.push(Finding {
        file: path.to_string(),
        line: line_idx + 1,
        lint,
        message,
        snippet: model.snippet(line_idx + 1).to_string(),
    });
}

/// Is `marker` present in the comment on line `i`, or in the contiguous
/// run of comment-only lines directly above it? A blank or code line
/// ends the walk — annotations must be adjacent to what they justify.
fn annotated(model: &SourceModel, i: usize, marker: &str) -> bool {
    if model.lines[i].comment.contains(marker) {
        return true;
    }
    let mut k = i;
    while k > 0 {
        k -= 1;
        let l = &model.lines[k];
        if !l.code.trim().is_empty() {
            return false; // a code line breaks adjacency
        }
        if l.comment.contains(marker) {
            return true;
        }
        if l.comment.is_empty() {
            return false; // fully blank line breaks adjacency
        }
    }
    false
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Substring search with identifier boundaries on whichever ends of the
/// pattern are identifier characters (so `to_vec` does not match
/// `into_vec`, and `unsafe` does not match `unsafe_audit`).
fn find_token(code: &str, pat: &str) -> bool {
    let pat_head_ident = pat.chars().next().is_some_and(is_ident);
    let pat_tail_ident = pat.chars().last().is_some_and(is_ident);
    let mut from = 0;
    while let Some(off) = code[from..].find(pat) {
        let start = from + off;
        let end = start + pat.len();
        let head_ok = !pat_head_ident
            || !code[..start].chars().last().is_some_and(is_ident);
        let tail_ok = !pat_tail_ident
            || !code[end..].chars().next().is_some_and(is_ident);
        if head_ok && tail_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn path_in(path: &str, list: &[&str]) -> bool {
    list.iter().any(|suffix| path.ends_with(suffix))
}

// ------------------------------------------------------- 1 unsafe-audit

/// Every `unsafe` must (a) live in an allowlisted file and (b) carry a
/// `SAFETY:` comment on or directly above its line.
fn unsafe_audit(path: &str, model: &SourceModel, out: &mut Vec<Finding>) {
    for i in 0..model.n_lines() {
        if model.test_mask[i] || !find_token(&model.lines[i].code, "unsafe")
        {
            continue;
        }
        if !path_in(path, UNSAFE_ALLOWLIST) {
            push(
                out,
                path,
                model,
                i,
                "unsafe-audit",
                format!(
                    "unsafe outside the allowlist ({}); all unsafety \
                     belongs in util/pool.rs",
                    UNSAFE_ALLOWLIST.join(", ")
                ),
            );
        }
        if !annotated(model, i, "SAFETY:") {
            push(
                out,
                path,
                model,
                i,
                "unsafe-audit",
                "unsafe without a `SAFETY:` comment on or above the line"
                    .to_string(),
            );
        }
    }
}

// ------------------------------------------------------ 2 no-alloc regions

/// Inside a region bracketed by a comment line starting `lint: no-alloc`
/// and one starting `lint: end`, allocating calls are forbidden unless
/// the line (or the comment block above it) carries `alloc-ok: <reason>`.
/// Unbalanced markers are findings too — a region that silently never
/// closes would swallow the rest of the file.
fn no_alloc_regions(
    path: &str,
    model: &SourceModel,
    out: &mut Vec<Finding>,
) {
    let mut open_at: Option<usize> = None;
    for i in 0..model.n_lines() {
        let comment = model.lines[i].comment.trim();
        if comment.starts_with("lint: no-alloc") {
            if open_at.is_some() {
                push(
                    out,
                    path,
                    model,
                    i,
                    "no-alloc",
                    "nested `lint: no-alloc` region".to_string(),
                );
            }
            open_at = Some(i);
            continue;
        }
        if comment.starts_with("lint: end") {
            if open_at.is_none() {
                push(
                    out,
                    path,
                    model,
                    i,
                    "no-alloc",
                    "`lint: end` without an open region".to_string(),
                );
            }
            open_at = None;
            continue;
        }
        if open_at.is_none() || model.test_mask[i] {
            continue;
        }
        for pat in ALLOC_PATTERNS {
            if find_token(&model.lines[i].code, pat)
                && !annotated(model, i, "alloc-ok:")
            {
                push(
                    out,
                    path,
                    model,
                    i,
                    "no-alloc",
                    format!(
                        "allocating call `{pat}` in a no-alloc region \
                         (annotate `alloc-ok: <reason>` if intended)"
                    ),
                );
            }
        }
    }
    if let Some(i) = open_at {
        push(
            out,
            path,
            model,
            i,
            "no-alloc",
            "`lint: no-alloc` region never closed".to_string(),
        );
    }
}

// -------------------------------------------------------- 3 spawn-sites

/// OS-thread creation is confined to the spawn allowlist; every other
/// module must borrow the persistent pool.
fn spawn_sites(path: &str, model: &SourceModel, out: &mut Vec<Finding>) {
    if path_in(path, SPAWN_ALLOWLIST) {
        return;
    }
    for i in 0..model.n_lines() {
        if model.test_mask[i] {
            continue;
        }
        for pat in SPAWN_PATTERNS {
            if find_token(&model.lines[i].code, pat) {
                push(
                    out,
                    path,
                    model,
                    i,
                    "spawn-sites",
                    format!(
                        "`{pat}` outside the spawn allowlist ({})",
                        SPAWN_ALLOWLIST.join(", ")
                    ),
                );
            }
        }
    }
}

// --------------------------------------------------- 4 atomics-ordering

/// Every `Ordering::Relaxed` needs an `ordering: <why relaxed is sound>`
/// comment — the PR 5 memory-ordering argument, machine-checked.
fn atomics_ordering(
    path: &str,
    model: &SourceModel,
    out: &mut Vec<Finding>,
) {
    for i in 0..model.n_lines() {
        if model.test_mask[i] {
            continue;
        }
        if find_token(&model.lines[i].code, "Ordering::Relaxed")
            && !annotated(model, i, "ordering:")
        {
            push(
                out,
                path,
                model,
                i,
                "atomics-ordering",
                "Ordering::Relaxed without an `ordering:` justification \
                 comment"
                    .to_string(),
            );
        }
    }
}

// ---------------------------------------------------- 5 determinism

/// Hash-order iteration is the classic way bitwise determinism dies:
/// in `placement/`, `cluster/` and `moe/exec.rs`, iterating a
/// `HashMap`/`HashSet` binding is flagged unless annotated
/// `det-ok: <reason>`. Keyed lookups are fine — only iteration order is
/// nondeterministic.
fn determinism(path: &str, model: &SourceModel, out: &mut Vec<Finding>) {
    let in_scope = path.contains("placement/")
        || path.contains("cluster/")
        || path.ends_with("moe/exec.rs");
    if !in_scope {
        return;
    }
    // Pass 1: names bound to hash collections (lets and struct fields).
    let mut names: Vec<String> = Vec::new();
    for i in 0..model.n_lines() {
        let code = &model.lines[i].code;
        if !(code.contains("HashMap") || code.contains("HashSet")) {
            continue;
        }
        if let Some(name) = hash_binding_name(code) {
            if !names.contains(&name) {
                names.push(name);
            }
        }
    }
    // Pass 2: iteration over those names.
    const ITER_CALLS: &[&str] = &[
        ".iter()",
        ".iter_mut()",
        ".keys()",
        ".values()",
        ".values_mut()",
        ".into_iter()",
        ".drain(",
    ];
    for i in 0..model.n_lines() {
        if model.test_mask[i] {
            continue;
        }
        let code = &model.lines[i].code;
        for name in &names {
            let called = ITER_CALLS.iter().any(|call| {
                find_token(code, &format!("{name}{call}"))
            });
            let for_loop = code.contains("for ")
                && code.contains(" in ")
                && code
                    .split(" in ")
                    .nth(1)
                    .is_some_and(|rhs| find_token(rhs, name));
            if (called || for_loop) && !annotated(model, i, "det-ok:") {
                push(
                    out,
                    path,
                    model,
                    i,
                    "determinism",
                    format!(
                        "iteration over hash collection `{name}` in a \
                         determinism-critical module (annotate \
                         `det-ok: <reason>` if order cannot leak into \
                         outputs)"
                    ),
                );
                break;
            }
        }
    }
}

/// The identifier a `HashMap`/`HashSet` is bound to on this line, if the
/// line declares one: `let [mut] NAME: HashMap…`, `let [mut] NAME =
/// HashMap::new…`, or a struct field `NAME: HashMap…`.
fn hash_binding_name(code: &str) -> Option<String> {
    let trimmed = code.trim();
    if let Some(rest) = trimmed
        .strip_prefix("let ")
        .map(|r| r.strip_prefix("mut ").unwrap_or(r))
    {
        let name: String =
            rest.chars().take_while(|&c| is_ident(c)).collect();
        if !name.is_empty() {
            return Some(name);
        }
    }
    // Struct field / typed binding: the identifier directly before the
    // `:` that precedes the hash type.
    let hash_pos = code.find("HashMap").or_else(|| code.find("HashSet"))?;
    let before = &code[..hash_pos];
    let colon = before.rfind(':')?;
    // `::` (e.g. `std::collections::HashMap`) is a path, not a binding.
    if before[..colon].ends_with(':') || before[colon + 1..].contains(':')
    {
        return None;
    }
    let name: String = before[..colon]
        .trim_end()
        .chars()
        .rev()
        .take_while(|&c| is_ident(c))
        .collect::<String>()
        .chars()
        .rev()
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> Vec<Finding> {
        lint_file(path, &SourceModel::parse(src))
    }

    fn lints(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.lint).collect()
    }

    // -- unsafe-audit ---------------------------------------------------

    #[test]
    fn unsafe_outside_allowlist_is_flagged() {
        let f = run(
            "src/moe/exec.rs",
            "// SAFETY: justified but misplaced\nlet p = unsafe { *q };\n",
        );
        assert_eq!(lints(&f), vec!["unsafe-audit"]);
        assert!(f[0].message.contains("allowlist"));
        assert_eq!(f[0].line, 2);
        assert!(f[0].snippet.contains("unsafe"));
    }

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let f = run(
            "src/util/pool.rs",
            "fn f() {\n    let p = unsafe { *q };\n}\n",
        );
        assert_eq!(lints(&f), vec!["unsafe-audit"]);
        assert!(f[0].message.contains("SAFETY"));
    }

    #[test]
    fn safety_comment_above_or_inline_passes() {
        assert!(run(
            "src/util/pool.rs",
            "// SAFETY: disjoint indices, fenced.\n// Second line of argument.\nlet p = unsafe { *q };\n",
        )
        .is_empty());
        assert!(run(
            "src/util/pool.rs",
            "let p = unsafe { *q }; // SAFETY: disjoint\n",
        )
        .is_empty());
        // A blank line between comment and site breaks adjacency.
        assert_eq!(
            run(
                "src/util/pool.rs",
                "// SAFETY: stale\n\nlet p = unsafe { *q };\n",
            )
            .len(),
            1
        );
    }

    #[test]
    fn unsafe_in_strings_comments_and_tests_is_ignored() {
        assert!(run(
            "src/moe/exec.rs",
            "let s = \"unsafe\"; // unsafe is discussed here only\n/* unsafe */\n#[cfg(test)]\nmod tests {\n    fn t() { let p = unsafe { *q }; }\n}\n",
        )
        .is_empty());
    }

    // -- no-alloc -------------------------------------------------------

    #[test]
    fn alloc_in_region_is_flagged_each_pattern() {
        for line in [
            "let v = Vec::new();",
            "let v = vec![0; n];",
            "let v = xs.to_vec();",
            "let b = Box::new(f);",
            "let s = String::from(x);",
            "let c = arc.clone();",
        ] {
            let src = format!(
                "// lint: no-alloc\n{line}\n// lint: end\n"
            );
            let f = run("src/moe/arena.rs", &src);
            assert_eq!(lints(&f), vec!["no-alloc"], "missed: {line}");
        }
    }

    #[test]
    fn alloc_ok_annotation_suppresses() {
        let src = "// lint: no-alloc\n// alloc-ok: growth path, counted by the arena\nlet v = Vec::new();\nlet w = xs.to_vec(); // alloc-ok: cold init\n// lint: end\n";
        assert!(run("src/moe/arena.rs", src).is_empty());
    }

    #[test]
    fn alloc_outside_region_is_fine() {
        assert!(run("src/moe/arena.rs", "let v = Vec::new();\n").is_empty());
    }

    #[test]
    fn alloc_in_region_string_or_test_is_ignored() {
        let src = "// lint: no-alloc\nlet s = \"Vec::new() vec![]\";\n#[cfg(test)]\nfn t() { let v = Vec::new(); }\n// lint: end\n";
        assert!(run("src/moe/arena.rs", src).is_empty());
    }

    #[test]
    fn unbalanced_region_markers_are_findings() {
        let f = run("src/moe/arena.rs", "// lint: no-alloc\nlet x = 1;\n");
        assert_eq!(lints(&f), vec!["no-alloc"]);
        assert!(f[0].message.contains("never closed"));
        let f = run("src/moe/arena.rs", "let x = 1;\n// lint: end\n");
        assert!(f[0].message.contains("without an open region"));
    }

    #[test]
    fn into_vec_is_not_to_vec() {
        let src = "// lint: no-alloc\nlet v = xs.into_vec();\n// lint: end\n";
        assert!(run("src/moe/arena.rs", src).is_empty());
    }

    // -- spawn-sites ----------------------------------------------------

    #[test]
    fn spawns_outside_allowlist_are_flagged() {
        for pat in [
            "std::thread::spawn(|| {});",
            "let b = std::thread::Builder::new();",
            "std::thread::scope(|s| {});",
        ] {
            let f = run("src/moe/exec.rs", &format!("{pat}\n"));
            assert_eq!(lints(&f), vec!["spawn-sites"], "missed: {pat}");
        }
    }

    #[test]
    fn spawns_in_allowlisted_files_pass() {
        for path in [
            "src/util/pool.rs",
            "src/util/threadpool.rs",
            "src/cluster/worker.rs",
            "src/serve/service.rs",
        ] {
            assert!(run(path, "std::thread::spawn(|| {});\n").is_empty());
        }
    }

    #[test]
    fn spawn_in_test_code_is_ignored() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { std::thread::spawn(|| {}); }\n}\n";
        assert!(run("src/serve/handle.rs", src).is_empty());
    }

    // -- atomics-ordering -----------------------------------------------

    #[test]
    fn relaxed_without_justification_is_flagged() {
        let f = run(
            "src/util/logging.rs",
            "LEVEL.store(1, Ordering::Relaxed);\n",
        );
        assert_eq!(lints(&f), vec!["atomics-ordering"]);
    }

    #[test]
    fn relaxed_with_ordering_comment_passes() {
        assert!(run(
            "src/util/logging.rs",
            "// ordering: monotone counter, no dependent reads.\nLEVEL.store(1, Ordering::Relaxed);\nX.load(Ordering::Relaxed); // ordering: hint only\n",
        )
        .is_empty());
        // Stronger orderings need no annotation.
        assert!(run(
            "src/serve/handle.rs",
            "X.load(Ordering::Acquire);\nY.store(1, Ordering::Release);\n",
        )
        .is_empty());
    }

    // -- determinism ----------------------------------------------------

    #[test]
    fn hash_iteration_in_scope_is_flagged() {
        for iter in [
            "for (k, v) in &index {",
            "for k in index.keys() {",
            "index.iter().for_each(|_| {});",
            "let v: Vec<_> = index.values().collect();",
            "index.drain();",
        ] {
            let src = format!(
                "let index: std::collections::HashMap<usize, usize> = make();\n{iter}\n"
            );
            let f = run("src/cluster/worker.rs", &src);
            assert_eq!(lints(&f), vec!["determinism"], "missed: {iter}");
        }
    }

    #[test]
    fn hash_lookup_is_not_iteration() {
        let src = "let index: std::collections::HashMap<usize, usize> = make();\nlet i = index[&expert];\nlet j = index.get(&expert);\n";
        assert!(run("src/cluster/worker.rs", src).is_empty());
    }

    #[test]
    fn det_ok_annotation_suppresses() {
        let src = "let seen: HashSet<usize> = HashSet::new();\n// det-ok: result is re-sorted before use\nfor s in seen.iter() {\n}\n";
        assert!(run("src/placement/planner.rs", src).is_empty());
    }

    #[test]
    fn hash_iteration_out_of_scope_is_ignored() {
        let src = "let m: HashMap<u32, u32> = HashMap::new();\nfor (k, v) in &m {\n}\n";
        assert!(run("src/training/data.rs", src).is_empty());
        assert!(run("src/serve/service.rs", src).is_empty());
    }

    #[test]
    fn struct_field_hash_bindings_are_tracked() {
        let src = "struct S {\n    cache: HashMap<u32, u32>,\n}\nfn f(s: &S) {\n    for k in s.cache.keys() {\n    }\n}\n";
        let f = run("src/placement/profile.rs", src);
        assert_eq!(lints(&f), vec!["determinism"]);
    }

    #[test]
    fn btreemap_iteration_is_fine() {
        let src = "let m: BTreeMap<u32, u32> = BTreeMap::new();\nfor (k, v) in &m {\n}\n";
        assert!(run("src/placement/planner.rs", src).is_empty());
    }
}

//! A hand-rolled, dependency-free Rust *lexical* model — just enough
//! tokenization to tell code from comments from literals, so lint
//! patterns never fire inside a string, a doc comment or a `#[cfg(test)]`
//! fixture.
//!
//! Per source line the model exposes:
//!
//! * `code` — the line with comments removed and the *contents* of
//!   string/char literals blanked (a string literal collapses to `""`),
//!   so pattern searches see real code only;
//! * `comment` — the concatenated text of every comment overlapping the
//!   line (`//`, `///`, `//!` and `/* .. */`, nested), which is where
//!   the annotation grammar (`SAFETY:`, `ordering:`, `alloc-ok:`,
//!   `det-ok:` and region markers) lives;
//! * `test_mask` — whether the line sits inside a `#[cfg(test)]`-gated
//!   item (attribute through matching close brace), which lints skip:
//!   test fixtures may intentionally contain seeded violations.
//!
//! Handled literal forms: `"…"` with escapes, raw strings `r"…"` /
//! `r#"…"#` with any hash count, byte strings `b"…"` / `br#"…"#`, char
//! and byte-char literals (`'x'`, `'\n'`, `b'x'`), and the char-vs-
//! lifetime ambiguity (`'a` in `&'a str` stays code). Block comments
//! nest, as in Rust proper.

/// One source line, split into its code and comment projections.
#[derive(Clone, Debug, Default)]
pub struct Line {
    /// Code with comments stripped and literal contents blanked.
    pub code: String,
    /// Text of all comments on the line.
    pub comment: String,
}

/// The lexical projection of one file.
#[derive(Clone, Debug, Default)]
pub struct SourceModel {
    /// Original source lines (for diagnostics snippets).
    pub raw: Vec<String>,
    pub lines: Vec<Line>,
    /// `true` for lines inside a `#[cfg(test)]`-gated item.
    pub test_mask: Vec<bool>,
}

impl SourceModel {
    pub fn parse(text: &str) -> SourceModel {
        let mut model = SourceModel {
            raw: text.split('\n').map(str::to_string).collect(),
            ..SourceModel::default()
        };
        lex(text, &mut model);
        model.test_mask = test_mask(&model.lines);
        model
    }

    pub fn n_lines(&self) -> usize {
        self.lines.len()
    }

    /// Original text of 1-based line `n`, trimmed, for diagnostics.
    pub fn snippet(&self, line_no: usize) -> &str {
        self.raw
            .get(line_no - 1)
            .map(|s| s.trim())
            .unwrap_or("")
    }
}

enum State {
    Code,
    LineComment,
    /// Nesting depth — Rust block comments nest.
    BlockComment(u32),
    /// `None` = escaped string; `Some(n)` = raw string closed by `"` +
    /// `n` hashes.
    Str(Option<u32>),
    CharLit,
}

fn lex(text: &str, model: &mut SourceModel) {
    let chars: Vec<char> = text.chars().collect();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    // Last code char emitted on this line, to keep `r`/`b` that are the
    // tail of an identifier (e.g. `for`) from opening a raw string.
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            model.lines.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    code.push_str("\"\"");
                    state = State::Str(None);
                    i += 1;
                } else if c == '\'' {
                    // Char literal iff `'\…` or `'x'`; otherwise a
                    // lifetime (or loop label), which stays code.
                    if next == Some('\\')
                        || (next.is_some()
                            && chars.get(i + 2) == Some(&'\''))
                    {
                        code.push_str("' '");
                        state = State::CharLit;
                        i += 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if (c == 'r' || c == 'b')
                    && !prev_is_ident(&code)
                {
                    // Possible raw/byte string: [b] r? #* " — scan the
                    // prefix without consuming unless it really opens
                    // one.
                    if let Some((skip, hashes)) = raw_string_open(
                        &chars[i..],
                    ) {
                        code.push_str("\"\"");
                        state = State::Str(Some(hashes));
                        i += skip;
                    } else if c == 'b'
                        && next == Some('\'')
                    {
                        // Byte-char literal `b'x'`.
                        code.push_str("' '");
                        state = State::CharLit;
                        i += 2;
                    } else if c == 'b' && next == Some('"') {
                        code.push_str("\"\"");
                        state = State::Str(None);
                        i += 2;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str(None) => {
                if c == '\\' {
                    // Escaped char (incl. \" and \\) — but leave a
                    // line-continuation's newline to the top-level
                    // handler so line indices stay aligned.
                    i += if chars.get(i + 1) == Some(&'\n') {
                        1
                    } else {
                        2
                    };
                } else if c == '"' {
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::Str(Some(hashes)) => {
                if c == '"'
                    && chars[i + 1..]
                        .iter()
                        .take(hashes as usize)
                        .filter(|&&h| h == '#')
                        .count()
                        == hashes as usize
                {
                    state = State::Code;
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    // Final line when the file does not end in a newline.
    if !code.is_empty() || !comment.is_empty() {
        model.lines.push(Line { code, comment });
    }
    // `split('\n')` on trailing-newline input yields one extra empty
    // raw line; mirror it so raw and lines stay index-aligned.
    while model.lines.len() < model.raw.len() {
        model.lines.push(Line::default());
    }
    while model.raw.len() < model.lines.len() {
        model.raw.push(String::new());
    }
}

fn prev_is_ident(code: &str) -> bool {
    code.chars()
        .last()
        .is_some_and(|p| p.is_alphanumeric() || p == '_')
}

/// Does `chars` open a raw/byte-raw string (`r"`, `r#"`, `br##"`, …)?
/// Returns (chars to skip through the opening quote, hash count).
fn raw_string_open(chars: &[char]) -> Option<(usize, u32)> {
    let mut j = 0;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((j + 1, hashes))
    } else {
        None
    }
}

/// Mark every line belonging to a `#[cfg(test)]`-gated item: from the
/// attribute line through the matching close brace of the item it gates
/// (or through the terminating `;` of a braceless item).
fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0i64;
        let mut opened = false;
        let mut k = i;
        while k < lines.len() {
            mask[k] = true;
            for c in lines[k].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    ';' if !opened && depth == 0 => {
                        depth = i64::MIN; // braceless item: done
                    }
                    _ => {}
                }
                if (opened && depth == 0) || depth == i64::MIN {
                    break;
                }
            }
            if (opened && depth == 0) || depth < 0 {
                break;
            }
            k += 1;
        }
        i = k + 1;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_split_from_code() {
        let m = SourceModel::parse(
            "let x = 1; // trailing note\n/* block */ let y = 2;\n",
        );
        assert_eq!(m.lines[0].code.trim(), "let x = 1;");
        assert!(m.lines[0].comment.contains("trailing note"));
        assert_eq!(m.lines[1].code.trim(), "let y = 2;");
        assert!(m.lines[1].comment.contains("block"));
    }

    #[test]
    fn nested_block_comments_and_multiline() {
        let m = SourceModel::parse(
            "a(); /* outer /* inner */ still comment */ b();\n/*\nx()\n*/ c();\n",
        );
        assert_eq!(m.lines[0].code.replace(' ', ""), "a();b();");
        assert_eq!(m.lines[1].code, "");
        assert_eq!(m.lines[2].code, "");
        assert!(m.lines[2].comment.contains("x()"));
        assert_eq!(m.lines[3].code.trim(), "c();");
    }

    #[test]
    fn string_contents_are_blanked() {
        let m = SourceModel::parse(
            r#"println!("vec![no // comment] unsafe"); call();"#,
        );
        assert!(!m.lines[0].code.contains("vec!["));
        assert!(!m.lines[0].code.contains("unsafe"));
        assert!(m.lines[0].comment.is_empty());
        assert!(m.lines[0].code.contains("call();"));
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let m = SourceModel::parse(
            r#"let s = "a\"b // not a comment"; t();"#,
        );
        assert!(m.lines[0].comment.is_empty());
        assert!(m.lines[0].code.contains("t();"));
        assert!(!m.lines[0].code.contains("not a comment"));
    }

    #[test]
    fn raw_strings_any_hash_count() {
        let src = "let a = r\"x // y\"; let b = r##\"unsafe \"# inner\"##; u();\n";
        let m = SourceModel::parse(src);
        assert!(m.lines[0].comment.is_empty());
        assert!(!m.lines[0].code.contains("unsafe"));
        assert!(m.lines[0].code.contains("u();"));
    }

    #[test]
    fn multiline_strings_stay_strings() {
        let m = SourceModel::parse(
            "let s = \"line one\nvec![] // two\";\nafter();\n",
        );
        assert!(m.lines[1].comment.is_empty());
        assert!(!m.lines[1].code.contains("vec!["));
        assert_eq!(m.lines[2].code.trim(), "after();");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let m = SourceModel::parse(
            "fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'y'; let n = '\\n'; g();\n",
        );
        assert!(m.lines[0].code.contains("&'a str"));
        assert!(!m.lines[1].code.contains('y'), "char contents blanked");
        assert!(m.lines[1].code.contains("g();"));
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let m = SourceModel::parse(
            "let a = b\"unsafe\"; let c = b'x'; let r = br#\"vec![\"#; h();\n",
        );
        assert!(!m.lines[0].code.contains("unsafe"));
        assert!(!m.lines[0].code.contains("vec!["));
        assert!(m.lines[0].code.contains("h();"));
    }

    #[test]
    fn identifier_tails_do_not_open_raw_strings() {
        // `for`/`br` as identifier tails must not eat the rest of the
        // file as a raw string.
        let m = SourceModel::parse("for x in abr { y(\"s\"); }\nz();\n");
        assert!(m.lines[0].code.contains("for x in abr"));
        assert_eq!(m.lines[1].code.trim(), "z();");
    }

    #[test]
    fn cfg_test_items_are_masked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { unsafe {} }\n}\nfn live2() {}\n";
        let m = SourceModel::parse(src);
        assert_eq!(
            m.test_mask,
            vec![false, true, true, true, true, false, false]
        );
    }

    #[test]
    fn cfg_test_on_braceless_item() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() {}\n";
        let m = SourceModel::parse(src);
        assert_eq!(m.test_mask, vec![true, true, false, false]);
        // Trailing empty raw line stays aligned.
        assert_eq!(m.raw.len(), m.lines.len());
    }
}

//! Cluster topology: device count, expert placement, link model.

use crate::config::{ExpertKind, MoeConfig, Precision};
use crate::placement::PlacementPlan;

/// α–β communication model: transferring `b` bytes costs α + β·b seconds.
/// Defaults approximate NVLink-class interconnect scaled to the simulated
/// device speed (what matters for the paper's claims is the *ratio* of
/// comm to compute, not absolute values).
#[derive(Clone, Debug)]
pub struct LinkModel {
    pub alpha_s: f64,
    pub beta_s_per_byte: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // 10 µs latency, 50 GB/s effective per-link bandwidth.
        LinkModel { alpha_s: 10e-6, beta_s_per_byte: 1.0 / 50e9 }
    }
}

#[derive(Clone, Debug)]
pub struct Topology {
    pub n_devices: usize,
    pub link: LinkModel,
    /// Relative FFN throughput per device (1.0 = the nominal
    /// [`DEVICE_FLOPS`] device). A heterogeneous fleet sets these from
    /// `--flops-per-s`; compute *time* on device `d` divides by
    /// `device_speed[d]`, and speed never changes routing or outputs —
    /// only modeled/measured time.
    ///
    /// [`DEVICE_FLOPS`]: crate::placement::DEVICE_FLOPS
    pub device_speed: Vec<f64>,
    /// FFN expert placement. `None` is the historical round-robin modulo
    /// (valid for any expert count and bitwise-identical to an explicit
    /// round-robin plan); an installed plan fixes the expert count.
    placement: Option<PlacementPlan>,
}

impl Topology {
    pub fn new(n_devices: usize) -> Topology {
        assert!(n_devices > 0);
        Topology {
            n_devices,
            link: LinkModel::default(),
            device_speed: vec![1.0; n_devices],
            placement: None,
        }
    }

    /// Set per-device relative speeds (builder form).
    pub fn with_device_speeds(mut self, speeds: Vec<f64>) -> Topology {
        assert_eq!(
            speeds.len(),
            self.n_devices,
            "device speed count does not match topology"
        );
        assert!(
            speeds.iter().all(|&s| s > 0.0),
            "device speeds must be positive"
        );
        self.device_speed = speeds;
        self
    }

    /// Relative speed of device `d`.
    pub fn speed(&self, device: usize) -> f64 {
        self.device_speed[device]
    }

    /// Install an FFN placement plan (builder form).
    pub fn with_placement(mut self, plan: PlacementPlan) -> Topology {
        self.set_placement(plan);
        self
    }

    /// Install an FFN placement plan.
    pub fn set_placement(&mut self, plan: PlacementPlan) {
        assert_eq!(
            plan.n_devices(),
            self.n_devices,
            "placement plan device count does not match topology"
        );
        self.placement = Some(plan);
    }

    /// The installed plan, if any (`None` = round-robin default).
    pub fn placement(&self) -> Option<&PlacementPlan> {
        self.placement.as_ref()
    }

    /// The effective plan for `n_ffn_experts` FFN experts (materialises
    /// the round-robin default when no plan is installed).
    pub fn effective_placement(&self, n_ffn_experts: usize)
        -> PlacementPlan {
        match &self.placement {
            Some(p) => p.clone(),
            None => {
                PlacementPlan::round_robin(n_ffn_experts, self.n_devices)
            }
        }
    }

    /// Owner (primary-replica) device of FFN expert `e`. Without an
    /// installed plan this is round-robin sharding (Megatron-style expert
    /// parallelism); with a plan, whatever the planner decided.
    pub fn ffn_owner(&self, expert: usize) -> usize {
        match &self.placement {
            Some(p) => p.owner(expert),
            None => expert % self.n_devices,
        }
    }

    /// Number of replicas FFN expert `e` has (1 without a plan).
    pub fn ffn_replica_count(&self, expert: usize) -> usize {
        match &self.placement {
            Some(p) => p.replica_count(expert),
            None => 1,
        }
    }

    /// Device of replica `j` of FFN expert `e` in the canonical (sorted)
    /// replica enumeration. Allocation-free; used per micro-batch slice
    /// on the dispatch path.
    pub fn ffn_replica(&self, expert: usize, j: usize) -> usize {
        match &self.placement {
            Some(p) => p.replicas(expert)[j],
            None => {
                debug_assert_eq!(j, 0);
                expert % self.n_devices
            }
        }
    }

    /// Stack-wide serving precision of FFN expert `e` (DESIGN.md §17):
    /// the installed plan's per-expert map, or `F32` under the
    /// round-robin default. Uniform across every replica of the expert,
    /// so dispatch may slice a replicated expert's micro-batch freely
    /// without outputs depending on which replica ran which slice.
    pub fn ffn_precision(&self, expert: usize) -> Precision {
        match &self.placement {
            Some(p) => p.precision(expert),
            None => Precision::F32,
        }
    }

    /// Device of origin for token `t` when a batch of `n_tokens` is sharded
    /// evenly (data parallel within the MoE layer).
    pub fn token_home(&self, token: usize, n_tokens: usize) -> usize {
        let per = n_tokens.div_ceil(self.n_devices);
        (token / per).min(self.n_devices - 1)
    }

    /// Does serving assignment (token, expert) require an all-to-all hop?
    /// ZC experts never do — they are replicated on every device,
    /// whatever the FFN placement says. A multi-replica FFN expert is
    /// local iff *some* replica sits on the token's home device (the
    /// load-split dispatch below then sends the home-local slice there,
    /// see `ClusterSim::forward`).
    pub fn needs_transfer(
        &self,
        cfg: &MoeConfig,
        token: usize,
        n_tokens: usize,
        expert: usize,
    ) -> bool {
        match cfg.kind(expert) {
            ExpertKind::Ffn => {
                let home = self.token_home(token, n_tokens);
                (0..self.ffn_replica_count(expert))
                    .all(|j| self.ffn_replica(expert, j) != home)
            }
            _ => false, // replicated: always local
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_placement() {
        let t = Topology::new(4);
        assert_eq!(t.ffn_owner(0), 0);
        assert_eq!(t.ffn_owner(5), 1);
        assert_eq!(t.ffn_owner(7), 3);
    }

    #[test]
    fn explicit_round_robin_plan_matches_default() {
        let base = Topology::new(4);
        let planned = Topology::new(4)
            .with_placement(PlacementPlan::round_robin(8, 4));
        for e in 0..8 {
            assert_eq!(base.ffn_owner(e), planned.ffn_owner(e));
        }
        assert!(base.placement().is_none());
        assert!(planned.placement().unwrap().is_round_robin());
        assert_eq!(base.effective_placement(8), planned.effective_placement(8));
    }

    #[test]
    fn installed_plan_overrides_modulo() {
        let plan =
            PlacementPlan::from_owner(vec![3, 3, 0, 1], 4).unwrap();
        let t = Topology::new(4).with_placement(plan);
        assert_eq!(t.ffn_owner(0), 3);
        assert_eq!(t.ffn_owner(2), 0);
        assert_eq!(t.ffn_owner(3), 1);
    }

    #[test]
    #[should_panic]
    fn plan_device_mismatch_panics() {
        let plan = PlacementPlan::round_robin(4, 2);
        let _ = Topology::new(4).with_placement(plan);
    }

    #[test]
    fn token_homes_cover_devices() {
        let t = Topology::new(4);
        let homes: Vec<usize> =
            (0..16).map(|tok| t.token_home(tok, 16)).collect();
        assert_eq!(homes[0], 0);
        assert_eq!(homes[15], 3);
        for d in 0..4 {
            assert_eq!(homes.iter().filter(|&&h| h == d).count(), 4);
        }
    }

    #[test]
    fn token_home_handles_ragged_batches() {
        // n_tokens not divisible by n_devices: ceil sharding, the last
        // device absorbs the short tail and every home stays in range.
        let t = Topology::new(4);
        let homes: Vec<usize> =
            (0..10).map(|tok| t.token_home(tok, 10)).collect();
        assert_eq!(homes, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 3]);
        // Fewer tokens than devices: one token per device, trailing
        // devices idle, no out-of-range home.
        let t8 = Topology::new(8);
        for tok in 0..3 {
            assert_eq!(t8.token_home(tok, 3), tok);
        }
        // A single token parks on device 0.
        assert_eq!(t8.token_home(0, 1), 0);
    }

    #[test]
    fn single_device_owns_everything_and_never_transfers() {
        let cfg = MoeConfig::preset("sm-8e");
        let t = Topology::new(1);
        for e in 0..cfg.n_ffn_experts {
            assert_eq!(t.ffn_owner(e), 0);
        }
        for tok in 0..32 {
            assert_eq!(t.token_home(tok, 32), 0);
            for e in 0..cfg.n_experts() {
                assert!(!t.needs_transfer(&cfg, tok, 32, e));
            }
        }
    }

    #[test]
    fn zc_experts_never_transfer() {
        let cfg = MoeConfig::preset("sm-8e");
        let t = Topology::new(4);
        for tok in 0..32 {
            for e in cfg.n_ffn_experts..cfg.n_experts() {
                assert!(!t.needs_transfer(&cfg, tok, 32, e));
            }
        }
        // FFN experts on other devices do transfer.
        assert!(t.needs_transfer(&cfg, 0, 32, 1)); // token home 0, owner 1
        assert!(!t.needs_transfer(&cfg, 0, 32, 0));
    }

    #[test]
    fn zc_experts_never_transfer_under_any_plan() {
        // The replication invariant is structural: no placement plan can
        // make a zero-computation expert pay an all-to-all hop.
        let cfg = MoeConfig::preset("sm-8e");
        let plans = [
            PlacementPlan::round_robin(cfg.n_ffn_experts, 4),
            PlacementPlan::from_owner(vec![0; cfg.n_ffn_experts], 4)
                .unwrap(),
            PlacementPlan::from_owner(
                (0..cfg.n_ffn_experts).rev().map(|e| e % 4).collect(),
                4,
            )
            .unwrap(),
        ];
        for plan in plans {
            let t = Topology::new(4).with_placement(plan);
            for tok in 0..16 {
                for e in cfg.n_ffn_experts..cfg.n_experts() {
                    assert!(!t.needs_transfer(&cfg, tok, 16, e));
                }
            }
        }
    }

    #[test]
    fn device_speeds_default_uniform_and_validate() {
        let t = Topology::new(3);
        assert_eq!(t.device_speed, vec![1.0; 3]);
        let t = Topology::new(3).with_device_speeds(vec![2.0, 1.0, 0.5]);
        assert_eq!(t.speed(0), 2.0);
        assert_eq!(t.speed(2), 0.5);
    }

    #[test]
    #[should_panic]
    fn wrong_speed_count_panics() {
        let _ = Topology::new(2).with_device_speeds(vec![1.0]);
    }

    #[test]
    #[should_panic]
    fn non_positive_speed_panics() {
        let _ = Topology::new(2).with_device_speeds(vec![1.0, 0.0]);
    }

    #[test]
    fn precision_accessor_follows_plan_or_defaults_f32() {
        let base = Topology::new(4);
        assert_eq!(base.ffn_precision(2), Precision::F32);
        let mut plan = PlacementPlan::round_robin(8, 4);
        plan.set_precision(5, Precision::Int8);
        let t = Topology::new(4).with_placement(plan);
        assert_eq!(t.ffn_precision(5), Precision::Int8);
        assert_eq!(t.ffn_precision(4), Precision::F32);
    }

    #[test]
    fn replica_accessors_follow_plan_or_modulo() {
        let base = Topology::new(4);
        for e in 0..8 {
            assert_eq!(base.ffn_replica_count(e), 1);
            assert_eq!(base.ffn_replica(e, 0), e % 4);
        }
        let mut plan = PlacementPlan::round_robin(8, 4);
        plan.add_replica(5, 3);
        plan.add_replica(5, 0);
        let t = Topology::new(4).with_placement(plan);
        assert_eq!(t.ffn_replica_count(5), 3);
        assert_eq!(t.ffn_replica(5, 0), 0);
        assert_eq!(t.ffn_replica(5, 1), 1);
        assert_eq!(t.ffn_replica(5, 2), 3);
        assert_eq!(t.ffn_owner(5), 0, "primary is the smallest replica");
        assert_eq!(t.ffn_replica_count(0), 1);
    }

    #[test]
    fn replicated_expert_is_local_where_any_replica_lives() {
        let cfg = MoeConfig::preset("sm-8e");
        // Expert 1 on devices {1, 3}: tokens homed on 1 or 3 are local,
        // tokens homed on 0 or 2 still pay the hop.
        let mut plan = PlacementPlan::round_robin(cfg.n_ffn_experts, 4);
        plan.add_replica(1, 3);
        let t = Topology::new(4).with_placement(plan);
        assert!(t.needs_transfer(&cfg, 0, 16, 1)); // home 0
        assert!(!t.needs_transfer(&cfg, 4, 16, 1)); // home 1
        assert!(t.needs_transfer(&cfg, 8, 16, 1)); // home 2
        assert!(!t.needs_transfer(&cfg, 15, 16, 1)); // home 3
    }

    #[test]
    fn needs_transfer_follows_installed_plan() {
        let cfg = MoeConfig::preset("sm-8e");
        // Every FFN expert on device 3: only tokens homed on 3 are local.
        let plan =
            PlacementPlan::from_owner(vec![3; cfg.n_ffn_experts], 4)
                .unwrap();
        let t = Topology::new(4).with_placement(plan);
        for e in 0..cfg.n_ffn_experts {
            assert!(t.needs_transfer(&cfg, 0, 16, e)); // home 0
            assert!(!t.needs_transfer(&cfg, 15, 16, e)); // home 3
        }
    }
}

//! Cluster topology: device count, expert placement, link model.

use crate::config::{ExpertKind, MoeConfig};

/// α–β communication model: transferring `b` bytes costs α + β·b seconds.
/// Defaults approximate NVLink-class interconnect scaled to the simulated
/// device speed (what matters for the paper's claims is the *ratio* of
/// comm to compute, not absolute values).
#[derive(Clone, Debug)]
pub struct LinkModel {
    pub alpha_s: f64,
    pub beta_s_per_byte: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // 10 µs latency, 50 GB/s effective per-link bandwidth.
        LinkModel { alpha_s: 10e-6, beta_s_per_byte: 1.0 / 50e9 }
    }
}

#[derive(Clone, Debug)]
pub struct Topology {
    pub n_devices: usize,
    pub link: LinkModel,
}

impl Topology {
    pub fn new(n_devices: usize) -> Topology {
        assert!(n_devices > 0);
        Topology { n_devices, link: LinkModel::default() }
    }

    /// Owner device of FFN expert `e` (round-robin sharding, Megatron-style
    /// expert parallelism).
    pub fn ffn_owner(&self, expert: usize) -> usize {
        expert % self.n_devices
    }

    /// Device of origin for token `t` when a batch of `n_tokens` is sharded
    /// evenly (data parallel within the MoE layer).
    pub fn token_home(&self, token: usize, n_tokens: usize) -> usize {
        let per = n_tokens.div_ceil(self.n_devices);
        (token / per).min(self.n_devices - 1)
    }

    /// Does serving assignment (token, expert) require an all-to-all hop?
    /// ZC experts never do — they are replicated on every device.
    pub fn needs_transfer(
        &self,
        cfg: &MoeConfig,
        token: usize,
        n_tokens: usize,
        expert: usize,
    ) -> bool {
        match cfg.kind(expert) {
            ExpertKind::Ffn => {
                self.ffn_owner(expert) != self.token_home(token, n_tokens)
            }
            _ => false, // replicated: always local
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_placement() {
        let t = Topology::new(4);
        assert_eq!(t.ffn_owner(0), 0);
        assert_eq!(t.ffn_owner(5), 1);
        assert_eq!(t.ffn_owner(7), 3);
    }

    #[test]
    fn token_homes_cover_devices() {
        let t = Topology::new(4);
        let homes: Vec<usize> =
            (0..16).map(|tok| t.token_home(tok, 16)).collect();
        assert_eq!(homes[0], 0);
        assert_eq!(homes[15], 3);
        for d in 0..4 {
            assert_eq!(homes.iter().filter(|&&h| h == d).count(), 4);
        }
    }

    #[test]
    fn zc_experts_never_transfer() {
        let cfg = MoeConfig::preset("sm-8e");
        let t = Topology::new(4);
        for tok in 0..32 {
            for e in cfg.n_ffn_experts..cfg.n_experts() {
                assert!(!t.needs_transfer(&cfg, tok, 32, e));
            }
        }
        // FFN experts on other devices do transfer.
        assert!(t.needs_transfer(&cfg, 0, 32, 1)); // token home 0, owner 1
        assert!(!t.needs_transfer(&cfg, 0, 32, 0));
    }
}

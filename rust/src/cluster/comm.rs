//! All-to-all communication accounting + analytic α–β cost.

use super::topology::Topology;

/// Per-(src, dst) byte counts for one all-to-all phase.
#[derive(Clone, Debug)]
pub struct TrafficMatrix {
    pub n: usize,
    pub bytes: Vec<u64>, // row-major [src][dst], diagonal = local (free)
}

impl TrafficMatrix {
    pub fn new(n: usize) -> TrafficMatrix {
        TrafficMatrix { n, bytes: vec![0; n * n] }
    }

    pub fn add(&mut self, src: usize, dst: usize, bytes: u64) {
        self.bytes[src * self.n + dst] += bytes;
    }

    /// Zero every entry, keeping the allocation (scratch reuse).
    pub fn clear(&mut self) {
        self.bytes.fill(0);
    }

    pub fn total_offdiag(&self) -> u64 {
        let mut t = 0;
        for s in 0..self.n {
            for d in 0..self.n {
                if s != d {
                    t += self.bytes[s * self.n + d];
                }
            }
        }
        t
    }

    pub fn sent_by(&self, src: usize) -> u64 {
        (0..self.n)
            .filter(|&d| d != src)
            .map(|d| self.bytes[src * self.n + d])
            .sum()
    }

    pub fn received_by(&self, dst: usize) -> u64 {
        (0..self.n)
            .filter(|&s| s != dst)
            .map(|s| self.bytes[s * self.n + dst])
            .sum()
    }

    /// α–β all-to-all time: latency once (messages overlap) plus the
    /// bandwidth term of the most loaded device port (max of send/recv).
    pub fn alltoall_time(&self, topo: &Topology) -> f64 {
        if self.total_offdiag() == 0 {
            return 0.0;
        }
        let worst = (0..self.n)
            .map(|d| self.sent_by(d).max(self.received_by(d)))
            .max()
            .unwrap_or(0);
        topo.link.alpha_s + topo.link.beta_s_per_byte * worst as f64
    }
}

/// Traffic of one MoE layer step: dispatch (tokens to expert owners) and
/// combine (outputs back home). Symmetric in bytes.
#[derive(Clone, Debug)]
pub struct LayerTraffic {
    pub dispatch: TrafficMatrix,
    pub combine: TrafficMatrix,
}

impl LayerTraffic {
    pub fn new(n: usize) -> LayerTraffic {
        LayerTraffic {
            dispatch: TrafficMatrix::new(n),
            combine: TrafficMatrix::new(n),
        }
    }

    /// Record one (token, expert) assignment's traffic; `token_bytes` is
    /// d_model * 4.
    pub fn record_assignment(
        &mut self,
        home: usize,
        owner: usize,
        token_bytes: u64,
    ) {
        self.dispatch.add(home, owner, token_bytes);
        self.combine.add(owner, home, token_bytes);
    }

    pub fn total_time(&self, topo: &Topology) -> f64 {
        self.dispatch.alltoall_time(topo) + self.combine.alltoall_time(topo)
    }

    /// Zero both phases, keeping the allocations (scratch reuse).
    pub fn clear(&mut self) {
        self.dispatch.clear();
        self.combine.clear();
    }

    pub fn total_bytes(&self) -> u64 {
        self.dispatch.total_offdiag() + self.combine.total_offdiag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_conservation() {
        let mut m = TrafficMatrix::new(3);
        m.add(0, 1, 100);
        m.add(0, 2, 50);
        m.add(1, 0, 25);
        m.add(2, 2, 999); // diagonal: local, excluded
        assert_eq!(m.total_offdiag(), 175);
        assert_eq!(m.sent_by(0), 150);
        assert_eq!(m.received_by(0), 25);
        assert_eq!(m.received_by(2), 50);
    }

    #[test]
    fn empty_traffic_is_free() {
        let m = TrafficMatrix::new(4);
        assert_eq!(m.alltoall_time(&Topology::new(4)), 0.0);
    }

    #[test]
    fn alltoall_time_scales_with_worst_port() {
        let topo = Topology::new(2);
        let mut a = TrafficMatrix::new(2);
        a.add(0, 1, 1_000_000);
        let mut b = TrafficMatrix::new(2);
        b.add(0, 1, 2_000_000);
        assert!(b.alltoall_time(&topo) > a.alltoall_time(&topo));
        // Bandwidth term dominates latency at MB scale.
        let want = topo.link.alpha_s
            + topo.link.beta_s_per_byte * 2_000_000.0;
        assert!((b.alltoall_time(&topo) - want).abs() < 1e-12);
    }

    #[test]
    fn sent_received_offdiag_reconcile() {
        // Conservation: every off-diagonal byte is sent by exactly one
        // device and received by exactly one, so the three accountings
        // agree — and the diagonal never leaks into any of them.
        let mut m = TrafficMatrix::new(4);
        let mut rng = crate::util::rng::Rng::new(9);
        for s in 0..4 {
            for d in 0..4 {
                m.add(s, d, rng.below(1000) as u64); // diagonal included
            }
        }
        let sent: u64 = (0..4).map(|d| m.sent_by(d)).sum();
        let recv: u64 = (0..4).map(|d| m.received_by(d)).sum();
        assert_eq!(sent, m.total_offdiag());
        assert_eq!(recv, m.total_offdiag());
        // Diagonal excluded from per-device ports.
        let mut only_diag = TrafficMatrix::new(3);
        for d in 0..3 {
            only_diag.add(d, d, 777);
        }
        assert_eq!(only_diag.total_offdiag(), 0);
        for d in 0..3 {
            assert_eq!(only_diag.sent_by(d), 0);
            assert_eq!(only_diag.received_by(d), 0);
        }
        assert_eq!(only_diag.alltoall_time(&Topology::new(3)), 0.0);
    }

    #[test]
    fn alltoall_time_is_bottleneck_port_max_of_send_and_recv() {
        // Device 0 receives from everyone: its receive port is the
        // bottleneck even though every sender is lightly loaded.
        let topo = Topology::new(4);
        let mut m = TrafficMatrix::new(4);
        for s in 1..4 {
            m.add(s, 0, 1000);
        }
        let want = topo.link.alpha_s + topo.link.beta_s_per_byte * 3000.0;
        assert!((m.alltoall_time(&topo) - want).abs() < 1e-15);
        // A fan-out sender is bottlenecked on its send port the same way.
        let mut f = TrafficMatrix::new(4);
        for d in 1..4 {
            f.add(0, d, 1000);
        }
        assert!((f.alltoall_time(&topo) - want).abs() < 1e-15);
        // Per device the port cost is max(send, recv), not the sum:
        // 2000 sent + 1500 received on device 0 costs max = 2000.
        let mut b = TrafficMatrix::new(2);
        b.add(0, 1, 2000);
        b.add(1, 0, 1500);
        let want_b =
            topo.link.alpha_s + topo.link.beta_s_per_byte * 2000.0;
        assert!((b.alltoall_time(&Topology::new(2)) - want_b).abs()
            < 1e-15);
    }

    #[test]
    fn layer_traffic_total_time_sums_both_phases() {
        let topo = Topology::new(2);
        let mut lt = LayerTraffic::new(2);
        lt.record_assignment(0, 1, 4096);
        let want = 2.0
            * (topo.link.alpha_s + topo.link.beta_s_per_byte * 4096.0);
        assert!((lt.total_time(&topo) - want).abs() < 1e-15);
        // All-local traffic is free in both phases.
        let mut local = LayerTraffic::new(2);
        local.record_assignment(1, 1, 4096);
        assert_eq!(local.total_time(&topo), 0.0);
        assert_eq!(local.total_bytes(), 0);
    }

    #[test]
    fn dispatch_and_combine_are_symmetric() {
        let mut lt = LayerTraffic::new(4);
        lt.record_assignment(0, 3, 512);
        lt.record_assignment(1, 1, 512); // local: on diagonal
        assert_eq!(lt.dispatch.total_offdiag(), 512);
        assert_eq!(lt.combine.total_offdiag(), 512);
        assert_eq!(lt.total_bytes(), 1024);
    }
}

//! All-to-all communication accounting + analytic α–β cost.

use super::topology::Topology;

/// Per-(src, dst) byte counts for one all-to-all phase.
#[derive(Clone, Debug)]
pub struct TrafficMatrix {
    pub n: usize,
    pub bytes: Vec<u64>, // row-major [src][dst], diagonal = local (free)
}

impl TrafficMatrix {
    pub fn new(n: usize) -> TrafficMatrix {
        TrafficMatrix { n, bytes: vec![0; n * n] }
    }

    pub fn add(&mut self, src: usize, dst: usize, bytes: u64) {
        self.bytes[src * self.n + dst] += bytes;
    }

    pub fn total_offdiag(&self) -> u64 {
        let mut t = 0;
        for s in 0..self.n {
            for d in 0..self.n {
                if s != d {
                    t += self.bytes[s * self.n + d];
                }
            }
        }
        t
    }

    pub fn sent_by(&self, src: usize) -> u64 {
        (0..self.n)
            .filter(|&d| d != src)
            .map(|d| self.bytes[src * self.n + d])
            .sum()
    }

    pub fn received_by(&self, dst: usize) -> u64 {
        (0..self.n)
            .filter(|&s| s != dst)
            .map(|s| self.bytes[s * self.n + dst])
            .sum()
    }

    /// α–β all-to-all time: latency once (messages overlap) plus the
    /// bandwidth term of the most loaded device port (max of send/recv).
    pub fn alltoall_time(&self, topo: &Topology) -> f64 {
        if self.total_offdiag() == 0 {
            return 0.0;
        }
        let worst = (0..self.n)
            .map(|d| self.sent_by(d).max(self.received_by(d)))
            .max()
            .unwrap_or(0);
        topo.link.alpha_s + topo.link.beta_s_per_byte * worst as f64
    }
}

/// Traffic of one MoE layer step: dispatch (tokens to expert owners) and
/// combine (outputs back home). Symmetric in bytes.
#[derive(Clone, Debug)]
pub struct LayerTraffic {
    pub dispatch: TrafficMatrix,
    pub combine: TrafficMatrix,
}

impl LayerTraffic {
    pub fn new(n: usize) -> LayerTraffic {
        LayerTraffic {
            dispatch: TrafficMatrix::new(n),
            combine: TrafficMatrix::new(n),
        }
    }

    /// Record one (token, expert) assignment's traffic; `token_bytes` is
    /// d_model * 4.
    pub fn record_assignment(
        &mut self,
        home: usize,
        owner: usize,
        token_bytes: u64,
    ) {
        self.dispatch.add(home, owner, token_bytes);
        self.combine.add(owner, home, token_bytes);
    }

    pub fn total_time(&self, topo: &Topology) -> f64 {
        self.dispatch.alltoall_time(topo) + self.combine.alltoall_time(topo)
    }

    pub fn total_bytes(&self) -> u64 {
        self.dispatch.total_offdiag() + self.combine.total_offdiag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_conservation() {
        let mut m = TrafficMatrix::new(3);
        m.add(0, 1, 100);
        m.add(0, 2, 50);
        m.add(1, 0, 25);
        m.add(2, 2, 999); // diagonal: local, excluded
        assert_eq!(m.total_offdiag(), 175);
        assert_eq!(m.sent_by(0), 150);
        assert_eq!(m.received_by(0), 25);
        assert_eq!(m.received_by(2), 50);
    }

    #[test]
    fn empty_traffic_is_free() {
        let m = TrafficMatrix::new(4);
        assert_eq!(m.alltoall_time(&Topology::new(4)), 0.0);
    }

    #[test]
    fn alltoall_time_scales_with_worst_port() {
        let topo = Topology::new(2);
        let mut a = TrafficMatrix::new(2);
        a.add(0, 1, 1_000_000);
        let mut b = TrafficMatrix::new(2);
        b.add(0, 1, 2_000_000);
        assert!(b.alltoall_time(&topo) > a.alltoall_time(&topo));
        // Bandwidth term dominates latency at MB scale.
        let want = topo.link.alpha_s
            + topo.link.beta_s_per_byte * 2_000_000.0;
        assert!((b.alltoall_time(&topo) - want).abs() < 1e-12);
    }

    #[test]
    fn dispatch_and_combine_are_symmetric() {
        let mut lt = LayerTraffic::new(4);
        lt.record_assignment(0, 3, 512);
        lt.record_assignment(1, 1, 512); // local: on diagonal
        assert_eq!(lt.dispatch.total_offdiag(), 512);
        assert_eq!(lt.combine.total_offdiag(), 512);
        assert_eq!(lt.total_bytes(), 1024);
    }
}

//! Persistent worker threads: each owns the FFN experts placed on one
//! simulated device (plus a replica of all ZC experts) and executes its
//! micro-batches with measured wall-clock compute time, scaled by the
//! device's relative speed so heterogeneous fleets report heterogeneous
//! compute seconds.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::MoeConfig;
use crate::moe::experts::{FfnExpert, FfnScratch};
use crate::tensor::Tensor;

/// One FFN micro-batch for a worker: (layer-local) expert id placed on
/// this worker, which replica slice of that expert's token batch this is,
/// gathered input rows, gates, original token ids, and the caller-owned
/// output buffer. `x` and `y` come from the cluster arena's wire pool and
/// are echoed back on the [`WorkResult`] so the caller can return them.
pub struct WorkUnit {
    pub expert: usize,
    /// Replica-slice index within the expert's canonical token order
    /// (0 for single-replica experts). The combiner merges parts in
    /// ascending `part` order, which — with contiguous slices — restores
    /// the exact single-owner token order.
    pub part: usize,
    pub x: Tensor, // [n, D] gathered rows
    pub gates: Vec<f32>,
    pub tokens: Vec<usize>,
    /// Output buffer, `[n, D]`, pre-zeroed by the caller (the batched
    /// kernel accumulates into it).
    pub y: Tensor,
}

/// Result of a work unit: gated outputs to scatter-add at the token homes.
/// Echoes the unit's expert/part ids so callers attribute results without
/// relying on reply ordering, and echoes both tensors for buffer reuse.
pub struct WorkResult {
    pub expert: usize,
    pub part: usize,
    pub tokens: Vec<usize>,
    pub x: Tensor, // the unit's input buffer, returned for pooling
    pub y: Tensor, // [n, D], already gate-scaled
    pub compute_s: f64,
}

enum Msg {
    Work(Vec<WorkUnit>, Sender<Vec<WorkResult>>),
    Shutdown,
}

/// Handle to one device worker thread.
pub struct Worker {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
    pub device: usize,
    pub owned_experts: Vec<usize>,
}

impl Worker {
    /// Spawn a worker owning `experts` (global FFN ids -> weights).
    /// `speed` is the device's relative compute rate (1.0 = baseline);
    /// reported `compute_s` is wall-clock divided by it, so a 2x device
    /// finishes the same unit in half the modeled time.
    pub fn spawn(
        device: usize,
        owned_experts: Vec<usize>,
        weights: Vec<FfnExpert>,
        speed: f64,
        _cfg: &MoeConfig,
    ) -> Worker {
        assert_eq!(owned_experts.len(), weights.len());
        assert!(speed > 0.0, "device speed must be positive");
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let owned = owned_experts.clone();
        let handle = std::thread::Builder::new()
            .name(format!("moepp-worker-{device}"))
            .spawn(move || {
                let index: std::collections::HashMap<usize, usize> = owned
                    .iter()
                    .enumerate()
                    .map(|(i, &e)| (e, i))
                    .collect();
                // Persistent scratch: the batched kernel grows it on first
                // use and the hot loop stays allocation-free thereafter.
                let mut scratch = FfnScratch::new(0);
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Shutdown => break,
                        Msg::Work(units, reply) => {
                            let results = units
                                .into_iter()
                                .map(|mut u| {
                                    let t0 = Instant::now();
                                    let w = &weights[index[&u.expert]];
                                    // Gate-scaled batched forward into the
                                    // caller's pre-zeroed buffer: rows
                                    // arrive back already `g * FFN(x)`.
                                    w.forward_batch_into(
                                        &u.x,
                                        Some(u.gates.as_slice()),
                                        &mut scratch,
                                        &mut u.y.data,
                                        None,
                                    );
                                    WorkResult {
                                        expert: u.expert,
                                        part: u.part,
                                        tokens: u.tokens,
                                        x: u.x,
                                        y: u.y,
                                        compute_s: t0
                                            .elapsed()
                                            .as_secs_f64()
                                            / speed,
                                    }
                                })
                                .collect();
                            let _ = reply.send(results);
                        }
                    }
                }
            })
            .expect("spawn worker");
        Worker { tx, handle: Some(handle), device, owned_experts }
    }

    /// OS thread identity of this worker — stable for the worker's whole
    /// life, which is what lets tests prove a migration respawned only
    /// the affected devices (untouched workers keep their identity).
    pub fn thread_id(&self) -> std::thread::ThreadId {
        self.handle.as_ref().expect("worker running").thread().id()
    }

    /// Submit micro-batches; returns a receiver for the results.
    pub fn submit(&self, units: Vec<WorkUnit>)
        -> Receiver<Vec<WorkResult>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Msg::Work(units, reply_tx))
            .expect("worker alive");
        reply_rx
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn worker_computes_gated_ffn() {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(0);
        let e = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let want_raw =
            e.forward(&Tensor::full(&[2, cfg.d_model], 0.5));
        let w = Worker::spawn(0, vec![3], vec![e], 1.0, &cfg);
        let rx = w.submit(vec![WorkUnit {
            expert: 3,
            part: 0,
            x: Tensor::full(&[2, cfg.d_model], 0.5),
            gates: vec![1.0, 0.5],
            tokens: vec![10, 11],
            y: Tensor::zeros(&[2, cfg.d_model]),
        }]);
        let results = rx.recv().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.expert, 3);
        assert_eq!(r.part, 0);
        assert_eq!(r.tokens, vec![10, 11]);
        assert_eq!(r.x.dims2(), (2, cfg.d_model), "input echoed back");
        assert!(r.compute_s >= 0.0);
        let d = cfg.d_model;
        for j in 0..d {
            assert!((r.y.data[j] - want_raw.data[j]).abs() < 1e-5);
            assert!((r.y.data[d + j] - 0.5 * want_raw.data[d + j]).abs()
                < 1e-5);
        }
    }

    #[test]
    fn multiple_submissions_in_order() {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(1);
        let e = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let w = Worker::spawn(1, vec![0], vec![e], 2.0, &cfg);
        for _ in 0..5 {
            let rx = w.submit(vec![WorkUnit {
                expert: 0,
                part: 0,
                x: Tensor::zeros(&[1, cfg.d_model]),
                gates: vec![1.0],
                tokens: vec![0],
                y: Tensor::zeros(&[1, cfg.d_model]),
            }]);
            let r = rx.recv().unwrap();
            assert_eq!(r.len(), 1);
        }
    }
}

//! Persistent worker threads: each owns the FFN experts placed on one
//! simulated device (plus a replica of all ZC experts) and executes its
//! micro-batches with measured wall-clock compute time, scaled by the
//! device's relative speed so heterogeneous fleets report heterogeneous
//! compute seconds.
//!
//! Owned experts arrive as [`ExpertParams`] — f32 or pre-quantized int8
//! weights, per the placement plan's stack-wide precision map
//! (DESIGN.md §17) — and the worker keeps one scratch of each kind so
//! mixed-precision devices stay allocation-free in steady state.
//!
//! Workers are the only place injected faults *act* (DESIGN.md §16):
//! each work message carries its batch number, and a worker with an
//! installed [`FaultInjector`] checks the (batch, layer, device)
//! coordinate once per message — a single `Option` branch on the
//! no-fault fast path. Submission and spawning are fallible so the
//! driver recovers from a dead worker instead of panicking with it.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::MoeConfig;
use crate::fault::{ClusterError, FaultInjector, FaultKind};
use crate::moe::experts::{ExpertParams, FfnScratch, QuantScratch};
use crate::tensor::Tensor;

/// One FFN micro-batch for a worker: (layer-local) expert id placed on
/// this worker, which replica slice of that expert's token batch this is,
/// gathered input rows, gates, original token ids, and the caller-owned
/// output buffer. `x` and `y` come from the cluster arena's wire pool and
/// are echoed back on the [`WorkResult`] so the caller can return them.
pub struct WorkUnit {
    pub expert: usize,
    /// Replica-slice index within the expert's canonical token order
    /// (0 for single-replica experts). The combiner merges parts in
    /// ascending `part` order, which — with contiguous slices — restores
    /// the exact single-owner token order.
    pub part: usize,
    pub x: Tensor, // [n, D] gathered rows
    pub gates: Vec<f32>,
    pub tokens: Vec<usize>,
    /// Output buffer, `[n, D]`, pre-zeroed by the caller (the batched
    /// kernel accumulates into it).
    pub y: Tensor,
}

/// Result of a work unit: gated outputs to scatter-add at the token homes.
/// Echoes the unit's expert/part ids so callers attribute results without
/// relying on reply ordering, and echoes both tensors for buffer reuse.
pub struct WorkResult {
    pub expert: usize,
    pub part: usize,
    pub tokens: Vec<usize>,
    pub x: Tensor, // the unit's input buffer, returned for pooling
    pub y: Tensor, // [n, D], already gate-scaled
    pub compute_s: f64,
}

enum Msg {
    /// `batch` is the sim-local batch number — the fault coordinate the
    /// worker checks against its injector before touching the units.
    Work { batch: u64, units: Vec<WorkUnit>, reply: Sender<Vec<WorkResult>> },
    Shutdown,
}

/// A submit that found the worker already dead. Carries the (device,
/// layer) coordinate for diagnostics and hands the unsent units back
/// intact so the caller can return their buffers to the pool and
/// redispatch the work elsewhere.
pub struct SubmitError {
    pub device: usize,
    pub layer: usize,
    pub units: Vec<WorkUnit>,
}

impl SubmitError {
    pub fn to_cluster_error(&self) -> ClusterError {
        ClusterError::WorkerLost { device: self.device, layer: self.layer }
    }
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SubmitError")
            .field("device", &self.device)
            .field("layer", &self.layer)
            .field("units", &self.units.len())
            .finish()
    }
}

/// Handle to one device worker thread (one per (layer, device)).
pub struct Worker {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
    pub device: usize,
    pub layer: usize,
    pub owned_experts: Vec<usize>,
    injector: Option<Arc<FaultInjector>>,
}

impl Worker {
    /// Spawn a worker owning `experts` (global FFN ids -> weights).
    /// `speed` is the device's relative compute rate (1.0 = baseline);
    /// reported `compute_s` is wall-clock divided by it, so a 2x device
    /// finishes the same unit in half the modeled time.
    ///
    /// Infallible convenience for fault-free contexts (layer 0, no
    /// injector) — the cluster driver uses [`Worker::try_spawn`].
    pub fn spawn(
        device: usize,
        owned_experts: Vec<usize>,
        weights: Vec<ExpertParams>,
        speed: f64,
        cfg: &MoeConfig,
    ) -> Worker {
        Worker::try_spawn(0, device, owned_experts, weights, speed, cfg, None)
            .expect("spawn without an injector cannot be refused")
    }

    /// Fallible spawn: refuses to bring up a device the injector has
    /// marked permanently lost, so migration-apply and rejoin surface
    /// [`ClusterError::RespawnFailed`] instead of resurrecting dead
    /// hardware.
    pub fn try_spawn(
        layer: usize,
        device: usize,
        owned_experts: Vec<usize>,
        weights: Vec<ExpertParams>,
        speed: f64,
        _cfg: &MoeConfig,
        injector: Option<Arc<FaultInjector>>,
    ) -> Result<Worker, ClusterError> {
        assert_eq!(owned_experts.len(), weights.len());
        assert!(speed > 0.0, "device speed must be positive");
        if let Some(inj) = injector.as_deref() {
            if inj.is_lost(device) {
                return Err(ClusterError::RespawnFailed { device, layer });
            }
        }
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let owned = owned_experts.clone();
        let inj_thread = injector.clone();
        let handle = std::thread::Builder::new()
            .name(format!("moepp-worker-{device}"))
            .spawn(move || {
                let index: std::collections::HashMap<usize, usize> = owned
                    .iter()
                    .enumerate()
                    .map(|(i, &e)| (e, i))
                    .collect();
                // Persistent scratch, one per kernel precision: the
                // batched kernels grow them on first use and the hot
                // loop stays allocation-free thereafter.
                let mut scratch = FfnScratch::new(0);
                let mut qscratch = QuantScratch::new();
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Shutdown => break,
                        Msg::Work { batch, units, reply } => {
                            if let Some(inj) = inj_thread.as_deref() {
                                match inj.fault_at(batch, layer, device) {
                                    Some(FaultKind::Panic) => panic!(
                                        "injected fault: worker panic \
                                         (device {device}, layer {layer}, \
                                         batch {batch})"
                                    ),
                                    Some(FaultKind::Hang) => {
                                        // Blocks until teardown releases
                                        // the latch; the driver detects
                                        // the loss via its reply
                                        // deadline. The stranded units'
                                        // buffers are dropped, not
                                        // pooled — a counted fault-path
                                        // cost.
                                        drop(reply);
                                        drop(units);
                                        inj.hang_until_released();
                                        continue;
                                    }
                                    Some(FaultKind::DeviceLoss) => {
                                        // Permanent: refuse respawn too.
                                        inj.mark_lost(device);
                                        return;
                                    }
                                    None => {}
                                }
                            }
                            let results = units
                                .into_iter()
                                .map(|mut u| {
                                    let t0 = Instant::now();
                                    let w = &weights[index[&u.expert]];
                                    // Gate-scaled batched forward into the
                                    // caller's pre-zeroed buffer: rows
                                    // arrive back already `g * FFN(x)`,
                                    // through the f32 or int8 kernel per
                                    // this expert's serving precision.
                                    w.forward_batch_into(
                                        &u.x,
                                        Some(u.gates.as_slice()),
                                        &mut scratch,
                                        &mut qscratch,
                                        &mut u.y.data,
                                        None,
                                    );
                                    WorkResult {
                                        expert: u.expert,
                                        part: u.part,
                                        tokens: u.tokens,
                                        x: u.x,
                                        y: u.y,
                                        compute_s: t0
                                            .elapsed()
                                            .as_secs_f64()
                                            / speed,
                                    }
                                })
                                .collect();
                            let _ = reply.send(results);
                        }
                    }
                }
            })
            .expect("spawn worker");
        Ok(Worker {
            tx,
            handle: Some(handle),
            device,
            layer,
            owned_experts,
            injector,
        })
    }

    /// OS thread identity of this worker — stable for the worker's whole
    /// life, which is what lets tests prove a migration respawned only
    /// the affected devices (untouched workers keep their identity).
    pub fn thread_id(&self) -> std::thread::ThreadId {
        self.handle.as_ref().expect("worker running").thread().id()
    }

    /// Submit micro-batches for `batch`; returns a receiver for the
    /// results, or — if the worker is already dead — the units back,
    /// intact, with the loss coordinate.
    pub fn submit(
        &self,
        batch: u64,
        units: Vec<WorkUnit>,
    ) -> Result<Receiver<Vec<WorkResult>>, SubmitError> {
        let (reply_tx, reply_rx) = channel();
        match self.tx.send(Msg::Work { batch, units, reply: reply_tx }) {
            Ok(()) => Ok(reply_rx),
            Err(std::sync::mpsc::SendError(msg)) => {
                let units = match msg {
                    Msg::Work { units, .. } => units,
                    Msg::Shutdown => Vec::new(),
                };
                Err(SubmitError {
                    device: self.device,
                    layer: self.layer,
                    units,
                })
            }
        }
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        // Release any hung workers first: a hang fault parks the thread
        // on the injector latch, and joining it without the release
        // would deadlock teardown.
        if let Some(inj) = self.injector.as_deref() {
            inj.release_hangs();
        }
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            // A panicked (injected-fault) worker makes join return Err;
            // teardown tolerates it.
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{FaultPlan, FaultSpec};
    use crate::moe::experts::{FfnExpert, QuantFfnExpert};
    use crate::util::rng::Rng;

    #[test]
    fn worker_computes_gated_ffn() {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(0);
        let e = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let want_raw =
            e.forward(&Tensor::full(&[2, cfg.d_model], 0.5));
        let w = Worker::spawn(
            0,
            vec![3],
            vec![ExpertParams::F32(e)],
            1.0,
            &cfg,
        );
        let rx = w
            .submit(0, vec![WorkUnit {
                expert: 3,
                part: 0,
                x: Tensor::full(&[2, cfg.d_model], 0.5),
                gates: vec![1.0, 0.5],
                tokens: vec![10, 11],
                y: Tensor::zeros(&[2, cfg.d_model]),
            }])
            .unwrap();
        let results = rx.recv().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.expert, 3);
        assert_eq!(r.part, 0);
        assert_eq!(r.tokens, vec![10, 11]);
        assert_eq!(r.x.dims2(), (2, cfg.d_model), "input echoed back");
        assert!(r.compute_s >= 0.0);
        let d = cfg.d_model;
        for j in 0..d {
            assert!((r.y.data[j] - want_raw.data[j]).abs() < 1e-5);
            assert!((r.y.data[d + j] - 0.5 * want_raw.data[d + j]).abs()
                < 1e-5);
        }
    }

    #[test]
    fn int8_worker_tracks_f32_and_is_deterministic() {
        // A worker serving a pre-quantized expert stays close to its
        // f32 twin and returns bitwise-identical outputs on repeated
        // submissions of the same unit (the int8 kernel is per-token
        // pure — DESIGN.md §17).
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(9);
        let e = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let x = Tensor::randn(&mut rng, &[4, cfg.d_model], 1.0);
        let want = e.forward(&x);
        let q = QuantFfnExpert::from_f32(&e);
        let w = Worker::spawn(
            0,
            vec![1],
            vec![ExpertParams::Int8(q)],
            1.0,
            &cfg,
        );
        let run = || {
            let rx = w
                .submit(0, vec![WorkUnit {
                    expert: 1,
                    part: 0,
                    x: x.clone(),
                    gates: vec![1.0; 4],
                    tokens: vec![0, 1, 2, 3],
                    y: Tensor::zeros(&[4, cfg.d_model]),
                }])
                .unwrap();
            rx.recv().unwrap().remove(0).y
        };
        let y1 = run();
        let y2 = run();
        assert_eq!(y1.data, y2.data, "int8 worker must be deterministic");
        let num: f32 = y1
            .data
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let den: f32 =
            want.data.iter().map(|v| v * v).sum::<f32>().max(1e-12);
        assert!(
            (num / den).sqrt() < 0.1,
            "int8 worker drifted {} from f32",
            (num / den).sqrt()
        );
    }

    #[test]
    fn multiple_submissions_in_order() {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(1);
        let e = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let w = Worker::spawn(
            1,
            vec![0],
            vec![ExpertParams::F32(e)],
            2.0,
            &cfg,
        );
        for b in 0..5 {
            let rx = w
                .submit(b, vec![WorkUnit {
                    expert: 0,
                    part: 0,
                    x: Tensor::zeros(&[1, cfg.d_model]),
                    gates: vec![1.0],
                    tokens: vec![0],
                    y: Tensor::zeros(&[1, cfg.d_model]),
                }])
                .unwrap();
            let r = rx.recv().unwrap();
            assert_eq!(r.len(), 1);
        }
    }

    fn unit(cfg: &MoeConfig) -> WorkUnit {
        WorkUnit {
            expert: 0,
            part: 0,
            x: Tensor::zeros(&[1, cfg.d_model]),
            gates: vec![1.0],
            tokens: vec![0],
            y: Tensor::zeros(&[1, cfg.d_model]),
        }
    }

    #[test]
    fn injected_panic_disconnects_and_submit_returns_units() {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(2);
        let e = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let inj = Arc::new(FaultInjector::new(FaultPlan::new(vec![
            FaultSpec {
                batch: 1,
                layer: 0,
                device: 0,
                kind: FaultKind::Panic,
            },
        ])));
        let w = Worker::try_spawn(
            0,
            0,
            vec![0],
            vec![ExpertParams::F32(e)],
            1.0,
            &cfg,
            Some(inj),
        )
        .unwrap();
        // Batch 0 is clean.
        let rx = w.submit(0, vec![unit(&cfg)]).unwrap();
        assert_eq!(rx.recv().unwrap().len(), 1);
        // Batch 1 trips the fault: the reply channel disconnects.
        let rx = w.submit(1, vec![unit(&cfg)]).unwrap();
        assert!(rx.recv().is_err(), "panicked worker must disconnect");
        // The worker is gone: the next submit hands the units back with
        // the loss coordinate.
        let err = w.submit(2, vec![unit(&cfg)]).unwrap_err();
        assert_eq!((err.device, err.layer), (0, 0));
        assert_eq!(err.units.len(), 1, "unsent units come back intact");
        assert_eq!(
            err.to_cluster_error(),
            ClusterError::WorkerLost { device: 0, layer: 0 }
        );
    }

    #[test]
    fn device_loss_marks_injector_and_refuses_respawn() {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(3);
        let e = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let inj = Arc::new(FaultInjector::new(FaultPlan::new(vec![
            FaultSpec {
                batch: 0,
                layer: 2,
                device: 5,
                kind: FaultKind::DeviceLoss,
            },
        ])));
        let w = Worker::try_spawn(
            2,
            5,
            vec![0],
            vec![ExpertParams::F32(e)],
            1.0,
            &cfg,
            Some(inj.clone()),
        )
        .unwrap();
        let rx = w.submit(0, vec![unit(&cfg)]).unwrap();
        assert!(rx.recv().is_err());
        assert!(inj.is_lost(5), "device loss is recorded as permanent");
        let mut rng = Rng::new(4);
        let e2 = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let refused = Worker::try_spawn(
            2,
            5,
            vec![0],
            vec![ExpertParams::F32(e2)],
            1.0,
            &cfg,
            Some(inj.clone()),
        );
        assert_eq!(
            refused.err(),
            Some(ClusterError::RespawnFailed { device: 5, layer: 2 })
        );
        inj.revive(5);
        let mut rng = Rng::new(5);
        let e3 = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        assert!(Worker::try_spawn(
            2,
            5,
            vec![0],
            vec![ExpertParams::F32(e3)],
            1.0,
            &cfg,
            Some(inj),
        )
        .is_ok());
    }

    #[test]
    fn hung_worker_times_out_and_teardown_does_not_deadlock() {
        let cfg = MoeConfig::preset("test");
        let mut rng = Rng::new(6);
        let e = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let inj = Arc::new(FaultInjector::new(FaultPlan::new(vec![
            FaultSpec {
                batch: 0,
                layer: 0,
                device: 1,
                kind: FaultKind::Hang,
            },
        ])));
        let w = Worker::try_spawn(
            0,
            1,
            vec![0],
            vec![ExpertParams::F32(e)],
            1.0,
            &cfg,
            Some(inj),
        )
        .unwrap();
        let rx = w.submit(0, vec![unit(&cfg)]).unwrap();
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(40)).is_err(),
            "hung worker must miss the deadline"
        );
        // Dropping `w` releases the latch then joins — must not hang.
        drop(w);
    }

    #[test]
    fn refused_try_spawn_errs_on_lost_device() {
        let cfg = MoeConfig::preset("test");
        let inj = Arc::new(FaultInjector::new(FaultPlan::new(Vec::new())));
        inj.mark_lost(2);
        let mut rng = Rng::new(7);
        let e = FfnExpert::init(&mut rng, cfg.d_model, cfg.d_ff);
        let r = Worker::try_spawn(
            1,
            2,
            vec![0],
            vec![ExpertParams::F32(e)],
            1.0,
            &cfg,
            Some(inj),
        );
        assert_eq!(
            r.err(),
            Some(ClusterError::RespawnFailed { device: 2, layer: 1 })
        );
    }
}

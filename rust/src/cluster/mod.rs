//! Simulated multi-GPU expert-parallel cluster — the substrate behind the
//! paper's *deployment friendly* claim.
//!
//! The paper's argument (Sec. 1, 3.4): FFN experts are sharded across
//! devices, so top-K routing forces an all-to-all token exchange and is
//! exposed to expert load imbalance; zero-computation experts have ~no
//! parameters, so **every device holds a replica of all ZC experts** and a
//! ZC-routed token never leaves its device.
//!
//! We reproduce that mechanism with:
//!
//! * [`topology`] — device count, expert placement (a
//!   [`crate::placement::PlacementPlan`]; round-robin sharding of FFN
//!   experts by default, ZC experts always replicated), and an α–β link
//!   model;
//! * [`comm`]     — all-to-all traffic accounting + analytic cost;
//! * [`worker`]   — persistent worker threads that *actually execute* their
//!   FFN expert shards (native backend), so compute times are measured, not
//!   assumed;
//! * [`sim`]      — the per-layer expert-parallel step: dispatch → traffic
//!   matrix → worker execution → makespan = max_d(compute_d) + comm;
//!   applies placement migrations between batches (online replanning on
//!   the serving path — DESIGN.md §10).

pub mod comm;
pub mod sim;
pub mod topology;
pub mod worker;

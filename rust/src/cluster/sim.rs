//! The expert-parallel simulation: the MoE++ stack across simulated
//! devices, producing a makespan = max-device compute + all-to-all time,
//! plus the load-imbalance and traffic figures the paper argues about.
//!
//! Forward semantics (routing, dispatch, ZC-inline application, residual
//! threading) come from the shared executor ([`crate::moe::exec`],
//! DESIGN.md §7); this module contributes the [`ClusterBackend`]: FFN
//! micro-batches are shipped to the owning device's worker thread while
//! zero-computation experts run inline on the token's home device — so the
//! simulated output is numerically interchangeable with the single-process
//! engine, with per-device compute and all-to-all traffic measured on top.
//!
//! **Placement** (DESIGN.md §10, §13): which devices hold each FFN
//! expert comes from the topology's [`PlacementPlan`] — a replica *set*
//! per expert (round-robin single replicas when none is installed). A
//! replicated expert's token micro-batch is split across its replicas in
//! deterministic contiguous slices, sized by the replica devices' speed
//! weights (a 2× device takes ~2× the rows). Placement is pure layout —
//! the
//! combine stage scatter-adds expert outputs in a canonical order that
//! depends only on the device count, and within an expert every token is
//! a distinct output row — so *any* plan, replicated or not, produces
//! bitwise-identical model outputs, and the default reproduces the
//! historical device-major order exactly.
//! [`ClusterSim::apply_placement`] migrates experts between batches, and
//! an attached [`Replanner`] does so automatically on the serving path.
//!
//! **Fault tolerance** (DESIGN.md §16): `forward` is fallible and
//! recovers from lost workers. Loss is detected at the reply loop
//! (channel disconnect, or a reply deadline when a [`FaultInjector`] is
//! installed); the lost replica's (expert, row-range) units are rebuilt
//! from the dispatch plan and redispatched to surviving replicas — the
//! canonical combine order makes the recovered output **bitwise equal**
//! to the fault-free run — and only when no replica of an expert
//! survives do its tokens degrade to copy-expert semantics (counted as
//! `degraded_tokens`). ZC experts run inline on token homes and never
//! degrade. Dead devices are quarantined in a [`DeviceHealth`] table,
//! masked out of dispatch and planner candidates, and restored by
//! [`ClusterSim::rejoin`].

use std::sync::Arc;

use anyhow::Result;

use crate::config::MoeConfig;
use crate::coordinator::dispatch::DispatchPlan;
use crate::fault::{ClusterError, DeviceHealth, FaultInjector, FaultPlan};
use crate::moe::arena::{ExecArena, FfnArena};
use crate::moe::balance::load_cv;
use crate::moe::exec::{self, ExpertBackend, FfnLayerReport, ForwardStats};
use crate::moe::experts::{
    copy_expert_into, ExpertParams, QuantFfnExpert,
};
use crate::moe::weights::StackWeights;
use crate::obs::{EventKind, Obs};
use crate::placement::{
    speed_weight, weighted_share, MigrationPlan, PlacementPlan, Replanner,
};
use crate::tensor::ops::axpy;
use crate::tensor::Tensor;
use crate::util::pool::{ExecPool, Executor, TaskHandle};

use super::comm::LayerTraffic;
use super::topology::Topology;
use super::worker::{Worker, WorkResult, WorkUnit};

/// Per-layer simulation report.
#[derive(Clone, Debug, Default)]
pub struct LayerSimReport {
    /// Measured compute seconds per device (FFN shards).
    pub device_compute_s: Vec<f64>,
    /// Measured ZC compute on token-home devices (negligible by design).
    pub zc_compute_s: f64,
    /// Analytic all-to-all time (dispatch + combine).
    pub comm_s: f64,
    /// Off-device bytes moved.
    pub comm_bytes: u64,
    /// Device load (FFN assignments landing on each device).
    pub device_load: Vec<usize>,
    pub dropped: usize,
}

impl LayerSimReport {
    /// Simulated step time: slowest device + communication.
    pub fn makespan(&self) -> f64 {
        self.device_compute_s
            .iter()
            .cloned()
            .fold(0.0, f64::max)
            + self.zc_compute_s
            + self.comm_s
    }

    pub fn load_imbalance_cv(&self) -> f64 {
        load_cv(&self.device_load)
    }
}

/// Whole-stack simulation report.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub layers: Vec<LayerSimReport>,
    pub tokens: usize,
    /// The shared executor's routing/expert statistics — identical in
    /// structure to the serving engine's, enabling cross-backend
    /// accounting comparisons.
    pub stats: ForwardStats,
}

impl SimReport {
    pub fn total_makespan(&self) -> f64 {
        self.layers.iter().map(|l| l.makespan()).sum()
    }

    /// Deterministic analytic makespan: per layer, the bottleneck
    /// device's FFN assignments × `compute_s_per_assignment` plus the
    /// analytic comm time. Unlike [`SimReport::total_makespan`] (measured
    /// wall clock, noisy), this is identical across runs — the figure the
    /// placement sweeps and tests compare. It shares the placement
    /// [`CostModel`]'s objective *shape* but uses actual token homes and
    /// per-batch loads, so it can deviate a few percent from the model's
    /// uniform-home, aggregated-profile prediction (see
    /// `placement::cost` docs).
    ///
    /// [`CostModel`]: crate::placement::CostModel
    pub fn modeled_makespan(&self, compute_s_per_assignment: f64) -> f64 {
        self.modeled_makespan_on(compute_s_per_assignment, &[])
    }

    /// [`SimReport::modeled_makespan`] on a heterogeneous fleet: device
    /// `d`'s assignments each cost `compute_s_per_assignment /
    /// device_speed[d]` (missing entries default to 1.0). The bottleneck
    /// fold matches [`CostModel`]'s — per device, load × per-device
    /// seconds, max over device index order.
    ///
    /// [`CostModel`]: crate::placement::CostModel
    pub fn modeled_makespan_on(
        &self,
        compute_s_per_assignment: f64,
        device_speed: &[f64],
    ) -> f64 {
        self.layers
            .iter()
            .map(|l| {
                let mut worst = 0.0f64;
                for (dev, &load) in l.device_load.iter().enumerate() {
                    let s =
                        device_speed.get(dev).copied().unwrap_or(1.0);
                    worst = worst
                        .max(load as f64 * compute_s_per_assignment / s);
                }
                worst + l.comm_s
            })
            .sum()
    }

    pub fn total_comm_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.comm_bytes).sum()
    }

    pub fn total_comm_s(&self) -> f64 {
        self.layers.iter().map(|l| l.comm_s).sum()
    }

    pub fn mean_load_cv(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.load_imbalance_cv()).sum::<f64>()
            / self.layers.len() as f64
    }

    pub fn expert_throughput(&self) -> f64 {
        self.tokens as f64 / self.total_makespan().max(1e-12)
    }
}

/// Expert-parallel cluster executing a MoE++ stack.
pub struct ClusterSim {
    pub cfg: MoeConfig,
    pub topo: Topology,
    pub weights: StackWeights,
    layer_cfgs: Vec<MoeConfig>,
    /// Per layer: worker handles (device-major).
    workers: Vec<Vec<Worker>>,
    /// Online replanner driving `apply_placement` between served batches.
    replanner: Option<Replanner>,
    /// In-flight off-thread planning task: submitted at the batch
    /// boundary where the replanner's window fills, polled (never
    /// awaited) at each later boundary and applied at the first one
    /// that finds it finished — the local search neither runs on nor
    /// blocks the serving scheduler thread (DESIGN.md §12).
    pending_plan: Option<TaskHandle<Option<MigrationPlan>>>,
    /// Batch boundaries since the in-flight planning task was submitted.
    /// Past the replanner's staleness bound the handle is abandoned — a
    /// proposal that old was searched against loads the fleet has since
    /// outgrown (the dropped handle detaches; the task finishes
    /// harmlessly on the pool worker and its result is never read).
    pending_plan_age: usize,
    /// Replans applied since the serving layer last collected the count.
    replans_unreported: u64,
    /// Reusable stack-forward buffers (routing, per-layer y; the worker
    /// backend keeps its own per-device tensors) — DESIGN.md §11.
    arena: ExecArena,
    /// The sim's executor pool (DESIGN.md §12). The cluster backend runs
    /// FFN work on its own per-device worker threads, so the pool's job
    /// side idles; its task side carries the replanner's local search off
    /// the scheduler thread (one lazily-spawned worker, spawned once).
    pool: ExecPool,
    /// Observability bundle (DESIGN.md §15): forwards stamp per-layer
    /// and replica-split records, `note_batch` stamps the replan trail.
    obs: Option<Arc<Obs>>,
    /// Deterministic fault injector (DESIGN.md §16). `None` on the
    /// production path: workers skip the fault check entirely and the
    /// reply loop uses a plain blocking `recv` — the no-fault fast path
    /// costs one `Option` branch per work message.
    injector: Option<Arc<FaultInjector>>,
    /// Quarantine table: devices whose workers were lost. Down devices
    /// are masked out of dispatch splits and planner candidates until
    /// [`ClusterSim::rejoin`] restores them.
    health: DeviceHealth,
    /// Set when a device goes down (or rejoins): the next `note_batch`
    /// pushes the new health mask into the replanner and forces a plan
    /// task past the interval gate, so placement heals at the next
    /// boundary rather than a window later.
    health_dirty: bool,
    /// Batches executed by this sim — the deterministic `batch`
    /// coordinate fault specs trigger on (sim-local, independent of the
    /// obs batch id so fault plans replay identically with or without
    /// an observability bundle attached).
    batch_count: u64,
    /// The last fault `forward` hit, kept as a typed side channel
    /// because the vendored `anyhow` has no downcast: the serve backend
    /// reads it via [`ClusterSim::take_fault`] to classify the failure.
    last_fault: Option<ClusterError>,
}

impl ClusterSim {
    pub fn new(cfg: MoeConfig, topo: Topology, seed: u64) -> ClusterSim {
        if let Some(plan) = topo.placement() {
            assert_eq!(
                plan.n_ffn_experts(),
                cfg.n_ffn_experts,
                "placement plan expert count does not match config"
            );
        }
        let weights = StackWeights::init(seed, &cfg);
        let workers = Self::spawn_workers(&weights, &cfg, &topo, None);
        let layer_cfgs = vec![cfg.clone(); cfg.n_layers];
        let health = DeviceHealth::new(topo.n_devices);
        ClusterSim {
            cfg,
            topo,
            weights,
            layer_cfgs,
            workers,
            replanner: None,
            pending_plan: None,
            pending_plan_age: 0,
            replans_unreported: 0,
            arena: ExecArena::new(),
            pool: ExecPool::new(1),
            obs: None,
            injector: None,
            health,
            health_dirty: false,
            batch_count: 0,
            last_fault: None,
        }
    }

    /// Install an observability bundle: subsequent forwards stamp their
    /// per-layer/per-replica records and `note_batch` stamps the replan
    /// trail into it (DESIGN.md §15).
    pub fn set_obs(&mut self, obs: Arc<Obs>) {
        self.obs = Some(obs);
    }

    /// Attach an online replanner; on the serving path it observes every
    /// executed batch and migrates experts between batches when its
    /// hysteresis gates clear.
    pub fn with_replanner(mut self, replanner: Replanner) -> ClusterSim {
        self.replanner = Some(replanner);
        self
    }

    /// Install a deterministic fault plan (DESIGN.md §16) and respawn
    /// every worker with the shared injector threaded into its loop.
    /// Faults fire at (batch, layer, device) coordinates — never wall
    /// clock — so every run of the same plan is reproducible.
    pub fn with_faults(mut self, plan: FaultPlan) -> ClusterSim {
        let injector = Arc::new(FaultInjector::new(plan));
        self.injector = Some(injector);
        self.workers = Self::spawn_workers(
            &self.weights,
            &self.cfg,
            &self.topo,
            self.injector.clone(),
        );
        self
    }

    /// The typed fault behind the most recent `forward` error, if any
    /// (cleared on read and at each forward entry). The serve backend
    /// uses this instead of downcasting: the vendored `anyhow` carries
    /// only a string chain.
    pub fn take_fault(&mut self) -> Option<ClusterError> {
        self.last_fault.take()
    }

    /// Quarantine table for the fleet (read-only view).
    pub fn health(&self) -> &DeviceHealth {
        &self.health
    }

    /// Restore a quarantined device: respawn its worker on every layer
    /// with the experts the *current* placement assigns it, then lift
    /// the quarantine and mark health dirty so the replanner folds the
    /// device back into the next plan. After a degrade-only loss (the
    /// placement never changed), rejoin alone restores full-precision
    /// outputs. Fails with [`ClusterError::RespawnFailed`] if the
    /// injector still marks the device as permanently lost (call
    /// [`FaultInjector::revive`] first in tests).
    pub fn rejoin(&mut self, dev: usize) -> Result<(), ClusterError> {
        for (li, (layer, workers)) in self
            .weights
            .layers
            .iter()
            .zip(&mut self.workers)
            .enumerate()
        {
            workers[dev] = Self::spawn_device_worker(
                li,
                layer,
                &self.cfg,
                &self.topo,
                dev,
                self.injector.clone(),
            )?;
        }
        self.health.mark_up(dev);
        self.health_dirty = true;
        Ok(())
    }

    /// The installed fault injector (tests use it to revive lost
    /// devices before `rejoin`).
    pub fn injector(&self) -> Option<&Arc<FaultInjector>> {
        self.injector.as_ref()
    }

    /// Per-layer, per-device worker threads owning the FFN shards the
    /// topology's placement assigns them. Infallible at construction: a
    /// fresh (or absent) injector refuses no device.
    fn spawn_workers(
        weights: &StackWeights,
        cfg: &MoeConfig,
        topo: &Topology,
        injector: Option<Arc<FaultInjector>>,
    ) -> Vec<Vec<Worker>> {
        weights
            .layers
            .iter()
            .enumerate()
            .map(|(li, layer)| {
                (0..topo.n_devices)
                    .map(|dev| {
                        Self::spawn_device_worker(
                            li,
                            layer,
                            cfg,
                            topo,
                            dev,
                            injector.clone(),
                        )
                        .expect("initial worker spawn cannot be refused")
                    })
                    .collect()
            })
            .collect()
    }

    /// One device's worker for one layer, loaded with every FFN expert
    /// whose replica set includes this device (a replicated expert's
    /// weights live on each of its replicas), at the plan's stack-wide
    /// per-expert precision — int8 experts are quantized here, once at
    /// spawn, so the worker's serving loop never touches f32 weights —
    /// running at the topology's per-device speed. Refused
    /// ([`ClusterError::RespawnFailed`]) when the injector marks the
    /// device permanently lost.
    fn spawn_device_worker(
        layer_idx: usize,
        layer: &crate::moe::weights::MoeLayerWeights,
        cfg: &MoeConfig,
        topo: &Topology,
        dev: usize,
        injector: Option<Arc<FaultInjector>>,
    ) -> Result<Worker, ClusterError> {
        let owned: Vec<usize> = (0..cfg.n_ffn_experts)
            .filter(|&e| {
                (0..topo.ffn_replica_count(e))
                    .any(|j| topo.ffn_replica(e, j) == dev)
            })
            .collect();
        let w = owned
            .iter()
            .map(|&e| match topo.ffn_precision(e) {
                crate::config::Precision::Int8 => ExpertParams::Int8(
                    QuantFfnExpert::from_f32(&layer.ffn[e]),
                ),
                crate::config::Precision::F32 => {
                    ExpertParams::F32(layer.ffn[e].clone())
                }
            })
            .collect();
        Worker::try_spawn(
            layer_idx,
            dev,
            owned,
            w,
            topo.speed(dev),
            cfg,
            injector,
        )
    }

    /// The effective FFN placement currently executing.
    pub fn placement(&self) -> PlacementPlan {
        self.topo.effective_placement(self.cfg.n_ffn_experts)
    }

    /// Migrate to `plan`: install it on the topology and respawn **only
    /// the workers of devices whose resident-expert set or serving
    /// precision changed** — the devices of the replica-delta's adds
    /// and drops, plus every device holding a replica of an expert
    /// whose precision flipped (the holding worker must requantize,
    /// locally: precision changes move no bytes over the interconnect,
    /// see [`PlacementPlan::diff_precision`]). The between-batch stall
    /// scales with the migration, not with cluster size; untouched
    /// devices' worker threads survive by identity (asserted in
    /// `tests/cluster_placement.rs`). Returns the number of experts
    /// whose replica set or precision changed. Call between batches —
    /// never during a forward.
    pub fn apply_placement(&mut self, plan: &PlacementPlan)
        -> Result<usize> {
        anyhow::ensure!(
            plan.n_devices() == self.topo.n_devices,
            "plan is for {} devices, cluster has {}",
            plan.n_devices(),
            self.topo.n_devices
        );
        anyhow::ensure!(
            plan.n_ffn_experts() == self.cfg.n_ffn_experts,
            "plan places {} experts, config has {}",
            plan.n_ffn_experts(),
            self.cfg.n_ffn_experts
        );
        plan.validate()?;
        let current = self.placement();
        let changed = current.diff_experts(plan);
        let reprecised = current.diff_precision(plan);
        if changed.is_empty() && reprecised.is_empty() {
            return Ok(0);
        }
        // A manually-applied plan invalidates any in-flight replanner
        // proposal (it was searched against the placement just replaced).
        self.pending_plan = None;
        self.pending_plan_age = 0;
        let delta = current.delta(plan);
        let mut affected = vec![false; self.topo.n_devices];
        for &(_, dev) in delta.adds.iter().chain(delta.drops.iter()) {
            affected[dev] = true;
        }
        // A precision flip re-encodes the expert on every device that
        // holds (or will hold) a replica: both plans' replica sets are
        // marked so no worker keeps serving at the stale precision.
        for &e in &reprecised {
            for &dev in
                current.replicas(e).iter().chain(plan.replicas(e))
            {
                affected[dev] = true;
            }
        }
        self.topo.set_placement(plan.clone());
        for (li, (layer, workers)) in self
            .weights
            .layers
            .iter()
            .zip(&mut self.workers)
            .enumerate()
        {
            for (dev, worker) in workers.iter_mut().enumerate() {
                // Quarantined devices keep their dead worker handles:
                // dispatch masks them out, and only `rejoin` respawns
                // them (a respawn here would be refused anyway while
                // the injector marks the device lost).
                if !affected[dev] || self.health.is_down(dev) {
                    continue;
                }
                match Self::spawn_device_worker(
                    li,
                    layer,
                    &self.cfg,
                    &self.topo,
                    dev,
                    self.injector.clone(),
                ) {
                    Ok(w) => *worker = w,
                    Err(e) => {
                        // A worker refused/died during migration: the
                        // sim stays usable — quarantine the device so
                        // dispatch never routes to its stale worker,
                        // and surface the typed error. The pending
                        // replan proposal was already invalidated
                        // above, matching the manual-apply rule.
                        crate::warn_log!(
                            "apply_placement respawn failed: {e}; \
                             device {dev} quarantined"
                        );
                        self.health.mark_down(dev);
                        self.health_dirty = true;
                        self.last_fault = Some(e.clone());
                        return Err(e.into());
                    }
                }
            }
        }
        // Union count: an expert that both moved and flipped precision
        // is one changed expert, not two.
        let moved = reprecised
            .iter()
            .filter(|e| !changed.contains(e))
            .count();
        Ok(changed.len() + moved)
    }

    /// Feed one executed batch's stats to the attached replanner. The
    /// serving backend calls this after every batch, so everything here
    /// happens *between* batches — and the expensive part (the planner's
    /// local search) never touches this thread at all (DESIGN.md §12):
    ///
    /// 1. when the replanner's observation window fills, the search is
    ///    **submitted** to the sim's pool and this call returns;
    /// 2. every later batch boundary **polls** (non-blocking
    ///    `try_take`); the first boundary that finds the search finished
    ///    — normally the very next one, since planning overlapped a
    ///    whole batch — applies its gated proposal before the next
    ///    batch executes. A search slower than a batch just stays in
    ///    flight: `note_batch` is O(1) on this thread unconditionally,
    ///    which is what kills the periodic tail-latency spike at large
    ///    expert counts — **bounded by the staleness gate**: a proposal
    ///    older than `max_proposal_age_batches` boundaries (still
    ///    running *or* just finished) is abandoned rather than applied,
    ///    because it was searched against a load profile the fleet has
    ///    since outgrown. Dropping the handle merely detaches the task;
    ///    it finishes harmlessly on the pool worker.
    ///
    /// Outputs are unaffected either way: placement never changes math.
    pub fn note_batch(&mut self, stats: &ForwardStats) {
        let Some(mut rp) = self.replanner.take() else { return };
        rp.observe(stats, &self.cfg);
        if self.health_dirty {
            // A device was lost (or rejoined) since the last boundary:
            // push the new mask into the planner and force a plan task
            // now, bypassing the interval/gain gates — healing a hole
            // in the fleet must not wait out a hysteresis window. Any
            // in-flight proposal was searched against the old fleet and
            // is abandoned.
            self.health_dirty = false;
            rp.set_down_devices(self.health.down_devices());
            if self.pending_plan.take().is_some() {
                self.stamp_replan_abandoned();
            }
            let task = rp.plan_task_forced(&self.placement());
            self.pending_plan = Some(self.pool.submit(move || task.run()));
            self.pending_plan_age = 0;
            self.replanner = Some(rp);
            return;
        }
        if let Some(handle) = self.pending_plan.take() {
            self.pending_plan_age += 1;
            let stale = rp.proposal_stale(self.pending_plan_age);
            match handle.try_take() {
                // Still planning: keep polling unless the proposal has
                // gone stale, in which case abandon it — never block
                // the scheduler either way.
                None => {
                    if stale {
                        self.stamp_replan_abandoned();
                        rp.window_reset();
                    } else {
                        self.pending_plan = Some(handle);
                    }
                }
                Some(Ok(Some(mig))) => {
                    self.stamp_replan_proposed(&mig);
                    if stale {
                        // Finished, but too late to trust.
                        self.stamp_replan_abandoned();
                        rp.window_reset();
                    } else if self.apply_placement(&mig.plan).is_ok() {
                        self.stamp_replan_committed(&mig);
                        rp.committed();
                        self.replans_unreported += 1;
                    } else {
                        rp.window_reset();
                    }
                }
                // Gates held: restart the window, exactly like the
                // synchronous failed-attempt rule.
                Some(Ok(None)) => rp.window_reset(),
                // The task panicked (a planner bug, NOT a gate): the
                // pool contained it, but it must not be silent — every
                // window would fill, panic and reset, permanently
                // disabling replanning with no trace.
                Some(Err(msg)) => {
                    crate::warn_log!(
                        "placement planning task panicked: {msg}; \
                         replanning window restarted"
                    );
                    debug_assert!(
                        false,
                        "placement planning task panicked: {msg}"
                    );
                    rp.window_reset();
                }
            }
        } else if rp.ready() {
            let task = rp.plan_task(&self.placement());
            self.pending_plan = Some(self.pool.submit(move || task.run()));
            self.pending_plan_age = 0;
        }
        self.replanner = Some(rp);
    }

    /// Replan trail (DESIGN.md §15): a finished planning task produced a
    /// proposal (whether or not it will be applied).
    fn stamp_replan_proposed(&self, mig: &MigrationPlan) {
        if let Some(o) = &self.obs {
            o.registry().inc(o.h.replan_proposed);
            o.trace.push(EventKind::ReplanProposed {
                batch: o.current_batch(),
                moves: mig.moves.len() as u32,
                gain_ppm: mig.gain_ppm(),
            });
        }
    }

    /// Replan trail: the proposal survived the gates and was applied at
    /// this batch boundary.
    fn stamp_replan_committed(&self, mig: &MigrationPlan) {
        if let Some(o) = &self.obs {
            o.registry().inc(o.h.replan_committed);
            o.registry()
                .add(o.h.migration_bytes, mig.migration_bytes);
            o.trace.push(EventKind::ReplanCommitted {
                batch: o.current_batch(),
                moves: mig.moves.len() as u32,
                bytes: mig.migration_bytes,
            });
        }
    }

    /// Replan trail: an in-flight or just-finished proposal aged past
    /// the staleness bound and was dropped, not applied.
    fn stamp_replan_abandoned(&self) {
        if let Some(o) = &self.obs {
            o.registry().inc(o.h.replan_abandoned);
            o.trace.push(EventKind::ReplanAbandoned {
                batch: o.current_batch(),
                age_batches: self.pending_plan_age as u32,
            });
        }
    }

    /// Backing-allocation growths of the sim's arena (routing, per-layer
    /// `y`, FFN pools and the cluster wire pool) — the steady-state
    /// zero-allocation regression signal for the cluster path.
    pub fn arena_growths(&self) -> u64 {
        self.arena.growths()
    }

    /// True while a submitted planning task has not yet been joined
    /// (diagnostics / tests of the off-thread replan protocol).
    pub fn replan_in_flight(&self) -> bool {
        self.pending_plan.is_some()
    }

    /// Per-(layer, device) worker thread identities — the migration
    /// regression test uses these to prove untouched devices' workers
    /// survive `apply_placement` by identity.
    pub fn worker_thread_ids(&self) -> Vec<Vec<std::thread::ThreadId>> {
        self.workers
            .iter()
            .map(|row| row.iter().map(Worker::thread_id).collect())
            .collect()
    }

    /// Replans applied since last asked (serving metrics hook).
    pub fn take_replan_count(&mut self) -> u64 {
        std::mem::take(&mut self.replans_unreported)
    }

    /// Total replans committed by the attached replanner.
    pub fn replan_count(&self) -> usize {
        self.replanner.as_ref().map_or(0, |r| r.replans)
    }

    /// Run one batch [T, D] through the full stack on the cluster,
    /// returning the combined hidden states and the simulation report.
    /// `&mut self`: the sim's [`ExecArena`] backs the stack loop's
    /// reusable buffers (DESIGN.md §11).
    ///
    /// Fallible since DESIGN.md §16: a lost worker is recovered by
    /// redispatching its units to surviving replicas (bitwise-identical
    /// outputs) or degrading to copy-expert semantics when no replica
    /// remains — `Err` surfaces only when recovery itself is impossible
    /// (the redispatch target died too, or every device is gone). The
    /// typed fault is also kept for [`ClusterSim::take_fault`].
    pub fn forward(
        &mut self,
        x: &Tensor,
    ) -> Result<(Tensor, SimReport), ClusterError> {
        self.last_fault = None;
        let batch = self.batch_count;
        self.batch_count += 1;
        if let (Some(inj), Some(o)) =
            (self.injector.as_deref(), self.obs.as_deref())
        {
            // Stamp the faults *scheduled* for this batch up front from
            // the deterministic plan (the trace uses the obs batch id
            // `forward_stack` is about to claim).
            for s in inj.faults_for_batch(batch) {
                o.registry().inc(o.h.faults);
                o.trace.push(EventKind::FaultInjected {
                    batch: o.peek_batch(),
                    layer: s.layer as u16,
                    device: s.device as u16,
                    kind: s.kind.code(),
                });
            }
        }
        let mut backend = ClusterBackend {
            topo: &self.topo,
            workers: &self.workers,
            n_ffn: self.cfg.n_ffn_experts,
            obs: self.obs.as_deref(),
            injector: self.injector.as_deref(),
            health: &mut self.health,
            health_dirty: &mut self.health_dirty,
            fault: &mut self.last_fault,
            batch,
        };
        match exec::forward_stack(
            &mut backend, &self.weights, &self.layer_cfgs, x,
            &mut self.arena, &Executor::Pool(&self.pool),
            self.obs.as_deref(),
        ) {
            Ok((y, stats, execs)) => {
                let layers = execs
                    .into_iter()
                    .map(|ex| LayerSimReport {
                        device_compute_s: ex.report.device_compute_s,
                        zc_compute_s: ex.zc_s,
                        comm_s: ex.report.comm_s,
                        comm_bytes: ex.report.comm_bytes,
                        device_load: ex.report.device_load,
                        dropped: ex.stats.dropped,
                    })
                    .collect();
                let report =
                    SimReport { layers, tokens: stats.tokens, stats };
                Ok((y, report))
            }
            Err(e) => {
                let fault = match &self.last_fault {
                    Some(f) => f.clone(),
                    None => ClusterError::Internal(format!("{e:#}")),
                };
                Err(fault)
            }
        }
    }
}

/// The sharded-worker expert backend: each FFN micro-batch is split into
/// contiguous replica slices ([`crate::placement::replica_slices`] — one
/// slice per device holding the expert, all of it for a single-replica
/// expert), gathered, charged for any off-device hop (token home ->
/// replica device and back), and executed on each replica's persistent
/// worker thread. Workers run concurrently; results are scatter-added at
/// the token homes in a canonical order that depends only on the device
/// count — see `execute_ffn`.
struct ClusterBackend<'a> {
    topo: &'a Topology,
    workers: &'a [Vec<Worker>],
    n_ffn: usize,
    /// When installed, replicated experts' per-replica slices are
    /// stamped as [`EventKind::ReplicaSplit`] records (the driver reads
    /// the batch id it claimed at `forward_stack` entry).
    obs: Option<&'a Obs>,
    /// Fault injector, when installed: switches the reply loop from a
    /// blocking `recv` to a `recv_timeout` at the plan's reply deadline
    /// (a hung worker must not hang the batch).
    injector: Option<&'a FaultInjector>,
    /// Fleet quarantine table: down devices are excluded from dispatch
    /// splits entirely (their speed weight never enters `total_w`), and
    /// devices discovered dead here are marked down for the rest of the
    /// forward and beyond.
    health: &'a mut DeviceHealth,
    /// Raised when this forward changes the health table, so
    /// `note_batch` forces a replan around the hole.
    health_dirty: &'a mut bool,
    /// Typed-fault side channel back to [`ClusterSim::take_fault`].
    fault: &'a mut Option<ClusterError>,
    /// The sim-local batch coordinate fault specs trigger on.
    batch: u64,
}

impl ClusterBackend<'_> {
    /// First discovery of a dead device this forward: quarantine it,
    /// record it in `newly_down` (it *was* dispatched to this layer, so
    /// its units must be rebuilt), and stamp the trace.
    fn note_lost(
        &mut self,
        dev: usize,
        layer: usize,
        newly_down: &mut Vec<usize>,
    ) {
        if self.health.mark_down(dev) {
            newly_down.push(dev);
            *self.health_dirty = true;
            if let Some(o) = self.obs {
                o.trace.push(EventKind::WorkerLost {
                    batch: o.current_batch(),
                    layer: layer as u16,
                    device: dev as u16,
                });
            }
        }
    }

    fn stamp_redispatch(
        &self,
        layer: usize,
        expert: usize,
        from: usize,
        to: usize,
        rows: usize,
    ) {
        if let Some(o) = self.obs {
            o.registry().inc(o.h.redispatches);
            o.trace.push(EventKind::Redispatch {
                batch: o.current_batch(),
                layer: layer as u16,
                expert: expert as u16,
                from: from as u16,
                to: to as u16,
                rows: rows as u32,
            });
        }
    }

    fn stamp_degraded(&self, layer: usize, expert: usize, tokens: usize) {
        if let Some(o) = self.obs {
            o.registry().add(o.h.degraded_tokens, tokens as u64);
            o.trace.push(EventKind::Degraded {
                batch: o.current_batch(),
                layer: layer as u16,
                expert: expert as u16,
                tokens: tokens as u32,
            });
        }
    }

    /// Worker-loss recovery (DESIGN.md §16), entered only when the
    /// reply loop lost at least one device. Replays the dispatch split
    /// arithmetic under the *dispatch-time* health mask (down now minus
    /// `newly_down`) to find the exact (expert, part, row-range) units
    /// whose results never arrived, rebuilds their wire buffers from
    /// `h`, and redispatches each to the first currently-healthy
    /// replica of its expert. The redispatched result fills the same
    /// `(expert, part)` slot the lost one would have, so the canonical
    /// combine is untouched and outputs stay bitwise-identical to the
    /// fault-free run. Units of an expert with no surviving replica are
    /// appended to `degraded` instead. One redispatch round only: a
    /// failure inside it is a hard [`ClusterError::WorkerLost`].
    #[allow(clippy::too_many_arguments)]
    fn recover(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        arena: &mut FfnArena,
        newly_down: &[usize],
        expert_results: &mut [Vec<Option<WorkResult>>],
        degraded: &mut Vec<(usize, usize, usize)>,
        device_compute: &mut [f64],
        device_load: &mut [usize],
        traffic: &mut LayerTraffic,
    ) -> Result<(), ClusterError> {
        let (t, d) = h.dims2();
        let token_bytes = (d * 4) as u64;
        let n_dev = self.topo.n_devices;
        let was_up = |health: &DeviceHealth, dev: usize| {
            !health.is_down(dev) || newly_down.contains(&dev)
        };
        let mut redispatch: Vec<Vec<WorkUnit>> =
            (0..n_dev).map(|_| Vec::new()).collect();
        for (bi, fb) in plan.ffn_batches.iter().enumerate() {
            let n_rows = fb.tokens.len();
            let n_rep = self.topo.ffn_replica_count(fb.expert);
            // Identical split arithmetic to dispatch, under the
            // dispatch-time mask.
            let mut total_w = 0u64;
            for j in 0..n_rep {
                let dev = self.topo.ffn_replica(fb.expert, j);
                if was_up(self.health, dev) {
                    total_w += speed_weight(self.topo.speed(dev));
                }
            }
            if total_w == 0 {
                continue; // degraded at dispatch already
            }
            let mut prefix_w = 0u64;
            let mut start = 0usize;
            for j in 0..n_rep {
                let dev = self.topo.ffn_replica(fb.expert, j);
                if !was_up(self.health, dev) {
                    continue;
                }
                let w = speed_weight(self.topo.speed(dev));
                let len =
                    weighted_share(n_rows as u64, total_w, prefix_w, w)
                        as usize;
                prefix_w += w;
                if len == 0 {
                    continue;
                }
                if expert_results[fb.expert][j].is_none() {
                    // This unit's reply never arrived. Its device is in
                    // `newly_down` (or died before submit); route the
                    // same rows to a surviving replica, or degrade.
                    let target = (0..n_rep)
                        .map(|k| self.topo.ffn_replica(fb.expert, k))
                        .find(|&dv| !self.health.is_down(dv));
                    device_load[dev] -= len;
                    match target {
                        None => degraded.push((bi, start, len)),
                        Some(dst) => {
                            let slice =
                                &fb.tokens[start..start + len];
                            let mut xb = arena.wire.take(len, d);
                            let mut yb = arena.wire.take(len, d);
                            yb.data.fill(0.0);
                            for (i, &tok) in slice.iter().enumerate() {
                                xb.row_mut(i)
                                    .copy_from_slice(h.row(tok));
                                let home =
                                    self.topo.token_home(tok, t);
                                if home != dst {
                                    // Recovery traffic is *added* on
                                    // top of the first attempt's: the
                                    // lost shipment did move bytes.
                                    traffic.record_assignment(
                                        home,
                                        dst,
                                        token_bytes,
                                    );
                                }
                            }
                            device_load[dst] += len;
                            self.stamp_redispatch(
                                layer, fb.expert, dev, dst, len,
                            );
                            redispatch[dst].push(WorkUnit {
                                expert: fb.expert,
                                part: j,
                                x: xb,
                                gates: fb.gates[start..start + len]
                                    .to_vec(),
                                tokens: slice.to_vec(),
                                y: yb,
                            });
                        }
                    }
                }
                start += len;
            }
            debug_assert_eq!(start, n_rows);
        }
        // One redispatch round, submitted then collected per target.
        // A loss here means both the original replica and the recovery
        // target died within one batch: give up with the typed error.
        let deadline =
            self.injector.map(FaultInjector::reply_deadline);
        for (dst, units) in redispatch.into_iter().enumerate() {
            if units.is_empty() {
                continue;
            }
            let rx = match self.workers[layer][dst]
                .submit(self.batch, units)
            {
                Ok(rx) => rx,
                Err(err) => {
                    for u in err.units {
                        arena.wire.put(u.x);
                        arena.wire.put(u.y);
                    }
                    self.health.mark_down(dst);
                    *self.health_dirty = true;
                    return Err(err.to_cluster_error());
                }
            };
            let results = match deadline {
                Some(dl) => rx.recv_timeout(dl).map_err(|_| ()),
                None => rx.recv().map_err(|_| ()),
            };
            match results {
                Ok(results) => {
                    for r in results {
                        device_compute[dst] += r.compute_s;
                        let (e, part) = (r.expert, r.part);
                        expert_results[e][part] = Some(r);
                    }
                }
                Err(()) => {
                    self.health.mark_down(dst);
                    *self.health_dirty = true;
                    return Err(ClusterError::WorkerLost {
                        device: dst,
                        layer,
                    });
                }
            }
        }
        Ok(())
    }
}

impl ExpertBackend for ClusterBackend<'_> {
    // FFN compute runs on the per-device worker threads, so the host
    // executor idles; the gather/output tensors crossing the (simulated)
    // device boundary come from the arena's wire pool and are echoed
    // back with each result, so steady-state forwards allocate none.
    fn execute_ffn(
        &mut self,
        layer: usize,
        plan: &DispatchPlan,
        h: &Tensor,
        y: &mut Tensor,
        arena: &mut FfnArena,
        _exec: &Executor,
    ) -> Result<FfnLayerReport> {
        let (t, d) = h.dims2();
        let token_bytes = (d * 4) as u64;
        let n_dev = self.topo.n_devices;
        let mut traffic = LayerTraffic::new(n_dev);
        let mut per_device: Vec<Vec<WorkUnit>> =
            (0..n_dev).map(|_| Vec::new()).collect();
        let mut device_load = vec![0usize; n_dev];
        // Micro-batch slices degrading to copy-expert semantics:
        // (ffn_batch index, row start, len). Empty — and heap-free —
        // unless a fault leaves an expert with no surviving replica.
        let mut degraded: Vec<(usize, usize, usize)> = Vec::new(); // alloc-ok: empty Vec, heap-free until a fault degrades
        for (bi, batch) in plan.ffn_batches.iter().enumerate() {
            let n_rows = batch.tokens.len();
            let n_rep = self.topo.ffn_replica_count(batch.expert);
            // Deterministic speed-weighted contiguous split across the
            // expert's replica enumeration: same boundaries as
            // `placement::replica_slices` fed the replica devices'
            // `speed_weight`s, computed inline to stay allocation-free.
            // Depends only on (n_rows, healthy replica devices'
            // speeds) — never on workers or partitions. Quarantined
            // replicas are masked out entirely (their weight never
            // enters `total_w` — `weighted_share` rejects zero
            // weights); with *no* healthy replica the whole micro-batch
            // degrades.
            let mut total_w = 0u64;
            for j in 0..n_rep {
                let dev = self.topo.ffn_replica(batch.expert, j);
                if !self.health.is_down(dev) {
                    total_w += speed_weight(self.topo.speed(dev));
                }
            }
            if total_w == 0 {
                degraded.push((bi, 0, n_rows));
                continue;
            }
            let mut prefix_w = 0u64;
            let mut start = 0usize;
            for j in 0..n_rep {
                let dev = self.topo.ffn_replica(batch.expert, j);
                if self.health.is_down(dev) {
                    continue;
                }
                let w = speed_weight(self.topo.speed(dev));
                let len =
                    weighted_share(n_rows as u64, total_w, prefix_w, w)
                        as usize;
                prefix_w += w;
                if len == 0 {
                    continue; // slow replica or more replicas than tokens
                }
                let slice = &batch.tokens[start..start + len];
                device_load[dev] += len;
                if n_rep > 1 {
                    if let Some(o) = self.obs {
                        o.trace.push(EventKind::ReplicaSplit {
                            batch: o.current_batch(),
                            layer: layer as u16,
                            expert: batch.expert as u16,
                            device: dev as u16,
                            rows: len as u32,
                        });
                    }
                }
                let mut xb = arena.wire.take(len, d);
                let mut yb = arena.wire.take(len, d);
                // The batched kernel accumulates; pooled buffers carry
                // stale rows.
                yb.data.fill(0.0);
                for (i, &tok) in slice.iter().enumerate() {
                    xb.row_mut(i).copy_from_slice(h.row(tok));
                    let home = self.topo.token_home(tok, t);
                    if home != dev {
                        traffic.record_assignment(home, dev, token_bytes);
                    }
                }
                per_device[dev].push(WorkUnit {
                    expert: batch.expert,
                    part: j,
                    x: xb,
                    gates: batch.gates[start..start + len].to_vec(),
                    tokens: slice.to_vec(),
                    y: yb,
                });
                start += len;
            }
            debug_assert_eq!(start, n_rows);
        }

        let mut device_compute = vec![0.0f64; n_dev];
        let mut expert_results: Vec<Vec<Option<WorkResult>>> = (0
            ..self.n_ffn)
            .map(|e| {
                (0..self.topo.ffn_replica_count(e)).map(|_| None).collect()
            })
            .collect();
        // Devices that died during *this* call (submit refusal or lost
        // reply) — their dispatched units get rebuilt in `recover`.
        let mut newly_down: Vec<usize> = Vec::new(); // alloc-ok: empty Vec, heap-free on the no-fault path
        let mut rxs: Vec<
            Option<std::sync::mpsc::Receiver<Vec<WorkResult>>>,
        > = Vec::with_capacity(n_dev);
        // lint: no-alloc — the no-fault submit/collect fast path; fault
        // handling allocates only after a loss is detected.
        // Submit, then collect (workers run concurrently). Devices with
        // no rows this layer get no message — so a scheduled fault fires
        // only when its device actually holds work, and an idle replica
        // stays alive as a recovery target. A submit refusal means the
        // worker is already gone: recycle the unsent buffers and
        // quarantine — recovery rebuilds the units later.
        for (dev, units) in per_device.into_iter().enumerate() {
            if self.health.is_down(dev) || units.is_empty() {
                debug_assert!(
                    !self.health.is_down(dev) || units.is_empty()
                );
                rxs.push(None);
                continue;
            }
            match self.workers[layer][dev].submit(self.batch, units) {
                Ok(rx) => rxs.push(Some(rx)),
                Err(err) => {
                    for u in err.units {
                        arena.wire.put(u.x);
                        arena.wire.put(u.y);
                    }
                    self.note_lost(dev, layer, &mut newly_down);
                    rxs.push(None);
                }
            }
        }
        // Collect. Loss shows up as a disconnected reply channel (a
        // panicked/exited worker drops its senders) or, under an
        // injector, a reply-deadline timeout (a hung worker must not
        // hang the batch). A timeout false-positive is harmless:
        // result slots fill at most once and a late straggler's reply
        // fails silently on the dropped receiver.
        let deadline =
            self.injector.map(FaultInjector::reply_deadline);
        for (dev, rx) in rxs.into_iter().enumerate() {
            let Some(rx) = rx else { continue };
            let results = match deadline {
                Some(dl) => rx.recv_timeout(dl).map_err(|_| ()),
                None => rx.recv().map_err(|_| ()),
            };
            match results {
                Ok(results) => {
                    for r in results {
                        device_compute[dev] += r.compute_s;
                        let (e, part) = (r.expert, r.part);
                        expert_results[e][part] = Some(r);
                    }
                }
                Err(()) => self.note_lost(dev, layer, &mut newly_down),
            }
        }
        // lint: end
        if !newly_down.is_empty() {
            if let Err(e) = self.recover(
                layer,
                plan,
                h,
                arena,
                &newly_down,
                &mut expert_results,
                &mut degraded,
                &mut device_compute,
                &mut device_load,
                &mut traffic,
            ) {
                *self.fault = Some(e.clone());
                return Err(e.into());
            }
        }

        // Combine in the canonical round-robin interleave order
        // (expert % n_devices, expert): it depends only on the device
        // count, never on where an expert actually ran, so every
        // placement plan yields bitwise-identical outputs — and it is
        // exactly the device-major order the pre-placement simulator
        // produced, keeping the round-robin default bit-for-bit
        // compatible with history. Within an expert, parts merge in
        // ascending replica order, restoring the canonical token order —
        // and since each token is a distinct output row, per-row sums
        // are unaffected by the split anyway: replication is bitwise
        // invisible (§13).
        for dev in 0..n_dev {
            let mut e = dev;
            while e < self.n_ffn {
                for part in expert_results[e].iter_mut() {
                    if let Some(r) = part.take() {
                        for (i, &tok) in r.tokens.iter().enumerate() {
                            axpy(
                                1.0,
                                r.y.row(i),
                                &mut y.data[tok * d..(tok + 1) * d],
                            );
                        }
                        arena.wire.put(r.x);
                        arena.wire.put(r.y);
                    }
                }
                e += n_dev;
            }
        }
        // Graceful degradation (DESIGN.md §16): tokens of an expert
        // with no surviving FFN replica fall back to copy-expert
        // semantics — gate × input added to the residual, exactly the
        // ZC copy arm (`apply_zc_inline`) — applied after the combine
        // in a deterministic (batch-index, row-start) order. ZC experts
        // themselves run inline on token homes and never reach here.
        let mut degraded_tokens = 0u64;
        if !degraded.is_empty() {
            degraded.sort_unstable();
            for &(bi, start, len) in &degraded {
                let fb = &plan.ffn_batches[bi];
                for i in start..start + len {
                    let tok = fb.tokens[i];
                    copy_expert_into(
                        h.row(tok),
                        fb.gates[i],
                        &mut y.data[tok * d..(tok + 1) * d],
                    );
                }
                degraded_tokens += len as u64;
                self.stamp_degraded(layer, fb.expert, len);
            }
        }
        Ok(FfnLayerReport {
            device_compute_s: device_compute,
            device_load,
            comm_s: traffic.total_time(self.topo),
            comm_bytes: traffic.total_bytes(),
            degraded_tokens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn run(preset: &str, devices: usize, t: usize) -> SimReport {
        let cfg = MoeConfig::preset(preset);
        let mut sim =
            ClusterSim::new(cfg.clone(), Topology::new(devices), 0);
        let mut rng = Rng::new(42);
        let x = Tensor::randn(&mut rng, &[t, cfg.d_model], 1.0);
        sim.forward(&x).unwrap().1
    }

    #[test]
    fn moepp_moves_fewer_bytes_than_vanilla() {
        // The deployment-friendliness claim: ZC-routed tokens never cross
        // devices, so MoE++ all-to-all traffic < vanilla at same size.
        let a = run("test", 4, 128);
        let b = run("test:vanilla", 4, 128);
        assert!(a.total_comm_bytes() < b.total_comm_bytes(),
                "{} vs {}", a.total_comm_bytes(), b.total_comm_bytes());
    }

    #[test]
    fn single_device_has_no_traffic() {
        let r = run("test", 1, 64);
        assert_eq!(r.total_comm_bytes(), 0);
        assert_eq!(r.total_comm_s(), 0.0);
    }

    #[test]
    fn report_accounting() {
        let r = run("test", 2, 64);
        assert_eq!(r.layers.len(), 2);
        assert!(r.total_makespan() > 0.0);
        assert!(r.expert_throughput() > 0.0);
        for l in &r.layers {
            assert_eq!(l.device_compute_s.len(), 2);
            assert_eq!(l.device_load.len(), 2);
        }
        // The embedded executor stats agree with the sim layers.
        assert_eq!(r.stats.per_layer.len(), r.layers.len());
        for (s, l) in r.stats.per_layer.iter().zip(&r.layers) {
            assert_eq!(s.dropped, l.dropped);
        }
        // The analytic makespan is deterministic and tracks the same
        // device loads the measured makespan is built on.
        let c = 1e-7;
        assert!(r.modeled_makespan(c) > 0.0);
        assert_eq!(r.modeled_makespan(0.0), r.total_comm_s());
    }

    #[test]
    fn cluster_output_matches_single_engine() {
        // Cluster execution must be numerically interchangeable with the
        // single-process native engine (same weights seed).
        let cfg = MoeConfig::preset("test");
        let mut sim = ClusterSim::new(cfg.clone(), Topology::new(3), 7);
        let mut engine =
            crate::coordinator::engine::MoeEngine::native(cfg.clone(), 7);
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[32, cfg.d_model], 1.0);
        let (y_engine, stats) = engine.forward_stack(&x).unwrap();
        let (y_sim, rep) = sim.forward(&x).unwrap();
        assert!(y_sim.approx_eq(&y_engine, 1e-5, 1e-5));
        let engine_drops: usize =
            stats.per_layer.iter().map(|l| l.dropped).sum();
        let sim_drops: usize = rep.layers.iter().map(|l| l.dropped).sum();
        assert_eq!(engine_drops, sim_drops);
        assert_eq!(y_sim.shape, x.shape);
    }

    #[test]
    fn apply_placement_migrates_and_preserves_outputs() {
        let cfg = MoeConfig::preset("test"); // 4 FFN experts
        let mut sim =
            ClusterSim::new(cfg.clone(), Topology::new(2), 11);
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&mut rng, &[40, cfg.d_model], 1.0);
        let (y_before, _) = sim.forward(&x).unwrap();
        assert!(sim.placement().is_round_robin());

        let plan =
            PlacementPlan::from_owner(vec![1, 0, 1, 0], 2).unwrap();
        let moved = sim.apply_placement(&plan).unwrap();
        assert_eq!(moved, 4); // every expert changed owner
        assert_eq!(sim.placement(), plan);
        let (y_after, rep) = sim.forward(&x).unwrap();
        // Placement is pure layout: outputs are bit-identical.
        assert_eq!(y_before.data, y_after.data);
        // Per-device load follows the new owners.
        for l in &rep.layers {
            assert_eq!(l.device_load.len(), 2);
        }
        // Re-applying the same plan is a no-op.
        assert_eq!(sim.apply_placement(&plan).unwrap(), 0);
        // Wrong-shape plans are rejected.
        assert!(sim
            .apply_placement(&PlacementPlan::round_robin(4, 3))
            .is_err());
        assert!(sim
            .apply_placement(&PlacementPlan::round_robin(8, 2))
            .is_err());
    }

    #[test]
    fn replicated_plan_preserves_outputs_bitwise() {
        // Load-split routing is pure layout too: replicating an expert
        // splits its micro-batch across devices but the canonical
        // combine (and one-output-row-per-token) keeps outputs
        // bit-identical to the unreplicated cluster at the same device
        // count.
        let cfg = MoeConfig::preset("test"); // 4 FFN experts
        let mut sim =
            ClusterSim::new(cfg.clone(), Topology::new(2), 11);
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&mut rng, &[40, cfg.d_model], 1.0);
        let (y_before, rep_before) = sim.forward(&x).unwrap();

        // Expert 0 on both devices, the rest single-replica.
        let plan = PlacementPlan::from_replicas(
            vec![vec![0, 1], vec![1], vec![0], vec![1]],
            2,
        )
        .unwrap();
        assert!(plan.is_replicated());
        let changed = sim.apply_placement(&plan).unwrap();
        assert_eq!(changed, 1, "only expert 0's replica set changed");
        let (y_after, rep_after) = sim.forward(&x).unwrap();
        assert_eq!(y_before.data, y_after.data);
        // The split moves load, never loses it: per-layer totals match.
        for (a, b) in rep_before.layers.iter().zip(&rep_after.layers) {
            assert_eq!(
                a.device_load.iter().sum::<usize>(),
                b.device_load.iter().sum::<usize>()
            );
        }
        // Fully replicating everything is also bitwise-invisible.
        let full = PlacementPlan::from_replicas(
            vec![vec![0, 1]; 4],
            2,
        )
        .unwrap();
        sim.apply_placement(&full).unwrap();
        let (y_full, _) = sim.forward(&x).unwrap();
        assert_eq!(y_before.data, y_full.data);
    }

    #[test]
    fn mixed_precision_plan_is_deterministic_and_tracks_engine() {
        // A plan serving expert 0 at int8: the cluster must agree with
        // the single-process engine under the same stack-wide precision
        // map, and replicating the quantized expert must stay pure
        // layout — bitwise-identical outputs (the int8 kernel is
        // per-token pure, so replica slicing is invisible, DESIGN.md
        // §17).
        use crate::config::Precision;
        let cfg = MoeConfig::preset("test"); // 4 FFN experts
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&mut rng, &[40, cfg.d_model], 1.0);
        let prec = vec![
            Precision::Int8,
            Precision::F32,
            Precision::F32,
            Precision::F32,
        ];
        let mut engine =
            crate::coordinator::engine::MoeEngine::native(cfg.clone(), 11)
                .with_precision(prec);
        let (y_engine, _) = engine.forward_stack(&x).unwrap();

        let mut plan = PlacementPlan::round_robin(4, 2);
        plan.set_precision(0, Precision::Int8);
        let mut sim = ClusterSim::new(
            cfg.clone(),
            Topology::new(2).with_placement(plan.clone()),
            11,
        );
        let (y_single, _) = sim.forward(&x).unwrap();
        assert!(y_single.approx_eq(&y_engine, 1e-5, 1e-5));

        // Replicating the int8 expert changes nothing, bitwise.
        let mut repl = plan.clone();
        repl.add_replica(0, 1);
        let mut sim2 = ClusterSim::new(
            cfg.clone(),
            Topology::new(2).with_placement(repl),
            11,
        );
        let (y_repl, _) = sim2.forward(&x).unwrap();
        assert_eq!(y_single.data, y_repl.data);

        // Quantization genuinely changed the math vs the f32 cluster.
        let mut f32sim =
            ClusterSim::new(cfg.clone(), Topology::new(2), 11);
        let (y_f32, _) = f32sim.forward(&x).unwrap();
        assert_ne!(y_single.data, y_f32.data);
    }

    #[test]
    fn precision_only_migration_respawns_and_requantizes() {
        // Demoting an expert without moving it is a precision-only
        // diff: apply_placement must respawn the holding workers (they
        // requantize locally) even though no replica set changed — and
        // promoting back must restore the f32 outputs bitwise.
        use crate::config::Precision;
        let cfg = MoeConfig::preset("test");
        let mut sim = ClusterSim::new(cfg.clone(), Topology::new(2), 11);
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&mut rng, &[40, cfg.d_model], 1.0);
        let (y_f32, _) = sim.forward(&x).unwrap();

        let mut plan = sim.placement();
        plan.set_precision(0, Precision::Int8);
        let changed = sim.apply_placement(&plan).unwrap();
        assert_eq!(changed, 1, "one expert flipped precision");
        let (y_q, _) = sim.forward(&x).unwrap();
        assert_ne!(y_f32.data, y_q.data, "demotion changes the math");
        // Bitwise-equal to a cluster built on the mixed plan from
        // scratch: requantize-at-respawn and quantize-at-spawn agree.
        let mut fresh = ClusterSim::new(
            cfg.clone(),
            Topology::new(2).with_placement(plan.clone()),
            11,
        );
        let (y_fresh, _) = fresh.forward(&x).unwrap();
        assert_eq!(y_q.data, y_fresh.data);
        // Re-applying the same plan is a no-op.
        assert_eq!(sim.apply_placement(&plan).unwrap(), 0);
        // Promotion back to f32 is also a one-expert change.
        let mut back = plan.clone();
        back.set_precision(0, Precision::F32);
        assert_eq!(sim.apply_placement(&back).unwrap(), 1);
        let (y_back, _) = sim.forward(&x).unwrap();
        assert_eq!(y_f32.data, y_back.data);
    }

    #[test]
    fn speed_weighted_split_shifts_load_but_not_outputs() {
        // Heterogeneous fleet: the same replicated plan sends the fast
        // device a larger contiguous slice of each replicated expert's
        // micro-batch, but speeds are pure scheduling — outputs stay
        // bit-identical to the uniform-fleet cluster.
        let cfg = MoeConfig::preset("test"); // 4 FFN experts
        let plan = PlacementPlan::from_replicas(
            vec![vec![0, 1]; 4],
            2,
        )
        .unwrap();
        let mut uniform = ClusterSim::new(
            cfg.clone(),
            Topology::new(2).with_placement(plan.clone()),
            11,
        );
        let mut skewed = ClusterSim::new(
            cfg.clone(),
            Topology::new(2)
                .with_device_speeds(vec![3.0, 1.0])
                .with_placement(plan),
            11,
        );
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&mut rng, &[40, cfg.d_model], 1.0);
        let (y_uni, rep_uni) = uniform.forward(&x).unwrap();
        let (y_skw, rep_skw) = skewed.forward(&x).unwrap();
        assert_eq!(y_uni.data, y_skw.data);
        let (mut fast_uni, mut fast_skw) = (0usize, 0usize);
        for (a, b) in rep_uni.layers.iter().zip(&rep_skw.layers) {
            // The split moves rows toward the fast device without
            // losing any: per-layer totals match, and the ~3/4 share
            // never leaves the fast device with fewer rows.
            assert_eq!(
                a.device_load.iter().sum::<usize>(),
                b.device_load.iter().sum::<usize>()
            );
            assert!(b.device_load[0] >= a.device_load[0]);
            fast_uni += a.device_load[0];
            fast_skw += b.device_load[0];
        }
        assert!(
            fast_skw > fast_uni,
            "fast device got {fast_skw} rows vs uniform {fast_uni}"
        );
    }

    #[test]
    fn cluster_wire_buffers_are_pooled_after_warmup() {
        // The gather/output tensors shipped to device workers come from
        // the arena's wire pool: repeating the same batch stops growing
        // backing storage once the pool has warmed up.
        let cfg = MoeConfig::preset("test");
        let mut sim = ClusterSim::new(cfg.clone(), Topology::new(2), 3);
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&mut rng, &[32, cfg.d_model], 1.0);
        for _ in 0..3 {
            sim.forward(&x).unwrap();
        }
        let warm = sim.arena_growths();
        assert!(warm > 0);
        for _ in 0..4 {
            sim.forward(&x).unwrap();
        }
        assert_eq!(
            sim.arena_growths(),
            warm,
            "steady-state cluster forwards must not allocate"
        );
    }

    #[test]
    fn stale_planning_tasks_are_abandoned() {
        use crate::placement::{CostModel, Planner, ReplanConfig};
        use std::sync::mpsc::channel;

        let cfg = MoeConfig::preset("test");
        let rp = Replanner::new(
            Planner::new(CostModel::from_config(&cfg)),
            ReplanConfig {
                min_interval_batches: 1,
                max_proposal_age_batches: 2,
                ..ReplanConfig::default()
            },
            cfg.n_ffn_experts,
        );
        let mut sim = ClusterSim::new(cfg.clone(), Topology::new(2), 3)
            .with_replanner(rp);
        // Occupy the pool's single lazily-spawned task worker so the
        // planning task can never start — from the scheduler's view, a
        // planner stuck for many batches.
        let (gate_tx, gate_rx) = channel::<()>();
        let blocker = sim.pool.submit(move || {
            let _ = gate_rx.recv();
        });

        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[16, cfg.d_model], 1.0);
        let (_, rep) = sim.forward(&x).unwrap();
        sim.note_batch(&rep.stats);
        assert!(sim.replan_in_flight(), "window filled: task submitted");
        // Two boundaries age it to the bound (still kept)…
        for _ in 0..2 {
            let (_, rep) = sim.forward(&x).unwrap();
            sim.note_batch(&rep.stats);
        }
        assert!(sim.replan_in_flight(), "age 2 == bound: still polled");
        // …the third goes past it: abandoned, window reset, nothing
        // committed.
        let (_, rep) = sim.forward(&x).unwrap();
        sim.note_batch(&rep.stats);
        assert!(!sim.replan_in_flight(), "age 3 > 2: abandoned");
        assert_eq!(sim.replan_count(), 0);
        assert_eq!(sim.take_replan_count(), 0);
        // Unblock; the detached task finishes harmlessly on the worker.
        gate_tx.send(()).unwrap();
        blocker.wait().unwrap();
    }
}
